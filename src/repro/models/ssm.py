"""Selective SSM head (Mamba-style), for the Hymba hybrid architecture.

Per head: state h in R^{P x N} (P = head dim, N = ssm_state).
  h_t = exp(-softplus(dt_t) * A) * h_{t-1} + dt_t * (x_t outer B_t)
  y_t = h_t C_t + D * x_t
with input-dependent dt [B,T,H], B,C [B,T,N] (shared across heads, as in
Mamba), A [H] positive per head.  The sequence dimension is parallelized
with an associative scan of (decay, update) pairs — the TPU-native
formulation (no serial recurrence in train/prefill); decode carries the
(B,H,P,N) state one step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense_init


def ssm_init(key, d: int, n_heads: int, head_dim: int, state: int,
             dtype) -> Params:
    kx, kb, kc, kd, kA, ko = jax.random.split(key, 6)
    return {
        "w_in": dense_init(kx, d, (n_heads, head_dim), dtype),
        "w_bc": dense_init(kb, d, 2 * state, dtype),
        "w_dt": dense_init(kc, d, n_heads, dtype),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32) * 0.1,
        "w_out": dense_init(ko, n_heads * head_dim, d, dtype),
    }


def _gates(p: Params, x: jnp.ndarray, state: int):
    xs = jnp.einsum("btd,dhe->bthe", x, p["w_in"])       # [B,T,H,P]
    bc = jnp.einsum("btd,dn->btn", x, p["w_bc"]).astype(jnp.float32)
    Bm, Cm = bc[..., :state], bc[..., state:]            # [B,T,N]
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])                                  # [B,T,H]
    A = jnp.exp(p["A_log"])                              # [H] > 0
    decay = jnp.exp(-dt * A)                             # [B,T,H]
    return xs, Bm, Cm, dt, decay


def ssm_scan(p: Params, x: jnp.ndarray, state: int,
             chunk: int = 256) -> jnp.ndarray:
    """Full-sequence selective scan, *chunkwise-parallel* (mamba2-style):
    a serial lax.scan over chunks carries the (B,H,P,N) state; within a
    chunk an associative scan runs in parallel.  Peak memory is
    O(B * chunk * H * P * N) instead of O(B * T * H * P * N) — the naive
    whole-sequence associative scan put hymba train_4k at 153 GiB/device.
    x [B,T,D] -> y [B,T,D]."""
    xs, Bm, Cm, dt, decay = _gates(p, x, state)
    u = (dt[..., None, None] * xs.astype(jnp.float32)[..., None]
         * Bm[:, :, None, None, :])                       # [B,T,H,P,N]
    b, t, h, pdim, n = u.shape
    if t % chunk != 0 or t <= chunk:
        chunk = t
    nc = t // chunk
    u_c = u.reshape(b, nc, chunk, h, pdim, n).swapaxes(0, 1)
    a_c = decay.reshape(b, nc, chunk, h).swapaxes(0, 1)
    c_c = Cm.reshape(b, nc, chunk, n).swapaxes(0, 1)

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, u1 * a2[..., None, None] + u2

    def chunk_step(h0, args):
        ac, uc, cc = args                     # [B,c,H], [B,c,H,P,N], [B,c,N]
        _, h_loc = jax.lax.associative_scan(combine, (ac, uc), axis=1)
        carry_f = jnp.exp(jnp.cumsum(jnp.log(jnp.maximum(ac, 1e-38)),
                                     axis=1))              # prod of decays
        h_all = h_loc + carry_f[..., None, None] * h0[:, None]
        y = jnp.einsum("bthpn,btn->bthp", h_all, cc)
        return h_all[:, -1], y

    h0 = jnp.zeros((b, h, pdim, n), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (a_c, u_c, c_c))
    y = ys.swapaxes(0, 1).reshape(b, t, h, pdim)
    y = y + p["D"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t, h * pdim).astype(x.dtype)
    return jnp.einsum("btf,fd->btd", y, p["w_out"].reshape(h * pdim, -1))


def ssm_scan_ssd(p: Params, x: jnp.ndarray, state: int,
                 chunk: int = 256) -> jnp.ndarray:
    """SSD (mamba-2 duality) form of the selective scan.

    The chunked associative scan still materializes the (B,chunk,H,P,N)
    state sequence — P*N/1 = 1024x the token width for hymba — which made
    the hymba train_4k cell memory-bound at 2% of roofline.  The dual
    form never materializes per-step states:

      y_t = sum_{j<=t} [ (C_t . B_j) dt_j exp(L_t - L_j) ] x_j
            + exp(L_t) (C_t . h0)                       (carry-in term)

    with L = cumsum(log decay).  Peak intermediate = the (B,c,c,H) score
    tile (attention-like); states exist only at chunk boundaries.
    Identical math; extra O(c^2 (1 + P) H) flops per chunk — the classic
    SSD memory/compute trade, correct for a memory-bound cell.
    """
    xs, Bm, Cm, dt, decay = _gates(p, x, state)
    b, t, h, pdim = xs.shape
    if t % chunk != 0 or t <= chunk:
        chunk = t
    nc = t // chunk
    xf = xs.astype(jnp.float32)
    logd = jnp.log(jnp.maximum(decay, 1e-38))          # = -dt * A
    L = jnp.cumsum(logd.reshape(b, nc, chunk, h), axis=2)  # per chunk
    x_c = xf.reshape(b, nc, chunk, h, pdim).swapaxes(0, 1)
    B_c = Bm.reshape(b, nc, chunk, state).swapaxes(0, 1)
    C_c = Cm.reshape(b, nc, chunk, state).swapaxes(0, 1)
    dt_c = dt.reshape(b, nc, chunk, h).swapaxes(0, 1)
    L_c = L.swapaxes(0, 1)                                 # [nc,B,c,H]

    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_step(h0, args):
        xc, bc, cc, dtc, lc = args
        # intra-chunk: scores S[t,j] = (C_t.B_j) dt_j exp(L_t - L_j)
        cb = jnp.einsum("btn,bjn->btj", cc, bc)            # [B,c,c]
        dec = jnp.exp(jnp.clip(lc[:, :, None] - lc[:, None, :],
                               -60.0, 0.0))                # [B,c,c,H]
        s = cb[..., None] * dtc[:, None] * dec
        s = jnp.where(mask[None, :, :, None], s, 0.0)
        y = jnp.einsum("btjh,bjhp->bthp", s, xc)
        # carry-in: exp(L_t) (C_t . h0)
        ch0 = jnp.einsum("btn,bhpn->bthp", cc, h0)
        y = y + jnp.exp(lc)[..., None] * ch0
        # chunk-boundary state
        l_end = lc[:, -1]                                  # [B,H]
        w = dtc * jnp.exp(jnp.clip(l_end[:, None] - lc, -60.0, 0.0))
        h_new = jnp.einsum("bjh,bjhp,bjn->bhpn", w, xc, bc)
        h_new = h_new + jnp.exp(l_end)[..., None, None] * h0
        return h_new, y

    h0 = jnp.zeros((b, h, pdim, state), jnp.float32)
    _, ys = jax.lax.scan(chunk_step, h0, (x_c, B_c, C_c, dt_c, L_c))
    y = ys.swapaxes(0, 1).reshape(b, t, h, pdim)
    y = y + p["D"][:, None] * xf
    y = y.reshape(b, t, h * pdim).astype(x.dtype)
    return jnp.einsum("btf,fd->btd", y, p["w_out"].reshape(h * pdim, -1))


def ssm_decode_init(batch: int, n_heads: int, head_dim: int, state: int
                    ) -> jnp.ndarray:
    return jnp.zeros((batch, n_heads, head_dim, state), jnp.float32)


def ssm_decode_step(p: Params, x: jnp.ndarray, h: jnp.ndarray, state: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One token.  x [B,1,D]; h [B,H,P,N]."""
    xs, Bm, Cm, dt, decay = _gates(p, x, state)
    u = (dt[..., None, None] * xs.astype(jnp.float32)[..., None]
         * Bm[:, :, None, None, :])[:, 0]
    h = h * decay[:, 0][..., None, None] + u
    y = jnp.einsum("bhpn,bn->bhp", h, Cm[:, 0])
    y = y + p["D"][:, None] * xs[:, 0].astype(jnp.float32)
    b, hh, pdim = y.shape
    y = y.reshape(b, 1, hh * pdim).astype(x.dtype)
    return jnp.einsum("btf,fd->btd", y,
                      p["w_out"].reshape(hh * pdim, -1)), h
