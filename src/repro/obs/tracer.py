"""Structured tracing for the GraphAGILE stack.

The paper's whole argument is a *latency decomposition* — T_LoC
(software compilation) vs T_LoH (data loading/execution) and the
compiler's ability to overlap them — so the observability layer records
exactly that: nestable spans (compile passes, shard staging, tile
compute, halo exchange, request lifecycle phases), counters, and
instant events, on named tracks per device / residency path.

Export is Chrome trace-event JSON (the ``traceEvents`` array format),
directly openable in https://ui.perfetto.dev or ``chrome://tracing``:

    from repro.obs import enable_tracing
    tracer = enable_tracing()
    ...   # any engine / runtime / sampling work
    tracer.save("trace.json")          # -> load in ui.perfetto.dev
    tracer.summary()                   # -> plain-dict rollup

Design constraints:

* **Zero overhead when disabled.**  ``get_tracer()`` returns a
  process-wide :class:`NullTracer` singleton unless tracing was
  enabled; its ``span`` hands back one shared no-op context manager,
  so instrumented hot paths cost one attribute load + one truthiness
  check.  Instrumentation sites may also guard expensive ``args``
  construction behind ``tracer.enabled``.
* **Thread safety.**  The serving loop runs per-overlay worker
  threads; spans carry the recording thread's identity, so concurrent
  spans land on separate tracks and never need cross-thread nesting.
  Event append takes a lock only at span *end* (one append per span).
* **No heavy imports.**  Pure stdlib — ``repro.core`` (which must not
  depend on jax-importing modules at import time) can instrument
  freely.

Chrome trace-event specifics: spans are emitted as ``"X"`` (complete)
events with microsecond ``ts``/``dur`` relative to tracer start;
tracks are (pid=1, tid) pairs named via ``thread_name`` metadata
events.  Fractional microseconds are allowed by both viewers.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Tracer", "NullTracer", "get_tracer", "set_tracer",
    "enable_tracing", "disable_tracing", "tracing",
]


class _Span:
    """One open span; context manager, or end explicitly with
    :meth:`done`.  ``add(**kv)`` attaches args discovered mid-span
    (e.g. bytes counted while staging)."""

    __slots__ = ("_tracer", "name", "cat", "args", "track", "_t0",
                 "_closed")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[dict], track: Optional[str]) -> None:
        self._tracer = tracer
        self.name, self.cat, self.track = name, cat, track
        self.args = dict(args) if args else {}
        self._t0 = time.perf_counter_ns()
        self._closed = False

    def add(self, **kv: Any) -> "_Span":
        self.args.update(kv)
        return self

    def done(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._tracer._emit_complete(
            self.name, self.cat, self._t0, time.perf_counter_ns(),
            self.args, self.track)

    def __enter__(self) -> "_Span":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.done()


class _NullSpan:
    """Shared, reusable no-op span (the disabled-path object)."""

    __slots__ = ()

    def add(self, **kv: Any) -> "_NullSpan":
        return self

    def done(self) -> None:
        pass

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """API-complete no-op: every instrumentation site stays branch-free
    whether tracing is on or off."""

    enabled = False

    def span(self, name: str, cat: str = "", args: Optional[dict] = None,
             track: Optional[str] = None) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, cat: str = "",
                args: Optional[dict] = None,
                track: Optional[str] = None) -> None:
        pass

    def counter(self, name: str, value: float,
                track: Optional[str] = None) -> None:
        pass

    def complete(self, name: str, t0_ns: int, t1_ns: int,
                 cat: str = "", args: Optional[dict] = None,
                 track: Optional[str] = None) -> None:
        pass

    def now_ns(self) -> int:
        return 0

    def to_dict(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def summary(self) -> dict:
        return {"enabled": False, "events": 0, "spans": {}}

    def save(self, path: str) -> None:
        raise RuntimeError(
            "tracing is disabled; call repro.obs.enable_tracing() first")


class Tracer:
    """Thread-safe trace recorder; see module docstring."""

    enabled = True

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._events: List[dict] = []
        self._t0 = time.perf_counter_ns()
        # track name -> synthetic tid; real threads claim a tid from the
        # same space so named tracks and worker threads never collide.
        self._tracks: Dict[str, int] = {}
        self._thread_tids: Dict[int, int] = {}
        self._next_tid = 1

    # ------------------------------------------------------------------ #
    def now_ns(self) -> int:
        """Timestamp in the tracer's clock (for :meth:`complete`)."""
        return time.perf_counter_ns()

    def _us(self, t_ns: int) -> float:
        return (t_ns - self._t0) / 1e3

    def _tid(self, track: Optional[str]) -> int:
        # caller holds the lock
        if track is not None:
            tid = self._tracks.get(track)
            if tid is None:
                tid = self._next_tid
                self._next_tid += 1
                self._tracks[track] = tid
                self._events.append({
                    "ph": "M", "name": "thread_name", "pid": 1,
                    "tid": tid, "args": {"name": track}})
            return tid
        ident = threading.get_ident()
        tid = self._thread_tids.get(ident)
        if tid is None:
            tid = self._next_tid
            self._next_tid += 1
            self._thread_tids[ident] = tid
            self._events.append({
                "ph": "M", "name": "thread_name", "pid": 1, "tid": tid,
                "args": {"name": threading.current_thread().name}})
        return tid

    # ------------------------------------------------------------------ #
    def span(self, name: str, cat: str = "", args: Optional[dict] = None,
             track: Optional[str] = None) -> _Span:
        """Open a span; close it via ``with`` or ``.done()``.  Spans on
        one track nest by timestamps (Perfetto infers the tree from
        containment of complete events)."""
        return _Span(self, name, cat, args, track)

    def _emit_complete(self, name: str, cat: str, t0_ns: int, t1_ns: int,
                       args: dict, track: Optional[str]) -> None:
        with self._lock:
            self._events.append({
                "ph": "X", "name": name, "cat": cat or "default",
                "pid": 1, "tid": self._tid(track),
                "ts": self._us(t0_ns),
                "dur": max((t1_ns - t0_ns) / 1e3, 0.001),
                "args": args})

    def complete(self, name: str, t0_ns: int, t1_ns: int,
                 cat: str = "", args: Optional[dict] = None,
                 track: Optional[str] = None) -> None:
        """Record a span retroactively from explicit ``perf_counter_ns``
        endpoints — how cross-thread phases (queue wait measured at
        admission, closed by a worker) become spans."""
        self._emit_complete(name, cat, t0_ns, t1_ns,
                            dict(args) if args else {}, track)

    def instant(self, name: str, cat: str = "",
                args: Optional[dict] = None,
                track: Optional[str] = None) -> None:
        t = time.perf_counter_ns()
        with self._lock:
            self._events.append({
                "ph": "i", "s": "t", "name": name,
                "cat": cat or "default", "pid": 1,
                "tid": self._tid(track), "ts": self._us(t),
                "args": dict(args) if args else {}})

    def counter(self, name: str, value: float,
                track: Optional[str] = None) -> None:
        t = time.perf_counter_ns()
        with self._lock:
            self._events.append({
                "ph": "C", "name": name, "pid": 1,
                "tid": self._tid(track), "ts": self._us(t),
                "args": {"value": value}})

    # ------------------------------------------------------------------ #
    def events(self) -> List[dict]:
        """Snapshot of recorded events (copy; safe to mutate)."""
        with self._lock:
            return [dict(e) for e in self._events]

    def to_dict(self) -> dict:
        """Chrome/Perfetto trace-event JSON as a plain dict."""
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        """Write ``trace.json``; open it at https://ui.perfetto.dev."""
        with open(path, "w") as f:
            json.dump(self.to_dict(), f)
        return path

    def summary(self) -> dict:
        """Plain-dict rollup: per span name, count / total / max ms —
        the cheap view when no trace viewer is at hand."""
        spans: Dict[str, dict] = {}
        counters: Dict[str, float] = {}
        n = 0
        for e in self.events():
            n += 1
            if e["ph"] == "X":
                s = spans.setdefault(e["name"], {
                    "count": 0, "total_ms": 0.0, "max_ms": 0.0,
                    "cat": e.get("cat", "")})
                d_ms = e["dur"] / 1e3
                s["count"] += 1
                s["total_ms"] = round(s["total_ms"] + d_ms, 6)
                s["max_ms"] = round(max(s["max_ms"], d_ms), 6)
            elif e["ph"] == "C":
                counters[e["name"]] = e["args"]["value"]
        return {"enabled": True, "events": n, "spans": spans,
                "counters": counters}


# --------------------------------------------------------------------------- #
# Process-wide tracer registry.
# --------------------------------------------------------------------------- #
_NULL = NullTracer()
_current: Any = _NULL
_reg_lock = threading.Lock()


def get_tracer() -> Any:
    """The active tracer (a :class:`NullTracer` unless enabled)."""
    return _current


def set_tracer(tracer: Optional[Any]) -> Any:
    """Install ``tracer`` (``None`` -> the null tracer); returns the
    previously active one (for restore)."""
    global _current
    with _reg_lock:
        prev = _current
        _current = tracer if tracer is not None else _NULL
        return prev


def enable_tracing() -> Tracer:
    """Install and return a fresh :class:`Tracer`."""
    t = Tracer()
    set_tracer(t)
    return t


def disable_tracing() -> None:
    """Back to the zero-overhead null tracer."""
    set_tracer(None)


class tracing:
    """``with tracing() as t: ...`` — scoped enable, restores the
    previous tracer on exit (exception-safe)."""

    def __init__(self) -> None:
        self.tracer = Tracer()
        self._prev: Any = None

    def __enter__(self) -> Tracer:
        self._prev = set_tracer(self.tracer)
        return self.tracer

    def __exit__(self, *exc: Any) -> None:
        set_tracer(self._prev)
