"""``python -m repro.verify`` — verify ``.gagi`` bundles from the shell.

    python -m repro.verify out/*.gagi --json report.json --md report.md
    python -m repro.verify out/           # every .gagi under the dir
    python -m repro.verify prog.gagi --trace trace.json

Exit status 0 iff every program (and, with ``--trace``, the recorded
span ordering) verifies clean.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import List

from .checks import verify_gagi
from .race import check_trace
from .report import VerifyReport


def _expand(paths: List[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            out.extend(sorted(glob.glob(os.path.join(p, "*.gagi"))))
        else:
            out.append(p)
    return out


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="Statically verify compiled GraphAGILE programs.")
    ap.add_argument("paths", nargs="+",
                    help=".gagi files (or directories of them)")
    ap.add_argument("--json", metavar="OUT",
                    help="write the combined VerifyReports as JSON")
    ap.add_argument("--md", metavar="OUT",
                    help="write the combined VerifyReports as markdown")
    ap.add_argument("--trace", metavar="TRACE_JSON",
                    help="also race-check a recorded trace against each "
                         "program's dep_graph")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-program stdout lines")
    args = ap.parse_args(argv)

    paths = _expand(args.paths)
    if not paths:
        print("no .gagi programs found", file=sys.stderr)
        return 2

    reports: List[VerifyReport] = []
    ok = True
    for path in paths:
        rep = verify_gagi(path)
        reports.append(rep)
        ok = ok and rep.ok
        if args.trace:
            from repro.engine.program import CompiledProgram
            trep = check_trace(args.trace,
                               CompiledProgram.load(path).manifest)
            trep.program = f"{rep.program} [trace]"
            reports.append(trep)
            ok = ok and trep.ok
        if not args.quiet:
            status = "PASS" if rep.ok else "FAIL"
            print(f"[{status}] {rep.program}: "
                  f"{len(rep.checks_passed)}/{len(rep.checks_run)} "
                  f"checks passed, {len(rep.violations)} violation(s)")
            for v in rep.violations:
                print(f"    {v}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump({"ok": ok,
                       "reports": [r.to_dict() for r in reports]},
                      f, indent=1)
    if args.md:
        with open(args.md, "w") as f:
            f.write("# Program verification\n\n")
            for r in reports:
                f.write(r.to_markdown() + "\n")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
