from .synthetic import synthetic_batches  # noqa: F401
