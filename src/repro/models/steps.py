"""Step factories: train_step / prefill / serve_step for any arch.

These are the functions the dry-run lowers and the examples execute.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.optim import adamw_init, adamw_update, cosine_schedule

from .config import ModelConfig, ShapeCell
from .layers import softmax_xent
from .transformer import DecoderLM
from .whisper import WhisperModel


def build_model(cfg: ModelConfig, moe_impl: str = "dense", mesh=None):
    if cfg.encoder_decoder:
        return WhisperModel(cfg, moe_impl=moe_impl, mesh=mesh)
    return DecoderLM(cfg, moe_impl=moe_impl, mesh=mesh)


# --------------------------------------------------------------------------- #
def make_train_step(model, cfg: ModelConfig, base_lr: float = 3e-4,
                    keep_master: bool = True):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        if cfg.encoder_decoder:
            logits, aux = model.forward(params, batch["frames"],
                                        batch["targets"])
            labels = batch["target_labels"]
        elif cfg.cross_attn_every:
            logits, aux = model.forward(params, batch["tokens"],
                                        cross_kv_x=batch["vision"])
            labels = batch["labels"]
        else:
            logits, aux = model.forward(params, batch["tokens"])
            labels = batch["labels"]
        loss = softmax_xent(logits, labels)
        return loss + cfg.router_aux_coef * aux, (loss, aux)

    def train_step(params, opt_state, batch):
        (tot, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        lr = cosine_schedule(opt_state.step, base_lr)
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "aux": aux, "lr": lr}

    return train_step


def init_train_state(model, key, keep_master: bool = True):
    params = model.init_params(key)
    return params, adamw_init(params, keep_master=keep_master)


# --------------------------------------------------------------------------- #
def make_serve_step(model, cfg: ModelConfig):
    """One-token decode: (params, cache, token, pos) -> (next, cache)."""

    def serve_step(params, cache, token, pos):
        logits, cache = model.decode_step(params, cache, token, pos)
        nxt = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


def make_prefill_step(model, cfg: ModelConfig):
    """Forward over the prompt; returns last-position logits (the dry-run
    prefill cell lowers this; cache construction is exercised by the
    serving example at small scale)."""

    def prefill(params, batch):
        if cfg.encoder_decoder:
            logits, _ = model.forward(params, batch["frames"],
                                      batch["targets"])
        elif cfg.cross_attn_every:
            logits, _ = model.forward(params, batch["tokens"],
                                      cross_kv_x=batch["vision"])
        else:
            logits, _ = model.forward(params, batch["tokens"])
        return logits[:, -1, :]

    return prefill


# --------------------------------------------------------------------------- #
def input_specs(cfg: ModelConfig, cell: ShapeCell,
                zeros: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStruct (or zero-array) stand-ins for every model input of
    one (arch x shape) dry-run cell."""
    mk = (lambda s, d: (jnp.zeros(s, d) if zeros
                        else jax.ShapeDtypeStruct(s, d)))
    b, t = cell.global_batch, cell.seq_len
    dt = cfg.jdtype
    if cell.kind in ("train", "prefill"):
        if cfg.encoder_decoder:
            tl = cfg.decoder_target_len
            return {"frames": mk((b, t, cfg.d_model), dt),
                    "targets": mk((b, tl), jnp.int32),
                    "target_labels": mk((b, tl), jnp.int32)}
        out = {"tokens": mk((b, t), jnp.int32),
               "labels": mk((b, t), jnp.int32)}
        if cfg.cross_attn_every:
            out["vision"] = mk((b, cfg.n_vision_tokens, cfg.d_model), dt)
        if cell.kind == "prefill":
            out.pop("labels")
        return out
    # decode: one token + cache of seq_len
    return {"token": mk((b, 1), jnp.int32),
            "pos": mk((), jnp.int32)}
