"""Traced end-to-end inference: compile + host-streaming execution +
serving traffic, exported as Perfetto trace-event JSON.

  PYTHONPATH=src python examples/trace_inference.py [--out trace.json]

Open the written file at https://ui.perfetto.dev — the `compile` track
shows the §6 pass pipeline, `h2d` the double-buffered shard staging,
`exec:host` the per-shard compute (watch the stage spans of shard j+1
overlap the compute span of shard j — the paper's T_LoC/T_LoH overlap,
made visible), and `queue`/`overlay*` the request lifecycle through the
serving loop.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import graph as G  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402
from repro.engine import Engine, InferenceRequest  # noqa: E402
from repro.obs import enable_tracing  # noqa: E402
from repro.runtime import OverlayPool, ServeLoop  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="trace.json")
    args = ap.parse_args()

    tracer = enable_tracing()

    g = G.synthesize("CI", scale=0.1, seed=0).gcn_normalized()
    x = G.random_features(g, seed=1)
    engine = Engine(geometry=PartitionConfig(n1=32, n2=8))

    # Compile (per-pass spans on the `compile` track) and run the
    # partition-centric host-streaming path (stage/compute overlap on
    # the `h2d` / `exec:host` tracks).
    prog = engine.compile("b3", g)
    y = engine.run(prog, x, residency="host")
    print(f"host-streaming run: output {tuple(y.shape)}, "
          f"{engine.exec_stats.shards_streamed} shards streamed, "
          f"{engine.exec_stats.h2d_bytes} h2d bytes")

    # A little serving traffic: admission -> queue wait -> batch ->
    # execute spans through the ServeLoop (cache-hit instants on the
    # second wave).
    pool = OverlayPool(n_overlays=2, geometry=PartitionConfig(n1=32, n2=8))
    loop = ServeLoop(pool, max_batch=4)
    reqs = [InferenceRequest(model="b1", graph=g, features=x,
                             request_id=f"req{i}") for i in range(8)]
    resps = loop.serve(reqs)
    hits = sum(r.cache_hit for r in resps)
    print(f"served {len(resps)} requests ({hits} cache hits)")
    loop.shutdown()

    path = tracer.save(args.out)
    doc = json.load(open(path))
    print(f"\nwrote {path} ({len(doc['traceEvents'])} events) — open it "
          f"at https://ui.perfetto.dev")

    print("\nspan rollup (count / total ms):")
    summ = tracer.summary()
    for name, s in sorted(summ["spans"].items(),
                          key=lambda kv: -kv[1]["total_ms"])[:12]:
        print(f"  {name:<16} x{s['count']:<5} {s['total_ms']:.2f} ms")


if __name__ == "__main__":
    main()
