"""Deterministic synthetic data pipeline.

Generates reproducible token streams (Zipf-distributed ids with local
correlations so the loss actually decreases) sharded by host.  The
real-data interface is the same iterator contract: ``{"tokens", "labels"}``
int32 [B, T] per step.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.models.config import ModelConfig


def synthetic_batches(cfg: ModelConfig, batch: int, seq_len: int,
                      seed: int = 0, host_id: int = 0, n_hosts: int = 1
                      ) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite iterator of {tokens, labels[, vision/frames]} batches."""
    rng = np.random.default_rng(seed * 1000003 + host_id)
    v = cfg.vocab
    ranks = np.arange(1, min(v, 4096) + 1, dtype=np.float64)
    p = ranks ** -1.0
    p /= p.sum()
    while True:
        base = rng.choice(len(p), size=(batch, seq_len + 1), p=p)
        # local correlation: next token often echoes (t - 2)
        echo = rng.random((batch, seq_len + 1)) < 0.3
        base[:, 2:] = np.where(echo[:, 2:], base[:, :-2], base[:, 2:])
        toks = base.astype(np.int32) % v
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if cfg.cross_attn_every:
            out["vision"] = rng.normal(
                0, 0.1, (batch, cfg.n_vision_tokens, cfg.d_model)
            ).astype(np.float32)
        if cfg.encoder_decoder:
            tl = cfg.decoder_target_len
            out = {
                "frames": rng.normal(
                    0, 0.1, (batch, seq_len, cfg.d_model)
                ).astype(np.float32),
                "targets": toks[:, :tl],
                "target_labels": toks[:, 1:tl + 1],
            }
        yield out
