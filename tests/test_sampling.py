"""repro.sampling — mini-batch ego-network serving tests.

Acceptance criteria of the sampling PR:
  * padded (bucketed, graph-as-data) execution produces EXACTLY the
    unpadded subgraph run's logits — bit-identical — for GCN (SpDMM
    path) and GAT (SDDMM + edge-softmax + dynamic-weight path);
  * on a power-law graph with mixed target counts and fanouts the
    service's program-cache hit rate reaches >= 0.9 after warmup
    (bucketing collapses per-user geometry onto few compiled programs);
  * sampling is deterministic and honors per-hop fanout caps;
  * (satellites) ``core.ack`` counter is lock-guarded with ``reset``;
    ``random_graph`` grows the power-law exponent and dedupe knobs.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ack
from repro.core import graph as G
from repro.core.passes.partition import PartitionConfig, partition_graph
from repro.engine import Engine, InferenceRequest
from repro.sampling import (SamplingService, TargetRequest, bucket_for,
                            in_csr, layout_graph, sample_ego,
                            template_graph)

GEOM = PartitionConfig(n1=32, n2=8)


def _parent(nv=400, ne=2400, f=16, c=4, seed=3):
    g = G.random_graph(nv, ne, seed=seed, degree="powerlaw", dedupe=True)
    g.feat_dim, g.n_classes = f, c
    return g


# --------------------------------------------------------------------------- #
# CSR view + graph satellites.
# --------------------------------------------------------------------------- #
def test_csr_matches_coo_and_is_memoized():
    g = _parent()
    csr = g.in_csr()
    assert csr is g.in_csr()                    # memo: same object
    indeg = np.bincount(g.dst, minlength=g.n_vertices)
    assert np.array_equal(np.diff(csr.indptr), indeg)
    for v in (0, 7, g.n_vertices - 1):
        srcs, ws, eids = csr.in_neighbors(v)
        assert np.all(g.dst[eids] == v)
        assert np.array_equal(g.src[eids], srcs)
        assert np.array_equal(g.weight[eids], ws)
        assert np.all(np.diff(srcs) >= 0)       # src-sorted runs
    g2 = g.with_self_loops()                    # rebinding => fresh CSR
    assert g2.in_csr().n_edges == g.n_edges + g.n_vertices


def test_random_graph_alpha_and_dedupe():
    flat = G.random_graph(300, 3000, seed=5, degree="powerlaw", alpha=0.3)
    steep = G.random_graph(300, 3000, seed=5, degree="powerlaw", alpha=2.0)
    assert steep.in_degree().max() > flat.in_degree().max()

    gd = G.random_graph(50, 2000, seed=5, degree="powerlaw", dedupe=True)
    pairs = set(zip(gd.src.tolist(), gd.dst.tolist()))
    assert len(pairs) == gd.n_edges             # no duplicate edges
    assert float(gd.weight.sum()) == 2000.0     # multiplicity preserved


# --------------------------------------------------------------------------- #
# Sampler.
# --------------------------------------------------------------------------- #
def test_sampler_deterministic_targets_first_and_caps():
    g = _parent()
    a = sample_ego(g, [5, 9, 77], (6, 4), seed=11)
    b = sample_ego(g, [5, 9, 77], (6, 4), seed=11)
    assert np.array_equal(a.vertices, b.vertices)
    assert np.array_equal(a.graph.src, b.graph.src)
    assert np.array_equal(a.graph.dst, b.graph.dst)
    assert np.array_equal(a.targets, np.arange(3))
    assert np.array_equal(a.vertices[:3], [5, 9, 77])
    assert [len(h) for h in a.hops][0] == 3

    # per-hop caps: a vertex sampled at hop h has <= fanouts[h] in-edges
    indeg = np.bincount(a.graph.dst, minlength=a.graph.n_vertices)
    for hop, cap in zip(a.hops, (6, 4)):
        assert np.all(indeg[hop] <= cap)
    # vertices discovered at the last hop get no in-edges
    assert np.all(indeg[a.hops[-1]] == 0)

    c = sample_ego(g, [5, 9, 77], (6, 4), seed=12)
    assert not (np.array_equal(a.vertices, c.vertices)
                and np.array_equal(a.graph.src, c.graph.src))


def test_sampler_full_fallback_keeps_every_in_edge():
    g = _parent()
    ego = sample_ego(g, [3], ("full",), seed=0)
    csr = in_csr(g)
    assert ego.graph.n_edges == csr.in_degree(3)


def test_sampler_rejects_bad_targets():
    g = _parent()
    with pytest.raises(ValueError):
        sample_ego(g, [], (4,))
    with pytest.raises(ValueError):
        sample_ego(g, [1, 1], (4,))
    with pytest.raises(ValueError):
        sample_ego(g, [g.n_vertices], (4,))
    with pytest.raises(ValueError):
        sample_ego(g, [0], (0,))


# --------------------------------------------------------------------------- #
# Buckets: canonical template layout.
# --------------------------------------------------------------------------- #
def test_template_partitions_to_canonical_layout():
    g = _parent()
    sub = sample_ego(g, [5, 9, 77], (6, 4), seed=11).graph.gcn_normalized()
    bucket = bucket_for(sub, GEOM)
    for field in (bucket.n_vertices, bucket.n_edges, bucket.width):
        assert field & (field - 1) == 0          # powers of two
    tpl = template_graph(bucket, GEOM)
    pg = partition_graph(tpl, GEOM)
    nb = bucket.n_blocks(GEOM.n1)
    assert set(pg.tiles) == {(j, k) for j in range(nb) for k in range(nb)}
    assert all(len(ts) == 1 and ts[0].width == bucket.width
               for ts in pg.tiles.values())
    assert pg.n_edges == bucket.n_edges


def test_layout_rejects_oversized_graph():
    g = _parent()
    small = sample_ego(g, [5], (2,), seed=0).graph
    bucket = bucket_for(small, GEOM)
    big = sample_ego(g, [5, 9, 77, 100, 200], (8, 8), seed=0).graph
    with pytest.raises(ValueError):
        layout_graph(big.gcn_normalized(), bucket, GEOM)


# --------------------------------------------------------------------------- #
# Padding inertness, end-to-end through the engine (the tentpole's
# correctness contract): bucketed/padded graph-as-data execution must be
# BIT-IDENTICAL to the unpadded subgraph run.
# --------------------------------------------------------------------------- #
def _bucketed_pair(g, model, targets, fanouts, seed):
    X = G.random_features(g, seed=1)
    ego = sample_ego(g, targets, fanouts, seed=seed)
    sub = ego.graph.gcn_normalized()
    bucket = bucket_for(sub, GEOM)
    tpl = template_graph(bucket, GEOM)
    gd = layout_graph(sub, bucket, GEOM)
    x_sub = X[ego.vertices]
    x_pad = np.zeros((bucket.n_vertices, g.feat_dim), np.float32)
    x_pad[: x_sub.shape[0]] = x_sub
    unpadded = InferenceRequest(model=model, graph=sub,
                                features=jnp.asarray(x_sub))
    bucketed = InferenceRequest(model=model, graph=tpl,
                                features=jnp.asarray(x_pad), graph_data=gd)
    return unpadded, bucketed, ego


@pytest.mark.parametrize("model", ["b1", "b6", "b3"])  # GCN, GAT, SAGE
def test_padded_execution_is_bit_identical(model):
    g = _parent()
    unpadded, bucketed, ego = _bucketed_pair(
        g, model, [5, 9, 77], (6, 4), seed=11)
    eng = Engine(geometry=GEOM, n_pes=4)
    y_ref = np.asarray(eng.submit(unpadded).output)
    y_bkt = np.asarray(eng.submit(bucketed).output)
    # every real vertex row — not just the targets — is exact
    np.testing.assert_array_equal(y_bkt[: y_ref.shape[0]], y_ref)


def test_bucket_cache_key_collides_across_users():
    g = _parent()
    eng = Engine(geometry=GEOM, n_pes=4)
    keys = set()
    for seed in (11, 12, 13):
        _, bucketed, _ = _bucketed_pair(g, "b1", [5, 9, 77], (6, 4),
                                        seed=seed)
        keys.add(eng.cache_key(bucketed.model, bucketed.graph))
    assert len(keys) == 1        # different subgraphs, one program


def test_batched_bucketed_equals_single():
    # dense parent: fanout-saturated sampling keeps every user's ego
    # network in one geometry bucket (asserted below)
    g = _parent(nv=400, ne=24000)
    eng = Engine(geometry=GEOM, n_pes=4)
    reqs = []
    for i, seed in enumerate((11, 12, 13)):
        _, bucketed, _ = _bucketed_pair(g, "b1", [5 + i, 90 + i], (6, 4),
                                        seed=seed)
        bucketed.request_id = f"r{i}"
        reqs.append(bucketed)
    assert len({eng.cache_key(r.model, r.graph) for r in reqs}) == 1
    singles = [np.asarray(eng.submit(r).output) for r in reqs]
    batched = eng.submit_batch(reqs)
    assert all(r.batch_size == 3 for r in batched)
    for got, want in zip(batched, singles):
        np.testing.assert_allclose(np.asarray(got.output), want,
                                   rtol=1e-5, atol=1e-6)


def test_submit_batch_rejects_mixed_topology_sources():
    g = _parent()
    eng = Engine(geometry=GEOM, n_pes=4)
    _, bucketed, _ = _bucketed_pair(g, "b1", [5], (4,), seed=1)
    baked = InferenceRequest(model="b1", graph=bucketed.graph,
                             features=bucketed.features)
    with pytest.raises(ValueError, match="mix"):
        eng.submit_batch([bucketed, baked])


# --------------------------------------------------------------------------- #
# SamplingService: pool-integrated per-user serving (acceptance).
# --------------------------------------------------------------------------- #
def test_service_hit_rate_on_power_law_traffic():
    """Mixed target counts + fanouts on an RE-class power-law graph:
    bucketing collapses the request stream onto few programs, so the
    pool's program-cache hit rate reaches >= 0.9 after warmup."""
    # RE-class density (E/V >> fanout caps) so sampling saturates the
    # caps and per-user geometry lands in a handful of buckets
    g = _parent(nv=466, ne=60000, f=16, c=5, seed=1)
    X = G.random_features(g, seed=2)
    svc = SamplingService(g, X, n_overlays=2, geometry=GEOM, n_pes=4,
                          max_batch=4, max_wait_us=1e6)
    rng = np.random.default_rng(0)

    def mk(i):
        t = rng.choice(g.n_vertices, size=int(rng.integers(1, 4)),
                       replace=False)
        fan = [(6, 4), (4, 2), (6, 2)][i % 3]
        return TargetRequest(targets=[int(v) for v in t], model="b1",
                             fanouts=fan, request_id=f"u{i}",
                             seed=100 + i)

    try:
        svc.serve([mk(i) for i in range(12)])           # warmup
        h0 = sum(e.stats.cache_hits for e in svc.pool.engines)
        n0 = sum(e.stats.requests for e in svc.pool.engines)
        resps = svc.serve([mk(i) for i in range(12, 44)])
        h1 = sum(e.stats.cache_hits for e in svc.pool.engines)
        n1 = sum(e.stats.requests for e in svc.pool.engines)

        assert (h1 - h0) / (n1 - n0) >= 0.9             # acceptance
        assert [r.request_id for r in resps] == \
            [f"u{i}" for i in range(12, 44)]
        assert all(r.logits.shape == (len(r.targets), g.n_classes)
                   for r in resps)
        assert max(r.batch_size for r in resps) > 1     # coalescing real
        snap = svc.stats_snapshot()
        assert snap["distinct_buckets"] < 10
    finally:
        svc.shutdown()


def test_service_warm_pretraces_buckets():
    """After ``warm()`` every same-bucket request is a program-cache hit
    — the steady-state contract the benchmark relies on."""
    g = _parent(nv=400, ne=24000)
    X = G.random_features(g, seed=2)
    svc = SamplingService(g, X, n_overlays=1, geometry=GEOM, n_pes=4,
                          max_batch=4, max_wait_us=1e6)
    try:
        warmed = svc.warm([TargetRequest(targets=[5, 9], fanouts=(6, 4),
                                         seed=1)])
        assert warmed == 1
        resps = svc.serve([
            TargetRequest(targets=[10 + i, 200 + i], fanouts=(6, 4),
                          seed=50 + i, request_id=f"w{i}")
            for i in range(4)])
        assert all(r.cache_hit for r in resps)
    finally:
        svc.shutdown()


def test_service_is_deterministic_across_cache_states():
    """The same TargetRequest answered on a cold engine (compile) and on
    a warm one (cached program + jitted replay) yields identical logits."""
    g = _parent()
    X = G.random_features(g, seed=2)
    req = TargetRequest(targets=[5, 9], model="b1", fanouts=(6, 4),
                        seed=7)
    svc = SamplingService(g, X, n_overlays=1, geometry=GEOM, n_pes=4,
                          max_batch=1, max_wait_us=1e6)
    try:
        cold = svc.submit(req)
        warm = svc.submit(req)
        assert not cold.cache_hit and warm.cache_hit
        np.testing.assert_array_equal(cold.logits, warm.logits)
        assert np.array_equal(cold.targets, [5, 9])
    finally:
        svc.shutdown()


# --------------------------------------------------------------------------- #
# Satellite: ack counter thread safety.
# --------------------------------------------------------------------------- #
def test_ack_counter_is_thread_safe_and_resettable():
    ack.reset_counter()
    n_threads, n_incr = 8, 500

    def hammer(i):
        for _ in range(n_incr):
            ack._count(("t", i % 2))

    threads = [threading.Thread(target=hammer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    counts = ack.counter_snapshot()
    assert sum(counts.values()) == n_threads * n_incr
    ack.reset_counter()
    assert ack.counter_snapshot() == {}
