"""Paper Table 8: size of the generated binaries vs the input graphs."""
from __future__ import annotations

from .common import (DATASETS, MODELS, CompileOptions, compile_model,
                     dataset, emit)
from repro.core import gnn_builders as B


def run(quick: bool = False) -> None:
    ds = DATASETS[:3] if quick else DATASETS
    models = MODELS[:2] if quick else MODELS
    for bname in models:
        for dname, scale in ds:
            g = dataset(dname, scale)
            cr = compile_model(B.build(bname, g), g, CompileOptions())
            graph_bytes = g.n_edges * 12 + g.n_vertices * g.feat_dim * 4
            label = dname if scale == 1.0 else f"{dname}@{scale:g}"
            emit([f"table8,{bname}/{label},{cr.t_loc * 1e6:.0f},"
                  f"binary_B={len(cr.binary)};graph_B={graph_bytes};"
                  f"ratio={len(cr.binary) / graph_bytes:.2e}"])
