"""SpDMM-mode Pallas kernel (ACK SpDMM mode, paper Alg. 2/4).

Blocked-ELL sparse x dense:   out[r, :] = sum_k vals[r, k] * h[cols[r, k], :]

TPU adaptation (DESIGN.md §2): the compiler delivers each adjacency
sub-shard as a dst-sorted ELL tile, so each output row is owned by exactly
one kernel lane group — the FPGA's RAW-reorder hardware becomes a compile
time sort, and the banked-SRAM shuffle becomes a VMEM row gather
(``jnp.take`` along the sublane axis, Mosaic's dynamic-gather path).

Grid: (row blocks, feature fibers).  The source-feature tile for one fiber
is held whole in VMEM ((n_src, bf) — bounded by the partition pass's VMEM
budget); the kernel walks the ELL width serially, one gathered
rank-(bm, bf) multiply-add per step: exactly 2*nnz_padded*bf flops — the
edge-centric work of the paper, vectorized across lanes instead of across
p_sys/2 UR pipelines.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spdmm_kernel(cols_ref, vals_ref, h_ref, o_ref, *, width: int):
    # Each (row-block, fiber) grid cell is independent: accumulate the ELL
    # width serially in registers/VMEM and write once.
    h = h_ref[...].astype(jnp.float32)

    def body(k, acc):
        c = cols_ref[:, k]                       # [bm] int32 row gather
        hv = jnp.take(h, c, axis=0)              # [bm, bf]
        return acc + vals_ref[:, k][:, None].astype(jnp.float32) * hv

    acc = jax.lax.fori_loop(
        0, width, body, jnp.zeros(o_ref.shape, jnp.float32))
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bf", "interpret", "out_dtype"))
def spdmm(
    cols: jnp.ndarray,       # [n1, w] int32 local src indices (0 padded)
    vals: jnp.ndarray,       # [n1, w] f32 edge weights (0 padded)
    h: jnp.ndarray,          # [n_src, f] source feature tile
    *,
    bm: int = 128,
    bf: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    n1, w = cols.shape
    n_src, f = h.shape
    assert n1 % bm == 0 and f % bf == 0, (cols.shape, h.shape)
    grid = (n1 // bm, f // bf)
    return pl.pallas_call(
        functools.partial(_spdmm_kernel, width=w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
            pl.BlockSpec((n_src, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n1, f), out_dtype),
        interpret=interpret,
    )(cols, vals, h)
