"""Deprecated shim — the overlay executor now lives in ``repro.engine``.

``OverlayExecutor`` used to walk in-memory ``Program`` layer objects.
Execution is now *binary-driven* (``repro.engine.executor.BinaryExecutor``
interprets the decoded 128-bit instruction stream), so this class survives
only as a thin adapter: it wraps the old ``run(program, x)`` signature by
serializing the object-graph ``Program`` to its ISA binary + manifest once
and delegating every call to the binary path.  Weight rebinding on
``prog.model.weights`` between runs is honored (read live, as before),
but *structural* mutation of an already-compiled Program's layers is not
— the snapshot binary is replayed; recompile instead.  New code should
use::

    from repro.engine import Engine
    engine = Engine()
    prog = engine.compile(model, graph)
    y = engine.run(prog, x)
"""
from __future__ import annotations

import warnings
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.engine.executor import BinaryExecutor, ExecStats  # noqa: F401
from repro.engine.program import from_program

from .passes.kernel_map import Program


class OverlayExecutor:
    """Deprecated: use ``repro.engine.Engine`` instead."""

    def __init__(self, backend: str = "xla", overlap: bool = True,
                 interpret: bool = True) -> None:
        warnings.warn(
            "OverlayExecutor is deprecated; use repro.engine.Engine "
            "(binary-driven execution)", DeprecationWarning, stacklevel=2)
        self._executor = BinaryExecutor(backend=backend, overlap=overlap,
                                        interpret=interpret)
        self.ack = self._executor.ack
        self.overlap = overlap

    @property
    def stats(self) -> ExecStats:
        return self._executor.stats

    def run(self, prog: Program, x: jnp.ndarray,
            weights: Optional[Dict[str, np.ndarray]] = None) -> jnp.ndarray:
        view = getattr(prog, "_compiled_view", None)
        if view is None:
            view = from_program(prog)
            prog._compiled_view = view
        # The legacy executor read prog.model.weights live on every call;
        # keep that (the view's snapshot would go stale if a caller
        # rebinds entries of model.weights between runs).
        if weights is None:
            weights = prog.model.weights
        return self._executor.run(view, x, weights=weights)
