"""repro.livegraph: incremental mutation + versioned zero-downtime serving.

Covers the subsystem's acceptance criteria:
  * K random deltas applied incrementally produce tiles, signatures and
    — through b1 (GCN) / b3 (SAGE) / b6 (GAT) — *outputs* bit-identical
    to cold-compiling the mutated graph, on the device-resident,
    ``residency="host"``, and graph-as-data executor paths;
  * content-only deltas keep the program-cache key (zero recompiles,
    asserted via engine stats) while structural changes miss;
  * only touched tiles are rebuilt (retention asserted by object
    identity across versions);
  * cutover under load drops and misroutes nothing: every request is
    served on the version that was active at its admission, and
    drained retired versions are reclaimed;
  * the stale-CSR hazard is closed (mutation token) and the manifest
    carries per-tile nnz/density stats.
"""
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.passes.partition import PartitionConfig, partition_graph
from repro.engine import Engine, InferenceRequest, graph_signature
from repro.livegraph import (GraphDelta, GraphVersionStore,
                             LiveGraphServer, as_graph_data)
from repro.runtime import OverlayPool, ServeLoop

GEOM = PartitionConfig(n1=32, n2=8)


def _g(nv=90, ne=400, f=12, c=4, seed=0):
    g = G.random_graph(nv, ne, seed=seed, dedupe=True).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _engine(**kw) -> Engine:
    return Engine(geometry=GEOM, n_pes=4, **kw)


def _random_delta(g, rng, n_add=6, n_rm=2, weights=True):
    d = GraphDelta(g.n_vertices, feat_dim=g.feat_dim)
    for _ in range(n_add):
        u, v = map(int, rng.integers(0, g.n_vertices, 2))
        d.add_edge(u, v, float(rng.uniform(0.1, 1.0)) if weights else 1.0)
    for _ in range(n_rm):
        i = int(rng.integers(0, g.n_edges))
        d.remove_edge(int(g.src[i]), int(g.dst[i]))
    return d


# --------------------------------------------------------------------------- #
# GraphDelta: validation + coalescing semantics.
# --------------------------------------------------------------------------- #
def test_delta_validates_endpoints_and_weights():
    d = GraphDelta(10)
    with pytest.raises(IndexError):
        d.add_edge(10, 0)
    with pytest.raises(IndexError):
        d.remove_edge(0, -1)
    with pytest.raises(ValueError):
        d.add_edge(0, 1, float("nan"))
    v = d.add_vertex()
    assert v == 10
    d.add_edge(v, 3)            # edges may reference new vertices
    with pytest.raises(IndexError):
        d.add_edge(11, 3)


def test_delta_coalesce_remove_cancels_add():
    d = GraphDelta(10)
    d.add_edge(1, 2, 0.5)
    d.remove_edge(1, 2)         # kills the add, not a base edge
    cd = d.coalesce()
    assert cd.n_adds == 0
    assert cd.removed_pairs == [(1, 2)]
    assert cd.must_exist[(1, 2)] is False
    # remove-then-add re-creates the edge
    d2 = GraphDelta(10)
    d2.remove_edge(3, 4)
    d2.add_edge(3, 4, 2.0)
    cd2 = d2.coalesce()
    assert cd2.n_adds == 1 and cd2.must_exist[(3, 4)] is True
    # double-remove of a base pair with no re-add in between is an error
    d3 = GraphDelta(10)
    d3.remove_edge(3, 4)
    d3.remove_edge(3, 4)
    with pytest.raises(KeyError):
        d3.coalesce()


def test_delta_apply_to_missing_edge_raises():
    g = _g()
    absent = (0, 1)
    key = g.src.astype(np.int64) * g.n_vertices + g.dst
    while absent[0] * g.n_vertices + absent[1] in key:
        absent = (absent[0], absent[1] + 1)
    d = GraphDelta(g.n_vertices).remove_edge(*absent)
    with pytest.raises(KeyError):
        d.apply_to(g)
    store = GraphVersionStore(_g(), geometry=GEOM)
    with pytest.raises(KeyError):
        store.apply(d)
    assert len(store) == 1                    # failed delta left no version


def test_delta_apply_to_canonical_order():
    """Survivors keep their positions; adds append in arrival order."""
    g = _g()
    d = GraphDelta(g.n_vertices)
    d.add_edge(5, 6, 0.25)
    d.add_edge(1, 1, 0.75)
    i = 17
    d.remove_edge(int(g.src[i]), int(g.dst[i]))
    out = d.apply_to(g)
    key = g.src.astype(np.int64) * g.n_vertices + g.dst
    dead = int(g.src[i]) * g.n_vertices + int(g.dst[i])
    keep = key != dead
    assert np.array_equal(out.src[:-2], g.src[keep])
    assert np.array_equal(out.dst[:-2], g.dst[keep])
    assert (int(out.src[-2]), int(out.dst[-2])) == (5, 6)
    assert (int(out.src[-1]), int(out.dst[-1])) == (1, 1)


# --------------------------------------------------------------------------- #
# Incremental tile patching == cold partitioning; COW retention.
# --------------------------------------------------------------------------- #
def test_incremental_tiles_match_cold_partition():
    rng = np.random.default_rng(11)
    g_ref = _g(seed=4)
    store = GraphVersionStore(g_ref, geometry=GEOM)
    prev = store.head
    for k in range(6):
        d = _random_delta(g_ref, rng)
        g_ref = d.apply_to(g_ref)
        v = store.apply(d)
        pg_live, pg_cold = v.pgraph, partition_graph(g_ref, GEOM)
        assert set(pg_live.tiles) == set(pg_cold.tiles)
        for jk in pg_cold.tiles:
            live, cold = pg_live.tiles[jk], pg_cold.tiles[jk]
            assert len(live) == len(cold)
            for a, b in zip(live, cold):
                assert np.array_equal(a.cols, b.cols), jk
                assert np.array_equal(a.vals, b.vals), jk
                # epos VALUES differ (stable ids vs COO positions); the
                # occupancy pattern and nnz must agree exactly.
                assert np.array_equal(a.edge_pos >= 0,
                                      b.edge_pos >= 0), jk
                assert a.nnz == b.nnz
        assert np.array_equal(pg_live.inv_in_degree,
                              pg_cold.inv_in_degree)
        # stable edge ids: unique, in range, pad slot never collides
        eids = np.concatenate([t.edge_pos[t.edge_pos >= 0]
                               for ts in pg_live.tiles.values()
                               for t in ts])
        assert eids.shape[0] == np.unique(eids).shape[0]
        assert eids.max() < pg_live.n_edges
        # COW: untouched tiles are THE SAME objects as the parent's
        touched = {tuple(map(int, s.split(":")))
                   for s in v.stats.patched}
        shared = [jk for jk in pg_live.tiles if jk not in touched]
        assert shared, "delta touched every tile — shrink it"
        for jk in shared:
            assert v.store.tiles[jk] is prev.store.tiles[jk]
            assert v.store.hashes[jk] == prev.store.hashes[jk]
        assert v.stats.tiles_retained == len(shared)
        # canonical COO materialization matches the reference chain
        vg = v.as_graph()
        assert np.array_equal(vg.src, g_ref.src)
        assert np.array_equal(vg.dst, g_ref.dst)
        assert np.array_equal(vg.weight, g_ref.weight)
        prev = v


def test_eid_reuse_bounds_capacity_under_churn():
    """Removed edge ids are reallocated smallest-first: add/remove churn
    does not grow the executor's edge-valued buffers."""
    g = _g()
    store = GraphVersionStore(g, geometry=GEOM)
    for r in range(4):
        d = GraphDelta(store.head.n_vertices)
        i = 3 * r
        d.remove_edge(int(g.src[i]), int(g.dst[i]))
        d.add_edge(int(g.src[i]), int(g.dst[i]),
                   float(g.weight[i]))     # put it right back
        g = d.apply_to(g)
        store.apply(d)
    assert store.head.store.eid_capacity == store.head.store.live_edges


# --------------------------------------------------------------------------- #
# Signatures: content deltas hit the program cache, structure misses.
# --------------------------------------------------------------------------- #
def test_content_delta_keeps_cache_key_structural_delta_misses():
    g = _g(seed=7)
    store = GraphVersionStore(g, geometry=GEOM)
    v0 = store.head
    sig0, con0 = v0.structural_signature, v0.content_signature

    # weight-only change: same tiles, new content
    i = 9
    d = GraphDelta(g.n_vertices)
    d.remove_edge(int(g.src[i]), int(g.dst[i]))
    d.add_edge(int(g.src[i]), int(g.dst[i]), 123.0)
    v1 = store.apply(d)
    assert v1.structural_signature == sig0
    assert v1.content_signature != con0
    assert graph_signature(v1.as_graph()) == \
        graph_signature(v0.as_graph())
    assert not v1.stats.structural_change

    # emptying out a whole (j, k) tile is CONTENT-only: the tile keeps
    # its slice count as zero-nnz slices, so the binary's tile
    # enumeration (and the program-cache key) survives — the bind-time
    # remapper elides the dead slices as skip-empty instead.
    jk, te = min(v1.store.edges.items(), key=lambda kv: kv[1].n)
    d2 = GraphDelta(v1.n_vertices)
    for u, w_ in zip(te.src.tolist(), te.dst.tolist()):
        d2.remove_edge(u, w_)
    v2 = store.apply(d2)
    assert jk in v2.store.tiles
    assert len(v2.store.tiles[jk]) == len(v1.store.tiles[jk])
    assert all(t.nnz == 0 for t in v2.store.tiles[jk])
    assert f"{jk[0]}:{jk[1]}" in v2.stats.patched
    assert not v2.stats.structural_change
    assert v2.structural_signature == sig0
    assert graph_signature(v2.as_graph()) == \
        graph_signature(v1.as_graph())

    # a brand-new tile (vertex growth past the padded grid) IS
    # structural — the instruction binary enumerates tiles
    d3 = GraphDelta(v2.n_vertices)
    for _ in range(7):
        w = d3.add_vertex()
    d3.add_edge(0, w)
    v3 = store.apply(d3)
    assert v3.stats.tiles_created >= 1
    assert v3.stats.structural_change
    assert v3.structural_signature != sig0
    assert graph_signature(v3.as_graph()) != \
        graph_signature(v2.as_graph())


# --------------------------------------------------------------------------- #
# Equivalence suite: K deltas incrementally == cold compile, bit for
# bit, on every executor path.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["b1", "b3", "b6"])
def test_incremental_serving_bit_identical_to_cold(name):
    rng = np.random.default_rng(23)
    g_ref = _g(seed=1)
    store = GraphVersionStore(g_ref, geometry=GEOM)
    live = LiveGraphServer(store)
    eng = _engine()
    x0 = np.asarray(G.random_features(g_ref, seed=2))
    # warm version 0 (the one compile this engine should ever do)
    eng.submit(InferenceRequest(name, live, x0))
    for k in range(3):
        d = _random_delta(g_ref, rng, n_add=5, n_rm=1)
        if k == 1:
            nv = d.add_vertex(np.zeros(g_ref.feat_dim, np.float32))
            d.add_edge(nv, int(rng.integers(0, g_ref.n_vertices)), 0.4)
        g_ref = d.apply_to(g_ref)
        live.apply(d)
    x = np.zeros((g_ref.n_vertices, g_ref.feat_dim), np.float32)
    x[:x0.shape[0]] = x0

    cold = _engine()
    p_cold = cold.compile(name, g_ref)
    y_cold = cold.run(p_cold, x)

    resp = eng.submit(InferenceRequest(name, live, x))
    assert resp.cache_hit and eng.stats.compiles == 1, \
        "content-only deltas must reuse the compiled program"
    assert np.array_equal(np.asarray(resp.output), np.asarray(y_cold))

    prog = eng.compile(name, live)
    y_host = eng.run(prog, x, residency="host")
    assert np.array_equal(np.asarray(y_host), np.asarray(y_cold))

    y_gd = eng.run(prog, x, graph_data=as_graph_data(live.active.pgraph))
    assert np.array_equal(np.asarray(y_gd), np.asarray(y_cold))
    assert eng.stats.compiles == 1


def test_incremental_serving_on_mesh_path():
    """The placement-scheduled multi-device path stages patched tiles
    transparently (1-device mesh: same code path, no multi-host dep)."""
    rng = np.random.default_rng(29)
    g_ref = _g(seed=6)
    store = GraphVersionStore(g_ref, geometry=GEOM)
    live = LiveGraphServer(store)
    eng = _engine()
    eng.compile("b1", live)
    d = _random_delta(g_ref, rng, n_add=4, n_rm=1)
    g_ref = d.apply_to(g_ref)
    live.apply(d)
    x = np.asarray(G.random_features(g_ref, seed=3))
    y_mesh = eng.run(eng.compile("b1", live), x, mesh=1)
    cold = _engine()
    y_cold = cold.run(cold.compile("b1", g_ref), x)
    assert np.array_equal(np.asarray(y_mesh), np.asarray(y_cold))
    assert eng.stats.compiles == 1


def test_batched_serving_on_live_version():
    """submit_batch over a live handle: one pass, correct tiles, and
    mixed-version batches are refused (misroute guard)."""
    g = _g(seed=9)
    store = GraphVersionStore(g, geometry=GEOM)
    live = LiveGraphServer(store)
    eng = _engine()
    xs = [np.asarray(G.random_features(g, seed=s)) for s in (1, 2, 3)]
    reqs = [InferenceRequest("b1", live, x) for x in xs]
    resps = eng.submit_batch(reqs)
    singles = [eng.submit(InferenceRequest("b1", live, x)) for x in xs]
    for b, s in zip(resps, singles):
        # batched passes replay a vmapped executable: allclose, same as
        # the repo's other batch-vs-single equivalences
        np.testing.assert_allclose(np.asarray(b.output),
                                   np.asarray(s.output),
                                   rtol=1e-5, atol=1e-6)
    v0g = live.active.as_graph()
    live.apply(GraphDelta(live.n_vertices).add_edge(1, 2, 0.5))
    v1g = live.active.as_graph()
    mixed = [InferenceRequest("b1", v0g, xs[0]),
             InferenceRequest("b1", v1g, xs[1])]
    with pytest.raises(ValueError, match="mix graph versions"):
        eng.submit_batch(mixed)


# --------------------------------------------------------------------------- #
# Cutover under load: zero dropped, zero misrouted, retirees reclaimed.
# --------------------------------------------------------------------------- #
def test_cutover_under_load_zero_dropped_zero_misrouted():
    g = _g(seed=12)
    store = GraphVersionStore(g, geometry=GEOM)
    pool = OverlayPool(n_overlays=2, geometry=GEOM, n_pes=4)
    live = LiveGraphServer(store, metrics=pool.metrics)
    loop = ServeLoop(pool, max_batch=4, max_wait_us=1e9)
    rng = np.random.default_rng(31)
    feats = [np.asarray(G.random_features(g, seed=s)) for s in range(4)]

    # Reference outputs per version, computed BEFORE any reclamation.
    ref_eng = _engine()
    y_ref = {0: {i: np.asarray(ref_eng.run(
        ref_eng.compile("b1", store.head.as_graph()), f))
        for i, f in enumerate(feats)}}

    expected = {}
    n = 0
    try:
        for phase in range(3):
            for i in range(6):
                rid = f"p{phase}r{i}"
                loop.submit(InferenceRequest(
                    "b1", live, feats[i % 4], request_id=rid))
                expected[rid] = (live.active.vid, i % 4)
                n += 1
            if phase < 2:
                d = _random_delta(g, rng, n_add=2, n_rm=0)
                v = live.apply(d)
                y_ref[v.vid] = {i: np.asarray(ref_eng.run(
                    ref_eng.compile("b1", v.as_graph()), f))
                    for i, f in enumerate(feats)}
        resps = loop.drain()
    finally:
        loop.shutdown()

    assert len(resps) == n, "requests were dropped across cutover"
    by_rid = {r.request_id: r for r in resps}
    for rid, (vid, fi) in expected.items():
        r = by_rid[rid]
        assert r.graph_name.endswith(f"@v{vid}"), \
            f"{rid} admitted on v{vid} but served on {r.graph_name}"
        np.testing.assert_allclose(
            np.asarray(r.output), y_ref[vid][fi], rtol=1e-5, atol=1e-6,
            err_msg=f"{rid} output does not match its pinned version")

    # retired versions drained -> reclaimed; head survives
    assert sorted(store.versions()) == [live.active.vid]
    assert live.reclaimed == [0, 1]
    assert live.cutovers == 2
    # one compile in the whole pool: every version shared the program
    assert sum(e.stats.compiles for e in pool.engines) == 1

    snap = pool.metrics.snapshot(max_batch=4)
    lg = snap["livegraph"]
    assert lg["active_version"] == live.active.vid
    assert lg["cutovers"] == 2
    assert lg["versions_reclaimed"] == 2
    assert sum(lg["requests_per_version"].values()) == n


def test_metrics_without_live_graphs_have_no_livegraph_section():
    from repro.runtime import Metrics
    assert "livegraph" not in Metrics().snapshot()


# --------------------------------------------------------------------------- #
# Satellites: CSR invalidation token, manifest tile stats.
# --------------------------------------------------------------------------- #
def test_in_csr_mutation_token_invalidates():
    g = _g()
    csr0 = g.in_csr()
    assert g.in_csr() is csr0                 # memoized
    # in-place content mutation is invisible to identity checks...
    g.src[0] = (g.src[0] + 1) % g.n_vertices
    assert g.in_csr() is csr0                 # ...hence the hazard
    token = g.invalidate_views()              # the fix: bump per delta
    assert token == 1 and g.mutation_token == 1
    csr1 = g.in_csr()
    assert csr1 is not csr0
    order = np.lexsort((g.src, g.dst))
    assert np.array_equal(csr1.src, g.src[order])


def test_graph_signature_tracks_mutation_token():
    g = _g()
    s0 = graph_signature(g)
    g.weight[0] += 1.0
    assert graph_signature(g) == s0           # the stale memo
    g.invalidate_views()
    assert graph_signature(g) != s0


def test_manifest_tile_stats_present_and_rebind_refreshes(tmp_path):
    g = _g(seed=2)
    eng = _engine()
    prog = eng.compile("b1", g)
    ts = prog.manifest["tile_stats"]
    pg = prog.pgraph
    assert ts["n_tiles"] == len(pg.tiles)
    assert ts["total_nnz"] == pg.total_nnz()
    some = next(iter(ts["tiles"].values()))
    assert {"nnz", "slices", "width", "density"} <= set(some)
    # round-trips .gagi
    path = str(tmp_path / "live.gagi")
    prog.save(path)
    assert eng.load(path).manifest["tile_stats"] == ts

    # rebinding to a patched version refreshes stats + version labels
    store = GraphVersionStore(g, geometry=GEOM)
    live = LiveGraphServer(store)
    eng.submit(InferenceRequest("b1", live,
                                np.asarray(G.random_features(g, seed=1))))
    live.apply(GraphDelta(g.n_vertices).add_edge(0, 1, 0.5)
               .add_edge(2, 3, 0.5))
    bound = eng.compile("b1", live)
    assert bound.manifest["graph_version"] == 1
    assert bound.manifest["tile_stats"]["total_nnz"] == \
        ts["total_nnz"] + 2
    assert bound.manifest["graph_name"].endswith("@v1")
    assert "content_signature" in bound.manifest
    # the cached program's manifest is untouched (shallow-copy contract)
    assert "graph_version" not in prog.manifest


def test_version_bind_refuses_geometry_mismatch():
    g = _g()
    store = GraphVersionStore(g, geometry=GEOM)
    other = Engine(geometry=PartitionConfig(n1=64, n2=8), n_pes=4)
    prog = other.compile("b1", g)
    with pytest.raises(ValueError, match="geometry"):
        store.head.bind(prog)


def test_block_growth_changes_structure_and_stays_correct():
    """Adding vertices past the padded block capacity grows the tile
    grid: a structural change — new cache key, recompile — that still
    serves bit-identical results."""
    g = _g(nv=60, ne=260, seed=15)
    store = GraphVersionStore(g, geometry=GEOM)
    live = LiveGraphServer(store)
    eng = _engine()
    eng.compile("b1", live)
    nb0 = store.head.pgraph.n_blocks
    d = GraphDelta(g.n_vertices, feat_dim=g.feat_dim)
    first = d.add_vertex()
    for _ in range(GEOM.n1):                    # cross a block boundary
        d.add_vertex()
    d.add_edge(first, 0, 1.0)
    g_ref = d.apply_to(g)
    v = live.apply(d)
    assert v.pgraph.n_blocks == nb0 + 1
    assert v.stats.structural_change
    x = np.asarray(G.random_features(g_ref, seed=8))
    resp = eng.submit(InferenceRequest("b1", live, x))
    assert not resp.cache_hit and eng.stats.compiles == 2
    cold = _engine()
    y_cold = cold.run(cold.compile("b1", g_ref), x)
    assert np.array_equal(np.asarray(resp.output), np.asarray(y_cold))
