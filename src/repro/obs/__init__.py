"""repro.obs — observability for the GraphAGILE stack.

Two halves:

* :mod:`repro.obs.tracer` — structured tracing (nestable spans,
  counters, instant events) exported as Chrome/Perfetto trace-event
  JSON, threaded through the compiler passes, every executor residency
  path, and the serving runtime.  Zero overhead when disabled.
* :mod:`repro.obs.trajectory` — per-metric tolerance-band comparison
  of fresh BENCH_*.json artifacts against committed baselines, the
  engine behind the ``benchmarks/check_trajectory.py`` CI gate.
"""
from .tracer import (NullTracer, Tracer, disable_tracing,
                     enable_tracing, get_tracer, set_tracer, tracing)
from .trajectory import (DEFAULT_SPECS, FileReport, MetricResult,
                         MetricSpec, TrajectoryReport, compare_dirs,
                         compare_docs, compare_metrics, lookup)

__all__ = [
    "Tracer", "NullTracer", "get_tracer", "set_tracer",
    "enable_tracing", "disable_tracing", "tracing",
    "MetricSpec", "MetricResult", "FileReport", "TrajectoryReport",
    "DEFAULT_SPECS", "compare_metrics", "compare_docs", "compare_dirs",
    "lookup",
]
