"""Live-graph benchmark: incremental update cost vs full recompilation.

  PYTHONPATH=src python benchmarks/bench_live.py [--smoke]

Measures the ``repro.livegraph`` subsystem three ways:

  * **update latency** — applying a delta of D edges to a deployed
    graph (incremental tile patch + version build + program rebind)
    against the do-nothing-clever baseline (mutate the COO, recompile
    through the full pipeline), at D = 1 / 100 / 10k (smoke: 1/16/64).
    Also reports the fraction of tiles retained by reference per delta.
  * **cutover under load** — a request stream served through a
    ``ServeLoop`` while deltas cut the graph over mid-stream: sustained
    QPS, response count (asserted: zero dropped), misroutes (asserted:
    zero — every response carries the version it was admitted on), and
    requests per version.

Results land in ``BENCH_live.json`` at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

try:                                    # script: python benchmarks/bench_live.py
    from common import provenance, verify_section
except ImportError:                     # module: python -m benchmarks.bench_live
    from benchmarks.common import provenance, verify_section

from repro.core import graph as G  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402
from repro.engine import Engine, InferenceRequest  # noqa: E402
from repro.livegraph import (GraphDelta, GraphVersionStore,  # noqa: E402
                             LiveGraphServer)
from repro.runtime import Metrics, OverlayPool, ServeLoop  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_graph(smoke: bool, seed: int) -> "G.Graph":
    if smoke:
        g = G.random_graph(180, 900, seed=21 + seed,
                           dedupe=True).gcn_normalized()
        g.feat_dim, g.n_classes = 16, 4
        g.name = "SL"
    else:
        g = G.synthesize("PU", seed=seed).gcn_normalized()
    return g


def make_delta(g: "G.Graph", n_edges: int, rng) -> GraphDelta:
    """Mixed churn: ~90% adds, ~10% removes of existing edges."""
    d = GraphDelta(g.n_vertices, feat_dim=g.feat_dim)
    n_rm = max(1, n_edges // 10) if g.n_edges else 0
    n_add = n_edges - n_rm
    for _ in range(n_add):
        u, v = map(int, rng.integers(0, g.n_vertices, 2))
        d.add_edge(u, v, float(rng.uniform(0.1, 1.0)))
    picks = rng.choice(g.n_edges, size=min(n_rm, g.n_edges),
                       replace=False)
    seen = set()
    for i in picks:
        pair = (int(g.src[i]), int(g.dst[i]))
        if pair not in seen:        # one removal kills the whole pair
            seen.add(pair)
            d.remove_edge(*pair)
    return d


def bench_updates(geom, g, model: str, delta_sizes: List[int],
                  n_pes: int, seed: int) -> dict:
    """Incremental patch + rebind vs full pipeline recompile, per size."""
    rng = np.random.default_rng(100 + seed)
    eng = Engine(geometry=geom, n_pes=n_pes)
    store = GraphVersionStore(g, geometry=geom)
    live = LiveGraphServer(store)
    x = np.asarray(G.random_features(g, seed=2))
    eng.submit(InferenceRequest(model, live, x))     # compile v0 once
    # Arm the sparsity-adaptive remapper: every content-only rebind
    # below then re-prices exactly the delta-patched tiles in place,
    # and the bound manifest's remap record times that incremental
    # pass (reported next to the patch+rebind latency).
    eng.remap(eng.compile(model, live))
    out = {}
    g_mut = g
    for size in delta_sizes:
        d = make_delta(g_mut, size, rng)
        g_next = d.apply_to(g_mut)
        compiles_before = eng.stats.compiles

        t0 = time.perf_counter()
        v = live.apply(d)                            # patch + cutover
        bound = eng.compile(model, live)             # rebind (no compile)
        t_inc = time.perf_counter() - t0

        cold = Engine(geometry=geom, n_pes=n_pes)
        t0 = time.perf_counter()
        cold.compile(model, g_next)                  # full pipeline
        t_full = time.perf_counter() - t0

        assert v.stats.structural_change or \
            eng.stats.compiles == compiles_before, \
            "content-only delta must hit the program cache"
        rec = (bound.manifest or {}).get("remap")
        out[str(size)] = {
            "incremental_ms": round(t_inc * 1e3, 3),
            "full_recompile_ms": round(t_full * 1e3, 3),
            "speedup": round(t_full / t_inc, 2) if t_inc else 0.0,
            "tiles_retained": v.stats.tiles_retained,
            "tiles_total": v.stats.tiles_after,
            "retention": round(v.stats.retention, 4),
            "structural_change": v.stats.structural_change,
            # incremental remap: only the delta-patched tiles re-priced
            "remap_ms": rec["remap_ms"] if rec else None,
            "tiles_repriced": len(v.stats.patched) if rec else None,
        }
        g_mut = g_next
    out["compiles_incremental_path"] = eng.stats.compiles
    return out


def bench_cutover_qps(geom, g, model: str, n_requests: int,
                      n_cutovers: int, delta_size: int, n_pes: int,
                      n_overlays: int, max_batch: int,
                      seed: int) -> dict:
    """Sustained serving through live cutovers; asserts zero dropped
    and zero misrouted responses."""
    rng = np.random.default_rng(200 + seed)
    store = GraphVersionStore(g, geometry=geom)
    metrics = Metrics()
    pool = OverlayPool(n_overlays=n_overlays, geometry=geom,
                       n_pes=n_pes, metrics=metrics)
    live = LiveGraphServer(store, metrics=metrics)
    feats = [np.asarray(G.random_features(g, seed=300 + seed + i))
             for i in range(4)]
    # warm: compile the structure + jit the batched shapes once
    warm = ServeLoop(pool, max_batch=max_batch, max_wait_us=1e6)
    try:
        warm.serve([InferenceRequest(model, live, feats[i % 4],
                                     request_id=f"w{i}")
                    for i in range(max_batch)])
    finally:
        warm.shutdown()

    loop = ServeLoop(pool, max_batch=max_batch, max_wait_us=1e6,
                     max_queue=8 * max_batch, metrics=metrics)
    cut_every = max(1, n_requests // (n_cutovers + 1))
    expected = {}
    g_mut = live.active.as_graph()
    t0 = time.perf_counter()
    try:
        for i in range(n_requests):
            rid = f"r{i}"
            loop.submit(InferenceRequest(model, live, feats[i % 4],
                                         request_id=rid))
            expected[rid] = live.active.vid
            if (i + 1) % cut_every == 0 and live.cutovers < n_cutovers:
                d = make_delta(g_mut, delta_size, rng)
                g_mut = d.apply_to(g_mut)
                live.apply(d)
        resps = loop.drain()
        wall = time.perf_counter() - t0
    finally:
        loop.shutdown()

    dropped = n_requests - len(resps)
    misrouted = sum(
        not r.graph_name.endswith(f"@v{expected[r.request_id]}")
        for r in resps)
    assert dropped == 0, f"{dropped} requests dropped across cutover"
    assert misrouted == 0, f"{misrouted} requests misrouted"
    snap = metrics.snapshot(max_batch=max_batch)
    return {
        "requests": n_requests,
        "cutovers": live.cutovers,
        "delta_size": delta_size,
        "wall_s": round(wall, 6),
        "throughput_rps": round(n_requests / wall, 3),
        "dropped": dropped,
        "misrouted": misrouted,
        "versions_reclaimed": snap["livegraph"]["versions_reclaimed"],
        "requests_per_version":
            snap["livegraph"]["requests_per_version"],
        "p50_ms": snap["global"]["p50_latency_ms"],
        "p99_ms": snap["global"]["p99_latency_ms"],
        "compiles": sum(e.stats.compiles for e in pool.engines),
    }


def run(smoke: bool, out_path: str, seed: int = 0) -> dict:
    geom = PartitionConfig(n1=32, n2=8) if smoke \
        else PartitionConfig(n1=256, n2=32)
    n_pes = 4 if smoke else 8
    model = "b1"
    delta_sizes = [1, 16, 64] if smoke else [1, 100, 10_000]
    n_requests = 24 if smoke else 128
    g = make_graph(smoke, seed)
    report: dict = {
        "benchmark": "bench_live",
        "mode": "smoke" if smoke else "full",
        "model": model,
        "graph": {"name": g.name, "n_vertices": g.n_vertices,
                  "n_edges": g.n_edges},
        "provenance": provenance(seed),
    }
    print("delta_size,incremental_ms,full_recompile_ms,speedup,retention")
    report["updates"] = bench_updates(geom, g, model, delta_sizes,
                                      n_pes, seed)
    for size in delta_sizes:
        r = report["updates"][str(size)]
        print(f"{size},{r['incremental_ms']},{r['full_recompile_ms']},"
              f"{r['speedup']},{r['retention']}")
    report["cutover"] = bench_cutover_qps(
        geom, make_graph(smoke, seed), model, n_requests,
        n_cutovers=2, delta_size=delta_sizes[1], n_pes=n_pes,
        n_overlays=2, max_batch=4, seed=seed)
    c = report["cutover"]
    print(f"cutover,{c['requests']} reqs,{c['throughput_rps']} rps,"
          f"dropped={c['dropped']},misrouted={c['misrouted']}")
    # Static verification of the live-handle program (the rebind path's
    # capacity-checked kernel legality) — semantic trajectory metrics.
    live = LiveGraphServer(GraphVersionStore(make_graph(smoke, seed),
                                             geometry=geom))
    report["verify"] = verify_section(
        Engine(geometry=geom, n_pes=n_pes), [(model, live)])
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph + small deltas (CI gate)")
    ap.add_argument("--seed", type=int, default=0,
                    help="offsets graph/feature seeds; recorded in the "
                         "report provenance")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_live.json"))
    args = ap.parse_args()
    run(args.smoke, args.out, seed=args.seed)


if __name__ == "__main__":
    main()
