"""Trace attribution: span DAG, critical path, and measured accounting.

Consumes the Chrome/Perfetto trace-event JSON the :mod:`repro.obs.tracer`
emits and turns it back into *structure*:

* :func:`parse_spans` — the ``"X"`` complete events as :class:`Span`
  records with resolved track names;
* :func:`build_dag` — a :class:`TraceDAG`: the per-track containment
  forest (Perfetto infers nesting from timestamp containment; we make it
  explicit) plus dependency edges — previous-sibling order on each
  track, and the executor's cross-track producer edges (a ``stage`` span
  feeds the ``compute`` span of the same ``(layer, shard)``);
* :meth:`TraceDAG.critical_path` — the backward last-to-finish walk:
  from the last span to end, through the child that delayed each end and
  the gate (sibling / producer / parent) that delayed each start.  Its
  total is what the scoreboard-issue refactor is bounded by;
* :meth:`TraceDAG.slack_us` / :meth:`TraceDAG.stall_us` — per-span CPM
  slack (how far a span's finish could slip without moving the
  makespan) and *induced stall* (time a producer span kept its consumer
  waiting beyond the consumer's other gates — ≈0 for every ``stage``
  span when the double-buffer overlap works, the exposed staging time
  when it does not);
* :func:`attribution_table` — the measured per-(layer, tile-block,
  kernel-mode) accounting: wall time, tile ops, and staged bytes from
  the executor's spans, joined back to decoded instruction index ranges.

Everything here is pure stdlib over plain dicts, so saved ``trace.json``
files from other processes analyze the same as live ``tracer.events()``.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

__all__ = [
    "Span", "TraceDAG", "parse_spans", "build_dag", "attribution_table",
]

# containment / ordering fuzz for float-µs timestamps
_EPS = 2e-3


@dataclasses.dataclass
class Span:
    """One complete ("X") trace event, with graph fields filled by
    :func:`build_dag`."""

    index: int
    name: str
    cat: str
    tid: int
    track: str
    ts: float                 # µs from trace start
    dur: float                # µs
    args: Dict[str, Any]
    parent: Optional[int] = None
    children: List[int] = dataclasses.field(default_factory=list)

    @property
    def end(self) -> float:
        return self.ts + self.dur


def _event_list(trace: Union[dict, Sequence[dict], str]) -> List[dict]:
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    if isinstance(trace, dict):
        trace = trace.get("traceEvents", [])
    return list(trace)


def parse_spans(trace: Union[dict, Sequence[dict], str]) -> List[Span]:
    """Complete events of a trace (dict / event list / path to JSON) as
    :class:`Span` records, sorted by start time."""
    events = _event_list(trace)
    tracks: Dict[int, str] = {}
    for e in events:
        if e.get("ph") == "M" and e.get("name") == "thread_name":
            tracks[e["tid"]] = e.get("args", {}).get("name", "")
    spans = [
        Span(index=0, name=e["name"], cat=e.get("cat", ""),
             tid=e.get("tid", 0),
             track=tracks.get(e.get("tid", 0), str(e.get("tid", 0))),
             ts=float(e["ts"]), dur=float(e.get("dur", 0.0)),
             args=dict(e.get("args", {})))
        for e in events if e.get("ph") == "X"
    ]
    spans.sort(key=lambda s: (s.ts, -s.dur))
    for i, s in enumerate(spans):
        s.index = i
    return spans


class TraceDAG:
    """Span containment forest + dependency edges over one trace."""

    def __init__(self, spans: List[Span]) -> None:
        self.spans = spans
        n = len(spans)
        self.prev_sibling: List[Optional[int]] = [None] * n
        self.producers: List[List[int]] = [[] for _ in range(n)]
        self.consumers: List[List[int]] = [[] for _ in range(n)]
        self._build_forest()
        self._link_producers()

    # -------------------------------------------------------------- #
    def _build_forest(self) -> None:
        by_tid: Dict[int, List[Span]] = {}
        for s in self.spans:
            by_tid.setdefault(s.tid, []).append(s)
        for group in by_tid.values():
            stack: List[Span] = []          # open ancestors
            last_child_of: Dict[Optional[int], int] = {}
            for s in group:                 # already (ts, -dur) sorted
                while stack and stack[-1].end <= s.ts + _EPS:
                    stack.pop()
                parent = stack[-1] if stack else None
                if parent is not None and s.end > parent.end + _EPS:
                    parent = None           # overlap, not containment
                if parent is not None:
                    s.parent = parent.index
                    parent.children.append(s.index)
                prev = last_child_of.get(
                    parent.index if parent else None)
                if prev is not None:
                    self.prev_sibling[s.index] = prev
                last_child_of[parent.index if parent else None] = s.index
                stack.append(s)

    def _link_producers(self) -> None:
        """Executor cross-track edges: a ``stage`` span produces the
        working set its same-(layer, shard) ``compute`` span consumes."""
        stages: Dict[Tuple[Any, Any], int] = {}
        for s in self.spans:
            if s.name == "stage" and "shard" in s.args:
                stages[(s.args.get("layer"), s.args["shard"])] = s.index
        for s in self.spans:
            if s.name == "compute" and "shard" in s.args:
                p = stages.get((s.args.get("layer"), s.args["shard"]))
                if p is not None:
                    self._add_edge(p, s.index)

    def _add_edge(self, producer: int, consumer: int) -> None:
        if producer not in self.producers[consumer]:
            self.producers[consumer].append(producer)
            self.consumers[producer].append(consumer)

    # -------------------------------------------------------------- #
    def _start_gates(self, i: int) -> List[int]:
        """Spans that gate span ``i``'s start (sibling order + producer
        edges); the containment parent is handled separately."""
        g = []
        if self.prev_sibling[i] is not None:
            g.append(self.prev_sibling[i])
        g.extend(self.producers[i])
        return g

    @property
    def makespan_us(self) -> float:
        return max((s.end for s in self.spans), default=0.0)

    def _last_predecessor(self, cur: int, visited: set
                          ) -> Optional[int]:
        """Latest-ending unvisited span that finished by the time
        ``cur`` started — the classic retrospective "what had just
        finished when this could start" fallback that bridges
        cross-track waits no explicit edge records."""
        sp = self.spans
        limit = sp[cur].ts + _EPS
        lo, hi = 0, len(self._by_end)
        while lo < hi:
            mid = (lo + hi) // 2
            if sp[self._by_end[mid]].end <= limit:
                lo = mid + 1
            else:
                hi = mid
        for pos in range(lo - 1, -1, -1):
            i = self._by_end[pos]
            if i not in visited:
                return i
        return None

    def critical_path(self) -> List[Span]:
        """Backward last-to-finish walk: start from the span that ends
        last; a span's end is explained by its last-ending child, a
        span's start by its latest-ending gate (previous sibling,
        producer, or the last span to finish anywhere before it
        started), falling back to its containment parent."""
        if not self.spans:
            return []
        sp = self.spans
        if not hasattr(self, "_by_end"):
            self._by_end = sorted(range(len(sp)),
                                  key=lambda i: sp[i].end)
        cur = max(range(len(sp)), key=lambda i: sp[i].end)
        path, visited = [cur], {cur}
        via_end = True
        while True:
            nxt: Optional[int] = None
            if via_end:
                ch = [c for c in sp[cur].children if c not in visited]
                if ch:
                    nxt = max(ch, key=lambda i: sp[i].end)
            via_end = True
            if nxt is None:
                gates = [g for g in self._start_gates(cur)
                         if g not in visited]
                fb = self._last_predecessor(cur, visited)
                if fb is not None:
                    gates.append(fb)
                if gates:
                    nxt = max(gates, key=lambda i: sp[i].end)
                elif (sp[cur].parent is not None
                        and sp[cur].parent not in visited):
                    nxt = sp[cur].parent
                    via_end = False     # explain the PARENT's start next
                else:
                    break
            path.append(nxt)
            visited.add(nxt)
            cur = nxt
        path.reverse()
        return [sp[i] for i in path]

    def slack_us(self) -> List[float]:
        """Per-span CPM slack: how much later the span could have
        finished without moving any downstream start constraint (next
        sibling start, consumer start, parent end) or the makespan."""
        sp = self.spans
        makespan = self.makespan_us
        next_sibling: List[Optional[int]] = [None] * len(sp)
        for i, prev in enumerate(self.prev_sibling):
            if prev is not None:
                next_sibling[prev] = i
        out = []
        for s in sp:
            limits = [makespan]
            if next_sibling[s.index] is not None:
                limits.append(sp[next_sibling[s.index]].ts)
            for c in self.consumers[s.index]:
                limits.append(sp[c].ts)
            if s.parent is not None:
                limits.append(sp[s.parent].end)
            out.append(max(0.0, min(limits) - s.end))
        return out

    def stall_us(self) -> List[float]:
        """Per-span *induced stall*: time this span kept a consumer
        waiting beyond the consumer's other start gates.  A ``stage``
        span whose transfer hid entirely under the previous shard's
        compute induces ~0 stall; a stage that out-lived it exposes the
        difference as stall — the quantified overlap-failure signal."""
        sp = self.spans
        out = [0.0] * len(sp)
        for c in range(len(sp)):
            gates = self._start_gates(c)
            if not gates:
                continue
            ends = {g: sp[g].end for g in gates}
            for g in gates:
                others = [e for k, e in ends.items() if k != g]
                if sp[c].parent is not None:
                    others.append(sp[sp[c].parent].ts)
                baseline = max(others) if others else sp[g].ts
                out[g] += max(0.0, min(sp[g].end, sp[c].ts + _EPS)
                              - max(baseline, sp[g].ts))
        return out

    def summary(self) -> dict:
        """Plain-dict rollup for reports: makespan, the critical path
        (name, track, dur), and the top stall contributors."""
        cp = self.critical_path()
        stalls = self.stall_us()
        by_name: Dict[str, float] = {}
        for s, st in zip(self.spans, stalls):
            if st > 0:
                by_name[s.name] = by_name.get(s.name, 0.0) + st
        # Path length as the UNION of the path spans' intervals, so a
        # parent and the children explaining its end don't double count.
        covered = 0.0
        end = -1.0
        for s in sorted(cp, key=lambda s: s.ts):
            covered += max(0.0, s.end - max(s.ts, end))
            end = max(end, s.end)
        return {
            "makespan_us": round(self.makespan_us, 3),
            "n_spans": len(self.spans),
            "critical_path": [
                {"name": s.name, "track": s.track,
                 "dur_us": round(s.dur, 3)} for s in cp],
            "critical_path_us": round(covered, 3),
            "stall_us_by_name": {k: round(v, 3)
                                 for k, v in sorted(by_name.items())},
        }


def build_dag(trace: Union[dict, Sequence[dict], str]) -> TraceDAG:
    """Parse a trace and reconstruct its span DAG."""
    return TraceDAG(parse_spans(trace))


def attribution_table(trace: Union[dict, Sequence[dict], str]
                      ) -> List[dict]:
    """Measured per-(layer, tile-block, kernel-mode) accounting.

    Layer rows aggregate the executor's ``layer<id>`` spans per
    (track, layer, kernel): wall µs, tile ops, staged bytes (joined
    from same-layer ``stage`` spans) and halo-exchange bytes (mesh),
    each attributable back to the decoded instruction index range the
    span carries.  Host-streaming ``compute`` spans additionally yield
    per-shard tile-block rows (``"shard"`` set, layer row otherwise).
    """
    spans = parse_spans(trace)
    halo_by_layer: Dict[Any, int] = {}
    for s in spans:
        if s.name == "halo_exchange" and "layer" in s.args:
            halo_by_layer[s.args["layer"]] = (
                halo_by_layer.get(s.args["layer"], 0)
                + int(s.args.get("bytes", 0)))
    rows: Dict[Tuple, dict] = {}
    for s in spans:
        a = s.args
        if s.name.startswith("layer") and "kernel" in a:
            lid = a.get("step"), int(s.name[5:])
            key = (s.track, lid[1], a["kernel"], None)
            r = rows.setdefault(key, {
                "track": s.track, "layer": lid[1], "shard": None,
                "kernel": a["kernel"], "step": a.get("step"),
                "instr_lo": a.get("instr_lo", -1),
                "instr_hi": a.get("instr_hi", -1),
                "wall_us": 0.0, "tile_ops": 0, "staged_bytes": 0,
                "halo_bytes": 0})
            r["wall_us"] += s.dur
            r["tile_ops"] += int(a.get("tile_ops", 0))
            r["staged_bytes"] += int(a.get("h2d_bytes", 0))
            r["halo_bytes"] = halo_by_layer.get(lid[1], 0)
        elif s.name == "compute" and "shard" in a:
            key = (s.track, a.get("layer"), None, a["shard"])
            r = rows.setdefault(key, {
                "track": s.track, "layer": a.get("layer"),
                "shard": a["shard"], "kernel": None, "step": None,
                "instr_lo": -1, "instr_hi": -1, "wall_us": 0.0,
                "tile_ops": 0, "staged_bytes": 0, "halo_bytes": 0})
            r["wall_us"] += s.dur
            r["tile_ops"] += int(a.get("tiles", 0))
            r["staged_bytes"] += int(a.get("staged_bytes", 0))
    out = sorted(rows.values(),
                 key=lambda r: (r["track"], r["step"] is None,
                                r["step"] or 0, r["shard"] or 0))
    for r in out:
        r["wall_us"] = round(r["wall_us"], 3)
    return out
