"""Analytic latency model for the overlay on TPU v5e.

The paper evaluates T_LoH with a cycle-accurate simulator of the Alveo
U250 design; our hardware-adapted equivalent is a roofline model over the
compiled Program: each tiling block costs
    max(flops / PEAK_FLOPS, hbm_bytes / HBM_BW)
(double-buffering overlaps the loads of block t+1 with the compute of
block t — the paper's Fig. 16 optimization — so the max, not the sum),
blocks execute on their assigned PE, and a layer ends when its slowest PE
drains (Algorithm 9 barrier).  ``overlap=False`` models the ablation
(sum instead of max).
"""
from __future__ import annotations

from typing import Dict

from .ir import LayerType
from .passes.kernel_map import Program

PEAK_FLOPS = 197e12        # bf16 MXU, per chip
VPU_FLOPS = 8e12           # vector unit (sparse modes run on gathers/VPU)
HBM_BW = 819e9


def _block_cost(kind: str, tb, pg, f_in: int, overlap: bool) -> float:
    n1, n2 = pg.config.n1, pg.config.n2
    if kind == "gemm":
        flops = 2.0 * n1 * n2 * n2 * max(len(tb.k_list), 1)
        bytes_ = (n1 * n2 * 4 * (len(tb.k_list) + 1)
                  + n2 * n2 * 4 * len(tb.k_list))
        t_c, t_m = flops / PEAK_FLOPS, bytes_ / HBM_BW
    elif kind == "spdmm":
        nnz = sum(pg.tiles[(tb.out_j, k)][s].nnz for k, s in tb.k_list) \
            if tb.k_list else 0
        flops = 2.0 * nnz * n2
        bytes_ = sum(
            pg.tiles[(tb.out_j, k)][s].cols.nbytes * 2 + n1 * n2 * 4
            for k, s in tb.k_list) + n1 * n2 * 4
        t_c, t_m = flops / VPU_FLOPS, bytes_ / HBM_BW
    elif kind == "sddmm":
        t = pg.tiles[(tb.out_j, tb.tile_k)][tb.slice_id]
        flops = 2.0 * t.nnz * f_in
        bytes_ = t.cols.nbytes * 2 + 2 * n1 * f_in * 4 + t.nnz * 4
        t_c, t_m = flops / VPU_FLOPS, bytes_ / HBM_BW
    else:  # vadd / act / affine: bandwidth bound
        bytes_ = 3.0 * n1 * n2 * 4
        t_c, t_m = bytes_ / HBM_BW / 8, bytes_ / HBM_BW
    return max(t_c, t_m) if overlap else (t_c + t_m)


def predict_loh(prog: Program, overlap: bool = True) -> float:
    """Predicted hardware-execution latency (seconds) on TPU v5e."""
    total = 0.0
    for lb in prog.layer_blocks:
        pe_time: Dict[int, float] = {}
        for tb in lb.tiling_blocks:
            c = _block_cost(tb.kind, tb, prog.pgraph, lb.layer.f_in,
                            overlap)
            pe_time[tb.pe] = pe_time.get(tb.pe, 0.0) + c
        total += max(pe_time.values(), default=0.0)
    return total
