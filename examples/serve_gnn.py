"""End-to-end driver: a GNN inference *service* on the overlay runtime.

  PYTHONPATH=src python examples/serve_gnn.py

The paper's core claim in action, at traffic scale: a pool of K fixed
compute substrates (virtual overlays) serves a STREAM of (model, graph)
requests — GCN, SAGE, GAT, SGC on different graphs — through
``repro.runtime``:

  * dynamic batching: concurrent requests that share a deployed
    (model, graph) pair are coalesced into ONE binary pass
    (features stacked on a batch axis — the mini-batch trick of
    CPU-FPGA serving systems);
  * cache-affinity routing: a repeated pair is routed to the overlay
    that already compiled its program (T_LoC = 0 on a hit), new pairs
    go to the least-loaded overlay — Algorithm 9's idle-PE rule at
    request granularity;
  * zero tile-kernel recompilation anywhere (the FPGA
    "no reconfiguration" property, XLA edition): kernels are keyed by
    tile geometry, never by model or graph.
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core import ack  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.core import reference as R  # noqa: E402
from repro.core import gnn_builders as B  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402
from repro.engine import InferenceRequest  # noqa: E402
from repro.runtime import OverlayPool, ServeLoop  # noqa: E402

# 24-request traffic mix over 4 deployed (model, graph) pairs; each pair
# is queried 6 times with fresh features — the common production shape.
# Topologies are the paper datasets (PU scaled down for one CPU core);
# deployed feature widths are capped so the per-key whole-program jit of
# the batched path stays in seconds — repeats then replay the compiled
# executable in milliseconds, which is the point of the demo.
PAIRS = [("b1", "CO"), ("b6", "CI"), ("b3", "CO"), ("b7", "PU")]
SCALE = {"CI": 0.5, "PU": 0.25}
FEAT_CAP = 128
REPEATS = 6
MAX_BATCH = 3


def build_requests():
    graphs = {}
    reqs = []
    i = 0
    for _ in range(REPEATS):
        for mname, gname in PAIRS:
            if gname not in graphs:   # one deployed graph per dataset
                g = G.synthesize(gname, scale=SCALE.get(gname, 1.0),
                                 seed=0).gcn_normalized()
                g.feat_dim = min(g.feat_dim, FEAT_CAP)
                graphs[gname] = g
            g = graphs[gname]
            x = jnp.asarray(G.random_features(g, seed=i))  # fresh features
            reqs.append(InferenceRequest(model=mname, graph=g, features=x,
                                         request_id=f"req{i}", seed=0))
            i += 1
    return reqs


def main() -> None:
    # Fixed tile geometry = the overlay contract (one "bitstream"),
    # stamped out twice: a 2-overlay pool.
    pool = OverlayPool(n_overlays=2,
                       geometry=PartitionConfig(n1=256, n2=32))
    loop = ServeLoop(pool, max_batch=MAX_BATCH, max_wait_us=50_000,
                     max_queue=64)
    requests = build_requests()

    print(f"serving {len(requests)} requests (mixed models x mixed "
          f"graphs) on {len(pool)} overlays, dynamic batching "
          f"max_batch={MAX_BATCH}...\n")
    t0 = time.perf_counter()
    try:
        responses = loop.serve(requests)
    finally:
        loop.shutdown()
    wall = time.perf_counter() - t0

    for req, r in zip(requests, responses):
        m = B.build(req.model, req.graph, req.seed)
        err = float(jnp.max(jnp.abs(
            r.output - R.run_reference(m, req.graph, req.features))))
        tag = "HIT " if r.cache_hit else "miss"
        print(f"{r.request_id:5s}: {r.model_name:10s} on {r.graph_name:2s} "
              f"(|V|={req.graph.n_vertices:5d}) ov={r.overlay} "
              f"batch={r.batch_size} cache={tag} "
              f"T_LoC={r.t_loc * 1e3:6.1f}ms  "
              f"T_LoH={r.t_loh * 1e3:7.1f}ms  err={err:.1e}")

    snap = pool.metrics.snapshot(max_batch=MAX_BATCH)
    g = snap["global"]
    print(f"\ntotals: {g['requests']} requests in {wall * 1e3:.0f} ms "
          f"wall — {g['throughput_rps']:.1f} req/s, "
          f"p50={g['p50_latency_ms']:.0f} ms, "
          f"p99={g['p99_latency_ms']:.0f} ms")
    print(f"batching: {g['batches']} binary passes for {g['requests']} "
          f"requests (mean batch {g['mean_batch_size']:.1f}, occupancy "
          f"{g['batch_occupancy']:.0%}); program-cache hit rate "
          f"{g['cache_hit_rate']:.0%}")
    print("per-overlay:", json.dumps(pool.stats_snapshot()["overlays"],
                                     indent=1))
    n_kernels = len(ack.counter_snapshot())
    print(f"distinct tile kernels compiled across ALL requests: "
          f"{n_kernels} (bounded by tile geometry, not by #models, "
          f"#graphs or batch size — the overlay property)")


if __name__ == "__main__":
    main()
