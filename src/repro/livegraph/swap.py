"""Zero-downtime version cutover for the serving runtime.

:class:`LiveGraphServer` is the *handle* a live graph is served
through: requests are built with ``graph=server`` (it quacks enough
like a :class:`~repro.core.graph.Graph` for cost estimation and
naming), and the admission points — ``ServeLoop.submit``,
``Engine.submit`` / ``submit_batch`` — resolve the handle to the
active :class:`GraphVersion` at admission time via :meth:`admit`,
which pins the version with an inflight refcount.

The cutover protocol (the "swap") is then just bookkeeping:

  1. ``apply(delta)`` builds version N+1 in the
     :class:`GraphVersionStore` (copy-on-write; O(touched tiles)) and
     atomically makes it the active version — *new* admissions route to
     N+1 immediately;
  2. requests already admitted against N keep their pin and finish on
     N's tiles — no request is ever dropped or served a half-patched
     graph (a version is immutable);
  3. when a retired version's inflight count drains to zero it is
     reclaimed: dropped from the store, its bound-program cache
     released, its uniquely-owned tiles left to the collector.  Tiles
     shared with live versions survive by reference.

Because a content-only delta keeps the structural signature, the
program-cache entry compiled for version N serves N+1 as well — the
admission path rebinds it to the new tiles (``GraphVersion.bind``)
without recompiling, so a cutover costs O(touched tiles), never T_LoC.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple

from repro.obs.tracer import get_tracer

from .delta import GraphDelta
from .versioning import GraphVersion, GraphVersionStore


class LiveGraphServer:
    """Versioned serving handle over a :class:`GraphVersionStore`."""

    def __init__(self, store: GraphVersionStore, *,
                 metrics=None) -> None:
        self.store = store
        self.metrics = metrics
        self._lock = threading.Lock()
        self._active = store.head
        self._inflight: Dict[int, int] = {self._active.vid: 0}
        self._retired: Set[int] = set()
        self._served: Dict[int, int] = {}
        self.cutovers = 0
        self.reclaimed: List[int] = []
        # Duck-type marker: the engine/runtime admission points detect a
        # live handle via `getattr(graph, "_live_server", None)`.
        self._live_server = self
        if metrics is not None:
            metrics.set_active_version(self._active.vid)

    # ------------------------------------------------------------------ #
    # Graph-ish surface: enough for request_cost / builders / naming
    # before admission resolves the handle to a concrete version.
    # ------------------------------------------------------------------ #
    @property
    def active(self) -> GraphVersion:
        with self._lock:
            return self._active

    @property
    def n_vertices(self) -> int:
        return self.active.n_vertices

    @property
    def n_edges(self) -> int:
        return self.active.live_edges

    @property
    def feat_dim(self) -> int:
        return self.active.store.feat_dim

    @property
    def n_classes(self) -> int:
        return self.active.store.n_classes

    @property
    def name(self) -> str:
        return self.active.graph_name

    # ------------------------------------------------------------------ #
    # Pinning protocol.
    # ------------------------------------------------------------------ #
    def admit(self) -> GraphVersion:
        """Pin the active version for one request; pair with
        :meth:`release` when the request completes (or fails)."""
        with self._lock:
            v = self._active
            self._inflight[v.vid] = self._inflight.get(v.vid, 0) + 1
            return v

    def release(self, vid: int, served: bool = True) -> None:
        """Unpin; reclaim a retired version once it drains."""
        with self._lock:
            left = self._inflight.get(vid, 0) - 1
            self._inflight[vid] = max(left, 0)
            if served:
                self._served[vid] = self._served.get(vid, 0) + 1
                if self.metrics is not None:
                    self.metrics.record_version_request(vid)
            if left <= 0 and vid in self._retired:
                self._reclaim(vid)

    def _reclaim(self, vid: int) -> None:
        # caller holds the lock
        self._retired.discard(vid)
        self._inflight.pop(vid, None)
        if self.store.drop(vid):
            self.reclaimed.append(vid)
            if self.metrics is not None:
                self.metrics.record_version_reclaimed(vid)
            get_tracer().instant("reclaim", cat="livegraph",
                                 track="livegraph", args={"vid": vid})

    # ------------------------------------------------------------------ #
    # Cutover.
    # ------------------------------------------------------------------ #
    def apply(self, delta: GraphDelta) -> GraphVersion:
        """Apply a delta and cut over to the new version (see module
        docstring).  Returns the new active version."""
        new = self.store.apply(delta)
        return self.cutover(new)

    def cutover(self, version: GraphVersion) -> GraphVersion:
        """Atomically retire the active version in favor of
        ``version``; drained retirees are reclaimed on the spot."""
        with self._lock:
            old = self._active
            if version.vid == old.vid:
                return old
            self._active = version
            self._inflight.setdefault(version.vid, 0)
            self._retired.discard(version.vid)   # rollback re-arms it
            self.cutovers += 1
            self._retired.add(old.vid)
            pinned_old = self._inflight.get(old.vid, 0)
            if self.metrics is not None:
                self.metrics.record_cutover(old.vid, version.vid,
                                            pinned_old=pinned_old)
            get_tracer().instant(
                "cutover", cat="livegraph", track="livegraph",
                args={"from": old.vid, "to": version.vid,
                      "pinned_old": pinned_old})
            if pinned_old <= 0:
                self._reclaim(old.vid)
            return version

    # ------------------------------------------------------------------ #
    def snapshot(self) -> dict:
        """JSON-serializable serving-side version state."""
        with self._lock:
            return {
                "active_version": self._active.vid,
                "cutovers": self.cutovers,
                "inflight": {f"v{k}": v for k, v in
                             sorted(self._inflight.items()) if v},
                "requests_per_version": {
                    f"v{k}": v for k, v in sorted(self._served.items())},
                "versions_held": len(self.store),
                "versions_reclaimed": list(self.reclaimed),
                "content_signature": self._active.content_signature,
                "structural_signature":
                    self._active.structural_signature,
            }


# --------------------------------------------------------------------------- #
# Admission-point helpers (duck-typed so engine/runtime need no import
# of this package on their hot paths).
# --------------------------------------------------------------------------- #
def resolve_version(graph) -> Optional[GraphVersion]:
    """The version a graph-ish object denotes right now: a live handle
    resolves to its active version, a materialized version graph to its
    backing version, anything else to ``None``.  Does NOT pin."""
    server = getattr(graph, "_live_server", None)
    if server is not None:
        return server.active
    return getattr(graph, "_live_version", None)


def admit(graph) -> Tuple[object, Optional[Tuple[LiveGraphServer, int]]]:
    """Admission-time resolution: live handles are pinned (admit) and
    swapped for the active version's materialized graph; everything
    else passes through.  Returns ``(graph, pin)`` — callers must
    ``pin[0].release(pin[1])`` when the request completes."""
    server = getattr(graph, "_live_server", None)
    if server is None:
        return graph, None
    version = server.admit()
    return version.as_graph(), (server, version.vid)
