"""Multi-head Latent Attention (DeepSeek-V3, arXiv:2412.19437).

Train/prefill: queries from a low-rank q projection (q_lora), K/V expanded
from the compressed latent c_kv (kv_lora) plus a shared RoPE key (qk_rope).

Decode: the *absorbed* formulation — only (c_kv, k_rope) of size
(kv_lora + qk_rope) per token is cached; per-head K expansion weights are
absorbed into the query (q~ = q_nope @ W_uk^T) and V expansion into the
output, so a decode step never materializes per-head K/V for the history.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh

from .config import ModelConfig
from .layers import Params, dense_init, rms_norm, rope


def _constrain(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """Soft sharding constraint: applied only for axes present in the
    ambient mesh and divisible dims; no-op on a single device.  Used to
    pin the MLA einsum chain to (batch->data, heads->model) — without it
    GSPMD picks contraction splits that all-reduce score-sized tensors
    inside the chunk loop (EXPERIMENTS.md §Perf, deepseek train_4k)."""
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.axis_names:
        return x
    fixed = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fixed.append(None)
            continue
        axes = (ax,) if isinstance(ax, str) else tuple(ax)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        total = 1
        for a in axes:
            total *= mesh.shape[a]
        fixed.append((axes if len(axes) > 1 else (axes[0] if axes else
                                                  None))
                     if axes and dim % max(total, 1) == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def mla_init(key, cfg: ModelConfig, dtype) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_dq": dense_init(ks[0], d, cfg.q_lora, dtype),
        "q_norm": jnp.zeros((cfg.q_lora,), dtype),
        "w_uq": dense_init(ks[1], cfg.q_lora,
                           (h, cfg.qk_nope + cfg.qk_rope), dtype),
        "w_dkv": dense_init(ks[2], d, cfg.kv_lora + cfg.qk_rope, dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora,), dtype),
        "w_uk": dense_init(ks[3], cfg.kv_lora, (h, cfg.qk_nope), dtype),
        "w_uv": dense_init(ks[4], cfg.kv_lora, (h, cfg.v_head_dim), dtype),
        "wo": dense_init(ks[5], h * cfg.v_head_dim, d, dtype),
    }


def _project_q(p, cfg, x, positions):
    q = jnp.einsum("btd,dr->btr", x, p["w_dq"])
    q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("btr,rhe->bthe", q, p["w_uq"])
    q_nope, q_rope = q[..., :cfg.qk_nope], q[..., cfg.qk_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(p, cfg, x, positions):
    ckv = jnp.einsum("btd,dr->btr", x, p["w_dkv"])
    c, k_rope = ckv[..., :cfg.kv_lora], ckv[..., cfg.kv_lora:]
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c, k_rope


def mla_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, chunk: int = 0) -> jnp.ndarray:
    """Full-sequence (train/prefill) MLA, causal; query-chunked online
    softmax when ``chunk`` divides T (bounded memory)."""
    b, t, d = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c, k_rope = _latent_kv(p, cfg, x, positions)
    k_nope = jnp.einsum("btr,rhe->bthe", c, p["w_uk"])
    v = jnp.einsum("btr,rhe->bthe", c, p["w_uv"])
    bt = ("pod", "data")
    q_nope = _constrain(q_nope, bt, None, "model", None)
    q_rope = _constrain(q_rope, bt, None, "model", None)
    k_nope = _constrain(k_nope, bt, None, "model", None)
    v = _constrain(v, bt, None, "model", None)
    scale = (cfg.qk_nope + cfg.qk_rope) ** -0.5
    kpos = positions

    # Perf (EXPERIMENTS.md §Perf, deepseek train_4k iterations): keep the
    # T-wide tensors in bf16 (f32 accumulation in the dots + f32 softmax
    # stats) — halves the score-chain HBM traffic AND the GSPMD reshard
    # collectives that live inside this chunk loop.  NOTE: rematerializing
    # this body was tried and REFUTED — recompute re-runs the in-loop
    # reshard collectives in backward (+23% collective term).
    def chunk_attn(qn, qr, pq):
        s = jnp.einsum("bqhe,bkhe->bhqk", qn, k_nope,
                       preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bqhe,bke->bhqk", qr, k_rope,
                           preferred_element_type=jnp.float32)
        s = s * scale
        mask = pq[:, None] >= kpos[None, :]
        s = jnp.where(mask[None, None], s, -2e38)
        m = jnp.max(s, axis=-1, keepdims=True)
        e = jnp.exp(s - m)
        den = jnp.sum(e, axis=-1, keepdims=True)
        o = jnp.einsum("bhqk,bkhe->bqhe", e.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o / jnp.maximum(den.swapaxes(1, 2), 1e-30)  # [b,q,h,1]

    if chunk and t > chunk and t % chunk == 0:
        nc = t // chunk
        qn_c = q_nope.reshape(b, nc, chunk, h, -1).swapaxes(0, 1)
        qr_c = q_rope.reshape(b, nc, chunk, h, -1).swapaxes(0, 1)
        pq_c = positions.reshape(nc, chunk)
        o = jax.lax.map(lambda a: chunk_attn(*a), (qn_c, qr_c, pq_c))
        o = o.swapaxes(0, 1).reshape(b, t, h, -1)
    else:
        o = chunk_attn(q_nope, q_rope, positions)
    o = o.astype(x.dtype)
    return jnp.einsum("bthe,hed->btd", o,
                      p["wo"].reshape(h, cfg.v_head_dim, d))


def mla_cache_init(batch: int, max_len: int, cfg: ModelConfig, dtype
                   ) -> Dict[str, jnp.ndarray]:
    return {
        "c": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope), dtype),
    }


def mla_decode_step(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                    cache: Dict[str, jnp.ndarray], pos: jnp.ndarray
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Absorbed decode.  x [B,1,D]; cache c [B,S,kv_lora]."""
    b, _, d = x.shape
    h = cfg.n_heads
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _project_q(p, cfg, x, posv)
    c_new, kr_new = _latent_kv(p, cfg, x, posv)
    s_len = cache["c"].shape[1]
    cc = jax.lax.dynamic_update_slice_in_dim(
        cache["c"], c_new.astype(cache["c"].dtype), pos, axis=1)
    kr = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos, axis=1)
    cache = {"c": cc, "k_rope": kr}
    # absorb: q~ [B,1,H,kv_lora]
    q_abs = jnp.einsum("bthe,rhe->bthr", q_nope, p["w_uk"])
    s = jnp.einsum("bthr,bsr->bhts", q_abs.astype(jnp.float32),
                   cc.astype(jnp.float32))
    s = s + jnp.einsum("bthe,bse->bhts", q_rope.astype(jnp.float32),
                       kr.astype(jnp.float32))
    s = s * ((cfg.qk_nope + cfg.qk_rope) ** -0.5)
    valid = jnp.arange(s_len) <= pos
    s = jnp.where(valid[None, None, None, :], s, -2e38)
    pr = jax.nn.softmax(s, axis=-1)
    # attend over the latent, then expand through W_uv (absorbed output)
    o_lat = jnp.einsum("bhts,bsr->bthr", pr, cc.astype(jnp.float32))
    o = jnp.einsum("bthr,rhe->bthe", o_lat, p["w_uv"].astype(jnp.float32))
    o = o.astype(x.dtype)
    return jnp.einsum("bthe,hed->btd", o,
                      p["wo"].reshape(h, cfg.v_head_dim, d)), cache
