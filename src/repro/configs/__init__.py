from .registry import ARCHS, get_config, get_smoke_config  # noqa: F401
