"""Geometry buckets: pad sampled subgraphs into canonical ELL layouts.

Sampled ego networks have wildly varying (|V|, |E|, max degree); compiled
one-by-one they would thrash the engine's program cache (every request
pays T_LoC).  Following Dynasparse (arXiv 2303.12901) the variability is
absorbed at *runtime* by data-layout normalization instead:

  * a :class:`Bucket` rounds (|V|, max in-degree, |E|) up to powers of
    two — the subgraph "geometry";
  * :func:`template_graph` builds ONE deterministic graph per bucket
    whose fiber-shard partition is the bucket's *canonical layout*:
    every (shard j, sub-shard k) pair present, exactly one ELL slice,
    width exactly ``bucket.width``.  The engine compiles this template
    once; its cache key is the bucket's identity;
  * :func:`layout_graph` lays ANY subgraph that fits the bucket into
    that same canonical layout as plain arrays (``graph_data``) — the
    per-request topology the executor consumes *as data*, vmapped
    across a batch.

Padding is inert by construction: empty ELL slots are zero-weight
self-referencing entries (col 0, val 0, mask off) — the blocked-ELL
equivalent of zero-weight self-edges — and padded vertices are zero
feature rows, so padded execution is bit-identical to the unpadded
subgraph run (asserted end-to-end in ``tests/test_sampling.py``).

With all requests in a bucket sharing one template graph object, the
``(model schema, graph signature, geometry)`` program-cache key collides
across users, and the runtime ``Batcher`` coalesces their requests into
one binary pass.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.core.graph import Graph
from repro.core.passes.partition import LANE, PartitionConfig


def next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class Bucket:
    """Power-of-two geometry class of a (padded) subgraph."""

    n_vertices: int      # V rounded up to a power of two (>= LANE)
    n_edges: int         # E rounded up (see bucket_for for the bounds)
    width: int           # canonical ELL width >= max in-degree
    feat_dim: int
    n_classes: int

    @property
    def key(self) -> str:
        return (f"v{self.n_vertices}-e{self.n_edges}-w{self.width}-"
                f"f{self.feat_dim}-c{self.n_classes}")

    def n_blocks(self, n1: int) -> int:
        return -(-self.n_vertices // n1)


def bucket_for(g: Graph, cfg: PartitionConfig) -> Bucket:
    """Smallest bucket that admits ``g`` under tile geometry ``cfg``.

    The canonical layout gives every (dst row, source block) pair
    ``width`` ELL slots, so it admits any subgraph whose max in-degree
    is <= ``width``; |E| is rounded up to a power of two but kept within
    [template minimum, layout capacity] so the template itself is
    constructible (see :func:`template_graph`).
    """
    v = max(next_pow2(max(g.n_vertices, 1)), LANE)
    indeg = np.bincount(g.dst, minlength=g.n_vertices) if g.n_edges \
        else np.zeros(1, np.int64)
    width = max(next_pow2(int(indeg.max())), LANE)
    if width > cfg.width_cap:
        raise ValueError(
            f"max in-degree {int(indeg.max())} needs ELL width {width} "
            f"> width_cap {cfg.width_cap}; raise the cap or lower the "
            "sampling fanouts")
    nb = -(-v // cfg.n1)
    e = next_pow2(max(g.n_edges, 1))
    e = max(e, nb * nb * width)          # template floor: fill every tile
    e = min(e, v * nb * width)           # layout capacity ceiling
    return Bucket(n_vertices=v, n_edges=e, width=width,
                  feat_dim=g.feat_dim, n_classes=g.n_classes)


def template_graph(bucket: Bucket, cfg: PartitionConfig) -> Graph:
    """The bucket's canonical compile-time graph.

    Deterministic COO whose :func:`~repro.core.passes.partition.
    partition_graph` output is exactly the canonical layout: all
    ``nb x nb`` (j, k) tile pairs populated, one ELL slice each, width
    exactly ``bucket.width`` (the width-defining run is ``width``
    parallel edges on the first row of every pair).  Edge *values* are
    placeholders — per-request topology arrives as ``graph_data``.
    """
    n1 = cfg.n1
    v, w, e = bucket.n_vertices, bucket.width, bucket.n_edges
    nb = bucket.n_blocks(n1)
    src = np.empty(e, np.int32)
    dst = np.empty(e, np.int32)
    pos = 0
    used: Dict[tuple, int] = {}
    for j in range(nb):                  # width-defining full rows
        for k in range(nb):
            src[pos:pos + w] = k * n1
            dst[pos:pos + w] = j * n1
            pos += w
            used[(j * n1, k)] = w
    for d in range(v):                   # spread the remainder
        if pos >= e:
            break
        for k in range(nb):
            room = w - used.get((d, k), 0)
            take = min(room, e - pos)
            if take <= 0:
                continue
            src[pos:pos + take] = k * n1
            dst[pos:pos + take] = d
            pos += take
            if pos >= e:
                break
    if pos != e:                         # cannot happen: e <= v * nb * w
        raise AssertionError(
            f"template for bucket {bucket.key} placed {pos}/{e} edges")
    return Graph(n_vertices=v, src=src, dst=dst,
                 weight=np.ones(e, np.float32),
                 feat_dim=bucket.feat_dim, n_classes=bucket.n_classes,
                 name=f"bucket:{bucket.key}")


def layout_graph(g: Graph, bucket: Bucket,
                 cfg: PartitionConfig) -> Dict[str, object]:
    """Lay a subgraph into the bucket's canonical layout as arrays.

    Returns the ``graph_data`` structure the binary executor consumes in
    place of the program's baked tiles::

        {"tiles": {"j:k:0": {"cols", "vals", "mask", "epos"}, ...},
         "inv_in_degree": float32 [nb * n1]}

    Edge placement mirrors ``partition_graph`` exactly — (dst, src)
    sorted, per-row slots in that order, ``epos`` = original COO edge
    index, pad slots ``epos == -1`` — so padded execution reproduces the
    unpadded program's arithmetic bit for bit.
    """
    n1 = cfg.n1
    nb = bucket.n_blocks(n1)
    w = bucket.width
    if g.n_vertices > bucket.n_vertices or g.n_edges > bucket.n_edges:
        raise ValueError(
            f"graph (V={g.n_vertices}, E={g.n_edges}) exceeds bucket "
            f"{bucket.key}")

    order = np.lexsort((g.src, g.dst))
    src = g.src[order].astype(np.int64)
    dst = g.dst[order].astype(np.int64)
    val = g.weight[order].astype(np.float32)
    eid = order.astype(np.int32)

    cols = np.zeros((nb, nb, n1, w), np.int32)
    vals = np.zeros((nb, nb, n1, w), np.float32)
    mask = np.zeros((nb, nb, n1, w), bool)
    epos = np.full((nb, nb, n1, w), -1, np.int32)

    # slot index = rank of the edge within its (dst, src-block) run,
    # computed vectorized over the (dst, src)-sorted stream.
    j = dst // n1
    k = src // n1
    run = dst * nb + k                   # (dst row, source block) run id
    if run.shape[0]:
        change = np.empty(run.shape[0], bool)
        change[0] = True
        np.not_equal(run[1:], run[:-1], out=change[1:])
        starts = np.flatnonzero(change)
        slot = np.arange(run.shape[0]) - np.repeat(
            starts, np.diff(np.append(starts, run.shape[0])))
        if slot.size and int(slot.max()) >= w:
            raise ValueError(
                f"in-degree run exceeds bucket width {w} "
                f"(bucket {bucket.key} mismatched to graph)")
        r = dst % n1
        cols[j, k, r, slot] = (src % n1).astype(np.int32)
        vals[j, k, r, slot] = val
        mask[j, k, r, slot] = True
        epos[j, k, r, slot] = eid

    tiles = {f"{jj}:{kk}:0": {
        "cols": cols[jj, kk], "vals": vals[jj, kk],
        "mask": mask[jj, kk], "epos": epos[jj, kk]}
        for jj in range(nb) for kk in range(nb)}
    indeg = np.bincount(g.dst, minlength=nb * n1).astype(np.float32)
    inv = (1.0 / np.maximum(indeg, 1.0)).astype(np.float32)
    return {"tiles": tiles, "inv_in_degree": inv}
