"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the pod axis carries
data parallelism across pods (DCN-ish), model stays within a pod (ICI).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for tests (host devices)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
