"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract)."""
from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(x: jnp.ndarray, w: jnp.ndarray,
             out_dtype=jnp.float32) -> jnp.ndarray:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32),
                   preferred_element_type=jnp.float32).astype(out_dtype)


def spdmm_ref(cols: jnp.ndarray, vals: jnp.ndarray, h: jnp.ndarray,
              out_dtype=jnp.float32) -> jnp.ndarray:
    """out[r] = sum_k vals[r,k] * h[cols[r,k]].  Zero-padded entries
    (vals == 0) contribute nothing, so no mask is needed."""
    gathered = h.astype(jnp.float32)[cols]              # [n1, w, f]
    out = jnp.sum(gathered * vals[..., None].astype(jnp.float32), axis=1)
    return out.astype(out_dtype)


def sddmm_ref(h_dst: jnp.ndarray, h_src: jnp.ndarray, cols: jnp.ndarray,
              out_dtype=jnp.float32) -> jnp.ndarray:
    """score[r,k] = <h_dst[r], h_src[cols[r,k]]> (pad entries score the
    gathered row 0 — callers mask with edge validity)."""
    gathered = h_src.astype(jnp.float32)[cols]          # [n1, w, f]
    out = jnp.einsum("rwf,rf->rw", gathered, h_dst.astype(jnp.float32))
    return out.astype(out_dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        scale: float | None = None) -> jnp.ndarray:
    """[T, H, D] single-sequence attention oracle (f32 math)."""
    q, k, v = (a.astype(jnp.float32) for a in (q, k, v))
    t, h, d = q.shape
    s = jnp.einsum("qhd,khd->hqk", q, k) * (scale or d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,khd->qhd", p, v)
