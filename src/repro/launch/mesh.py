"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the pod axis carries
data parallelism across pods (DCN-ish), model stays within a pod (ICI).
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for tests (host devices)."""
    return make_mesh((n_data, n_model), ("data", "model"))
