"""Dry-run plumbing integration test: one real cell on the production
512-device multi-pod mesh, in a subprocess (the main test process stays
single-device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow          # 512-device lower+compile in a subprocess
@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_run_cell_whisper_decode(mesh):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["DRYRUN_SAVE_HLO"] = "0"
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        rec = run_cell("whisper-base", "decode_32k", {mesh!r})
        assert rec["memory"]["per_device_total"] > 0
        assert rec["analysis"]["flops_per_device"] > 0
        assert rec["roofline"]["dominant"] in (
            "compute_s", "memory_s", "collective_s")
        assert rec["n_devices"] == (512 if {mesh!r} == "multi" else 256)
        print("OK", rec["roofline"]["dominant"])
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=580)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


def test_cell_skip_rules():
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.launch.dryrun import LONG_OK, cell_supported
    # sub-quadratic-capable archs run long_500k; pure full-attention skip
    assert cell_supported("gemma3-12b", "long_500k") is None
    assert cell_supported("hymba-1.5b", "long_500k") is None
    assert cell_supported("granite-8b", "long_500k") is not None
    assert cell_supported("deepseek-v3-671b", "long_500k") is not None
    for arch in LONG_OK:
        assert cell_supported(arch, "train_4k") is None


def test_sweep_artifacts_complete():
    """The committed sweep must cover all 10 archs x 4 shapes x 2 meshes
    (40 cells/mesh: 34 runnable + 6 recorded skips)."""
    art = os.path.join(REPO, "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("sweep artifacts not present")
    recs = []
    for f in os.listdir(art):
        if f.endswith(".json") and "__naive" not in f and \
                f.count("__") == 2:
            recs.append(json.load(open(os.path.join(art, f))))
    assert len(recs) == 80, len(recs)
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("skipped")]
    assert len(ok) == 68, len(ok)
    assert len(skipped) == 12
    assert not [r for r in recs if r.get("status") == "error"]
