"""AdamW, built in-house (offline container: no optax).

State: first/second moments in f32 + optional f32 master params when the
model runs bf16.  ZeRO-1 sharding of this state is a *sharding spec*
decision (distributed/zero.py), not an algorithm change.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    master: Optional[Any]     # f32 copy of params (None if params are f32)


def adamw_init(params, keep_master: bool = True) -> AdamWState:
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    mu = jax.tree.map(f32, params)
    nu = jax.tree.map(f32, params)
    master = None
    if keep_master:
        # explicit copy: a no-op astype would alias the param buffer and
        # break double-donation in jitted train steps
        master = jax.tree.map(
            lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params)
    return AdamWState(jnp.zeros((), jnp.int32), mu, nu, master)


def adamw_update(params, grads, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns (new_params, new_state).  Global-norm clipping, decoupled
    weight decay, bias correction; f32 math throughout."""
    gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if grad_clip > 0:
        gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gn, 1e-9))
        gf = jax.tree.map(lambda g: g * scale, gf)
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, gf)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, gf)
    ref = state.master if state.master is not None else params

    def upd(p, m, v):
        return (p - lr * (m / c1 / (jnp.sqrt(v / c2) + eps)
                          + weight_decay * p))

    new_ref = jax.tree.map(upd, jax.tree.map(
        lambda p: p.astype(jnp.float32), ref), mu, nu)
    if state.master is not None:
        new_params = jax.tree.map(
            lambda r, p: r.astype(p.dtype), new_ref, params)
        return new_params, AdamWState(step, mu, nu, new_ref)
    new_params = jax.tree.map(
        lambda r, p: r.astype(p.dtype), new_ref, params)
    return new_params, AdamWState(step, mu, nu, None)
