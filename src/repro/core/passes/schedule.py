"""Compiler Step 4b — task scheduling (paper §6.6, Algorithm 9).

GraphAGILE executes layer by layer.  Within a layer, Tiling Blocks are
assigned to PEs.  The paper does this *dynamically* (idle PE pulls the next
block); in an SPMD software overlay the equivalent is a static balanced
assignment computed at compile time: Longest-Processing-Time (LPT) greedy
bin packing on the per-block cost estimate, which equalizes per-PE work the
same way the idle-PE rule does (and is deterministic, which SPMD needs).
The dynamic behaviour lives in the host serving runtime
(``repro/runtime/serve_loop.py``): its work queue feeds whichever overlay
drains first, and ``repro/runtime/pool.py`` reuses :func:`lpt_assign`
below to place new cache keys on the least-loaded overlay — the idle-PE
rule lifted to request granularity.

Double-buffer overlap: within each PE stream, the MEM_RD instructions of
tiling block t+1 may issue while block t computes (paper's
lock/unlock-annotated WAR protection).  The executor realizes this with
async dispatch; `overlap=False` inserts a barrier after every block
(used by the Fig. 16 ablation).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

from .kernel_map import Program


@dataclasses.dataclass
class ScheduleReport:
    per_layer_imbalance: List[float]   # max/mean PE load per layer

    @property
    def worst_imbalance(self) -> float:
        return max(self.per_layer_imbalance, default=1.0)


def lpt_assign(costs: Sequence[float], n_bins: int,
               initial_loads: Optional[Sequence[float]] = None
               ) -> Tuple[List[int], List[float]]:
    """Longest-Processing-Time greedy bin packing.

    Items are visited in decreasing cost order; each goes to the
    currently least-loaded bin (ties broken by lowest bin index, so the
    assignment is deterministic).  ``initial_loads`` seeds the bins with
    pre-existing work — the serving runtime passes each overlay's
    outstanding load so new keys land on the idle overlay, mirroring the
    paper's idle-PE-pulls-next-block rule.

    Returns ``(assignment, loads)``: the bin index per item (input
    order) and the final per-bin loads.
    """
    loads = list(initial_loads) if initial_loads is not None \
        else [0.0] * n_bins
    if len(loads) != n_bins:
        raise ValueError(f"initial_loads has {len(loads)} bins, "
                         f"expected {n_bins}")
    heap = [(load, b) for b, load in enumerate(loads)]
    heapq.heapify(heap)
    assignment = [0] * len(costs)
    order = sorted(range(len(costs)), key=lambda i: (-costs[i], i))
    for i in order:
        load, b = heapq.heappop(heap)
        assignment[i] = b
        loads[b] = load + costs[i]
        heapq.heappush(heap, (loads[b], b))
    return assignment, loads


def run(prog: Program, n_pes: int = 8) -> ScheduleReport:
    """LPT-assign tiling blocks to PEs; annotate pe ids on instructions."""
    prog.n_pes = n_pes
    imbalances: List[float] = []
    for lb in prog.layer_blocks:
        tbs = lb.tiling_blocks
        assignment, loads = lpt_assign([tb.cost for tb in tbs], n_pes)
        for tb, pe in zip(tbs, assignment):
            tb.pe = pe
            for ins in tb.instrs:
                ins.pe = pe
        mean = sum(loads) / n_pes
        imbalances.append((max(loads) / mean) if mean > 0 else 1.0)
    return ScheduleReport(per_layer_imbalance=imbalances)
