"""Binary decoder: 128-bit instruction stream -> execution plan.

This is the software analogue of the overlay's Instruction Queue +
scheduler (paper §5.2): the serialized binary is split at CSI boundaries
into Layer Blocks, each Layer Block into Tiling Blocks delimited by the
FLAG_LAST MEM_WR, and every dispatch fact the executor needs — kernel
kind, output tile coordinates, reduction steps, fused epilogues, PE
assignment — is read back out of instruction fields.  No IR objects are
consulted; the ISA is load-bearing.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.ir import LayerType
from repro.core.isa import FLAG_LAST, Instr, Opcode, Region, disassemble

_COMPUTE_OPS = (Opcode.GEMM, Opcode.SPDMM, Opcode.SDDMM, Opcode.VADD)


@dataclasses.dataclass
class TilePlan:
    """One decoded Tiling Block: an inseparable sequence for one PE."""

    pe: int
    compute: List[Instr]                 # compute instrs, stream order
    epilogue: List[Tuple[str, int]]      # ("affine", 0) / ("act", act_id)
    out_i: int = -1                      # output fiber (vertex-valued)
    out_j: int = -1                      # output row-block / shard row
    tile_k: int = -1                     # edge-valued: source block
    slice_id: int = 0                    # edge-valued: ELL width slice
    instr_lo: int = -1                   # first instruction index of block
    instr_hi: int = -1                   # FLAG_LAST MEM_WR index (inclusive)


@dataclasses.dataclass
class LayerPlan:
    """One decoded Layer Block (CSI + its tiling blocks)."""

    layer_id: int
    layer_type: LayerType
    f_in: int
    f_out: int
    mode: int            # CSI act field: AggOp / Activation / pair-sum
    act_enabled: bool
    on_edges: bool
    tiles: List[TilePlan]
    instr_lo: int = -1                   # CSI instruction index
    instr_hi: int = -1                   # last instruction index (inclusive)


@dataclasses.dataclass
class ExecutionPlan:
    layers: List[LayerPlan]

    @property
    def n_layers(self) -> int:
        return len(self.layers)


def _close_tile(layer: LayerPlan, instrs: List[Instr],
                base: int = -1) -> TilePlan:
    lt = layer.layer_type
    standalone_act = lt in (LayerType.ACTIVATION, LayerType.BATCHNORM)
    compute: List[Instr] = []
    epilogue: List[Tuple[str, int]] = []
    tp = TilePlan(pe=0, compute=compute, epilogue=epilogue)
    for off, ins in enumerate(instrs):
        if ins.op in _COMPUTE_OPS:
            compute.append(ins)
        elif ins.op in (Opcode.ACT, Opcode.AFFINE):
            if standalone_act:
                compute.append(ins)
            elif ins.op == Opcode.AFFINE:
                epilogue.append(("affine", 0))
            else:
                epilogue.append(("act", ins.act))
        elif ins.op == Opcode.MEM_WR:
            tp.pe = ins.pe
            try:
                region = Region(ins.args[1])
            except ValueError:
                where = base + off if base >= 0 else off
                raise ValueError(
                    f"malformed program: instruction {where} MEM_WR "
                    f"names unknown region {ins.args[1]} (valid: "
                    f"0..{max(Region)})") from None
            if region == Region.OUT_SUBFIBER:
                tp.out_i, tp.out_j = ins.args[2], ins.args[3]
            else:                                   # OUT_EDGE: (j, k)
                tp.out_j, tp.tile_k = ins.args[2], ins.args[3]
    # Edge-valued kernels carry the ELL slice in their compute instr.
    if compute and compute[0].op == Opcode.SDDMM:
        tp.slice_id = compute[0].args[3]
    elif compute and standalone_act and layer.on_edges:
        tp.slice_id = compute[0].args[3]
    return tp


def decode_program(instrs: List[Instr]) -> ExecutionPlan:
    """Group a decoded instruction list into layer/tiling blocks."""
    layers: List[LayerPlan] = []
    current: Optional[LayerPlan] = None
    pending: List[Instr] = []
    pending_lo = -1                      # stream index of pending[0]
    expected: List[int] = []             # CSI-announced tiling block counts
    for idx, ins in enumerate(instrs):
        if ins.op == Opcode.HALT:
            break
        if ins.op == Opcode.CSI:
            try:
                layer_type = LayerType(ins.args[1])
            except ValueError:
                raise ValueError(
                    f"malformed program: instruction {idx} CSI "
                    f"announces unknown layer type {ins.args[1]} "
                    f"(valid: 0..{max(LayerType)})") from None
            current = LayerPlan(
                layer_id=ins.args[0],
                layer_type=layer_type,
                f_in=ins.args[2], f_out=ins.args[3],
                mode=ins.act, act_enabled=ins.act_en,
                on_edges=ins.on_edges, tiles=[],
                instr_lo=idx, instr_hi=idx)
            layers.append(current)
            expected.append(ins.arg4)
            pending = []
            pending_lo = -1
            continue
        if current is None:
            raise ValueError(
                f"malformed program: {ins.op.name} before the first CSI")
        if not pending:
            pending_lo = idx
        pending.append(ins)
        current.instr_hi = idx
        if ins.op == Opcode.MEM_WR and ins.flags & FLAG_LAST:
            tp = _close_tile(current, pending, base=pending_lo)
            tp.instr_lo, tp.instr_hi = pending_lo, idx
            current.tiles.append(tp)
            pending = []
            pending_lo = -1
    for lp, n in zip(layers, expected):
        if len(lp.tiles) != n:
            raise ValueError(
                f"malformed program: layer {lp.layer_id} announces {n} "
                f"tiling blocks but {len(lp.tiles)} were decoded")
    return ExecutionPlan(layers=layers)


def decode_binary(binary: bytes) -> ExecutionPlan:
    return decode_program(disassemble(binary))
