"""Degrade gracefully when hypothesis is absent (it lives in the optional
``[test]`` extra): property tests skip individually, while the plain
tests in the same module still run.

Usage in a test module::

    from _hypothesis_compat import given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # pragma: no cover - env dependent
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `hypothesis.strategies` at decoration time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*a, **k):
        return lambda f: f

    def given(*a, **k):
        def deco(f):
            return pytest.mark.skip(
                reason="hypothesis not installed (pip install .[test])")(f)
        return deco
