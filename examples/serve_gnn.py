"""End-to-end driver: a GNN inference service on the overlay.

  PYTHONPATH=src python examples/serve_gnn.py

The paper's core claim in action: one fixed compute substrate serves a
STREAM of (model, graph) requests — GCN, SAGE, GAT, SGC on different
graphs — through ``Engine.serve``: per-request software compilation in
milliseconds, ZERO recompilation of the tile executables (the FPGA
"no reconfiguration" property, XLA edition), and an LRU *program* cache
on top: repeated (model, graph) pairs — the common shape of production
traffic, same deployed model queried with fresh features — skip software
compilation entirely (T_LoC = 0 on a hit).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core import ack  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.core import reference as R  # noqa: E402
from repro.core import gnn_builders as B  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402
from repro.engine import Engine, InferenceRequest  # noqa: E402

# The 8-request mix: 4 distinct (model, graph) pairs, each hit twice with
# different query features — the second occurrence must be a cache hit.
MIX = [("b1", "CO"), ("b6", "CI"), ("b3", "CO"), ("b7", "PU"),
       ("b1", "CO"), ("b6", "CI"), ("b3", "CO"), ("b7", "PU")]


def build_requests():
    graphs = {}
    reqs = []
    for i, (mname, gname) in enumerate(MIX):
        if gname not in graphs:   # one deployed graph per dataset
            graphs[gname] = G.synthesize(gname, seed=0).gcn_normalized()
        g = graphs[gname]
        x = jnp.asarray(G.random_features(g, seed=i))   # fresh features
        reqs.append(InferenceRequest(model=mname, graph=g, features=x,
                                     request_id=f"req{i}", seed=0))
    return reqs


def main() -> None:
    # Fixed tile geometry = the overlay contract (one "bitstream").
    engine = Engine(geometry=PartitionConfig(n1=256, n2=32))
    requests = build_requests()

    print(f"serving {len(requests)} requests "
          f"(mixed models x mixed graphs, one overlay, LRU program "
          f"cache)...\n")
    t0 = time.perf_counter()
    responses = engine.serve(requests)
    wall = time.perf_counter() - t0

    for req, r in zip(requests, responses):
        m = B.build(req.model, req.graph, req.seed)
        err = float(jnp.max(jnp.abs(
            r.output - R.run_reference(m, req.graph, req.features))))
        tag = "HIT " if r.cache_hit else "miss"
        print(f"{r.request_id}: {r.model_name:10s} on {r.graph_name:2s} "
              f"(|V|={req.graph.n_vertices:5d}) cache={tag} "
              f"T_LoC={r.t_loc * 1e3:6.1f}ms  "
              f"T_LoH={r.t_loh * 1e3:7.1f}ms  err={err:.1e}")

    s = engine.stats
    no_cache_t_loc = sum(
        p.t_loc for p in engine.cache.values()) * 2        # each pair x2
    print(f"\ntotals: {s.requests} requests in {wall * 1e3:.0f} ms wall — "
          f"{s.cache_hits} cache hits, {s.cache_misses} misses, "
          f"{s.compiles} compiles")
    print(f"compile time paid: {s.total_t_loc * 1e3:.1f} ms "
          f"(no-cache baseline would pay ~{no_cache_t_loc * 1e3:.1f} ms)")
    n_kernels = len(ack.compile_counter)
    print(f"distinct tile kernels compiled across ALL requests: "
          f"{n_kernels} (bounded by tile geometry, not by #models or "
          f"#graphs — the overlay property)")


if __name__ == "__main__":
    main()
