"""Sharded checkpointing with atomic commits and elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json        (step, keys, shapes, dtypes, mesh, flag)
            <flat-key>.npy       (one file per leaf; host-gathered)

Fault-tolerance contract:
  * atomic: written to step_<N>.tmp, fsync'd, renamed — a crash mid-save
    never corrupts the latest complete checkpoint;
  * resumable: ``latest_step`` only returns directories whose manifest
    carries the "complete" flag;
  * elastic: leaves are saved unsharded (host-gathered), so a run saved
    on N chips restores onto any M-chip mesh — ``restore`` device_puts
    each leaf with the *target* sharding;
  * bounded: ``keep`` retains the most recent checkpoints only.

On a real multi-host pod, the same format shards the save across hosts
(each host writes leaves it owns; the manifest lists per-leaf owners) —
the single-host path here is the degenerate case.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

_SEP = "__"
# dtypes numpy can't round-trip natively: stored as raw views
_VIEW_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16)}


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree: Any,
         meta: Optional[Dict[str, Any]] = None, keep: int = 3) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        logical = str(arr.dtype)
        if logical in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[logical][1])
        np.save(os.path.join(tmp, key + ".npy"), arr)
        manifest["leaves"][key] = {"shape": list(arr.shape),
                                   "dtype": logical}
    manifest["complete"] = True
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        mpath = os.path.join(ckpt_dir, name, "manifest.json")
        try:
            with open(mpath) as f:
                if json.load(f).get("complete"):
                    out.append(int(m.group(1)))
        except (OSError, json.JSONDecodeError):
            continue
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None
            ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure of ``target_tree``; reshard onto the
    current mesh via ``shardings`` (pytree of NamedSharding) if given."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no complete checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_target = _flatten(target_tree)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    loaded = {}
    for key, ref in flat_target.items():
        arr = np.load(os.path.join(d, key + ".npy"))
        logical = manifest["leaves"].get(key, {}).get("dtype",
                                                      str(arr.dtype))
        if logical in _VIEW_DTYPES:
            arr = arr.view(_VIEW_DTYPES[logical][0])
        if key in flat_sh:
            loaded[key] = jax.device_put(arr, flat_sh[key])
        else:
            loaded[key] = jax.numpy.asarray(arr)
    # rebuild tree in target structure
    paths, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    keys = [_SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path) for path, _ in paths]
    leaves = [loaded[k] for k in keys]
    return (jax.tree_util.tree_unflatten(treedef, leaves), step,
            manifest.get("meta", {}))
