"""Roofline report: aggregate dry-run artifacts into the §Dry-run and
§Roofline tables of EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]

Per (arch x shape x mesh): the three roofline terms (seconds), the
dominant term, MODEL_FLOPS (6*N*D train / 2*N*D decode+prefill, N =
active params), the useful-compute ratio MODEL_FLOPS / HLO_FLOPs, and a
one-line "what would move the dominant term" note.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e)
HBM_BW = 819e9          # B/s / chip
ICI_BW = 50e9           # B/s / link

_MOVE_NOTES = {
    "compute_s": ("raise MXU utilization: larger per-device batch or "
                  "less recompute (remat policy)"),
    "memory_s": ("cut HBM traffic: fuse epilogues, chunk the loss, "
                 "avoid f32 round-trips, smaller attention chunks"),
    "collective_s": ("reshard to cut collectives: different einsum "
                     "order, overlap a2a with expert compute, "
                     "hierarchical reduction over pod axis"),
}


def model_flops(rec: Dict) -> float:
    n_active = rec.get("n_active_params", 0)
    if rec["kind"] == "train":
        return 6.0 * n_active * rec["tokens"]
    if rec["kind"] == "prefill":
        return 2.0 * n_active * rec["tokens"]
    # decode: one token per sequence in the batch
    return 2.0 * n_active * rec["tokens"]


def load(art_dir: str, mesh: Optional[str] = None) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        if "__naive" in f or "__tag" in f:
            continue
        r = json.load(open(f))
        if mesh and r.get("mesh") != mesh:
            continue
        out.append(r)
    return out


def fmt_row(r: Dict) -> str:
    if r.get("skipped"):
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                f"skip: {r['skipped'][:42]}… |")
    if r.get("status") != "ok":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                "ERROR |")
    rf = r["roofline"]
    mf = model_flops(r)
    n_dev = r["n_devices"]
    hlo_flops_total = r["analysis"]["flops_per_device"] * n_dev
    ratio = mf / hlo_flops_total if hlo_flops_total else 0.0
    dom = rf["dominant"].replace("_s", "")
    mem_gib = r["memory"]["per_device_total"] / 2 ** 30
    return (f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.4f} | "
            f"{rf['memory_s']:.4f} | {rf['collective_s']:.4f} | "
            f"**{dom}** | {ratio:.2f} | {mem_gib:.1f} GiB |")


def dominant_note(r: Dict) -> str:
    return _MOVE_NOTES[r["roofline"]["dominant"]]


def report(art_dir: str) -> str:
    lines = []
    lines.append("### Single-pod (16x16 = 256 chips) roofline, "
                 "per (arch x shape)\n")
    lines.append("| arch | shape | compute (s) | memory (s) | "
                 "collective (s) | bottleneck | 6ND/HLO | mem/dev |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in load(art_dir, "single"):
        lines.append(fmt_row(r))
    lines.append("")
    lines.append("### Multi-pod (2x16x16 = 512 chips) — compile proof + "
                 "roofline\n")
    lines.append("| arch | shape | compute (s) | memory (s) | "
                 "collective (s) | bottleneck | 6ND/HLO | mem/dev |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for r in load(art_dir, "multi"):
        lines.append(fmt_row(r))
    return "\n".join(lines)


def reanalyze(art_dir: str) -> None:
    """Re-run the HLO static analysis from stored .hlo.gz artifacts
    (analyzer improvements without recompiling 80 cells)."""
    import gzip

    from repro.launch.hlo_analysis import analyze
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("status") != "ok":
            continue
        hp = f.replace(".json", ".hlo.gz")
        if not os.path.exists(hp):
            continue
        with gzip.open(hp, "rt") as fh:
            hlo = fh.read()
        costs = analyze(hlo, r["n_devices"])
        r["analysis"] = {
            "flops_per_device": costs.flops,
            "hbm_bytes_per_device": costs.hbm_bytes,
            "collective_bytes_per_device": costs.collective_bytes,
            "total_collective_bytes_per_device":
                costs.total_collective_bytes,
            "unknown_trip_whiles": costs.unknown_trip_whiles,
        }
        r["roofline"] = {
            "compute_s": costs.flops / PEAK_FLOPS,
            "memory_s": costs.hbm_bytes / HBM_BW,
            "collective_s": costs.total_collective_bytes / ICI_BW,
        }
        r["roofline"]["dominant"] = max(
            ("compute_s", "memory_s", "collective_s"),
            key=r["roofline"].get)
        with open(f, "w") as fh:
            json.dump(r, fh, indent=1)
        print(f"[reanalyzed] {os.path.basename(f)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "artifacts",
        "dryrun"))
    ap.add_argument("--reanalyze", action="store_true")
    args = ap.parse_args()
    if args.reanalyze:
        reanalyze(args.dir)
        return
    print(report(args.dir))


if __name__ == "__main__":
    main()
