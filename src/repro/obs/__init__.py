"""repro.obs — observability for the GraphAGILE stack.

Four parts:

* :mod:`repro.obs.tracer` — structured tracing (nestable spans,
  counters, instant events) exported as Chrome/Perfetto trace-event
  JSON, threaded through the compiler passes, every executor residency
  path, and the serving runtime.  Zero overhead when disabled.
* :mod:`repro.obs.attrib` — trace analysis: span-DAG reconstruction,
  critical path, per-span slack/stall, and the measured
  per-(layer, tile-block, kernel-mode) attribution table.
* :mod:`repro.obs.conformance` — measured-vs-predicted cost
  accounting: joins :mod:`repro.core.perfmodel` per-layer predictions
  with executor measurements, fits effective machine constants, and
  emits the ``ConformanceReport`` CI consumes.
* :mod:`repro.obs.trajectory` — per-metric tolerance-band comparison
  of fresh BENCH_*.json artifacts against committed baselines, the
  engine behind the ``benchmarks/check_trajectory.py`` CI gate.
"""
from .attrib import Span, TraceDAG, attribution_table, build_dag, \
    parse_spans
from .conformance import (ConformanceReport, build_report, fit_stage_bw,
                          ls_scale, nrmse)
from .tracer import (NullTracer, Tracer, disable_tracing,
                     enable_tracing, get_tracer, set_tracer, tracing)
from .trajectory import (DEFAULT_SPECS, FileReport, MetricResult,
                         MetricSpec, TrajectoryReport, compare_dirs,
                         compare_docs, compare_metrics, lookup)

__all__ = [
    "Tracer", "NullTracer", "get_tracer", "set_tracer",
    "enable_tracing", "disable_tracing", "tracing",
    "Span", "TraceDAG", "parse_spans", "build_dag",
    "attribution_table",
    "ConformanceReport", "build_report", "ls_scale", "nrmse",
    "fit_stage_bw",
    "MetricSpec", "MetricResult", "FileReport", "TrajectoryReport",
    "DEFAULT_SPECS", "compare_metrics", "compare_docs", "compare_dirs",
    "lookup",
]
