"""Serving benchmark: sequential Engine.serve vs batched OverlayPool.

  PYTHONPATH=src python benchmarks/bench_serve.py [--smoke]

Measures the traffic layer PR 2 added on top of the single-request
engine: the same request stream is served (a) one at a time by one
Engine and (b) by a K-overlay pool with dynamic batching — same
programs, one binary pass per batch.  Two traffic shapes:

  * ``same_key`` — one deployed (model, graph) pair queried repeatedly
    with fresh features (the batcher's best case: every flush is full);
  * ``mixed``    — four deployed pairs interleaved (batches form per
    key; cache-affinity routing spreads keys across overlays).

Both paths are warmed first (programs compiled, tile kernels jitted for
the shapes each path uses), so the timed pass measures steady-state
serving throughput.  Results land in ``BENCH_serve.json`` at the repo
root: throughput, p50/p99 latency, program-cache hit rate, batch
occupancy, and the batched/sequential speedup per traffic shape.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

try:                                    # script: python benchmarks/bench_serve.py
    from common import provenance, verify_section
except ImportError:                     # module: python -m benchmarks.bench_serve
    from benchmarks.common import provenance, verify_section

from repro.core import graph as G  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402
from repro.engine import Engine, InferenceRequest  # noqa: E402
from repro.runtime import Metrics, OverlayPool, ServeLoop  # noqa: E402
from repro.runtime.metrics import percentile  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def make_graphs(smoke: bool, seed: int):
    if smoke:
        ga = G.random_graph(120, 480, seed=11 + seed).gcn_normalized()
        gb = G.random_graph(150, 600, seed=12 + seed).gcn_normalized()
        ga.feat_dim, ga.n_classes = 16, 4
        gb.feat_dim, gb.n_classes = 16, 4
        ga.name, gb.name = "SA", "SB"
    else:
        ga = G.synthesize("CI", seed=seed).gcn_normalized()
        gb = G.synthesize("CO", seed=seed).gcn_normalized()
    return ga, gb


def make_traffic(shape: str, n: int, ga, gb,
                 seed: int) -> List[InferenceRequest]:
    pairs = [("b1", ga)] if shape == "same_key" else \
        [("b1", ga), ("b6", gb), ("b7", ga), ("b3", gb)]
    reqs = []
    for i in range(n):
        m, g = pairs[i % len(pairs)]
        x = jnp.asarray(G.random_features(g, seed=1000 + seed + i))
        reqs.append(InferenceRequest(model=m, graph=g, features=x,
                                     request_id=f"{shape}{i}"))
    return reqs


def bench_sequential(geom, reqs, n_pes: int) -> dict:
    eng = Engine(geometry=geom, n_pes=n_pes)
    eng.serve(reqs)                       # warm: programs + tile kernels
    h0, n0 = eng.stats.cache_hits, eng.stats.requests
    t0 = time.perf_counter()
    resps = eng.serve(reqs)
    wall = time.perf_counter() - t0
    lats = [r.t_loc + r.t_loh for r in resps]
    return {
        "wall_s": round(wall, 6),
        "throughput_rps": round(len(reqs) / wall, 3),
        "p50_ms": round(percentile(lats, 50) * 1e3, 3),
        "p99_ms": round(percentile(lats, 99) * 1e3, 3),
        "cache_hit_rate": round(
            (eng.stats.cache_hits - h0) / (eng.stats.requests - n0), 6),
        "binary_passes": len(reqs),
    }


def bench_batched(geom, reqs, n_pes: int, n_overlays: int,
                  max_batch: int) -> dict:
    pool = OverlayPool(n_overlays=n_overlays, geometry=geom, n_pes=n_pes)
    # warm with the real traffic once: programs compiled, batched-shape
    # tile kernels jitted, affinity established
    pool.serve(reqs, max_batch=max_batch, max_wait_us=1e6)
    metrics = Metrics()
    loop = ServeLoop(pool, max_batch=max_batch, max_wait_us=1e6,
                     max_queue=4 * max_batch * max(1, n_overlays),
                     metrics=metrics)
    try:
        t0 = time.perf_counter()
        loop.serve(reqs)
        wall = time.perf_counter() - t0
    finally:
        loop.shutdown()
    snap = metrics.snapshot(max_batch=max_batch)["global"]
    return {
        "wall_s": round(wall, 6),
        "throughput_rps": round(len(reqs) / wall, 3),
        "p50_ms": snap["p50_latency_ms"],
        "p99_ms": snap["p99_latency_ms"],
        "cache_hit_rate": snap["cache_hit_rate"],
        "batch_occupancy": snap["batch_occupancy"],
        "binary_passes": snap["batches"],
    }


def run(smoke: bool, n_requests: int, n_overlays: int, max_batch: int,
        out_path: str, seed: int = 0) -> dict:
    geom = PartitionConfig(n1=32, n2=8) if smoke \
        else PartitionConfig(n1=256, n2=32)
    n_pes = 4 if smoke else 8
    ga, gb = make_graphs(smoke, seed)
    report: dict = {
        "benchmark": "bench_serve",
        "mode": "smoke" if smoke else "full",
        "requests_per_shape": n_requests,
        "overlays": n_overlays,
        "max_batch": max_batch,
        "provenance": provenance(seed),
        "traffic": {},
    }
    print("shape,path,wall_s,throughput_rps,p50_ms,p99_ms")
    for shape in ("same_key", "mixed"):
        reqs = make_traffic(shape, n_requests, ga, gb, seed)
        seq = bench_sequential(geom, reqs, n_pes)
        bat = bench_batched(geom, reqs, n_pes, n_overlays, max_batch)
        speedup = bat["throughput_rps"] / seq["throughput_rps"] \
            if seq["throughput_rps"] else 0.0
        report["traffic"][shape] = {
            "sequential": seq, "batched": bat,
            "batched_speedup": round(speedup, 3),
        }
        for path, r in (("sequential", seq), ("batched", bat)):
            print(f"{shape},{path},{r['wall_s']},{r['throughput_rps']},"
                  f"{r['p50_ms']},{r['p99_ms']}")
        print(f"{shape},speedup,{speedup:.3f}x,,,")
    # Static verification of every (model, graph) program the mixed
    # traffic exercises — semantic trajectory metrics, not wall time.
    report["verify"] = verify_section(
        Engine(geometry=geom, n_pes=n_pes),
        [("b1", ga), ("b6", gb), ("b7", ga), ("b3", gb)])
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graphs + short stream (CI gate)")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per traffic shape")
    ap.add_argument("--overlays", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0,
                    help="offsets graph/feature seeds; recorded in the "
                         "report provenance")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_serve.json"))
    args = ap.parse_args()
    n = args.requests if args.requests is not None \
        else (16 if args.smoke else 64)
    run(args.smoke, n, args.overlays, args.max_batch, args.out,
        seed=args.seed)


if __name__ == "__main__":
    main()
