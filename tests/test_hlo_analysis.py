"""HLO static analyzer: trip-count-aware FLOP/byte/collective accounting
(the §Roofline engine) verified against constructed programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import _shape_bytes, _split_args, analyze


def _compile_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


def test_scan_flops_are_trip_multiplied():
    def f(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        y, _ = jax.lax.scan(body, x, w)
        return y.sum()

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((8, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((16, 64), jnp.float32))
    c = analyze(txt, 1)
    dot_flops = 2 * 16 * 64 * 64 * 8
    assert 0.9 * dot_flops <= c.flops <= 1.6 * dot_flops, c.flops
    # XLA's own cost_analysis undercounts by ~the layer count:
    xla = jax.jit(f).lower(
        jax.ShapeDtypeStruct((8, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((16, 64), jnp.float32)).compile()
    ca = xla.cost_analysis()   # dict (new jax) or list-of-dicts (old jax)
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    assert (ca or {}).get("flops", 0) < 0.3 * c.flops


def test_nested_scan_multiplicity():
    def f(x):
        def outer(c, _):
            def inner(d, _):
                return jnp.tanh(d @ d.T @ d), None
            d, _ = jax.lax.scan(inner, c, None, length=4)
            return d, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y.sum()

    txt = _compile_text(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    c = analyze(txt, 1)
    per_iter = 2 * 2 * 32 * 32 * 32      # two dots
    want = per_iter * 12                  # 3 x 4 iterations
    assert 0.9 * want <= c.flops <= 1.5 * want, (c.flops, want)


def test_shape_bytes():
    assert _shape_bytes("f32[4,8]{1,0}") == 128
    assert _shape_bytes("bf16[10]") == 20
    assert _shape_bytes("(f32[2,2], s32[4])") == 32
    assert _shape_bytes("pred[]") == 1


def test_split_args_nested():
    assert _split_args("%a, %b") == ["%a", "%b"]
    assert _split_args("f32[1,2]{1,0} %a, (s32[], f32[2]) %b") == \
        ["f32[1,2]{1,0} %a", "(s32[], f32[2]) %b"]


def test_dynamic_slice_counts_slice_not_buffer():
    """Per-iteration weight slices must not charge the stacked buffer."""
    def f(w, x):
        def body(c, i):
            wl = jax.lax.dynamic_index_in_dim(w, i, keepdims=False)
            return c * wl, None
        y, _ = jax.lax.scan(body, x, jnp.arange(64))
        return y.sum()

    txt = _compile_text(
        f, jax.ShapeDtypeStruct((64, 128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32))
    c = analyze(txt, 1)
    full_buffer_per_iter = 64 * 128 * 128 * 4 * 64
    assert c.hbm_bytes < 0.5 * full_buffer_per_iter, c.hbm_bytes


def test_collectives_counted():
    import os
    import subprocess
    import sys
    import textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        from repro.launch.hlo_analysis import analyze
        mesh = make_mesh((8,), ("d",))
        def f(x):
            return (x @ x.T).sum()
        sh = NamedSharding(mesh, P(None, "d"))
        co = jax.jit(f, in_shardings=(sh,)).lower(
            jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
        c = analyze(co.as_text(), 8)
        assert c.total_collective_bytes > 0, c.collective_bytes
        print("COLL", c.collective_bytes)
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLL" in out.stdout
