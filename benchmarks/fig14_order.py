"""Paper Fig. 14: impact of computation-order optimization on T_LoH.
``derived`` = speedup (the paper reports 82%/9.6%/.../260%/0% on b1-b8;
exact values depend on the IR decomposition — trends must match:
large for b1/b7, zero for b8)."""
from __future__ import annotations

from .common import (Engine, MODELS, dataset, emit, features, run_model)

GRAPHS = [("CO", 1.0), ("PU", 1.0)]


def run(quick: bool = False) -> None:
    graphs = GRAPHS[:1] if quick else GRAPHS
    models = ["b1", "b2", "b7", "b8"] if quick else MODELS
    engine = Engine()
    for bname in models:
        for dname, scale in graphs:
            g = dataset(dname, scale)
            x = features(g)
            _, t_on, _, prog_on, p_on = run_model(
                bname, g, x, engine, order_opt=True)
            _, t_off, _, prog_off, p_off = run_model(
                bname, g, x, engine, order_opt=False)
            label = dname if scale == 1.0 else f"{dname}@{scale:g}"
            rep = prog_on.source.order_report
            emit([f"fig14,{bname}/{label},{t_on * 1e6:.0f},"
                  f"speedup={(t_off / t_on - 1) * 100:.1f}%;"
                  f"pred_speedup={(p_off / p_on - 1) * 100:.1f}%;"
                  f"cc_red={rep.reduction * 100:.1f}%"])
