"""Compiler Step 4a — kernel mapping (paper §6.6).

Each IR layer becomes a **Layer Block**: one Control-and-Scheduling
Instruction (CSI) plus a set of **Tiling Blocks** obtained by unfolding the
outer loops of the partition-centric execution scheme (Algorithms 6-8).
A Tiling Block is an inseparable instruction sequence executed by one PE.

Mode selection: Aggregate -> SpDMM mode, Linear -> GEMM mode,
Vector-Inner -> SDDMM mode, Vector-Add -> vector-addition mode,
standalone Activation/BatchNorm -> ACT/AFFINE epilogue instructions.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

from ..ir import Activation, LayerIR, LayerType, ModelIR
from ..isa import (FLAG_ACC, FLAG_LAST, FLAG_LOCK, FLAG_UNLOCK, Buf, Instr,
                   Opcode, Region)
from .partition import PartitionedGraph


@dataclasses.dataclass
class TilingBlock:
    layer_id: int
    kind: str                      # spdmm | gemm | sddmm | vadd | act | affine
    out_i: int                     # output fiber index (or -1)
    out_j: int                     # output row-block / shard-row (or -1)
    k_list: List[Tuple[int, int]]  # reduction steps: (block, slice) pairs
    cost: float                    # scheduler load estimate
    instrs: List[Instr] = dataclasses.field(default_factory=list)
    pe: int = 0                    # assigned by the scheduler
    tile_k: int = -1               # sddmm: source block index
    slice_id: int = 0              # sddmm: ELL width slice index


@dataclasses.dataclass
class LayerBlock:
    layer_id: int
    layer: LayerIR
    csi: Instr
    tiling_blocks: List[TilingBlock]


@dataclasses.dataclass
class Program:
    model: ModelIR
    pgraph: PartitionedGraph
    layer_blocks: List[LayerBlock]
    n_pes: int
    f_pad: Dict[int, Tuple[int, int]]  # layer -> (padded f_in, padded f_out)

    def all_instrs(self) -> List[Instr]:
        out: List[Instr] = []
        for lb in self.layer_blocks:
            out.append(lb.csi)
            for tb in lb.tiling_blocks:
                out.extend(tb.instrs)
        out.append(Instr(Opcode.HALT))
        return out

    def instruction_count(self) -> int:
        return len(self.all_instrs())


def _epilogue(l: LayerIR, instrs: List[Instr], on_edges: bool) -> None:
    """Fused scale/shift + activation epilogue instructions."""
    if "fused_scale" in l.attrs:
        instrs.append(Instr(Opcode.AFFINE, on_edges=on_edges,
                            args=(l.layer_id, 0, 0, 0)))
    if "fused_act" in l.attrs:
        instrs.append(Instr(Opcode.ACT, act=int(l.attrs["fused_act"]),
                            act_en=True, on_edges=on_edges,
                            args=(l.layer_id, 0, 0, 0)))


def map_layer(
    l: LayerIR, pg: PartitionedGraph, nb: int
) -> List[TilingBlock]:
    cfg = pg.config
    n1, n2 = cfg.n1, cfg.n2
    fi = max(1, math.ceil(l.f_in / n2))
    fo = max(1, math.ceil(l.f_out / n2))
    blocks: List[TilingBlock] = []

    if l.layer_type == LayerType.AGGREGATE:
        dyn = 1 if "edge_weight_layer" in l.attrs else 0
        for i in range(fi):                      # fiber loop  (Alg. 6 line 2)
            for j in range(nb):                  # shard loop  (Alg. 6 line 3)
                ks: List[Tuple[int, int]] = []
                ins: List[Instr] = []
                nnz_total = 0
                for k in range(nb):
                    for s, t in enumerate(pg.tiles.get((j, k), [])):
                        ins.append(Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                                         args=(Buf.EDGE, Region.SUBSHARD,
                                               j, k), arg4=t.nnz))
                        ins.append(Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                                         args=(Buf.FEATURE, Region.SUBFIBER,
                                               i, k)))
                        if dyn:
                            ins.append(Instr(
                                Opcode.MEM_RD,
                                args=(Buf.EDGE, Region.EDGE_WEIGHTS, j, k)))
                        acc = FLAG_ACC if ks else 0
                        # args[3] packs (ELL slice << 1 | dyn) so the
                        # runtime can address pg.tiles[(j, k)][s].
                        ins.append(Instr(Opcode.SPDMM,
                                         flags=FLAG_UNLOCK | acc,
                                         args=(j, k, i, (s << 1) | dyn),
                                         arg4=t.nnz))
                        ks.append((k, s))
                        nnz_total += t.nnz
                _epilogue(l, ins, on_edges=False)
                ins.append(Instr(Opcode.MEM_WR, flags=FLAG_LAST,
                                 args=(Buf.RESULT, Region.OUT_SUBFIBER,
                                       i, j)))
                # Load estimate in the same units as the sddmm branch and
                # the conformance oracle (work scales with the layer's
                # feature width, not the padded fiber tile).
                blocks.append(TilingBlock(
                    l.layer_id, "spdmm", i, j, ks,
                    cost=max(nnz_total, 1) * l.f_in, instrs=ins))

    elif l.layer_type == LayerType.LINEAR:
        for i in range(fo):                      # output fiber
            for j in range(nb):                  # row block
                ins = []
                ks = []
                for k in range(fi):              # reduction over input fibers
                    ins.append(Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                                     args=(Buf.FEATURE, Region.SUBFIBER,
                                           k, j)))
                    ins.append(Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                                     args=(Buf.WEIGHT, Region.WEIGHT_BLOCK,
                                           k, i)))
                    acc = FLAG_ACC if ks else 0
                    ins.append(Instr(Opcode.GEMM, flags=FLAG_UNLOCK | acc,
                                     args=(j, k, i, 0),
                                     arg4=n1 * n2 * n2))
                    ks.append((k, 0))
                _epilogue(l, ins, on_edges=False)
                ins.append(Instr(Opcode.MEM_WR, flags=FLAG_LAST,
                                 args=(Buf.RESULT, Region.OUT_SUBFIBER,
                                       i, j)))
                blocks.append(TilingBlock(
                    l.layer_id, "gemm", i, j, ks,
                    cost=2.0 * n1 * n2 * n2 * fi, instrs=ins))

    elif l.layer_type == LayerType.VECTOR_INNER:
        for (j, k), slices in sorted(pg.tiles.items()):   # Alg. 7
            for s, t in enumerate(slices):
                ins = [Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                             args=(Buf.EDGE, Region.SUBSHARD, j, k),
                             arg4=t.nnz)]
                ks = []
                for i in range(fi):
                    ins.append(Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                                     args=(Buf.FEATURE, Region.SUBFIBER,
                                           i, j)))
                    ins.append(Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                                     args=(Buf.FEATURE, Region.SUBFIBER,
                                           i, k)))
                    acc = FLAG_ACC if ks else 0
                    ins.append(Instr(Opcode.SDDMM, flags=FLAG_UNLOCK | acc,
                                     args=(j, k, i, s), arg4=t.nnz))
                    ks.append((i, 0))
                _epilogue(l, ins, on_edges=True)
                ins.append(Instr(Opcode.MEM_WR, flags=FLAG_LAST,
                                 args=(Buf.RESULT, Region.OUT_EDGE, j, k)))
                blocks.append(TilingBlock(
                    l.layer_id, "sddmm", -1, j, ks,
                    cost=max(t.nnz, 1) * l.f_in, instrs=ins,
                    tile_k=k, slice_id=s))

    elif l.layer_type == LayerType.VECTOR_ADD:
        for i in range(fi):                      # Alg. 8
            for j in range(nb):
                ins = [
                    Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                          args=(Buf.FEATURE, Region.SUBFIBER, i, j)),
                    Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                          args=(Buf.FEATURE, Region.SUBFIBER, i, j)),
                    Instr(Opcode.VADD, flags=FLAG_UNLOCK,
                          args=(i, j, 0, 0)),
                ]
                _epilogue(l, ins, on_edges=False)
                ins.append(Instr(Opcode.MEM_WR, flags=FLAG_LAST,
                                 args=(Buf.RESULT, Region.OUT_SUBFIBER,
                                       i, j)))
                blocks.append(TilingBlock(
                    l.layer_id, "vadd", i, j, [], cost=n1 * n2, instrs=ins))

    elif l.layer_type in (LayerType.ACTIVATION, LayerType.BATCHNORM):
        on_edges = bool(l.attrs.get("on_edges"))
        op = (Opcode.AFFINE if l.layer_type == LayerType.BATCHNORM
              else Opcode.ACT)
        if on_edges:
            # One tiling block per edge tile.
            for (j, k), slices in sorted(pg.tiles.items()):
                for s, t in enumerate(slices):
                    ins = [
                        Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                              args=(Buf.EDGE, Region.EDGE_WEIGHTS, j, k)),
                        Instr(op, act=int(l.act), act_en=True, on_edges=True,
                              flags=FLAG_UNLOCK, args=(l.layer_id, j, k, s)),
                        Instr(Opcode.MEM_WR, flags=FLAG_LAST,
                              args=(Buf.RESULT, Region.OUT_EDGE, j, k)),
                    ]
                    blocks.append(TilingBlock(
                        l.layer_id, "act", -1, j, [(k, s)],
                        cost=max(t.nnz, 1), instrs=ins))
        else:
            for i in range(fi):
                for j in range(nb):
                    ins = [
                        Instr(Opcode.MEM_RD, flags=FLAG_LOCK,
                              args=(Buf.FEATURE, Region.SUBFIBER, i, j)),
                        Instr(op, act=int(l.act), act_en=l.act_enabled,
                              flags=FLAG_UNLOCK, args=(l.layer_id, i, j, 0)),
                        Instr(Opcode.MEM_WR, flags=FLAG_LAST,
                              args=(Buf.RESULT, Region.OUT_SUBFIBER, i, j)),
                    ]
                    blocks.append(TilingBlock(
                        l.layer_id,
                        "affine" if op == Opcode.AFFINE else "act",
                        i, j, [], cost=n1 * n2, instrs=ins))
    else:
        raise ValueError(l.layer_type)
    return blocks


def run(m: ModelIR, pg: PartitionedGraph, n_pes: int = 8) -> Program:
    nb = pg.n_blocks
    layer_blocks: List[LayerBlock] = []
    f_pad: Dict[int, Tuple[int, int]] = {}
    for lid in m.topo_order():
        l = m.layers[lid]
        tbs = map_layer(l, pg, nb)
        # The CSI act field is the layer's mode selector (ISA v3): AggOp
        # for AGGREGATE, Activation for ACTIVATION, 1 for pair-sum
        # VECTOR_INNER — so the runtime dispatches from the binary alone.
        if l.layer_type == LayerType.AGGREGATE:
            mode = int(l.agg_op)
        elif l.layer_type == LayerType.VECTOR_INNER:
            mode = 1 if l.attrs.get("mode") == "pair_sum" else 0
        else:
            mode = int(l.act)
        csi = Instr(Opcode.CSI, act=mode, act_en=l.act_enabled,
                    on_edges=bool(l.attrs.get("on_edges"))
                    or l.layer_type == LayerType.VECTOR_INNER,
                    args=(lid, int(l.layer_type), l.f_in, l.f_out),
                    arg4=len(tbs))
        layer_blocks.append(LayerBlock(lid, l, csi, tbs))
        n2 = pg.config.n2
        f_pad[lid] = (math.ceil(max(l.f_in, 1) / n2) * n2,
                      math.ceil(max(l.f_out, 1) / n2) * n2)
    return Program(model=m, pgraph=pg, layer_blocks=layer_blocks,
                   n_pes=n_pes, f_pad=f_pad)
