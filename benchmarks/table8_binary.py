"""Paper Table 8: size of the generated binaries vs the input graphs."""
from __future__ import annotations

from .common import DATASETS, Engine, MODELS, dataset, emit


def run(quick: bool = False) -> None:
    ds = DATASETS[:3] if quick else DATASETS
    models = MODELS[:2] if quick else MODELS
    engine = Engine()
    for bname in models:
        for dname, scale in ds:
            g = dataset(dname, scale)
            prog = engine.compile(bname, g)
            graph_bytes = g.n_edges * 12 + g.n_vertices * g.feat_dim * 4
            label = dname if scale == 1.0 else f"{dname}@{scale:g}"
            emit([f"table8,{bname}/{label},{prog.t_loc * 1e6:.0f},"
                  f"binary_B={len(prog.binary)};graph_B={graph_bytes};"
                  f"ratio={len(prog.binary) / graph_bytes:.2e}"])
