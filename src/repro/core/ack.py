"""Adaptive Computation Kernel (paper §5.4) — the unified compute engine.

One module executes every GNN kernel by mode switching: GEMM mode,
SpDMM mode, SDDMM mode, vector-addition mode, plus the activation /
affine epilogues of the Activation Unit.

Backends:
  * ``xla``    — jnp tile ops (vectorized gathers / dots), the production
                 path on CPU and the GSPMD path on TPU.
  * ``pallas`` — the hand-written Pallas kernels in ``repro.kernels``
                 (VMEM BlockSpec tiling; interpret=True on CPU).

Every tile function is jit-compiled once per *tile shape* and cached —
never per model or per graph.  This is the overlay property: changing the
GNN model or the input graph changes the instruction stream only, exactly
like the FPGA overlay avoids reconfiguration.  ``compile_counter`` exposes
the cache behaviour to the tests/benchmarks.
"""
from __future__ import annotations

import functools
import threading
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .ir import Activation
from .reference import apply_activation

# Tile-shape-keyed kernel instantiation counts.  The runtime's per-overlay
# worker threads all funnel through _count, so every mutation (and the
# reset) holds _counter_lock; readers that only iterate a snapshot should
# call ``counter_snapshot``.
compile_counter: Dict[Tuple, int] = {}
_counter_lock = threading.Lock()


def _count(key: Tuple) -> None:
    with _counter_lock:
        compile_counter[key] = compile_counter.get(key, 0) + 1


def reset_counter() -> None:
    """Clear the kernel-instantiation counter (tests/benchmarks)."""
    with _counter_lock:
        compile_counter.clear()


def counter_snapshot() -> Dict[Tuple, int]:
    """Consistent copy of the counter, safe to iterate while serving."""
    with _counter_lock:
        return dict(compile_counter)


# --------------------------------------------------------------------------- #
# GEMM mode: output-stationary blocked matmul (Algorithm 1).
# --------------------------------------------------------------------------- #
@jax.jit
def _gemm_xla(h: jnp.ndarray, w: jnp.ndarray, acc: jnp.ndarray) -> jnp.ndarray:
    return acc + jnp.dot(h, w, preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------- #
# SpDMM mode: blocked-ELL scatter-gather (Algorithms 2 & 4).
#   out[r] (+)= reduce_k vals[r,k] * h_src[cols[r,k]]
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("op",))
def _spdmm_xla(h_src, cols, vals, mask, acc, flag, op: str):
    gathered = h_src[cols]                       # [n1, w, n2]
    if op in ("sum", "mean"):
        msg = gathered * vals[..., None]
        out = acc + jnp.sum(msg, axis=1)
        return out, flag | mask.any(axis=1)
    big = jnp.float32(3.4e38)
    msg = gathered * vals[..., None]
    if op == "max":
        msg = jnp.where(mask[..., None], msg, -big)
        return jnp.maximum(acc, jnp.max(msg, axis=1)), flag | mask.any(axis=1)
    if op == "min":
        msg = jnp.where(mask[..., None], msg, big)
        return jnp.minimum(acc, jnp.min(msg, axis=1)), flag | mask.any(axis=1)
    raise ValueError(op)


# --------------------------------------------------------------------------- #
# Dense-aggregate GEMM: densified SpDMM for remapped high-density tiles
# (Dynasparse-style sparsity-adaptive mode switch).  The ELL tile is
# scattered into an (n1, n1_src) dense adjacency block and dispatched as
# a matmul on the systolic-array path.  Pad slots carry cols == 0 /
# vals == 0, so scatter-add deposits zeros harmlessly; duplicate cols
# sum, matching SpDMM's per-edge accumulation.
# --------------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("n_src",))
def densify_tile(cols, vals, n_src: int) -> jnp.ndarray:
    """Scatter an ELL slice into its (n1, n_src) dense adjacency block.
    Executors cache the result per (j, k, s) so one densification feeds
    every output fiber's GEMM dispatch."""
    rows = jnp.arange(cols.shape[0])[:, None]
    return jnp.zeros((cols.shape[0], n_src),
                     jnp.float32).at[rows, cols].add(vals)


@jax.jit
def _gemm_agg_xla(cols, vals, h_src, acc):
    rows = jnp.arange(cols.shape[0])[:, None]
    dense = jnp.zeros((cols.shape[0], h_src.shape[0]),
                      jnp.float32).at[rows, cols].add(vals)
    return acc + jnp.dot(dense, h_src, preferred_element_type=jnp.float32)


# --------------------------------------------------------------------------- #
# SDDMM mode: per-edge inner products (Algorithm 3).
#   score[r, k] = <h_dst[r], h_src[cols[r, k]]>
# --------------------------------------------------------------------------- #
@jax.jit
def _sddmm_xla(h_dst, h_src, cols, mask, acc):
    gathered = h_src[cols]                       # [n1, w, n2]
    part = jnp.einsum("rwf,rf->rw", gathered, h_dst)
    return acc + jnp.where(mask, part, 0.0)


@jax.jit
def _sddmm_pair_xla(h_dst, h_src, cols, mask, acc):
    """GAT pair scores: score[r,k] = h_src[cols[r,k], 0] + h_dst[r, 1]."""
    part = h_src[cols][:, :, 0] + h_dst[:, 1][:, None]
    return acc + jnp.where(mask, part, 0.0)


@jax.jit
def _vadd_xla(a, b, alpha, beta):
    return alpha * a + beta * b


@functools.partial(jax.jit, static_argnames=("act",))
def _act_xla(x, act: int):
    return apply_activation(x, Activation(act))


@jax.jit
def _affine_xla(x, scale, shift):
    return x * scale + shift


class ACK:
    """Mode-switched compute engine; see module docstring."""

    def __init__(self, backend: str = "xla", interpret: bool = True) -> None:
        assert backend in ("xla", "pallas")
        self.backend = backend
        self.interpret = interpret
        if backend == "pallas":
            from repro.kernels import ops as kops  # local import: optional
            self._kops = kops

    # -- GEMM ----------------------------------------------------------- #
    def gemm(self, h, w, acc):
        _count(("gemm", h.shape, w.shape, self.backend))
        if self.backend == "pallas":
            return acc + self._kops.gemm(h, w, interpret=self.interpret)
        return _gemm_xla(h, w, acc)

    # -- Dense-aggregate GEMM (remapped SpDMM tiles) --------------------- #
    def gemm_agg(self, cols, vals, h_src, acc):
        """Aggregate a remapped ELL tile by densifying it and running the
        GEMM datapath.  Always the xla scatter+dot path — densification is
        a gather-free matmul feed, which is exactly what the pallas GEMM
        kernel would see anyway."""
        _count(("gemm_agg", h_src.shape, cols.shape, self.backend))
        return _gemm_agg_xla(cols, vals, h_src, acc)

    # -- SpDMM ---------------------------------------------------------- #
    def spdmm(self, h_src, cols, vals, mask, acc, flag, op: str = "sum"):
        _count(("spdmm", h_src.shape, cols.shape, op, self.backend))
        if self.backend == "pallas" and op in ("sum", "mean"):
            out = acc + self._kops.spdmm(cols, vals, h_src,
                                         interpret=self.interpret)
            return out, flag | mask.any(axis=1)
        return _spdmm_xla(h_src, cols, vals, mask, acc, flag, op)

    # -- SDDMM ---------------------------------------------------------- #
    def sddmm(self, h_dst, h_src, cols, mask, acc, pair_sum: bool = False):
        _count(("sddmm", h_dst.shape, cols.shape, pair_sum, self.backend))
        if pair_sum:
            return _sddmm_pair_xla(h_dst, h_src, cols, mask, acc)
        if self.backend == "pallas":
            return acc + jnp.where(
                mask, self._kops.sddmm(h_dst, h_src, cols,
                                       interpret=self.interpret), 0.0)
        return _sddmm_xla(h_dst, h_src, cols, mask, acc)

    # -- Vector addition / epilogues ------------------------------------ #
    def vadd(self, a, b, alpha: float, beta: float):
        _count(("vadd", a.shape, self.backend))
        return _vadd_xla(a, b, jnp.float32(alpha), jnp.float32(beta))

    def act(self, x, act: Activation):
        _count(("act", x.shape, int(act)))
        return _act_xla(x, int(act))

    def affine(self, x, scale, shift):
        _count(("affine", x.shape))
        return _affine_xla(x, scale, shift)
