"""repro.runtime — batching, routing, serving-loop, telemetry tests.

PR 2 acceptance criteria:
  * batched execution is numerically identical to sequential — same
    compiled programs, ``allclose`` outputs — on two models (b1 GCN,
    b6 GAT) over two graphs;
  * the batcher flushes on BOTH ``max_batch`` (size) and ``max_wait_us``
    (deadline), driven by an injected fake clock;
  * cache-affinity routing sends a repeated key to the same overlay
    (program-cache hit rate 1.0 after warmup);
  * bounded-queue admission control raises ``QueueFullError``;
  * metrics snapshots are JSON-serializable;
  * (satellite) ``ExecStats`` reset per run instead of accumulating.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.passes.partition import PartitionConfig
from repro.core.passes.schedule import lpt_assign
from repro.engine import Engine, InferenceRequest, stack_features
from repro.runtime import (Batch, Batcher, OverlayPool, QueueFullError,
    ServeLoop, warm_pool)

GEOM = PartitionConfig(n1=32, n2=8)


def _g(nv=70, ne=260, f=8, c=3, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _pool(n=2, **kw) -> OverlayPool:
    return OverlayPool(n_overlays=n, geometry=GEOM, n_pes=4, **kw)


def _req(model, g, seed, rid=None):
    x = jnp.asarray(G.random_features(g, seed=seed))
    return InferenceRequest(model=model, graph=g, features=x,
                            request_id=rid)


class FakeClock:
    def __init__(self) -> None:
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# --------------------------------------------------------------------------- #
# Batched == sequential (the tentpole's correctness contract).
# --------------------------------------------------------------------------- #
def test_batched_equals_sequential_two_models_two_graphs():
    """b1 (GCN) + b6 (GAT) over two graphs: OverlayPool.serve with
    batching produces the same outputs as one-at-a-time Engine.serve,
    including across the jitted-executable replay path (3 rounds)."""
    g1, g2 = _g(seed=21), _g(nv=80, ne=300, seed=22)
    reqs = []
    for rnd in range(3):
        for m, g in [("b1", g1), ("b6", g2), ("b1", g2), ("b6", g1)]:
            reqs.append(_req(m, g, seed=len(reqs),
                             rid=f"req{len(reqs)}"))

    pool = _pool(2)
    batched = pool.serve(reqs, max_batch=3, max_wait_us=1e9,
                         overlap_overlays=False)
    sequential = Engine(geometry=GEOM, n_pes=4).serve(reqs)

    assert [r.request_id for r in batched] == \
        [r.request_id for r in sequential]
    for b, s in zip(batched, sequential):
        np.testing.assert_allclose(np.asarray(b.output),
                                   np.asarray(s.output),
                                   rtol=1e-5, atol=1e-5)
        assert b.batch_size == 3 and s.batch_size == 1
        assert b.overlay in (0, 1)


def test_engine_submit_batch_one_pass_and_rejects_mixed_keys():
    g = _g(seed=5)
    eng = Engine(geometry=GEOM, n_pes=4)
    reqs = [_req("b1", g, seed=i) for i in range(4)]
    resps = eng.submit_batch(reqs)
    assert [r.batch_size for r in resps] == [4] * 4
    # one binary pass: per-run stats count a single traversal
    single = Engine(geometry=GEOM, n_pes=4)
    single.submit(reqs[0])
    assert eng.exec_stats.tile_ops == single.exec_stats.tile_ops
    assert eng.exec_stats.runs == 1
    # mixed cache keys in one batch are a caller bug
    other = _g(nv=60, ne=200, seed=6)
    with pytest.raises(ValueError, match="one cache key"):
        eng.submit_batch([_req("b1", g, 0), _req("b1", other, 0)])


def test_stack_features_pads_and_stacks():
    xs = stack_features([np.ones((3, 2)), np.ones((2, 4))])
    assert xs.shape == (2, 3, 4)
    assert float(xs[1, 2, 0]) == 0.0       # padded rows are zero
    assert float(xs[0, 0, 3]) == 0.0       # padded cols are zero


# --------------------------------------------------------------------------- #
# Batcher flush policies (fake-clock driven).
# --------------------------------------------------------------------------- #
def test_batcher_flushes_on_max_batch():
    clock = FakeClock()
    b = Batcher(max_batch=3, max_wait_us=1e9, clock=clock)
    g = _g()
    assert b.add("k", _req("b1", g, 0), 0) is None
    assert b.add("k", _req("b1", g, 1), 1) is None
    full = b.add("k", _req("b1", g, 2), 2)     # size flush, no time passed
    assert full is not None and len(full) == 3
    assert full.indices == [0, 1, 2]
    assert b.depth == 0


def test_batcher_flushes_on_max_wait_us():
    clock = FakeClock()
    b = Batcher(max_batch=100, max_wait_us=2000.0, clock=clock)
    g = _g()
    b.add("k", _req("b1", g, 0), 0)
    clock.advance(0.0015)                      # 1.5 ms < 2 ms deadline
    assert b.due() == []
    b.add("k2", _req("b7", g, 1), 1)           # younger group
    clock.advance(0.0010)                      # "k" now 2.5 ms old
    due = b.due()
    assert [x.key for x in due] == ["k"]       # k2 (1 ms old) stays
    assert b.depth == 1
    clock.advance(0.0015)
    assert [x.key for x in b.due()] == ["k2"]


def test_batcher_flush_all_first_arrival_order():
    b = Batcher(max_batch=10, max_wait_us=1e9, clock=FakeClock())
    g = _g()
    for i, key in enumerate(["kb", "ka", "kb", "kc"]):
        b.add(key, _req("b1", g, i), i)
    assert [x.key for x in b.flush_all()] == ["kb", "ka", "kc"]
    assert b.depth == 0


# --------------------------------------------------------------------------- #
# Cache-affinity routing.
# --------------------------------------------------------------------------- #
def test_repeated_key_routes_to_same_overlay_hit_rate_one():
    g1, g2 = _g(seed=31), _g(nv=80, ne=300, seed=32)
    pool = _pool(2)
    warmup = [_req("b1", g1, 0), _req("b6", g2, 1)]
    warm_pool(pool, warmup)
    assert pool.cache_hit_rate == 0.0          # warmup compiled cold

    # 4 post-warmup batches per key; every one must go to the key's
    # home overlay and hit its program cache
    reqs = []
    for rnd in range(4):
        reqs += [_req("b1", g1, 100 + rnd), _req("b1", g1, 200 + rnd),
                 _req("b6", g2, 300 + rnd), _req("b6", g2, 400 + rnd)]
    resps = pool.serve(reqs, max_batch=2, max_wait_us=1e9,
                       overlap_overlays=False)
    assert all(r.cache_hit for r in resps)     # hit rate 1.0 after warmup
    by_key = {}
    for r in resps:
        by_key.setdefault(r.cache_key, set()).add(r.overlay)
        assert r.t_loc == 0.0
    assert all(len(ovs) == 1 for ovs in by_key.values())
    # the two keys landed on different overlays (LPT spread them)
    assert len(set.union(*by_key.values())) == 2
    snap = pool.metrics.snapshot(max_batch=2)
    assert snap["global"]["cache_hit_rate"] == 1.0


def test_new_keys_lpt_balance_across_overlays():
    pool = _pool(3)
    batches = [Batch(key=f"k{i}", requests=[], indices=[],
                     created_at=0.0, cost=c)
               for i, c in enumerate([5.0, 3.0, 2.0, 2.0])]
    placed = pool.place(batches)
    # LPT: 5 -> ov0, 3 -> ov1, 2 -> ov2, 2 -> ov2 ... loads (5, 3, 4)
    assert placed == [0, 1, 2, 2]
    assert pool.loads == [5.0, 3.0, 4.0]
    # affinity is sticky: same key re-routes home regardless of load
    assert pool.route("k0", cost=1.0) == 0


def test_lpt_assign_balances_and_respects_initial_loads():
    assignment, loads = lpt_assign([4.0, 3.0, 2.0, 1.0], 2)
    assert max(loads) == 5.0                   # {4,1} vs {3,2}
    assignment, loads = lpt_assign([1.0], 2, initial_loads=[10.0, 0.0])
    assert assignment == [1]
    with pytest.raises(ValueError):
        lpt_assign([1.0], 3, initial_loads=[0.0])


def test_pool_rejects_mismatched_geometries():
    e1 = Engine(geometry=PartitionConfig(n1=32, n2=8))
    e2 = Engine(geometry=PartitionConfig(n1=64, n2=8))
    with pytest.raises(ValueError, match="geometry"):
        OverlayPool(engines=[e1, e2])


# --------------------------------------------------------------------------- #
# Serving loop: admission control, deadlines, deterministic drain.
# --------------------------------------------------------------------------- #
def test_admission_control_raises_queue_full():
    clock = FakeClock()
    pool = _pool(1)
    loop = ServeLoop(pool, max_batch=100, max_wait_us=1e9, max_queue=3,
                     clock=clock, overlap_overlays=False)
    g = _g()
    for i in range(3):
        loop.submit(_req("b1", g, i))
    with pytest.raises(QueueFullError):
        loop.submit(_req("b1", g, 99))
    assert pool.metrics.rejected == 1
    resps = loop.drain()                       # backpressure release
    assert len(resps) == 3 and loop.queue_depth == 0
    loop.submit(_req("b1", g, 99))             # queue has room again
    assert len(loop.drain()) == 1


def test_offline_serve_backpressure_rejects_nothing():
    """serve() exerts backpressure on a full queue (flush + continue);
    no request is dropped and none is counted as rejected."""
    g = _g()
    pool = _pool(1)
    reqs = [_req("b1", g, i, rid=f"r{i}") for i in range(9)]
    resps = pool.serve(reqs, max_batch=4, max_wait_us=1e9, max_queue=3,
                       overlap_overlays=False)
    assert [r.request_id for r in resps] == [f"r{i}" for i in range(9)]
    assert pool.metrics.rejected == 0
    assert pool.metrics.snapshot()["global"]["requests"] == 9


def test_serve_loop_deadline_flush_with_fake_clock():
    clock = FakeClock()
    pool = _pool(1)
    loop = ServeLoop(pool, max_batch=100, max_wait_us=5000.0,
                     max_queue=64, clock=clock, overlap_overlays=False)
    g = _g()
    loop.submit(_req("b1", g, 0))
    loop.poll()
    assert loop.queue_depth == 1               # deadline not reached
    clock.advance(0.006)                       # 6 ms > 5 ms
    loop.poll()
    assert loop.queue_depth == 0               # deadline flush dispatched
    r, = loop.drain()
    assert r.batch_size == 1


def test_serve_returns_request_order_and_json_metrics():
    g1, g2 = _g(seed=41), _g(nv=80, ne=300, seed=42)
    pool = _pool(2)
    reqs = [_req(m, g, seed=i, rid=f"r{i}") for i, (m, g) in enumerate(
        [("b1", g1), ("b6", g2), ("b1", g1), ("b6", g2),
         ("b1", g1), ("b6", g2)])]
    resps = pool.serve(reqs, max_batch=2, max_wait_us=1e9)  # threaded path
    assert [r.request_id for r in resps] == [f"r{i}" for i in range(6)]

    snap = pool.metrics.snapshot(max_batch=2)
    blob = json.loads(json.dumps(snap))        # JSON round-trip
    assert blob["global"]["requests"] == 6
    # per key: one full batch of 2 + one singleton flushed at drain
    assert blob["global"]["batches"] == 4
    assert blob["global"]["mean_batch_size"] == 1.5
    assert blob["global"]["batch_occupancy"] == 0.75
    assert set(blob["per_key"]) == {r.cache_key for r in resps}
    json.dumps(pool.stats_snapshot())          # also JSON-clean


# --------------------------------------------------------------------------- #
# Satellite: ExecStats reset per run (no cross-run accumulation).
# --------------------------------------------------------------------------- #
def test_exec_stats_reset_per_run_and_accumulate_in_total():
    g = _g(seed=51)
    eng = Engine(geometry=GEOM, n_pes=4)
    prog = eng.compile("b1", g)
    x = jnp.asarray(G.random_features(g, seed=0))

    eng.run(prog, x)
    first = eng.exec_stats
    assert first.runs == 1 and first.tile_ops > 0
    eng.run(prog, x)
    second = eng.exec_stats
    # per-run stats do NOT include the previous run
    assert (second.tile_ops, second.layers, second.runs) == \
        (first.tile_ops, first.layers, 1)
    assert eng.exec_stats_total.runs == 2
    assert eng.exec_stats_total.tile_ops == 2 * first.tile_ops
