"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle sweeps:
shapes x dtypes, plus property tests on ELL invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _arr(shape, dtype=np.float32, scale=1.0):
    return jnp.asarray((RNG.normal(0, 1, shape) * scale).astype(dtype))


GEMM_SHAPES = [(128, 128, 128), (256, 128, 384), (64, 32, 16),
               (100, 60, 33), (8, 8, 8), (1, 128, 1), (130, 70, 258)]


@pytest.mark.parametrize("m,k,n", GEMM_SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_gemm_sweep(m, k, n, dtype):
    x, w = _arr((m, k), dtype), _arr((k, n), dtype)
    got = ops.gemm(x, w, interpret=True)
    want = ref.gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype != np.float32 else 1e-5,
                               atol=1e-2 if dtype != np.float32 else 1e-4)


SPDMM_SHAPES = [(128, 16, 128, 128), (64, 8, 128, 32), (100, 24, 70, 33),
                (32, 64, 32, 8), (8, 8, 8, 8)]


@pytest.mark.parametrize("n1,w,ns,f", SPDMM_SHAPES)
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_spdmm_sweep(n1, w, ns, f, dtype):
    cols = jnp.asarray(RNG.integers(0, ns, (n1, w)).astype(np.int32))
    vals = _arr((n1, w), np.float32) * (RNG.random((n1, w)) > 0.4)
    vals = jnp.asarray(np.asarray(vals, np.float32))
    h = _arr((ns, f), dtype)
    got = ops.spdmm(cols, vals, h, interpret=True)
    want = ref.spdmm_ref(cols, vals, h)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2 if dtype != np.float32 else 1e-5,
                               atol=1e-2 if dtype != np.float32 else 1e-4)


SDDMM_SHAPES = [(128, 16, 128, 128), (64, 8, 96, 256), (56, 24, 70, 33),
                (8, 8, 8, 8)]


@pytest.mark.parametrize("n1,w,ns,f", SDDMM_SHAPES)
def test_sddmm_sweep(n1, w, ns, f):
    cols = jnp.asarray(RNG.integers(0, ns, (n1, w)).astype(np.int32))
    hd, hs = _arr((n1, f)), _arr((ns, f))
    got = ops.sddmm(hd, hs, cols, interpret=True)
    want = ref.sddmm_ref(hd, hs, cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(n1=st.integers(1, 64), w=st.integers(1, 32), ns=st.integers(1, 64),
       f=st.integers(1, 64), seed=st.integers(0, 9))
def test_spdmm_property(n1, w, ns, f, seed):
    r = np.random.default_rng(seed)
    cols = jnp.asarray(r.integers(0, ns, (n1, w)).astype(np.int32))
    vals = jnp.asarray(r.normal(0, 1, (n1, w)).astype(np.float32))
    h = jnp.asarray(r.normal(0, 1, (ns, f)).astype(np.float32))
    got = ops.spdmm(cols, vals, h, interpret=True)
    want = ref.spdmm_ref(cols, vals, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_zero_padding_is_inert():
    """ELL zero-pad entries (val==0) contribute exactly nothing."""
    cols = jnp.asarray(np.zeros((16, 8), np.int32))
    vals = jnp.asarray(np.zeros((16, 8), np.float32))
    h = _arr((16, 16))
    got = ops.spdmm(cols, vals, h, interpret=True)
    assert float(jnp.max(jnp.abs(got))) == 0.0


# --------------------------------------------------------------------------- #
# flash attention kernel
# --------------------------------------------------------------------------- #
FLASH_SHAPES = [(128, 128, 2, 64, True), (256, 256, 4, 32, True),
                (128, 256, 1, 64, False), (256, 128, 2, 128, True)]

# Pre-existing seed breakage unrelated to the GNN overlay: pallas
# interpret-mode state discharge crashes inside jax 0.4.37
# (`'int' object has no attribute 'shape'` in pallas/primitives.py) for
# this kernel's int-indexed loads.  Previously masked because this whole
# module failed collection on the missing hypothesis dependency.
_FLASH_INTERPRET_DRIFT = pytest.mark.xfail(
    reason="jax pallas interpret-mode drift (pre-existing, LM kernel)",
    strict=False)


@_FLASH_INTERPRET_DRIFT
@pytest.mark.parametrize("tq,tk,h,d,causal", FLASH_SHAPES)
def test_flash_attention_sweep(tq, tk, h, d, causal):
    from repro.kernels.flash_attention import flash_attention
    r = np.random.default_rng(7)
    q = jnp.asarray(r.normal(0, 1, (h, tq, d)).astype(np.float32))
    k = jnp.asarray(r.normal(0, 1, (h, tk, d)).astype(np.float32))
    v = jnp.asarray(r.normal(0, 1, (h, tk, d)).astype(np.float32))
    got = flash_attention(q, k, v, bq=64, bk=64, causal=causal,
                          interpret=True)
    s = jnp.einsum("hqd,hkd->hqk", q, k) * (d ** -0.5)
    if causal:
        qpos = np.arange(tq)[:, None]
        kpos = np.arange(tk)[None, :]
        s = jnp.where(jnp.asarray(qpos >= kpos)[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("hqk,hkd->hqd", p, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5)


@_FLASH_INTERPRET_DRIFT
def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    r = np.random.default_rng(8)
    q = jnp.asarray(r.normal(0, 1, (2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(r.normal(0, 1, (2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(r.normal(0, 1, (2, 128, 64))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, bq=64, bk=64, interpret=True)
    from repro.kernels.ref import flash_attention_ref
    want = flash_attention_ref(q.swapaxes(0, 1), k.swapaxes(0, 1),
                               v.swapaxes(0, 1)).swapaxes(0, 1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=3e-2, rtol=3e-2)
