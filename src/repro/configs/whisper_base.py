"""whisper-base [audio] 6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865 — enc-dec, conv frontend STUB (precomputed frame embeddings)
[arXiv:2212.04356; unverified]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="audio", n_layers=6, d_model=512,
        n_heads=8, n_kv_heads=8, d_ff=2048, vocab=51865,
        encoder_decoder=True, n_encoder_layers=6, decoder_target_len=448,
        tie_embeddings=True, rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, decoder_target_len=16,
        attn_chunk=0, remat="none")
