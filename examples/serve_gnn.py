"""End-to-end driver: a GNN inference service on the overlay.

  PYTHONPATH=src python examples/serve_gnn.py

The paper's core claim in action: one fixed compute substrate serves a
STREAM of (model, graph) requests — GCN, GAT, GIN, GraphSAGE, SGC on
different graphs — with per-request software compilation in milliseconds
and ZERO recompilation of the tile executables (the FPGA-overlay
"no reconfiguration" property, XLA edition).  The request queue feeds an
executor whenever it drains (Algorithm 9's idle-PE rule at request
granularity).
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import ack  # noqa: E402
from repro.core import gnn_builders as B  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.core import reference as R  # noqa: E402
from repro.core.compiler import CompileOptions, compile_model  # noqa: E402
from repro.core.executor import OverlayExecutor  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402


def main() -> None:
    rng = np.random.default_rng(0)
    # Fixed tile geometry = the overlay contract (one "bitstream").
    opts = CompileOptions(partition=PartitionConfig(n1=256, n2=32))
    executor = OverlayExecutor()

    requests = []
    for i, (mname, gname) in enumerate([
            ("b1", "CO"), ("b6", "CI"), ("b3", "CO"), ("b7", "PU"),
            ("b5", "CI"), ("b2", "PU"), ("b8", "CO"), ("b4", "CI")]):
        g = G.synthesize(gname, seed=i).gcn_normalized()
        requests.append((mname, g))

    print(f"serving {len(requests)} requests "
          f"(mixed models x mixed graphs, one overlay)...\n")
    total_compile = total_exec = 0.0
    for i, (mname, g) in enumerate(requests):
        x = jnp.asarray(G.random_features(g, seed=i))
        model = B.build(mname, g, seed=i)
        t0 = time.perf_counter()
        cr = compile_model(model, g, opts)
        t_loc = time.perf_counter() - t0
        t0 = time.perf_counter()
        y = executor.run(cr.program, x)
        y.block_until_ready()
        t_loh = time.perf_counter() - t0
        total_compile += t_loc
        total_exec += t_loh
        err = float(jnp.max(jnp.abs(
            y - R.run_reference(model, g, x))))
        print(f"req {i}: {mname:3s} on {g.name:2s} "
              f"(|V|={g.n_vertices:5d} |E|={g.n_edges:6d}) "
              f"T_LoC={t_loc * 1e3:6.1f}ms  T_LoH={t_loh * 1e3:7.1f}ms  "
              f"err={err:.1e}")

    n_kernels = len(ack.compile_counter)
    print(f"\ntotals: compile {total_compile * 1e3:.0f} ms, "
          f"execute {total_exec * 1e3:.0f} ms")
    print(f"distinct tile kernels compiled across ALL requests: "
          f"{n_kernels} (bounded by tile geometry, not by #models or "
          f"#graphs — the overlay property)")


if __name__ == "__main__":
    main()
