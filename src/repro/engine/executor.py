"""Binary-driven overlay executor (paper Alg. 9, ISA v3 runtime).

Unlike the original object-graph executor, this one consumes ONLY:

  * the decoded 128-bit instruction stream (layer/tiling-block dispatch,
    kernel kinds, tile coordinates, reduction order, fused epilogues,
    PE assignment),
  * the program manifest (weight-key indirections, dataflow operands,
    scalar coefficients), and
  * the DDR payload (weight arrays + fiber-shard ELL tiles).

No in-memory ``Program``/``LayerIR`` objects appear on the hot path, so a
``CompiledProgram`` loaded from a ``.gagi`` file executes identically to
one compiled in-process — the overlay contract: one fixed substrate, any
(model, graph) pair, driven purely by its binary.

Three execution paths share ONE shard-step abstraction (a per-layer
:class:`_ShardKernel` computing tiles through an operand
:class:`_OperandEnv`), so every path runs the same ACK kernels on the
same values in the same per-tile order — which is what makes their
results bit-identical:

  * **device** — every padded layer output device-resident; tiles are
    issued in PE-interleaved order straight off the resident arrays.
  * **host** — the partition-centric out-of-core scheme (paper §6.5,
    Algorithms 6-8): features host-resident, one destination shard's
    working set staged at a time with double-buffered async transfers.
    ``_run_host`` takes N feature *lanes* and interleaves them per
    staged shard, so a batch amortizes each tile-working-set transfer.
  * **mesh** — the placement-scheduled multi-device path: destination
    shards are LPT-assigned to the devices of a mesh (the manifest's
    ``placement`` section), each device executes its own greedy
    max-overlap shard order under ``repro.compat.shard_map``, and halo
    sub-fibers (source blocks a device does not own) move through an
    ``all_gather`` collective before aggregation layers.  The
    compile-time halo sets price the exchange; per-device counters land
    in :class:`ExecStats`.

Graph-as-data mode: ``run``/``run_batch`` accept an optional
``graph_data`` structure that *replaces the program's baked ELL tiles at
runtime* — the Dynasparse-style normalization the sampling layer uses.
The program is compiled once per geometry bucket (against the bucket's
canonical template, ``repro.sampling.buckets``), and each request ships
its actual topology as arrays matching the canonical layout::

    {"tiles": {"j:k:s": {"cols": int32 [n1, w], "vals": float32 [n1, w],
                         "mask": bool  [n1, w], "epos": int32  [n1, w]}},
     "inv_in_degree": float32 [nb * n1]}

``epos`` uses the same convention as the baked tiles (original COO edge
index, ``-1`` on pad slots).  In ``run_batch`` the structure is stacked
with a leading batch axis and vmapped together with the features, so N
*different* subgraphs sharing one bucket execute as ONE binary pass.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ack import ACK, densify_tile
from repro.core.ir import Activation, AggOp, LayerType
from repro.core.isa import Opcode
from repro.core.reference import apply_activation
from repro.obs.tracer import get_tracer

from .decoder import LayerPlan, TilePlan
from .program import CompiledProgram

# Kernel mode a layer family's tiles execute in (paper §5: the overlay's
# GEMM / SpDMM / SDDMM / vector / activation compute modes) — what the
# per-tile execution profile records next to nnz/density.
_KERNEL_MODES = {
    LayerType.AGGREGATE: "spdmm",
    LayerType.LINEAR: "gemm",
    LayerType.VECTOR_INNER: "sddmm",
    LayerType.VECTOR_ADD: "vadd",
    LayerType.ACTIVATION: "act",
    LayerType.BATCHNORM: "act",
}


def _tile_arrays(pg, gtiles, j: int, k: int, s: int):
    """(cols, vals, mask, epos) of tile (j, k, s) — from the runtime
    ``graph_data`` when present, else from the program's baked tiles.
    Shapes agree by the canonical-layout contract, so the same traced
    computation serves both sources.  Baked arrays stay on the host
    (numpy) — consumers device-convert implicitly on use, so unused
    elements cost nothing on the eager path."""
    if gtiles is None:
        t = pg.tiles[(j, k)][s]
        return t.cols, t.vals, t.edge_pos >= 0, t.edge_pos
    d = gtiles[f"{j}:{k}:{s}"]
    return d["cols"], d["vals"], d["mask"], d["epos"]


def _row_tiles(pg, j: int) -> List[Tuple[int, int]]:
    """The (k, slice) tiles of destination row block ``j``."""
    return [(k, s) for (jj, k), ts in sorted(pg.tiles.items())
            if jj == j for s in range(len(ts))]


class ResidentBudgetError(RuntimeError):
    """Raised when an execution mode cannot honor ``resident_budget_bytes``.

    Device-resident runs raise it up front (from the liveness-aware peak
    estimate, naming the first layer step that exceeds the budget); the
    partition-centric streaming path raises it only if a single shard's
    double-buffered working set exceeds the budget."""


@dataclasses.dataclass
class ExecStats:
    tile_ops: int = 0
    layers: int = 0
    runs: int = 0
    # Sparsity-adaptive remapping telemetry (repro.core.passes.remap).
    tiles_remapped: int = 0         # aggregate steps run on the GEMM path
    tiles_skipped: int = 0          # aggregate steps elided by skip-empty
    tile_ops_by_mode: Optional[Dict[str, int]] = None
    # Liveness / streaming telemetry (peaks are high-water marks).
    peak_live_outputs: int = 0      # layer outputs alive at once
    peak_live_bytes: int = 0        # bytes of those outputs
    shards_streamed: int = 0        # destination shards staged (host mode)
    h2d_bytes: int = 0              # bytes shipped host -> device
    peak_stage_bytes: int = 0       # double-buffered working set peak
    # Multi-device placement telemetry (mesh mode).
    n_devices: int = 1              # mesh size of the last run
    halo_bytes: int = 0             # compile-time halo exchange volume
    halo_gather_bytes: int = 0      # MEASURED all_gather volume (mesh)
    peak_device_bytes: int = 0      # est. per-device resident peak
    per_device: Optional[List[dict]] = None  # {"device","tile_ops",...}
    # Per-decoded-layer attribution, populated on every residency path:
    # {"layer","kernel","step","instr_lo","instr_hi","wall_s","tile_ops",
    #  + path extras ("h2d_bytes" host, "halo_gather_bytes" mesh)}.
    per_layer: Optional[List[dict]] = None

    # record keys that identify a layer rather than accumulate
    _LAYER_IDENTITY = ("layer", "kernel", "step", "type",
                      "instr_lo", "instr_hi")

    def note_layer(self, **rec) -> None:
        if self.per_layer is None:
            self.per_layer = []
        self.per_layer.append(rec)

    def note_mode(self, mode: str, n: int = 1) -> None:
        if self.tile_ops_by_mode is None:
            self.tile_ops_by_mode = {}
        self.tile_ops_by_mode[mode] = \
            self.tile_ops_by_mode.get(mode, 0) + n

    def add(self, other: "ExecStats") -> None:
        self.tile_ops += other.tile_ops
        self.layers += other.layers
        self.runs += other.runs
        self.tiles_remapped += other.tiles_remapped
        self.tiles_skipped += other.tiles_skipped
        if other.tile_ops_by_mode is not None:
            for m, n in other.tile_ops_by_mode.items():
                self.note_mode(m, n)
        self.shards_streamed += other.shards_streamed
        self.h2d_bytes += other.h2d_bytes
        self.halo_bytes += other.halo_bytes
        self.halo_gather_bytes += other.halo_gather_bytes
        if other.per_layer is not None:
            # MERGE per-layer attribution (keyed by decoded layer id +
            # kernel mode) so lifetime totals accumulate wall time and
            # tile ops per layer across runs, mirroring per_device.
            if self.per_layer is None:
                self.per_layer = [dict(r) for r in other.per_layer]
            else:
                by_key = {(r.get("layer"), r.get("kernel")): r
                          for r in self.per_layer}
                for orr in other.per_layer:
                    mine = by_key.get((orr.get("layer"),
                                       orr.get("kernel")))
                    if mine is None:
                        self.per_layer.append(dict(orr))
                        continue
                    for k, v in orr.items():
                        if k in self._LAYER_IDENTITY:
                            mine[k] = v
                        else:
                            mine[k] = mine.get(k, 0) + v
                self.per_layer.sort(key=lambda r: r.get("step", 0))
        self.n_devices = max(self.n_devices, other.n_devices)
        self.peak_live_outputs = max(self.peak_live_outputs,
                                     other.peak_live_outputs)
        self.peak_live_bytes = max(self.peak_live_bytes,
                                   other.peak_live_bytes)
        self.peak_stage_bytes = max(self.peak_stage_bytes,
                                    other.peak_stage_bytes)
        self.peak_device_bytes = max(self.peak_device_bytes,
                                     other.peak_device_bytes)
        if other.per_device is not None:
            # MERGE per-device counters (keyed by device index) so the
            # lifetime ``total`` keeps coherent per-device tile-op sums
            # across mesh runs instead of reporting only the last run.
            if self.per_device is None:
                self.per_device = [dict(d) for d in other.per_device]
            else:
                by_dev = {d.get("device"): d for d in self.per_device}
                for od in other.per_device:
                    mine = by_dev.get(od.get("device"))
                    if mine is None:
                        self.per_device.append(dict(od))
                        continue
                    for k, v in od.items():
                        if k in ("device", "blocks"):
                            mine[k] = v          # identity / geometry
                        else:
                            mine[k] = mine.get(k, 0) + v
                self.per_device.sort(key=lambda d: d.get("device", 0))

    @property
    def device_imbalance(self) -> float:
        """max/mean per-device tile ops of the last mesh run (1.0 when
        single-device or perfectly balanced)."""
        if not self.per_device:
            return 1.0
        loads = [d["tile_ops"] for d in self.per_device]
        mean = sum(loads) / len(loads)
        return (max(loads) / mean) if mean > 0 else 1.0


def _nbytes(a) -> int:
    """Array bytes; works for numpy arrays, jax arrays, and tracers."""
    return int(a.size) * a.dtype.itemsize


def _nbytes_any(a) -> int:
    """Bytes of an array OR a per-device list of arrays (mesh mode)."""
    if isinstance(a, (list, tuple)):
        return sum(_nbytes(x) for x in a)
    return _nbytes(a)


def _layer_out_bytes(lp: LayerPlan, pg) -> int:
    """Bytes of the padded output a layer keeps alive (liveness units)."""
    n1, n2 = pg.config.n1, pg.config.n2
    if lp.layer_type == LayerType.VECTOR_INNER or lp.on_edges:
        return (pg.n_edges + 1) * 4
    f = lp.f_out if lp.layer_type == LayerType.LINEAR else lp.f_in
    fp = ((max(f, 1) + n2 - 1) // n2) * n2
    return pg.n_blocks * n1 * fp * 4


def derive_residency(plan, lmeta: dict) -> dict:
    """Rebuild the residency schedule from the decoded binary alone —
    the fallback for ``.gagi`` bundles written before manifests carried
    a ``residency`` section.  Mirrors
    :func:`repro.core.passes.schedule.residency_schedule` (same greedy
    shard sequencing, same liveness rules) but reads TilePlans instead
    of compiler TilingBlocks."""
    from repro.core.passes.schedule import _order_shards
    last_use: Dict[int, int] = {}
    layers: Dict[str, dict] = {}
    for t, lp in enumerate(plan.layers):
        meta = lmeta[str(lp.layer_id)]
        ewl = meta.get("edge_weight_layer")
        feat_parents = [p for p in meta["parents"] if p != ewl]
        if lp.layer_type == LayerType.VECTOR_ADD:
            consumed = [int(o) for o in meta["operands"]]
        else:
            consumed = [int(feat_parents[0]) if feat_parents else -1]
        if ewl is not None:
            consumed.append(int(ewl))
        for c in consumed:
            last_use[c] = t
        sources: Dict[int, set] = {}
        for tp in lp.tiles:
            j = tp.out_j
            if j < 0:
                continue
            e = sources.setdefault(j, set())
            if lp.layer_type == LayerType.AGGREGATE:
                e.update(ins.args[1] for ins in tp.compute)
            elif lp.layer_type == LayerType.VECTOR_INNER:
                e.add(j)
                e.add(tp.tile_k)
            elif not lp.on_edges:
                e.add(j)
        layers[str(lp.layer_id)] = {
            "shard_order": [int(j) for j in _order_shards(sources)],
            "sources": {str(j): sorted(int(k) for k in ks)
                        for j, ks in sources.items()},
        }
    if plan.layers:
        last_use[plan.layers[-1].layer_id] = len(plan.layers)
    return {"last_use": {str(k): int(v)
                         for k, v in sorted(last_use.items())},
            "layers": layers}


def derive_placement(plan, residency: dict, geometry: dict,
                     n_devices: int) -> dict:
    """Rebuild the placement schedule from the decoded binary — the
    fallback for ``.gagi`` bundles written before manifests carried a
    ``placement`` section (or compiled for a different mesh size).
    Uses the same LPT costs (compute-instruction counts per destination
    row block) and the same :func:`build_placement` assembly as the
    compiler pass, so the derived schedule is identical to what
    ``placement_schedule`` would have emitted."""
    from repro.core.passes.schedule import build_placement, shard_block_costs
    costs = shard_block_costs(
        ([(tp.out_j, len(tp.compute)) for tp in lp.tiles]
         for lp in plan.layers),
        int(geometry["n_blocks"]))
    f_in = {str(lp.layer_id): int(lp.f_in) for lp in plan.layers}
    return build_placement(residency, costs, n_devices,
                           int(geometry["n1"]), int(geometry["n2"]), f_in)


def resolve_residency(prog: CompiledProgram) -> dict:
    """Manifest residency section, derived from the binary for
    pre-residency ``.gagi`` bundles (cached on the program)."""
    res = prog.manifest.get("residency")
    if res is None:
        res = prog.__dict__.get("_derived_residency")
        if res is None:
            res = derive_residency(prog.plan(), prog.manifest["layers"])
            prog.__dict__["_derived_residency"] = res
    return res


def ensure_placement(prog: CompiledProgram, n_devices: int) -> dict:
    """Manifest placement section for ``n_devices``, deriving one from
    the decoded binary when the manifest lacks it (old bundles, or a
    different mesh size than the program was compiled for).  The derived
    schedule is attached to the manifest so a subsequent ``save``
    serializes it and the round-trip cost is paid once."""
    pl = prog.manifest.get("placement")
    if pl is not None and int(pl.get("n_devices", 0)) == int(n_devices):
        return pl
    pl = derive_placement(prog.plan(), resolve_residency(prog),
                          prog.manifest["geometry"], int(n_devices))
    prog.manifest["placement"] = pl
    return pl


# --------------------------------------------------------------------------- #
# Operand environments — where a tile's operands come FROM.
#
# A kernel's tile computation is identical on every path; only operand
# residency differs.  Each environment answers the same five questions:
# a feature tile of source block k / fiber i, a named vector-add operand
# tile, a graph (ELL) tile, the per-edge dynamic weights of a tile, and
# the inverse-degree slice of a destination block.
# --------------------------------------------------------------------------- #
class _DeviceEnv:
    """Device-resident path: whole padded arrays live on device; tiles
    come from the program (or runtime ``graph_data``)."""

    def __init__(self, pg, gtiles, h=None, a=None, b=None, ew=None,
                 inv_deg=None):
        self.pg, self.gtiles = pg, gtiles
        self.n1, self.n2 = pg.config.n1, pg.config.n2
        self.h, self.a, self.b, self.ew, self.inv = h, a, b, ew, inv_deg

    def h_tile(self, k: int, i: int):
        return jax.lax.dynamic_slice(
            self.h, (k * self.n1, i * self.n2), (self.n1, self.n2))

    def operand_tile(self, which: str, j: int, i: int):
        arr = self.a if which == "a" else self.b
        return jax.lax.dynamic_slice(
            arr, (j * self.n1, i * self.n2), (self.n1, self.n2))

    def graph_tile(self, j: int, k: int, s: int):
        return _tile_arrays(self.pg, self.gtiles, j, k, s)

    def edge_weight_tile(self, j: int, k: int, s: int):
        _, _, mask, epos = self.graph_tile(j, k, s)
        return jnp.where(mask, self.ew[jnp.maximum(epos, 0)], 0.0)

    def inv_deg_tile(self, j: int):
        return jax.lax.dynamic_slice(self.inv, (j * self.n1,), (self.n1,))


class _HostEnv:
    """Host-streaming path: operands come from the staged working set of
    the CURRENT destination shard.  Per-lane arrays carry an ``l<n>:``
    prefix so N batch lanes share one staged tile set."""

    def __init__(self, pg, staged: Dict[str, Any], lane: int):
        self.n1, self.n2 = pg.config.n1, pg.config.n2
        self.staged, self.pre = staged, f"l{lane}:"

    def h_tile(self, k: int, i: int):
        return jax.lax.dynamic_slice(
            self.staged[f"{self.pre}h{k}"], (0, i * self.n2),
            (self.n1, self.n2))

    def operand_tile(self, which: str, j: int, i: int):
        return jax.lax.dynamic_slice(
            self.staged[f"{self.pre}{which}{j}"], (0, i * self.n2),
            (self.n1, self.n2))

    def graph_tile(self, j: int, k: int, s: int):
        return (self.staged[f"c{k}:{s}"], self.staged.get(f"v{k}:{s}"),
                self.staged[f"m{k}:{s}"], None)

    def edge_weight_tile(self, j: int, k: int, s: int):
        return jnp.where(self.staged[f"m{k}:{s}"],
                         self.staged[f"{self.pre}e{k}:{s}"], 0.0)

    def inv_deg_tile(self, j: int):
        return self.staged["deg"]


class _MeshEnv:
    """Multi-device path: operands are device-local placement slabs
    ``[B*n1, f]`` (B = row blocks per device), plus — for layers with a
    non-empty halo — the ``all_gather``ed ``[D, B*n1, f]`` view.  Block
    lookups go through the placement's block -> (device, slot) map with
    STATIC indices, so each device's schedule traces to plain slices."""

    def __init__(self, pg, place: Dict[int, Tuple[int, int]],
                 gathered=None, local_h=None, a=None, b=None, ew=None):
        self.pg, self.place = pg, place
        self.n1, self.n2 = pg.config.n1, pg.config.n2
        self.gathered, self.local_h = gathered, local_h
        self.a, self.b, self.ew = a, b, ew

    def _slab(self, k: int):
        d, slot = self.place[k]
        src = self.gathered[d] if self.gathered is not None \
            else self.local_h
        return src, slot

    def h_tile(self, k: int, i: int):
        src, slot = self._slab(k)
        return src[slot * self.n1:(slot + 1) * self.n1,
                   i * self.n2:(i + 1) * self.n2]

    def operand_tile(self, which: str, j: int, i: int):
        slot = self.place[j][1]
        arr = self.a if which == "a" else self.b
        return arr[slot * self.n1:(slot + 1) * self.n1,
                   i * self.n2:(i + 1) * self.n2]

    def graph_tile(self, j: int, k: int, s: int):
        return _tile_arrays(self.pg, None, j, k, s)

    def edge_weight_tile(self, j: int, k: int, s: int):
        t = self.pg.tiles[(j, k)][s]
        mask = t.edge_pos >= 0
        return jnp.where(mask, self.ew[np.maximum(t.edge_pos, 0)], 0.0)

    def inv_deg_tile(self, j: int):
        return jnp.asarray(
            self.pg.inv_in_degree[j * self.n1:(j + 1) * self.n1])


# --------------------------------------------------------------------------- #
# Shard kernels — ONE tile computation per layer family, shared by the
# device-resident, host-streaming, and multi-device paths.  Each kernel
# also knows its host-path staging recipe (``stage_shared`` arrays are
# shipped once per shard, ``stage_lane`` once per batch lane) and its
# host write-back, which is what lets ``_stream_shards`` drive every
# layer type through the same build/compute/write shard steps.
# --------------------------------------------------------------------------- #
class _ShardKernel:
    edge_valued = False

    def __init__(self, ex, lp: LayerPlan, meta: dict, pg, weights):
        self.ex, self.lp, self.meta, self.pg = ex, lp, meta, pg
        self.weights = weights
        self.n1, self.n2 = pg.config.n1, pg.config.n2

    def _fp(self, f: int) -> int:
        return ((max(f, 1) + self.n2 - 1) // self.n2) * self.n2

    # -- host staging ---------------------------------------------------- #
    def stage_shared(self, j: int, tps: List[TilePlan]) -> Dict[str, Any]:
        return {}

    def stage_lane(self, j: int, tps: List[TilePlan], io: dict,
                   srcs: List[int]) -> Dict[str, Any]:
        return {f"h{k}": io["h"][k * self.n1:(k + 1) * self.n1]
                for k in srcs}

    # -- outputs --------------------------------------------------------- #
    def out_width(self, io: dict) -> int:
        return self._fp(self.lp.f_in)

    def new_host_out(self, io: dict) -> np.ndarray:
        return np.zeros((self.pg.n_blocks * self.n1, self.out_width(io)),
                        np.float32)

    def host_write(self, out: np.ndarray, tp: TilePlan):
        i, j, n1, n2 = tp.out_i, tp.out_j, self.n1, self.n2

        def write(a, out=out, i=i, j=j):
            out[j * n1:(j + 1) * n1, i * n2:(i + 1) * n2] = a
        return write

    # -- the shared tile computation ------------------------------------- #
    def tile(self, tp: TilePlan, env):
        raise NotImplementedError


class _AggregateKernel(_ShardKernel):
    """SpDMM-mode aggregation (paper Alg. 6): accumulate source
    sub-fibers through a destination shard's ELL tiles.

    A sparsity-remapped binary (``repro.core.passes.remap``) may flip
    individual SPDMM steps to GEMM: the ELL slice is densified into an
    (n1, n1) adjacency block — cached per (j, k, s) so the fiber loop
    densifies once — and dispatched on the systolic-array path.
    Skip-empty elisions never reach here: the decoder drops NOPed steps,
    so ``tp.compute`` only holds live work (staging follows it)."""

    _DENSE_CACHE_CAP = 4         # (n1, n1) f32 blocks — bounded footprint

    def __init__(self, ex, lp, meta, pg, weights):
        super().__init__(ex, lp, meta, pg, weights)
        self.op = {AggOp.SUM: "sum", AggOp.MEAN: "mean",
                   AggOp.MAX: "max", AggOp.MIN: "min"}[AggOp(lp.mode)]
        self.dyn = meta.get("edge_weight_layer") is not None
        n1, n2 = self.n1, self.n2
        self.init = (
            jnp.full((n1, n2), -3.4e38, jnp.float32) if self.op == "max"
            else jnp.full((n1, n2), 3.4e38, jnp.float32)
            if self.op == "min" else jnp.zeros((n1, n2), jnp.float32))
        self._dense: Dict[Tuple[int, int, int], Any] = {}

    @staticmethod
    def _live_slices(tps: List[TilePlan]) -> set:
        """(k, s) tiles the decoded stream actually computes — after a
        skip-empty remap this is a subset of the shard row's tiles, so
        elided tiles are never staged either."""
        return {(ins.args[1], ins.args[3] >> 1)
                for tp in tps for ins in tp.compute}

    def stage_shared(self, j, tps):
        arrs: Dict[str, Any] = {}
        live = self._live_slices(tps)
        for k in range(self.pg.n_blocks):
            for s, t in enumerate(self.pg.tiles.get((j, k), [])):
                if (k, s) not in live:
                    continue
                arrs[f"c{k}:{s}"] = t.cols
                arrs[f"v{k}:{s}"] = t.vals
                arrs[f"m{k}:{s}"] = t.edge_pos >= 0
        if self.op == "mean":
            arrs["deg"] = np.asarray(
                self.pg.inv_in_degree[j * self.n1:(j + 1) * self.n1])
        return arrs

    def stage_lane(self, j, tps, io, srcs):
        arrs = super().stage_lane(j, tps, io, srcs)
        if self.dyn:
            ew = io["ew"]
            live = self._live_slices(tps)
            for k in range(self.pg.n_blocks):
                for s, t in enumerate(self.pg.tiles.get((j, k), [])):
                    if (k, s) in live:
                        arrs[f"e{k}:{s}"] = ew[np.maximum(t.edge_pos, 0)]
        return arrs

    def tile(self, tp, env):
        j, i, n2 = tp.out_j, tp.out_i, self.n2
        acc = self.init
        flag = jnp.zeros((self.n1,), bool)
        for ins in tp.compute:           # SPDMM/GEMM steps, stream order
            k, ii = ins.args[1], ins.args[2]
            s, dyn = ins.args[3] >> 1, ins.args[3] & 1
            h_tile = env.h_tile(k, ii)
            cols, v, mask, _ = env.graph_tile(j, k, s)
            if dyn:
                v = env.edge_weight_tile(j, k, s)
            if ins.op == Opcode.GEMM:    # remapped dense-aggregate step
                if dyn:
                    # per-lane edge weights: densify inline, no cache
                    acc = self.ex.ack.gemm_agg(cols, v, h_tile, acc)
                else:
                    dense = self._dense.get((j, k, s))
                    if dense is None:
                        if len(self._dense) >= self._DENSE_CACHE_CAP:
                            self._dense.clear()
                        dense = densify_tile(cols, v, n_src=self.n1)
                        self._dense[(j, k, s)] = dense
                    acc = self.ex.ack.gemm(dense, h_tile, acc)
                flag = flag | mask.any(axis=1)
                self.ex.stats.tiles_remapped += 1
                self.ex.stats.note_mode("gemm")
            else:
                acc, flag = self.ex.ack.spdmm(h_tile, cols, v, mask, acc,
                                              flag, self.op)
                self.ex.stats.note_mode("spdmm")
            self.ex.stats.tile_ops += 1
        if self.op in ("max", "min"):
            acc = jnp.where(flag[:, None], acc, 0.0)
        elif self.op == "mean":
            acc = acc * env.inv_deg_tile(j)[:, None]
        return self.ex._epilogue(tp, self.meta, acc, self.weights,
                                 i * n2, (i + 1) * n2)


class _LinearKernel(_ShardKernel):
    """GEMM-mode dense layer: reduce over input fibers of the own row
    block against weight blocks."""

    def __init__(self, ex, lp, meta, pg, weights):
        super().__init__(ex, lp, meta, pg, weights)
        fi_pad, fo_pad = self._fp(lp.f_in), self._fp(lp.f_out)
        W = np.zeros((fi_pad, fo_pad), np.float32)
        W0 = np.asarray(weights[meta["W"]], np.float32)
        W[: W0.shape[0], : W0.shape[1]] = W0
        self.Wj = jnp.asarray(W)
        self.b = None
        if "b" in meta:
            b0 = np.asarray(weights[meta["b"]], np.float32)
            self.b = jnp.asarray(np.pad(b0, (0, fo_pad - b0.shape[0])))

    def out_width(self, io):
        return self._fp(self.lp.f_out)

    def tile(self, tp, env):
        i, j, n1, n2 = tp.out_i, tp.out_j, self.n1, self.n2
        acc = jnp.zeros((n1, n2), jnp.float32)
        for ins in tp.compute:           # GEMM steps: args=(j, k, i)
            k = ins.args[1]
            h_tile = env.h_tile(j, k)
            w_tile = jax.lax.dynamic_slice(
                self.Wj, (k * n2, i * n2), (n2, n2))
            acc = self.ex.ack.gemm(h_tile, w_tile, acc)
            self.ex.stats.tile_ops += 1
            self.ex.stats.note_mode("gemm")
        if self.b is not None:
            acc = acc + jax.lax.dynamic_slice(self.b, (i * n2,), (n2,))
        return self.ex._epilogue(tp, self.meta, acc, self.weights,
                                 i * n2, (i + 1) * n2)


class _VAddKernel(_ShardKernel):
    """Vector-addition mode: elementwise alpha*a + beta*b per tile."""

    def __init__(self, ex, lp, meta, pg, weights):
        super().__init__(ex, lp, meta, pg, weights)
        self.alpha, self.beta = meta["alpha"], meta["beta"]

    def stage_lane(self, j, tps, io, srcs):
        return {f"a{j}": io["a"][j * self.n1:(j + 1) * self.n1],
                f"b{j}": io["b"][j * self.n1:(j + 1) * self.n1]}

    def out_width(self, io):
        return max(io["a"].shape[1], io["b"].shape[1])

    def tile(self, tp, env):
        i, j, n2 = tp.out_i, tp.out_j, self.n2
        ta = env.operand_tile("a", j, i)
        tb = env.operand_tile("b", j, i)
        v = self.ex.ack.vadd(ta, tb, self.alpha, self.beta)
        self.ex.stats.tile_ops += 1
        self.ex.stats.note_mode("vadd")
        return self.ex._epilogue(tp, self.meta, v, self.weights,
                                 i * n2, (i + 1) * n2)


class _VertexActKernel(_ShardKernel):
    """Standalone vertex activation / batch-norm (Activation Unit)."""

    def __init__(self, ex, lp, meta, pg, weights):
        super().__init__(ex, lp, meta, pg, weights)
        self.bn = lp.layer_type == LayerType.BATCHNORM
        if self.bn:
            mu, sig, gam, bet = (
                np.asarray(weights[meta[k]], np.float32)
                for k in ("mu", "sigma", "gamma", "beta"))
            eps = float(meta.get("eps", 1e-5))
            sc = gam / np.sqrt(sig ** 2 + eps)
            sh = bet - mu * sc
            fi_pad = self._fp(lp.f_in)
            self.sc = np.pad(sc, (0, fi_pad - sc.shape[0]))
            self.sh = np.pad(sh, (0, fi_pad - sh.shape[0]))

    def tile(self, tp, env):
        i, j, n2 = tp.out_i, tp.out_j, self.n2
        v = env.h_tile(j, i)
        op = tp.compute[0]               # the ACT / AFFINE instr
        if self.bn:
            v = self.ex.ack.affine(
                v, jnp.asarray(self.sc[i * n2:(i + 1) * n2]),
                jnp.asarray(self.sh[i * n2:(i + 1) * n2]))
        else:
            v = self.ex.ack.act(v, Activation(op.act))
        self.ex.stats.tile_ops += 1
        self.ex.stats.note_mode("act")
        return v


class _EdgeScoreKernel(_ShardKernel):
    """SDDMM-mode edge scoring (paper Alg. 7): per-edge inner products
    (or pair-sums) between destination and source sub-fibers."""

    edge_valued = True

    def __init__(self, ex, lp, meta, pg, weights):
        super().__init__(ex, lp, meta, pg, weights)
        self.pair = lp.mode == 1     # CSI mode bit — the binary decides

    def stage_shared(self, j, tps):
        arrs: Dict[str, Any] = {}
        for tp in tps:
            t = self.pg.tiles[(j, tp.tile_k)][tp.slice_id]
            arrs[f"c{tp.tile_k}:{tp.slice_id}"] = t.cols
            arrs[f"m{tp.tile_k}:{tp.slice_id}"] = t.edge_pos >= 0
        return arrs

    def new_host_out(self, io):
        return np.zeros((self.pg.n_edges + 1,), np.float32)

    def host_write(self, out, tp):
        tile = self.pg.tiles[(tp.out_j, tp.tile_k)][tp.slice_id]
        n_edges = self.pg.n_edges

        def write(a, tile=tile, out=out):
            mask_np = tile.edge_pos >= 0
            idx = np.where(mask_np, tile.edge_pos, n_edges)
            out[idx.ravel()] = a.ravel()
        return write

    def tile(self, tp, env):
        j, k, s = tp.out_j, tp.tile_k, tp.slice_id
        cols, _, mask, _ = env.graph_tile(j, k, s)
        acc = jnp.zeros(cols.shape, jnp.float32)
        for ins in tp.compute:           # SDDMM steps: args=(j, k, i, s)
            i = ins.args[2]
            h_dst = env.h_tile(j, i)
            h_src = env.h_tile(k, i)
            acc = self.ex.ack.sddmm(h_dst, h_src, cols, mask, acc,
                                    pair_sum=self.pair)
            self.ex.stats.tile_ops += 1
            self.ex.stats.note_mode("sddmm")
        return self.ex._epilogue(tp, self.meta, acc, self.weights,
                                 0, self.n2)


class BinaryExecutor:
    """Executes a CompiledProgram by interpreting its decoded binary.

    ``stats`` holds the counters of the most recent :meth:`run` only
    (reset at entry); ``total`` accumulates across the executor's
    lifetime.  A batched :meth:`run_batch` counts as ONE pass: the
    instruction stream is traversed once, whatever the batch size.
    """

    def __init__(self, backend: str = "xla", overlap: bool = True,
                 interpret: bool = True,
                 resident_budget_bytes: Optional[int] = None) -> None:
        self.ack = ACK(backend=backend, interpret=interpret)
        self.overlap = overlap
        self.resident_budget_bytes = resident_budget_bytes
        # Optional observer called as hook(event, layer_id, live_count)
        # with event in {"alloc", "free"} whenever a layer output is
        # materialized or released (tests count liveness through this).
        self.liveness_hook = None
        # Per-tile execution profiling (density + kernel mode, the
        # Dynasparse remapper's input): collected whenever tracing is
        # enabled OR this flag is set, folded into the program manifest
        # as ``exec_profile`` at the end of each run.
        self.profile_tiles = False
        self._tile_records: Optional[dict] = None
        self.stats = ExecStats()        # per-run (last run)
        self.total = ExecStats()        # lifetime accumulation

    # ------------------------------------------------------------------ #
    def _residency(self, prog: CompiledProgram) -> dict:
        return resolve_residency(prog)

    def _note_skips(self, prog: CompiledProgram) -> None:
        """Credit the run's skip-empty elisions from the remap record —
        the decoder drops NOPed steps, so the executor can't observe
        them; the record is how many compute steps one pass elides."""
        rec = prog.manifest.get("remap")
        if rec:
            self.stats.tiles_skipped = int(rec.get("skipped_tile_ops", 0))

    def _make_kernel(self, lp: LayerPlan, meta: dict, pg,
                     weights) -> _ShardKernel:
        lt = lp.layer_type
        if lt == LayerType.AGGREGATE:
            return _AggregateKernel(self, lp, meta, pg, weights)
        if lt == LayerType.LINEAR:
            return _LinearKernel(self, lp, meta, pg, weights)
        if lt == LayerType.VECTOR_INNER:
            return _EdgeScoreKernel(self, lp, meta, pg, weights)
        if lt == LayerType.VECTOR_ADD:
            return _VAddKernel(self, lp, meta, pg, weights)
        if lt in (LayerType.ACTIVATION, LayerType.BATCHNORM):
            return _VertexActKernel(self, lp, meta, pg, weights)
        raise ValueError(lt)

    # ------------------------------------------------------------------ #
    def _live_profile(self, prog: CompiledProgram,
                      x_cols: Optional[int] = None):
        """(static bytes, input-feature bytes, per-step live-output
        bytes) of a device-resident pass — the liveness-aware memory
        profile both the peak estimate and the budget gate read."""
        plan = prog.plan()
        pg = prog.pgraph
        n1, n2 = pg.config.n1, pg.config.n2
        vp = pg.n_blocks * n1
        res = self._residency(prog)
        last_use = {int(k): v for k, v in res["last_use"].items()}
        static = (pg.tile_bytes()
                  + sum(_nbytes(np.asarray(w))
                        for w in prog.weights.values())
                  + _nbytes(np.asarray(pg.inv_in_degree)))
        if not plan.layers:
            return static, 0, []
        fin_pad0 = ((max(plan.layers[0].f_in, 1) + n2 - 1) // n2) * n2
        xw = fin_pad0 if x_cols is None else max(
            fin_pad0, ((x_cols + n2 - 1) // n2) * n2)
        x_bytes = vp * xw * 4   # kept for the whole pass in device mode
        sizes = {lp.layer_id: _layer_out_bytes(lp, pg)
                 for lp in plan.layers}
        births = {lp.layer_id: t for t, lp in enumerate(plan.layers)}
        n = len(plan.layers)
        live = [sum(sz for lid, sz in sizes.items()
                    if births[lid] <= t <= max(last_use.get(lid, n),
                                               births[lid]))
                for t in range(n)]
        return static, x_bytes, live

    def estimate_device_peak_bytes(self, prog: CompiledProgram,
                                   x_cols: Optional[int] = None,
                                   assume_liveness: bool = True,
                                   batch: int = 1) -> int:
        """Liveness-aware peak device bytes of a device-resident run:
        graph tiles + weights + the input feature matrix + the maximum
        over layer steps of the concurrently-live padded outputs.
        ``assume_liveness=False`` prices the pre-liveness executor that
        kept every layer's output alive for the whole pass.  ``batch``
        scales the per-lane parts (features + live outputs) for a
        vmapped ``run_batch`` pass; tiles/weights are broadcast."""
        static, x_bytes, live = self._live_profile(prog, x_cols)
        if not live:
            return static
        if not assume_liveness:
            total = sum(_layer_out_bytes(lp, prog.pgraph)
                        for lp in prog.plan().layers)
            return static + batch * (x_bytes + total)
        return static + batch * (x_bytes + max(live))

    def _gate_device_budget(self, prog: CompiledProgram,
                            x_cols: Optional[int], batch: int = 1) -> None:
        """Refuse a device-resident run whose liveness-aware peak
        exceeds ``resident_budget_bytes`` — reporting the estimate, the
        budget, the overshoot, and the FIRST layer step whose live set
        pushes past the budget, so a refusal is actionable."""
        if self.resident_budget_bytes is None:
            return
        budget = self.resident_budget_bytes
        static, x_bytes, live = self._live_profile(prog, x_cols)
        est = (static + batch * (x_bytes + max(live))) if live else static
        if est <= budget:
            return
        detail = ""
        over = [t for t, lv in enumerate(live)
                if static + batch * (x_bytes + lv) > budget]
        if over:
            lp = prog.plan().layers[over[0]]
            detail = (f"; first exceeded at layer {lp.layer_id} "
                      f"({LayerType(lp.layer_type).name}, step "
                      f"{over[0] + 1}/{len(live)})")
        batch_note = f" for a batch of {batch}" if batch > 1 else ""
        raise ResidentBudgetError(
            f"device-resident execution needs ~{est} bytes "
            f"(liveness-aware peak{batch_note}) but "
            f"resident_budget_bytes={budget} ({est - budget} bytes over)"
            f"{detail}; re-run with residency='host' to stream "
            "shard-by-shard" + (" or shrink the batch" if batch > 1
                                 else ""))

    # ------------------------------------------------------------------ #
    # Per-tile execution profile (Dynasparse-style, see ROADMAP): which
    # kernel mode ran each graph tile, how often, against what density.
    # ------------------------------------------------------------------ #
    def _begin_profile(self) -> None:
        if get_tracer().enabled or self.profile_tiles:
            self._tile_records = {"modes": {}, "tiles": {}}
        else:
            self._tile_records = None

    def _profile_tile(self, kern: _ShardKernel, tp: TilePlan) -> None:
        """Record one TilePlan dispatch.  Graph (ELL) tiles are keyed
        (j, k, s) so their nnz/density can be joined at flush time;
        dense GEMM / vector tiles only feed the kernel-mode histogram."""
        recs = self._tile_records
        if recs is None:
            return
        lt = kern.lp.layer_type
        mode = _KERNEL_MODES[lt]
        tiles = recs["tiles"]
        if lt == LayerType.AGGREGATE:
            # Per-instruction mode: a sparsity-remapped binary may carry
            # GEMM steps inside an aggregate layer.
            for ins in tp.compute:
                imode = "gemm" if ins.op == Opcode.GEMM else mode
                key = (tp.out_j, ins.args[1], ins.args[3] >> 1)
                r = tiles.get(key)
                if r is None:
                    tiles[key] = r = {"kernel": imode, "ops": 0}
                r["kernel"] = imode
                r["ops"] += 1
                recs["modes"][imode] = recs["modes"].get(imode, 0) + 1
            return
        elif lt == LayerType.VECTOR_INNER:
            ops = len(tp.compute)
            key = (tp.out_j, tp.tile_k, tp.slice_id)
            r = tiles.get(key)
            if r is None:
                tiles[key] = r = {"kernel": mode, "ops": 0}
            r["ops"] += ops
        elif lt == LayerType.LINEAR:
            ops = len(tp.compute)
        else:
            ops = 1
        recs["modes"][mode] = recs["modes"].get(mode, 0) + ops

    def _flush_profile(self, prog: CompiledProgram) -> None:
        """Fold the run's per-tile records into the program manifest's
        ``exec_profile`` section (round-trips ``.gagi``): kernel-mode
        op histogram + per-graph-tile nnz/density/ops/mode — exactly
        the observed-density input a bind-time kernel remapper needs."""
        recs, self._tile_records = self._tile_records, None
        if recs is None:
            return
        pg = prog.pgraph
        prof = prog.manifest.get("exec_profile")
        if prof is None:
            prof = {"runs": 0, "kernel_modes": {}, "tiles": {},
                    "density_histogram": [0] * 10}
            prog.manifest["exec_profile"] = prof
        prof["runs"] += 1
        for mode, n in recs["modes"].items():
            prof["kernel_modes"][mode] = \
                prof["kernel_modes"].get(mode, 0) + int(n)
        for (j, k, s), r in recs["tiles"].items():
            slices = pg.tiles.get((j, k))
            if slices is None or s >= len(slices):
                continue                    # graph-as-data: template tile
            t = slices[s]
            slots = int(t.cols.size)
            density = (int(t.nnz) / slots) if slots else 0.0
            key = f"{j}:{k}:{s}"
            entry = prof["tiles"].get(key)
            if entry is None:
                entry = {"ops": 0}
                prof["tiles"][key] = entry
                prof["density_histogram"][min(int(density * 10), 9)] += 1
            entry.update(nnz=int(t.nnz), slots=slots,
                         density=round(density, 6), kernel=r["kernel"])
            entry["ops"] += int(r["ops"])

    # ------------------------------------------------------------------ #
    def _watermark(self, event: str, layer_id: int, vals: Dict,
                   edge_vals: Dict) -> None:
        live = len(vals) + len(edge_vals)
        if event == "alloc":
            self.stats.peak_live_outputs = max(
                self.stats.peak_live_outputs, live)
            self.stats.peak_live_bytes = max(
                self.stats.peak_live_bytes,
                sum(_nbytes_any(a) for d in (vals, edge_vals)
                    for a in d.values()))
        if self.liveness_hook is not None:
            self.liveness_hook(event, layer_id, live)

    def _free_dead(self, t: int, sink: int, last_use: Dict[int, int],
                   vals: Dict, edge_vals: Dict) -> None:
        """Release every value whose LAST consumer was step ``t`` —
        interval liveness from the manifest's residency table."""
        for d in (vals, edge_vals):
            for lid in [l for l in d
                        if l != sink and last_use.get(l, -1) == t]:
                del d[lid]
                self._watermark("free", lid, vals, edge_vals)

    # ------------------------------------------------------------------ #
    def run(self, prog: CompiledProgram, x: jnp.ndarray,
            weights: Optional[Dict[str, np.ndarray]] = None,
            graph_data: Optional[dict] = None,
            residency: str = "device", mesh=None) -> jnp.ndarray:
        if residency not in ("device", "host"):
            raise ValueError("residency must be 'device' or 'host', "
                             f"got {residency!r}")
        if mesh is not None:
            if graph_data is not None:
                raise ValueError(
                    "graph-as-data execution is device-resident only "
                    "(bucketed subgraphs are small by construction)")
            if residency == "host":
                raise ValueError(
                    "mesh execution already places shards across "
                    "devices; residency='host' does not compose with it")
            return self._run_mesh(prog, x, weights=weights, mesh=mesh)
        if residency == "host":
            if graph_data is not None:
                raise ValueError(
                    "graph-as-data execution is device-resident only "
                    "(bucketed subgraphs are small by construction)")
            return self._run_host(prog, [x], weights)[0]
        self._gate_device_budget(prog, int(x.shape[1]))
        self.stats = ExecStats(runs=1)
        self._note_skips(prog)
        tracer = get_tracer()
        self._begin_profile()
        with tracer.span("decode", cat="exec", track="exec:device",
                         args={"cached": prog._plan is not None}):
            plan = prog.plan()
        man = prog.manifest
        pg = prog.pgraph
        res = self._residency(prog)
        last_use = {int(k): v for k, v in res["last_use"].items()}
        gtiles = graph_data["tiles"] if graph_data is not None else None
        weights = weights if weights is not None else prog.weights
        lmeta = man["layers"]
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        vp = nb * n1
        nv = pg.n_vertices

        def f_pad(f: int) -> int:
            return ((max(f, 1) + n2 - 1) // n2) * n2

        def pad_vertex(a: jnp.ndarray, fp: int) -> jnp.ndarray:
            a = jnp.asarray(a, jnp.float32)
            return jnp.pad(a, ((0, vp - a.shape[0]),
                               (0, fp - a.shape[1])))

        fin_pad0 = f_pad(plan.layers[0].f_in)
        x_pad = pad_vertex(x, max(fin_pad0,
                                  ((x.shape[1] + n2 - 1) // n2) * n2))
        vals: Dict[int, jnp.ndarray] = {}       # layer -> padded output
        edge_vals: Dict[int, jnp.ndarray] = {}  # layer -> (E,) edge scores
        inv_deg = jnp.asarray(graph_data["inv_in_degree"]
                              if graph_data is not None
                              else pg.inv_in_degree)

        sink = man["sink"]
        for t, lp in enumerate(plan.layers):
            meta = lmeta[str(lp.layer_id)]
            self.stats.layers += 1
            ewl = meta.get("edge_weight_layer")
            feat_parents = [p for p in meta["parents"] if p != ewl]
            h_in = (vals.get(feat_parents[0], x_pad) if feat_parents
                    else x_pad)
            lt = lp.layer_type
            t_wall0 = time.perf_counter()
            ops0 = self.stats.tile_ops
            lspan = tracer.span(
                f"layer{lp.layer_id}", cat="exec", track="exec:device",
                args={"type": LayerType(lt).name,
                      "kernel": _KERNEL_MODES[lt], "step": t,
                      "tiles": len(lp.tiles),
                      "instr_lo": lp.instr_lo, "instr_hi": lp.instr_hi})

            if lt in (LayerType.ACTIVATION, LayerType.BATCHNORM) \
                    and lp.on_edges:
                edge_vals[lp.layer_id] = self._run_edge_act(
                    lp, pg, edge_vals[feat_parents[0]], gtiles)
            else:
                io = {"h": h_in,
                      "ew": edge_vals.get(ewl) if ewl is not None
                      else None}
                if lt == LayerType.VECTOR_ADD:
                    a_id, b_id = meta["operands"]
                    io["a"] = x_pad if a_id == -1 else vals[a_id]
                    io["b"] = x_pad if b_id == -1 else vals[b_id]
                kern = self._make_kernel(lp, meta, pg, weights)
                env = _DeviceEnv(pg, gtiles, h=io["h"], a=io.get("a"),
                                 b=io.get("b"), ew=io["ew"],
                                 inv_deg=inv_deg)
                if kern.edge_valued:
                    ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
                    for tp in self._block_order(lp):
                        self._profile_tile(kern, tp)
                        acc = kern.tile(tp, env)
                        _, _, mask, epos = env.graph_tile(
                            tp.out_j, tp.tile_k, tp.slice_id)
                        idx = jnp.where(mask, epos, pg.n_edges)
                        ew = ew.at[idx.ravel()].set(acc.ravel())
                        if not self.overlap:
                            jax.block_until_ready(ew)
                    edge_vals[lp.layer_id] = ew[: pg.n_edges]
                else:
                    out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
                    for tp in self._block_order(lp):
                        self._profile_tile(kern, tp)
                        v = kern.tile(tp, env)
                        out_tiles[(tp.out_i, tp.out_j)] = v
                        if not self.overlap:
                            jax.block_until_ready(v)
                    vals[lp.layer_id] = self._assemble(
                        out_tiles, nb, kern.out_width(io) // n2)
            lspan.add(tile_ops=self.stats.tile_ops - ops0).done()
            self.stats.note_layer(
                layer=int(lp.layer_id), kernel=_KERNEL_MODES[lt],
                step=t, instr_lo=lp.instr_lo, instr_hi=lp.instr_hi,
                wall_s=time.perf_counter() - t_wall0,
                tile_ops=self.stats.tile_ops - ops0)
            self._watermark("alloc", lp.layer_id, vals, edge_vals)
            # Interval liveness: drop outputs whose last consumer just
            # ran, so peak memory follows the live-set, not model depth.
            self._free_dead(t, sink, last_use, vals, edge_vals)

        self._flush_profile(prog)
        self.total.add(self.stats)
        return vals[sink][:nv, :man["sink_f_out"]]

    # ------------------------------------------------------------------ #
    def run_batch(self, prog: CompiledProgram, xs: jnp.ndarray,
                  weights: Optional[Dict[str, np.ndarray]] = None,
                  graph_data: Optional[dict] = None,
                  residency: str = "device", mesh=None) -> jnp.ndarray:
        """Execute ONE binary pass for a stacked ``[N, V, F]`` batch.

        The instruction stream is decoded and traversed once; every tile
        op is vectorized over the leading batch axis (``jax.vmap``), so N
        requests that share a compiled program pay the Python-side
        dispatch cost of a single request.  Per-run ``stats`` therefore
        report one pass worth of tile ops, matching the hardware story:
        the overlay executes the same binary, on wider data.

        The traced-and-jitted batched pass is memoized **on the
        program** per (batch shape, executor config): steady-state
        traffic — repeated batches of the same deployed (model, graph)
        pair — replays a compiled whole-program executable with zero
        Python-side instruction dispatch, which is what lets the
        serving runtime saturate the substrate.  (A ``weights``
        override bypasses the memo: the executable closes over the
        program's own weights.)
        """
        if xs.ndim != 3:
            raise ValueError(
                "run_batch expects stacked [N, V, F] features, got "
                f"shape {tuple(xs.shape)}")
        if mesh is not None:
            if graph_data is not None:
                raise ValueError(
                    "graph-as-data execution is device-resident only")
            batch = ExecStats()
            ys = []
            for i in range(int(xs.shape[0])):
                ys.append(self.run(prog, xs[i], weights=weights,
                                   mesh=mesh))
                batch.add(self.stats)
            batch.runs = 1                  # one logical batched pass
            self.stats = batch
            return jnp.stack(ys)
        if residency == "host":
            # Streaming mode trades latency for footprint: the batch
            # lanes stream TOGETHER, interleaved per staged shard, so
            # each destination shard's tile working set is shipped once
            # for the whole batch (host-path batching).  The device
            # still holds one double-buffered window, but its sub-fiber
            # half now scales with the batch — a budget sized for
            # single-lane streaming may need a smaller batch.
            if graph_data is not None:
                raise ValueError(
                    "graph-as-data execution is device-resident only")
            ys = self._run_host(
                prog, [xs[i] for i in range(int(xs.shape[0]))], weights)
            return jnp.stack(ys)
        # Budget-gate the vmapped pass at BATCH scale, on every call —
        # per-lane checks inside run() undercount by the batch factor,
        # and memoized replays never re-enter run() at all.
        self._gate_device_budget(prog, int(xs.shape[2]),
                                 batch=int(xs.shape[0]))
        if weights is not None:
            if graph_data is not None:
                return jax.vmap(lambda x, gd: self.run(
                    prog, x, weights=weights, graph_data=gd)
                )(xs, graph_data)
            return jax.vmap(lambda x: self.run(prog, x,
                                               weights=weights))(xs)
        # graph_data shapes are fixed by the program's canonical layout,
        # so (batch shape, presence flag) fully keys the executable.
        key = (tuple(xs.shape), str(xs.dtype), graph_data is not None,
               self.ack.backend, self.ack.interpret, self.overlap)
        cache = prog.__dict__.setdefault("_batch_exec", {})
        entry = cache.get(key)
        if entry is None:
            if graph_data is not None:
                fn = jax.jit(jax.vmap(
                    lambda x, gd: self.run(prog, x, graph_data=gd)))
                y = fn(xs, graph_data)  # traces now; run() sets stats
            else:
                fn = jax.jit(jax.vmap(lambda x: self.run(prog, x)))
                y = fn(xs)
            cache[key] = (fn, dataclasses.replace(self.stats))
            return y
        fn, stats = entry
        self.stats = dataclasses.replace(stats)
        self.total.add(self.stats)
        return fn(xs, graph_data) if graph_data is not None else fn(xs)

    # ------------------------------------------------------------------ #
    # Partition-centric out-of-core execution (paper §6.5, Alg. 6-8).
    #
    # Features stay HOST-resident (numpy); the device holds one
    # destination shard's working set at a time — its (j, k) sub-shard
    # tiles plus the source sub-fibers they gather from — while the NEXT
    # shard's working set is already in flight (``jax.device_put`` is
    # async), the software analogue of the paper's double-buffered
    # DDR<->BRAM overlap.  Every tile op runs through the same shard
    # kernels on the same values in the same order as the
    # device-resident path, so results are bit-identical.
    # ------------------------------------------------------------------ #
    def _stage(self, arrs: Dict[str, np.ndarray], **span_args):
        """Ship one working set host -> device; returns (staged, bytes).
        ``span_args`` (e.g. ``shard=j``, ``layer=lid``) land on the stage
        span so trace analysis can join stage -> compute per shard."""
        with get_tracer().span("stage", cat="h2d", track="h2d",
                               args=span_args or None) as sp:
            staged = {k: jax.device_put(a) for k, a in arrs.items()}
            nbytes = sum(_nbytes(a) for a in arrs.values())
            sp.add(bytes=nbytes, arrays=len(arrs))
        self.stats.h2d_bytes += nbytes
        return staged, nbytes

    def _stream_shards(self, order, build, compute, layer: int = -1
                       ) -> None:
        """Drive one layer's destination shards through the double
        buffer: stage shard ``order[0]``, then for each shard dispatch
        its tile ops (async), stage the NEXT shard's working set while
        they run, and only then block on the outputs and write them back
        to the host.  ``build(j)`` assembles shard j's working set as
        name -> numpy array; ``compute(j, staged)`` dispatches the tile
        ops and returns ``(write_back, device_value)`` pairs."""
        if not order:
            return
        tracer = get_tracer()
        staged_next, next_bytes = self._stage(
            build(order[0]), shard=int(order[0]), layer=layer)
        for idx, j in enumerate(order):
            staged, cur_bytes = staged_next, next_bytes
            # The compute span covers dispatch THROUGH write-back; the
            # next shard's stage span is emitted inside this window, so
            # the trace shows the double-buffer overlap directly (the
            # acceptance property: stage and compute spans intersect).
            cspan = tracer.span("compute", cat="exec", track="exec:host",
                                args={"shard": int(j), "layer": layer,
                                      "staged_bytes": cur_bytes})
            pending = compute(j, staged)
            if idx + 1 < len(order):
                staged_next, next_bytes = self._stage(
                    build(order[idx + 1]), shard=int(order[idx + 1]),
                    layer=layer)
            else:
                staged_next, next_bytes = None, 0
            window = cur_bytes + next_bytes
            self.stats.peak_stage_bytes = max(
                self.stats.peak_stage_bytes, window)
            if (self.resident_budget_bytes is not None
                    and window + self._static_bytes
                    > self.resident_budget_bytes):
                lanes = getattr(self, "_host_lanes", 1)
                raise ResidentBudgetError(
                    f"shard working set ({window} bytes double-buffered "
                    f"+ {self._static_bytes} resident weights) exceeds "
                    "resident_budget_bytes="
                    f"{self.resident_budget_bytes}; recompile with a "
                    "smaller n1 / width_cap"
                    + (" or shrink the batch (the staged window "
                       f"carries {lanes} interleaved lanes)"
                       if lanes > 1 else ""))
            for write, val in pending:
                write(np.asarray(val))          # D2H; blocks shard j only
            cspan.add(tiles=len(pending)).done()
            self.stats.shards_streamed += 1

    def _run_host(self, prog: CompiledProgram, xs: List[Any],
                  weights: Optional[Dict[str, np.ndarray]] = None
                  ) -> List[jnp.ndarray]:
        """Stream ``len(xs)`` feature lanes through the partition-centric
        path as ONE pass.  Lanes are interleaved per staged shard: the
        shard's tile working set (``stage_shared``) ships host->device
        once for the whole batch, each lane adds only its source
        sub-fibers (``stage_lane``) — host-path batching."""
        self.stats = ExecStats(runs=1)
        self._note_skips(prog)
        tracer = get_tracer()
        self._begin_profile()
        with tracer.span("decode", cat="exec", track="exec:host",
                         args={"cached": prog._plan is not None,
                               "lanes": len(xs)}):
            plan = prog.plan()
        man = prog.manifest
        pg = prog.pgraph
        res = self._residency(prog)
        weights = weights if weights is not None else prog.weights
        self._static_bytes = sum(_nbytes(np.asarray(w))
                                 for w in weights.values())
        lmeta = man["layers"]
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        vp = nb * n1
        nv = pg.n_vertices
        sink = man["sink"]
        last_use = {int(k): v for k, v in res["last_use"].items()}
        L = len(xs)
        self._host_lanes = L    # budget refusals name the lane count

        fin_pad0 = ((max(plan.layers[0].f_in, 1) + n2 - 1) // n2) * n2
        x_hosts: List[Optional[np.ndarray]] = []
        for x in xs:
            x_np = np.asarray(x, np.float32)
            xw = max(fin_pad0, ((x_np.shape[1] + n2 - 1) // n2) * n2)
            xh = np.zeros((vp, xw), np.float32)
            xh[: x_np.shape[0], : x_np.shape[1]] = x_np
            x_hosts.append(xh)
        vals: List[Dict[int, np.ndarray]] = [{} for _ in range(L)]
        edge_vals: List[Dict[int, np.ndarray]] = [{} for _ in range(L)]

        for t, lp in enumerate(plan.layers):
            meta = lmeta[str(lp.layer_id)]
            rl = res["layers"][str(lp.layer_id)]
            self.stats.layers += 1
            ewl = meta.get("edge_weight_layer")
            feat_parents = [p for p in meta["parents"] if p != ewl]
            lt = lp.layer_type
            t_wall0 = time.perf_counter()
            ops0 = self.stats.tile_ops
            h2d0 = self.stats.h2d_bytes
            lspan = tracer.span(
                f"layer{lp.layer_id}", cat="exec", track="exec:host",
                args={"type": LayerType(lt).name,
                      "kernel": _KERNEL_MODES[lt], "step": t,
                      "tiles": len(lp.tiles), "lanes": L,
                      "instr_lo": lp.instr_lo, "instr_hi": lp.instr_hi})

            if lt in (LayerType.ACTIVATION, LayerType.BATCHNORM) \
                    and lp.on_edges:
                outs = self._host_edge_act(
                    lp, pg, [edge_vals[ln][feat_parents[0]]
                             for ln in range(L)])
                for ln in range(L):
                    edge_vals[ln][lp.layer_id] = outs[ln]
            else:
                kern = self._make_kernel(lp, meta, pg, weights)
                by_j: Dict[int, List[TilePlan]] = {}
                for tp in self._block_order(lp):
                    by_j.setdefault(tp.out_j, []).append(tp)
                order = [j for j in rl["shard_order"] if j in by_j]
                srcs = rl["sources"]
                ios = []
                for ln in range(L):
                    h_in = (vals[ln].get(feat_parents[0], x_hosts[ln])
                            if feat_parents else x_hosts[ln])
                    io = {"h": h_in,
                          "ew": edge_vals[ln].get(ewl)
                          if ewl is not None else None}
                    if lt == LayerType.VECTOR_ADD:
                        a_id, b_id = meta["operands"]
                        io["a"] = (x_hosts[ln] if a_id == -1
                                   else vals[ln][a_id])
                        io["b"] = (x_hosts[ln] if b_id == -1
                                   else vals[ln][b_id])
                    ios.append(io)
                outs = [kern.new_host_out(ios[ln]) for ln in range(L)]

                def build(j, kern=kern, by_j=by_j, ios=ios, srcs=srcs):
                    arrs = kern.stage_shared(j, by_j[j])
                    for ln in range(L):
                        lane = kern.stage_lane(j, by_j[j], ios[ln],
                                               srcs.get(str(j), []))
                        for name, a in lane.items():
                            arrs[f"l{ln}:{name}"] = a
                    return arrs

                def compute(j, staged, kern=kern, by_j=by_j, outs=outs):
                    pending = []
                    for ln in range(L):
                        env = _HostEnv(pg, staged, ln)
                        for tp in by_j[j]:
                            if ln == 0:
                                self._profile_tile(kern, tp)
                            pending.append((kern.host_write(outs[ln], tp),
                                            kern.tile(tp, env)))
                    return pending

                self._stream_shards(order, build, compute,
                                    layer=int(lp.layer_id))
                for ln in range(L):
                    if kern.edge_valued:
                        edge_vals[ln][lp.layer_id] = \
                            outs[ln][: pg.n_edges]
                    else:
                        vals[ln][lp.layer_id] = outs[ln]
            lspan.add(tile_ops=self.stats.tile_ops - ops0,
                      h2d_bytes=self.stats.h2d_bytes - h2d0).done()
            self.stats.note_layer(
                layer=int(lp.layer_id), kernel=_KERNEL_MODES[lt],
                step=t, instr_lo=lp.instr_lo, instr_hi=lp.instr_hi,
                wall_s=time.perf_counter() - t_wall0,
                tile_ops=self.stats.tile_ops - ops0,
                h2d_bytes=self.stats.h2d_bytes - h2d0)
            self._watermark("alloc", lp.layer_id, vals[0], edge_vals[0])
            # Liveness hooks observe lane 0 only (one event per value,
            # as in a single run); every lane still frees its outputs.
            hook = self.liveness_hook
            for ln in range(L):
                self.liveness_hook = hook if ln == 0 else None
                self._free_dead(t, sink, last_use, vals[ln],
                                edge_vals[ln])
            self.liveness_hook = hook
            if last_use.get(-1, -1) == t:
                x_hosts = [None] * L   # input's last consumer has run

        ys = [jnp.asarray(vals[ln][sink][:nv, : man["sink_f_out"]])
              for ln in range(L)]
        self._flush_profile(prog)
        self.total.add(self.stats)
        return ys

    # ------------------------------------------------------------------ #
    def _edge_softmax_rows(self, scored) -> List[jnp.ndarray]:
        """Two-pass edge softmax over one destination row's tiles.
        ``scored`` is [(raw scores [n1, w], mask)] — masked max, then
        masked exp/sum, then per-tile normalized outputs (same order).
        Shared by every execution path so the reduction order — and
        therefore the bits — never depends on where tiles are resident."""
        n1 = scored[0][0].shape[0]
        mx = jnp.full((n1,), -3.4e38, jnp.float32)
        for sc, mask in scored:
            m = jnp.where(mask, sc, -3.4e38)
            mx = jnp.maximum(mx, jnp.max(m, axis=1))
        mx = jnp.where(mx <= -3.4e38, 0.0, mx)
        den = jnp.zeros((n1,), jnp.float32)
        exps = []
        for sc, mask in scored:
            e = jnp.where(mask, jnp.exp(sc - mx[:, None]), 0.0)
            den = den + jnp.sum(e, axis=1)
            exps.append(e)
            self.stats.tile_ops += 1
        den = jnp.maximum(den, 1e-12)
        return [e / den[:, None] for e in exps]

    def _host_edge_act(self, lp, pg, ews: List[np.ndarray]
                       ) -> List[np.ndarray]:
        """Edge activations on host-resident (E,) score vectors, one per
        batch lane; the softmax two-pass scheme stages each destination
        row's masks ONCE plus per-lane gathered scores and runs the SAME
        shared row math as the device path."""
        act = Activation(lp.mode)
        L = len(ews)
        if act != Activation.EDGE_SOFTMAX:
            self.stats.tile_ops += len(lp.tiles) * L
            return [np.asarray(apply_activation(jnp.asarray(ew), act))
                    for ew in ews]
        n1 = pg.config.n1
        nb = pg.n_blocks
        outs = [np.zeros((pg.n_edges + 1,), np.float32)
                for _ in range(L)]
        for j in range(nb):
            row_tiles = _row_tiles(pg, j)
            if not row_tiles:
                continue
            arrs = {}
            for k, s in row_tiles:
                tile = pg.tiles[(j, k)][s]
                arrs[f"m{k}:{s}"] = tile.edge_pos >= 0
                for ln in range(L):
                    arrs[f"l{ln}:s{k}:{s}"] = \
                        ews[ln][np.maximum(tile.edge_pos, 0)]
            staged, nbytes = self._stage(arrs)
            self.stats.peak_stage_bytes = max(
                self.stats.peak_stage_bytes, nbytes)
            if (self.resident_budget_bytes is not None
                    and nbytes + self._static_bytes
                    > self.resident_budget_bytes):
                raise ResidentBudgetError(
                    f"edge-softmax row working set ({nbytes} bytes + "
                    f"{self._static_bytes} resident weights) exceeds "
                    f"resident_budget_bytes={self.resident_budget_bytes}"
                    "; recompile with a smaller n1 / width_cap")
            for ln in range(L):
                scored = [(staged[f"l{ln}:s{k}:{s}"],
                           staged[f"m{k}:{s}"]) for k, s in row_tiles]
                normed = self._edge_softmax_rows(scored)
                for (k, s), out_t in zip(row_tiles, normed):
                    tile = pg.tiles[(j, k)][s]
                    mask_np = tile.edge_pos >= 0
                    idx = np.where(mask_np, tile.edge_pos, pg.n_edges)
                    masked = jnp.where(staged[f"m{k}:{s}"], out_t, 0.0)
                    outs[ln][idx.ravel()] = np.asarray(masked).ravel()
            self.stats.shards_streamed += 1
        return [o[: pg.n_edges] for o in outs]

    # ------------------------------------------------------------------ #
    # Multi-device placement execution.
    #
    # The manifest's placement schedule assigns destination row blocks
    # to the devices of a 1-D mesh; features live block-permuted as one
    # committed [B*n1, f] slab per device (B = row blocks per device).
    # Each layer: (1) if the layer's halo sets are non-empty, the parent
    # slabs are exchanged with an ``all_gather`` collective under
    # ``repro.compat.shard_map`` — the halo-exchange step, priced at
    # compile time by the placement's halo sets; (2) every device then
    # executes ITS OWN greedy max-overlap shard order, dispatching the
    # same jitted ACK tile kernels as the single-device path on its
    # committed operands (eager ops run where their operands live).
    # Because each tile op is the identical cached kernel on identical
    # values in the identical order, results are BIT-identical to the
    # single-device executor — the same property the host-streaming
    # path relies on.
    # ------------------------------------------------------------------ #
    def _mesh_exchange(self, slabs, mesh, axis, devs, width: int,
                       layer: int = -1, est_bytes: int = 0):
        """Halo exchange: per-device slabs -> a gathered ``[D, B*n1, f]``
        view committed to every device, via a ``shard_map`` all_gather
        over the mesh axis.  The span carries both the MEASURED gather
        volume (``bytes``) and the compile-time targeted-halo estimate
        (``est_bytes``) so conformance can quantify the gap a
        ppermute-style targeted exchange would close."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map as _shard_map

        D = len(slabs)
        rows = int(slabs[0].shape[0])
        with get_tracer().span(
                "halo_exchange", cat="comm", track="halo",
                args={"devices": D, "bytes": D * rows * width * 4,
                      "layer": layer, "est_bytes": est_bytes}):
            global_x = jax.make_array_from_single_device_arrays(
                (D * rows, width), NamedSharding(mesh, P(axis)),
                list(slabs))
            fn = _shard_map(lambda v: jax.lax.all_gather(v, axis),
                            mesh=mesh, in_specs=P(axis), out_specs=P(),
                            check_vma=False)
            gathered = fn(global_x)      # [D, rows, f], replicated
            return [jax.device_put(gathered, d) for d in devs]

    def _run_mesh(self, prog: CompiledProgram, x,
                  weights: Optional[Dict[str, np.ndarray]] = None,
                  mesh=None) -> jnp.ndarray:
        axis = mesh.axis_names[0]
        D = int(mesh.size)
        devs = list(np.asarray(mesh.devices).reshape(-1))
        tracer = get_tracer()
        self._begin_profile()
        pl = ensure_placement(prog, D)
        with tracer.span("decode", cat="exec", track="exec:dev0",
                         args={"cached": prog._plan is not None,
                               "devices": D}):
            plan = prog.plan()
        man = prog.manifest
        pg = prog.pgraph
        res = self._residency(prog)
        last_use = {int(k): v for k, v in res["last_use"].items()}
        wts = weights if weights is not None else prog.weights
        lmeta = man["layers"]
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        nv = pg.n_vertices
        sink = man["sink"]
        n_edges = pg.n_edges

        assignment = pl["assignment"]
        owned: List[List[int]] = [[] for _ in range(D)]
        for j, d in enumerate(assignment):
            owned[d].append(j)
        B = max(1, max((len(o) for o in owned), default=1))
        place = {j: (d, s) for d in range(D)
                 for s, j in enumerate(owned[d])}

        def f_pad(f: int) -> int:
            return ((max(f, 1) + n2 - 1) // n2) * n2

        fin_pad0 = f_pad(plan.layers[0].f_in)
        x_np = np.asarray(x, np.float32)
        xw = max(fin_pad0, ((x_np.shape[1] + n2 - 1) // n2) * n2)
        x_slabs: Optional[List[Any]] = []
        for d in range(D):
            slab = np.zeros((B * n1, xw), np.float32)
            for s, j in enumerate(owned[d]):
                blk = x_np[j * n1: (j + 1) * n1]
                slab[s * n1:s * n1 + blk.shape[0], : blk.shape[1]] = blk
            x_slabs.append(jax.device_put(slab, devs[d]))

        self.stats = ExecStats(runs=1, n_devices=D)
        self._note_skips(prog)
        per_dev = [{"device": d, "tile_ops": 0, "shards": 0,
                    "halo_bytes": 0, "blocks": len(owned[d])}
                   for d in range(D)]
        peak_dev = 0
        vals: Dict[int, List[Any]] = {}       # layer -> per-device slabs
        edge_vals: Dict[int, List[Any]] = {}  # layer -> per-device (E+1,)

        for t, lp in enumerate(plan.layers):
            meta = lmeta[str(lp.layer_id)]
            self.stats.layers += 1
            ewl = meta.get("edge_weight_layer")
            feat_parents = [p for p in meta["parents"] if p != ewl]
            lt = lp.layer_type
            pll = pl["layers"][str(lp.layer_id)]
            gath_bytes = 0
            t_wall0 = time.perf_counter()
            ops0 = self.stats.tile_ops

            if lt in (LayerType.ACTIVATION, LayerType.BATCHNORM) \
                    and lp.on_edges:
                edge_vals[lp.layer_id] = self._mesh_edge_act(
                    lp, pg, edge_vals[feat_parents[0]], owned, per_dev)
            else:
                kern = self._make_kernel(lp, meta, pg, wts)
                by_j: Dict[int, List[TilePlan]] = {}
                for tp in self._block_order(lp):
                    by_j.setdefault(tp.out_j, []).append(tp)
                parents = (vals.get(feat_parents[0], x_slabs)
                           if feat_parents else x_slabs)
                gather = (lt in (LayerType.AGGREGATE,
                                 LayerType.VECTOR_INNER)
                          and any(pll["halo"][str(d)]
                                  for d in range(D)))
                gathered = None
                if gather:
                    width = int(parents[0].shape[1])
                    est = sum(pll["halo_bytes"].get(str(d), 0)
                              for d in range(D))
                    gathered = self._mesh_exchange(
                        parents, mesh, axis, devs, width,
                        layer=int(lp.layer_id), est_bytes=est)
                    gath_bytes = D * B * n1 * width * 4
                    self.stats.halo_gather_bytes += gath_bytes
                    for d in range(D):
                        per_dev[d]["halo_bytes"] += \
                            pll["halo_bytes"].get(str(d), 0)
                if lt == LayerType.VECTOR_ADD:
                    a_id, b_id = meta["operands"]
                    ops_a = x_slabs if a_id == -1 else vals[a_id]
                    ops_b = x_slabs if b_id == -1 else vals[b_id]
                    io_w = {"a": ops_a[0], "b": ops_b[0]}
                else:
                    ops_a = ops_b = None
                    io_w = {}
                width_out = (None if kern.edge_valued
                             else kern.out_width(io_w))
                nf = None if width_out is None else width_out // n2
                outs: List[Any] = []
                for d in range(D):
                    before = self.stats.tile_ops
                    dspan = tracer.span(
                        f"layer{lp.layer_id}", cat="exec",
                        track=f"exec:dev{d}",
                        args={"type": LayerType(lt).name,
                              "kernel": _KERNEL_MODES[lt], "step": t,
                              "instr_lo": lp.instr_lo,
                              "instr_hi": lp.instr_hi})
                    env = _MeshEnv(
                        pg, place,
                        gathered=gathered[d] if gather else None,
                        local_h=parents[d],
                        a=ops_a[d] if ops_a is not None else None,
                        b=ops_b[d] if ops_b is not None else None,
                        ew=edge_vals[ewl][d] if ewl is not None
                        else None)
                    order = [j for j in pll["order"][str(d)]
                             if j in by_j]
                    seen = set(order)
                    order += [j for j in owned[d]
                              if j in by_j and j not in seen]
                    if kern.edge_valued:
                        ew = jnp.zeros((n_edges + 1,), jnp.float32)
                        ew = jax.device_put(ew, devs[d])
                        for j in order:
                            for tp in by_j[j]:
                                self._profile_tile(kern, tp)
                                acc = kern.tile(tp, env)
                                tile = pg.tiles[(j, tp.tile_k)][
                                    tp.slice_id]
                                mask_np = tile.edge_pos >= 0
                                idx = np.where(mask_np, tile.edge_pos,
                                               n_edges)
                                ew = ew.at[idx.ravel()].set(acc.ravel())
                            per_dev[d]["shards"] += 1
                        outs.append(ew)
                    else:
                        tiles_out: Dict[Tuple[int, int], Any] = {}
                        for j in order:
                            for tp in by_j[j]:
                                self._profile_tile(kern, tp)
                                tiles_out[(tp.out_i, tp.out_j)] = \
                                    kern.tile(tp, env)
                            per_dev[d]["shards"] += 1
                        rows = []
                        for s in range(B):
                            jj = (owned[d][s] if s < len(owned[d])
                                  else -1)
                            if jj >= 0 and jj in by_j:
                                rows.append(jnp.concatenate(
                                    [tiles_out[(i, jj)]
                                     for i in range(nf)], axis=1))
                            else:
                                rows.append(jax.device_put(
                                    jnp.zeros((n1, width_out),
                                              jnp.float32), devs[d]))
                        outs.append(jnp.concatenate(rows, axis=0))
                    per_dev[d]["tile_ops"] += \
                        self.stats.tile_ops - before
                    dspan.add(tile_ops=self.stats.tile_ops
                              - before).done()
                if kern.edge_valued:
                    edge_vals[lp.layer_id] = outs
                else:
                    vals[lp.layer_id] = outs
                if not self.overlap:
                    jax.block_until_ready(outs)
            self.stats.note_layer(
                layer=int(lp.layer_id), kernel=_KERNEL_MODES[lt],
                step=t, instr_lo=lp.instr_lo, instr_hi=lp.instr_hi,
                wall_s=time.perf_counter() - t_wall0,
                tile_ops=self.stats.tile_ops - ops0,
                halo_gather_bytes=gath_bytes)
            live = sum(_nbytes_any(a) for dd in (vals, edge_vals)
                       for a in dd.values())
            peak_dev = max(peak_dev, live // D + gath_bytes)
            self._watermark("alloc", lp.layer_id, vals, edge_vals)
            self._free_dead(t, sink, last_use, vals, edge_vals)
            if last_use.get(-1, -1) == t:
                x_slabs = None         # input's last consumer has run

        self.stats.per_device = per_dev
        self.stats.halo_bytes = sum(d["halo_bytes"] for d in per_dev)
        self.stats.peak_device_bytes = peak_dev
        self._flush_profile(prog)
        self.total.add(self.stats)
        out = np.zeros((nb * n1, int(vals[sink][0].shape[1])),
                       np.float32)
        for j in range(nb):
            d, s = place[j]
            out[j * n1:(j + 1) * n1] = \
                np.asarray(vals[sink][d][s * n1:(s + 1) * n1])
        return jnp.asarray(out[:nv, : man["sink_f_out"]])

    def _mesh_edge_act(self, lp, pg, ew_slabs, owned, per_dev):
        """Edge activations on per-device ``(E+1,)`` score slabs.
        Softmax rows are destination-local under the placement (a row's
        tiles live with the device that owns the row block), so no
        collective is needed — each device normalizes its own rows with
        the shared two-pass row math."""
        act = Activation(lp.mode)
        D = len(ew_slabs)
        n_edges = pg.n_edges
        if act != Activation.EDGE_SOFTMAX:
            # One op per tile, credited to the tile's owning device so
            # sum(per_device tile_ops) == stats.tile_ops holds here too.
            dev_of = {j: d for d in range(D) for j in owned[d]}
            for tp in lp.tiles:
                per_dev[dev_of[tp.out_j]]["tile_ops"] += 1
            self.stats.tile_ops += len(lp.tiles)
            return [apply_activation(ew_slabs[d], act)
                    for d in range(D)]
        outs = []
        for d in range(D):
            before = self.stats.tile_ops
            ew_in = ew_slabs[d]
            out = jax.device_put(jnp.zeros((n_edges + 1,), jnp.float32),
                                 ew_in.devices().pop()
                                 if hasattr(ew_in, "devices")
                                 else None)
            for j in owned[d]:
                row_tiles = _row_tiles(pg, j)
                if not row_tiles:
                    continue
                scored, tiles = [], []
                for k, s in row_tiles:
                    tile = pg.tiles[(j, k)][s]
                    mask = tile.edge_pos >= 0
                    scored.append(
                        (ew_in[np.maximum(tile.edge_pos, 0)], mask))
                    tiles.append((tile, mask))
                normed = self._edge_softmax_rows(scored)
                for (tile, mask), out_t in zip(tiles, normed):
                    idx = np.where(mask, tile.edge_pos, n_edges)
                    out = out.at[idx.ravel()].set(
                        jnp.where(mask, out_t, 0.0).ravel())
                per_dev[d]["shards"] += 1
            per_dev[d]["tile_ops"] += self.stats.tile_ops - before
            outs.append(out)
        return outs

    # ------------------------------------------------------------------ #
    def _epilogue(self, tp: TilePlan, meta: dict, tile: jnp.ndarray,
                  weights, lo: int, hi: int) -> jnp.ndarray:
        """Fused scale/shift + activation, in decoded instruction order."""
        for kind, act_id in tp.epilogue:
            if kind == "affine":
                sc = jnp.asarray(np.asarray(
                    weights[meta["fused_scale"]], np.float32))
                sh = jnp.asarray(np.asarray(
                    weights[meta["fused_shift"]], np.float32))
                sc = jnp.pad(sc, (0, max(0, hi - sc.shape[0])))[lo:hi]
                sh = jnp.pad(sh, (0, max(0, hi - sh.shape[0])))[lo:hi]
                tile = self.ack.affine(tile, sc, sh)
            else:
                tile = self.ack.act(tile, Activation(act_id))
        return tile

    def _assemble(self, tiles: Dict[Tuple[int, int], jnp.ndarray], nb: int,
                  nf: int) -> jnp.ndarray:
        rows = []
        for j in range(nb):
            rows.append(jnp.concatenate([tiles[(i, j)] for i in range(nf)],
                                        axis=1))
        return jnp.concatenate(rows, axis=0)

    def _block_order(self, lp: LayerPlan) -> List[TilePlan]:
        """PE-interleaved issue order (round-robin across PE streams)."""
        streams: Dict[int, List[TilePlan]] = {}
        for tp in lp.tiles:
            streams.setdefault(tp.pe, []).append(tp)
        order: List[TilePlan] = []
        idx = 0
        keys = sorted(streams)
        while any(streams[k] for k in keys):
            k = keys[idx % len(keys)]
            if streams[k]:
                order.append(streams[k].pop(0))
            idx += 1
        return order

    # ------------------------------------------------------------------ #
    def _run_edge_act(self, lp, pg, ew_in, gtiles=None):
        """Edge activations; EDGE_SOFTMAX uses the two-pass tile scheme
        (max/sum accumulated per destination row across a shard's tiles,
        the Activation Unit's exp/divide applied per tile) through the
        shared row math."""
        act = Activation(lp.mode)
        if act != Activation.EDGE_SOFTMAX:
            out = apply_activation(ew_in, act)
            self.stats.tile_ops += len(lp.tiles)
            return out
        nb = pg.n_blocks
        ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
        for j in range(nb):
            row_tiles = _row_tiles(pg, j)
            if not row_tiles:
                continue
            scored, metas = [], []
            for k, s in row_tiles:
                _, _, mask, epos = _tile_arrays(pg, gtiles, j, k, s)
                scored.append((ew_in[jnp.maximum(epos, 0)], mask))
                metas.append((mask, epos))
            normed = self._edge_softmax_rows(scored)
            for (mask, epos), out_t in zip(metas, normed):
                idx = jnp.where(mask, epos, pg.n_edges)
                ew = ew.at[idx.ravel()].set(
                    jnp.where(mask, out_t, 0.0).ravel())
        return ew[: pg.n_edges]
