"""Graph containers and synthetic generators.

The container is offline, so the seven evaluation graphs of the paper
(Table 4) are synthesized to matching statistics: |V|, |E|, feature width,
number of classes, and a degree profile (power-law for the social/commerce
graphs, near-uniform for the citation graphs).  Latency and complexity
results of the compiler depend only on (|V|, |E|, degree structure, f), all
of which are matched; feature *values* are random.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

# name: (|V|, |E|, features, classes, degree profile)
PAPER_DATASETS: Dict[str, Tuple[int, int, int, int, str]] = {
    "CI": (3327, 4732, 3703, 6, "uniform"),       # Citeseer
    "CO": (2708, 5429, 1433, 7, "uniform"),       # Cora
    "PU": (19717, 44338, 500, 3, "uniform"),      # Pubmed
    "FL": (89250, 899756, 500, 7, "powerlaw"),    # Flickr
    "RE": (232965, 116069919, 602, 41, "powerlaw"),   # Reddit
    "YE": (716847, 6977410, 300, 100, "powerlaw"),    # Yelp
    "AP": (1569960, 264339468, 200, 107, "powerlaw"),  # Amazon-Products
}


@dataclasses.dataclass
class Graph:
    """COO graph (paper §5.1): edge e = (src, dst, weight)."""

    n_vertices: int
    src: np.ndarray        # int32 [E]
    dst: np.ndarray        # int32 [E]
    weight: np.ndarray     # float32 [E]
    feat_dim: int = 0
    n_classes: int = 0
    name: str = "graph"

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def out_degree(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n_vertices)

    def in_degree(self) -> np.ndarray:
        return np.bincount(self.dst, minlength=self.n_vertices)

    def with_self_loops(self) -> "Graph":
        """Add self loops (GCN uses N(i) ∪ {i})."""
        v = np.arange(self.n_vertices, dtype=np.int32)
        return dataclasses.replace(
            self,
            src=np.concatenate([self.src, v]),
            dst=np.concatenate([self.dst, v]),
            weight=np.concatenate(
                [self.weight, np.ones(self.n_vertices, np.float32)]
            ),
        )

    def gcn_normalized(self) -> "Graph":
        """Edge weights alpha_ji = 1/sqrt(D(j)D(i)) over the self-loop graph."""
        g = self.with_self_loops()
        deg = np.bincount(g.dst, minlength=g.n_vertices).astype(np.float32)
        deg = np.maximum(deg, 1.0)
        inv = 1.0 / np.sqrt(deg)
        w = inv[g.src] * inv[g.dst]
        return dataclasses.replace(g, weight=w.astype(np.float32))

    def mean_normalized(self) -> "Graph":
        """Edge weights 1/indeg(dst) — turns SUM aggregation into MEAN."""
        deg = np.maximum(self.in_degree().astype(np.float32), 1.0)
        w = self.weight / deg[self.dst]
        return dataclasses.replace(self, weight=w.astype(np.float32))

    def sorted_by_dst(self) -> "Graph":
        """Sort edges by (dst, src).

        On the FPGA, a RAW-hazard unit reorders conflicting destination
        updates at runtime; on TPU we sort at compile time so each
        destination row's edges are contiguous (see DESIGN.md §2).
        """
        order = np.lexsort((self.src, self.dst))
        return dataclasses.replace(
            self, src=self.src[order], dst=self.dst[order],
            weight=self.weight[order],
        )

    @property
    def mutation_token(self) -> int:
        """Monotone dirty counter for cached views (CSR, signatures).

        The memoized views on this object are keyed by array *identity*,
        which cannot see in-place content mutation.  Anything that
        mutates a deployed graph — ``repro.livegraph`` applying a delta,
        or a caller writing into the arrays directly — must call
        :meth:`invalidate_views`; cached views compare this token on
        access and rebuild when it moved.
        """
        return self.__dict__.get("_mutation_token", 0)

    def invalidate_views(self) -> int:
        """Bump :attr:`mutation_token` and drop every memoized view
        (in-CSR adjacency, edge digest).  Returns the new token."""
        token = self.mutation_token + 1
        self.__dict__["_mutation_token"] = token
        self.__dict__.pop("_in_csr", None)
        self.__dict__.pop("_edge_digest", None)
        return token

    def in_csr(self):
        """Cached in-adjacency CSR view (``repro.sampling.csr.CSR``).

        The per-user sampling layer needs O(degree) "who sends messages
        to vertex v" lookups on the host; this hook memoizes the one-time
        O(|V| + |E|) CSR build on the graph object (same identity-keyed
        invalidation rule as the engine's signature memo: rebinding the
        edge arrays invalidates).  In-place *content* mutation is
        invisible to identity checks — mutators must call
        :meth:`invalidate_views` (``repro.livegraph`` does, per delta),
        and the memo also re-checks :attr:`mutation_token` on access.
        """
        from repro.sampling.csr import in_csr  # lazy: core has no other
        return in_csr(self)                    # dependency on sampling


# --------------------------------------------------------------------------- #
def synthesize(
    name: str,
    scale: float = 1.0,
    seed: int = 0,
    degree: Optional[str] = None,
) -> Graph:
    """Synthesize a graph matching a paper dataset's statistics.

    ``scale`` < 1 shrinks |V| and |E| proportionally (used for the big
    graphs RE/YE/AP so CPU benchmark wall-times stay sane; always labeled).
    """
    nv, ne, f, c, prof = PAPER_DATASETS[name]
    nv = max(int(nv * scale), 16)
    ne = max(int(ne * scale), 32)
    g = random_graph(nv, ne, seed=seed, degree=degree or prof)
    g.feat_dim, g.n_classes = f, c
    g.name = name if scale == 1.0 else f"{name}@{scale:g}"
    return g


def random_graph(
    n_vertices: int, n_edges: int, seed: int = 0, degree: str = "uniform",
    alpha: float = 1.1, dedupe: bool = False,
) -> Graph:
    """Random COO graph.

    ``alpha`` is the power-law exponent of the Zipf-ish endpoint sampling
    (``degree="powerlaw"``; higher = heavier hubs).  With ``dedupe=True``
    duplicate (src, dst) draws are folded into a single edge whose weight
    counts the multiplicity — the realistic shape for sampled/benchmark
    traffic, where multi-edges are measurement artifacts.
    """
    rng = np.random.default_rng(seed)
    if degree == "powerlaw":
        # Zipf-ish endpoint sampling, truncated to |V|.
        ranks = np.arange(1, n_vertices + 1, dtype=np.float64)
        p = ranks ** -alpha
        p /= p.sum()
        dst = rng.choice(n_vertices, size=n_edges, p=p).astype(np.int32)
        src = rng.choice(n_vertices, size=n_edges, p=p).astype(np.int32)
    else:
        src = rng.integers(0, n_vertices, n_edges, dtype=np.int32)
        dst = rng.integers(0, n_vertices, n_edges, dtype=np.int32)
    w = np.ones(n_edges, np.float32)
    if dedupe:
        key = src.astype(np.int64) * n_vertices + dst
        uniq, inv = np.unique(key, return_inverse=True)
        mult = np.bincount(inv, minlength=uniq.shape[0])
        src = (uniq // n_vertices).astype(np.int32)
        dst = (uniq % n_vertices).astype(np.int32)
        w = mult.astype(np.float32)
    return Graph(n_vertices=n_vertices, src=src, dst=dst, weight=w)


def random_features(
    g: Graph, f: Optional[int] = None, seed: int = 1, dtype=np.float32
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    f = f or g.feat_dim
    return rng.normal(0, 1, (g.n_vertices, f)).astype(dtype) * 0.1
