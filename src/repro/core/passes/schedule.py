"""Compiler Step 4b — task scheduling (paper §6.6, Algorithm 9).

GraphAGILE executes layer by layer.  Within a layer, Tiling Blocks are
assigned to PEs.  The paper does this *dynamically* (idle PE pulls the next
block); in an SPMD software overlay the equivalent is a static balanced
assignment computed at compile time: Longest-Processing-Time (LPT) greedy
bin packing on the per-block cost estimate, which equalizes per-PE work the
same way the idle-PE rule does (and is deterministic, which SPMD needs).
The dynamic behaviour is preserved in the host serving loop
(`runtime/serve_loop.py`) where a work queue feeds whichever PE drains
first.

Double-buffer overlap: within each PE stream, the MEM_RD instructions of
tiling block t+1 may issue while block t computes (paper's
lock/unlock-annotated WAR protection).  The executor realizes this with
async dispatch; `overlap=False` inserts a barrier after every block
(used by the Fig. 16 ablation).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List

from .kernel_map import Program


@dataclasses.dataclass
class ScheduleReport:
    per_layer_imbalance: List[float]   # max/mean PE load per layer

    @property
    def worst_imbalance(self) -> float:
        return max(self.per_layer_imbalance, default=1.0)


def run(prog: Program, n_pes: int = 8) -> ScheduleReport:
    """LPT-assign tiling blocks to PEs; annotate pe ids on instructions."""
    prog.n_pes = n_pes
    imbalances: List[float] = []
    for lb in prog.layer_blocks:
        heap = [(0.0, pe) for pe in range(n_pes)]
        heapq.heapify(heap)
        for tb in sorted(lb.tiling_blocks, key=lambda t: -t.cost):
            load, pe = heapq.heappop(heap)
            tb.pe = pe
            for ins in tb.instrs:
                ins.pe = pe
            heapq.heappush(heap, (load + tb.cost, pe))
        loads = sorted(l for l, _ in heap)
        mean = sum(loads) / n_pes
        imbalances.append((loads[-1] / mean) if mean > 0 else 1.0)
    return ScheduleReport(per_layer_imbalance=imbalances)
