import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all   # full sweep

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>.json (resumable: cells
with an existing artifact are skipped unless --force).
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import compat  # noqa: E402
from repro.configs import ARCHS, get_config  # noqa: E402
from repro.distributed import sharding as SH  # noqa: E402
from repro.distributed.zero import opt_state_specs  # noqa: E402
from repro.launch.hlo_analysis import analyze  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.config import SHAPES, ModelConfig, ShapeCell  # noqa: E402
from repro.models.steps import (build_model, input_specs,  # noqa: E402
                                make_prefill_step, make_serve_step,
                                make_train_step)
from repro.optim import adamw_init  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")

# long_500k runs only for sub-quadratic-capable archs (DESIGN.md §4):
LONG_OK = {"gemma3-12b", "gemma3-27b", "hymba-1.5b", "xlstm-125m"}

# v5e constants for downstream roofline (recorded into artifacts)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def cell_supported(arch: str, shape: str) -> Optional[str]:
    """None if runnable; otherwise the reason for the skip."""
    if shape == "long_500k" and arch not in LONG_OK:
        return ("pure full-attention arch: 512k decode needs sub-quadratic "
                "attention / bounded state (see DESIGN.md §4)")
    return None


def _batch_shardings(cfg: ModelConfig, cell: ShapeCell, mesh, specs):
    out = {}
    for k, s in specs.items():
        nd = len(s.shape)
        if k == "pos":
            out[k] = NamedSharding(mesh, P())
        elif k in ("frames", "vision"):
            out[k] = NamedSharding(
                mesh, SH.batch_spec(mesh, s.shape[0], nd - 1))
        else:
            out[k] = NamedSharding(
                mesh, SH.batch_spec(mesh, s.shape[0], nd - 1))
    return out


def _cache_shardings(cfg: ModelConfig, cell: ShapeCell, mesh, cache_specs):
    b = cell.global_batch

    def leaf(path, s):
        names = [str(getattr(p, "key", getattr(p, "idx", p)))
                 for p in path]
        name = names[-1]
        shape = s.shape[1:]  # strip stacked layer dim
        if name in ("k", "v", "xk", "xv"):
            spec = SH.kv_cache_spec(b, mesh, shape[2], seq_len=shape[1])
        elif (name in ("c", "k_rope") and len(shape) == 3
                and shape[1] >= 4096):
            # MLA latent cache [B, S, R] (vs sLSTM scalar state [B, H, dh])
            spec = SH.latent_cache_spec(b, mesh)
        else:
            spec = SH.state_cache_spec(shape, mesh)
        return NamedSharding(mesh, P(None, *spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_specs)


def run_cell(arch: str, shape: str, mesh_kind: str,
             overrides: Optional[Dict[str, Any]] = None,
             tag: str = "") -> Dict[str, Any]:
    cfg = get_config(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    cell = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    moe_impl = "a2a" if cfg.is_moe else "dense"
    model = build_model(cfg, moe_impl=moe_impl, mesh=mesh)

    param_s = model.param_specs()
    param_sh = SH.param_shardings(mesh, param_s)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "n_devices": n_dev, "kind": cell.kind,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "tokens": cell.tokens if cell.kind != "decode" else
        cell.global_batch,
        "overrides": overrides or {}, "tag": tag,
    }

    t0 = time.time()
    with compat.set_mesh(mesh):
        if cell.kind == "train":
            fn = make_train_step(model, cfg)
            ospec = jax.eval_shape(adamw_init, param_s)
            osh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s),
                opt_state_specs(param_s, mesh),
                is_leaf=lambda x: isinstance(x, P))
            bspecs = input_specs(cfg, cell)
            bsh = _batch_shardings(cfg, cell, mesh, bspecs)
            lowered = jax.jit(
                fn, in_shardings=(param_sh, osh, bsh),
                donate_argnums=(0, 1)).lower(
                param_s, ospec, bspecs)
        elif cell.kind == "prefill":
            fn = make_prefill_step(model, cfg)
            bspecs = input_specs(cfg, cell)
            bsh = _batch_shardings(cfg, cell, mesh, bspecs)
            lowered = jax.jit(
                fn, in_shardings=(param_sh, bsh)).lower(param_s, bspecs)
        else:  # decode
            fn = make_serve_step(model, cfg)
            if cfg.encoder_decoder:
                cache_s = model.init_cache(
                    cell.global_batch, cfg.decoder_target_len,
                    zeros=False, cross_len=cell.seq_len)
            else:
                cache_s = model.init_cache(cell.global_batch,
                                           cell.seq_len, zeros=False)
            cache_sh = _cache_shardings(cfg, cell, mesh, cache_s)
            dspecs = input_specs(cfg, cell)
            tok_sh = NamedSharding(
                mesh, SH.batch_spec(mesh, cell.global_batch, 1))
            lowered = jax.jit(fn, in_shardings=(
                param_sh, cache_sh, tok_sh,
                NamedSharding(mesh, P())),
                donate_argnums=(1,)).lower(
                param_s, cache_s, dspecs["token"], dspecs["pos"])
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    mem = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "per_device_total": (mem.argument_size_in_bytes
                             + mem.output_size_in_bytes
                             + mem.temp_size_in_bytes
                             - mem.alias_size_in_bytes),
    }
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # old jax: list of per-device dicts
        ca = ca[0] if ca else {}
    rec["xla_cost"] = {"flops": ca.get("flops", 0.0),
                       "bytes": ca.get("bytes accessed", 0.0)}
    t2 = time.time()
    hlo = compiled.as_text()
    rec["hlo_bytes"] = len(hlo)
    if os.environ.get("DRYRUN_SAVE_HLO", "1") == "1":
        hp = artifact_path(arch, shape, mesh_kind, tag).replace(
            ".json", ".hlo.gz")
        with gzip.open(hp, "wt") as f:
            f.write(hlo)
    costs = analyze(hlo, n_dev)
    rec["analyze_s"] = round(time.time() - t2, 2)
    rec["analysis"] = {
        "flops_per_device": costs.flops,
        "hbm_bytes_per_device": costs.hbm_bytes,
        "collective_bytes_per_device": costs.collective_bytes,
        "total_collective_bytes_per_device":
            costs.total_collective_bytes,
        "unknown_trip_whiles": costs.unknown_trip_whiles,
    }
    # roofline terms (seconds)
    rec["roofline"] = {
        "compute_s": costs.flops / PEAK_FLOPS,
        "memory_s": costs.hbm_bytes / HBM_BW,
        "collective_s": costs.total_collective_bytes / ICI_BW,
    }
    dom = max(rec["roofline"], key=rec["roofline"].get)
    rec["roofline"]["dominant"] = dom
    return rec


def artifact_path(arch, shape, mesh_kind, tag="") -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    return os.path.join(
        ART_DIR, f"{arch}__{shape}__{mesh_kind}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=[None] + list(SHAPES))
    ap.add_argument("--mesh", default=None, choices=[None, "single",
                                                     "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [args.mesh] if args.mesh else ["single", "multi"]

    results = []
    for arch in archs:
        for shape in shapes:
            reason = cell_supported(arch, shape)
            for mesh_kind in meshes:
                path = artifact_path(arch, shape, mesh_kind)
                if reason:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": mesh_kind, "skipped": reason}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[skip] {arch} {shape} {mesh_kind}: {reason}")
                    continue
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {arch} {shape} {mesh_kind}")
                    continue
                print(f"[run] {arch} {shape} {mesh_kind} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mesh_kind)
                    rec["status"] = "ok"
                    print(f"  ok: lower={rec['lower_s']}s "
                          f"compile={rec['compile_s']}s "
                          f"mem/dev={rec['memory']['per_device_total']/2**30:.2f}GiB "
                          f"dominant={rec['roofline']['dominant']}",
                          flush=True)
                except Exception as e:  # record failures, keep sweeping
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error", "error": str(e)[:2000],
                           "trace": traceback.format_exc()[-4000:]}
                    print(f"  ERROR: {str(e)[:300]}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                results.append(rec)
    print(f"done ({len(results)} cells run)")


if __name__ == "__main__":
    main()
