"""Benchmark harness — one function per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only table7,...]

Output: ``name,us_per_call,derived`` CSV rows per benchmark, where
``derived`` carries the paper-metric (speedup / bytes / predicted-TPU
latency) for that table.  Big graphs run at a labeled synthesis scale
(see benchmarks/common.py); latency *ratios* (the paper's ablation
claims) are scale-free.
"""
from __future__ import annotations

import argparse

from . import (fig14_order, fig15_fusion, fig16_overlap, roofline_report,
               table7_latency, table8_binary, table10_loh)

ALL = {
    "table7": table7_latency.run,
    "table8": table8_binary.run,
    "fig14": fig14_order.run,
    "fig15": fig15_fusion.run,
    "fig16": fig16_overlap.run,
    "table10": table10_loh.run,
    "roofline": roofline_report.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small graphs only (CI smoke of the harness)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    names = [n.strip() for n in args.only.split(",") if n.strip()] \
        or list(ALL)
    print("benchmark,name,us_per_call,derived")
    for n in names:
        ALL[n](quick=args.quick)


if __name__ == "__main__":
    main()
