"""Compiler Step 3 — fiber-shard data partitioning (paper §6.5, Fig. 8).

The adjacency matrix A is split into *shards* of N1 rows; each shard into
*sub-shards* of N1 columns.  The feature matrix H is split into *fibers* of
N2 columns; each fiber into *sub-fibers* of N1 rows.  Every layer consumes
and produces the same (N1, N2) layout, so no inter-layer repartitioning is
needed (partition-centric execution, Algorithms 6-8).

TPU adaptation (see DESIGN.md §2): each sub-shard is stored as a *blocked
ELL* tile — rows sorted, per-row edges contiguous, padded to the tile's max
row degree (rounded to a multiple of 8 lanes).  Destination-sorting at
compile time replaces the FPGA's runtime RAW-hazard reorder unit; ELL
row-ownership replaces the banked-SRAM shuffle networks.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

from ..graph import Graph

LANE = 8  # pad max-nnz to a multiple of this (TPU sublane friendliness)


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    n1: int               # rows per shard == cols per sub-shard
    n2: int               # feature columns per fiber
    vmem_budget_bytes: int = 0  # informational: what drove the choice
    width_cap: int = 512  # max ELL width; wider rows are sliced into
                          # multiple accumulating tiles (power-law guard)

    def n_blocks(self, n_vertices: int) -> int:
        return math.ceil(n_vertices / self.n1)

    def n_fibers(self, f: int) -> int:
        return math.ceil(f / self.n2)


def choose_partition(
    n_vertices: int,
    f_max: int,
    vmem_budget_bytes: int = 3 << 20,   # paper: 3MB Feature Buffer per PE
    dtype_bytes: int = 4,
    n1_cap: int = 16384,                # paper: N_F1 = 16384
) -> PartitionConfig:
    """Pick (N1, N2) so a feature sub-fiber tile fits the buffer budget.

    Mirrors the paper's buffer sizing: N1 is the largest power of two such
    that an N1 x N2 tile (plus double-buffering already accounted in the
    budget) fits, capped by N_F1 and |V|."""
    n2 = min(128, max(LANE, 1 << (max(f_max, 1) - 1).bit_length()
                      if f_max < 128 else 128))
    n1 = 1 << int(math.log2(max(vmem_budget_bytes // (n2 * dtype_bytes), LANE)))
    n1 = int(min(n1, n1_cap))
    # Do not over-partition tiny graphs.
    while n1 >= 2 * n_vertices and n1 > LANE:
        n1 //= 2
    return PartitionConfig(n1=n1, n2=n2, vmem_budget_bytes=vmem_budget_bytes)


@dataclasses.dataclass
class ELLTile:
    """Sub-shard A(j, k) in blocked-ELL form (dst-major)."""

    shard_row: int            # j: destination block index
    shard_col: int            # k: source block index
    cols: np.ndarray          # int32 [n1, width] local src index, 0 pad
    vals: np.ndarray          # float32 [n1, width], 0 pad
    edge_pos: np.ndarray      # int32 [n1, width] global edge id, -1 pad
    nnz: int                  # true number of edges in this tile

    @property
    def width(self) -> int:
        return int(self.cols.shape[1])


@dataclasses.dataclass
class PartitionedGraph:
    config: PartitionConfig
    n_vertices: int
    n_edges: int
    n_blocks: int
    # (j, k) -> one or more ELL slices (several when a row block exceeds
    # the width cap; slices accumulate into the same output tile).
    tiles: Dict[Tuple[int, int], List[ELLTile]]
    # For MEAN aggregation: 1/in-degree per vertex (padded length).
    inv_in_degree: np.ndarray

    def tile_bytes(self) -> int:
        return sum(t.cols.nbytes + t.vals.nbytes + t.edge_pos.nbytes
                   for ts in self.tiles.values() for t in ts)

    def total_nnz(self) -> int:
        return sum(t.nnz for ts in self.tiles.values() for t in ts)

    # -------------------------------------------------------------- #
    # Working-set sizing for the partition-centric streaming executor
    # (one destination shard resident at a time, Algorithms 6-8).
    # -------------------------------------------------------------- #
    def subfiber_bytes(self, f_pad: int, dtype_bytes: int = 4) -> int:
        """Bytes of one staged source block: an [n1, f_pad] sub-fiber."""
        return self.config.n1 * int(f_pad) * dtype_bytes

    def shard_tile_bytes(self, j: int) -> int:
        """Bytes of destination shard ``j``'s sub-shard tiles (row j of
        the (j, k) tile grid) — the EDGE-buffer half of its working set."""
        return sum(t.cols.nbytes + t.vals.nbytes + t.edge_pos.nbytes
                   for k in range(self.n_blocks)
                   for t in self.tiles.get((j, k), []))

    def shard_working_set_bytes(self, j: int, sources, f_pad: int) -> int:
        """Device bytes to stage destination shard ``j``: its tiles plus
        the source sub-fibers ``sources`` it gathers from."""
        return (self.shard_tile_bytes(j)
                + len(set(sources)) * self.subfiber_bytes(f_pad))


# --------------------------------------------------------------------------- #
# Device-placement halo sets (multi-device partition-centric execution).
#
# When destination shards are placed on the devices of a mesh, each device
# owns the output sub-fibers of its assigned row blocks.  For a given
# layer, the *halo set* of a device is the set of source blocks its shards
# gather from but it does not own — exactly the sub-fibers that must move
# over the interconnect before the layer can run.  Computing the sets at
# compile time makes the exchange volume a manifest fact, the software
# analogue of the paper's compile-time data-movement plan.
# --------------------------------------------------------------------------- #
def halo_sets(assignment: List[int], sources: Dict[str, List[int]],
              n_devices: int) -> List[List[int]]:
    """Per-device halo sets for one layer.

    ``assignment`` maps row block -> owning device (LPT output);
    ``sources`` is the layer's residency table (destination shard ->
    source blocks it gathers from, stringified keys as in the manifest).
    Returns, per device, the sorted source blocks it needs but does not
    own.  Layers whose shards only read their own row block (GEMM,
    vector-add, activations) get empty halo sets.
    """
    owned: List[set] = [set() for _ in range(n_devices)]
    for j, d in enumerate(assignment):
        owned[d].add(j)
    need: List[set] = [set() for _ in range(n_devices)]
    for js, ks in sources.items():
        d = assignment[int(js)]
        need[d].update(int(k) for k in ks)
    return [sorted(need[d] - owned[d]) for d in range(n_devices)]


def partition_graph(g: Graph, cfg: PartitionConfig) -> PartitionedGraph:
    """COO -> fiber-shard blocked-ELL tiles.  O(|V| + |E|) (paper §8.1)."""
    n1 = cfg.n1
    nb = cfg.n_blocks(g.n_vertices)
    gs = g.sorted_by_dst()
    src, dst, w = gs.src, gs.dst, gs.weight
    eid = np.lexsort((g.src, g.dst)).astype(np.int32)  # original edge ids

    jb = dst // n1
    kb = src // n1
    key = jb.astype(np.int64) * nb + kb
    order = np.argsort(key, kind="stable")
    src, dst, w, eid, key = (a[order] for a in (src, dst, w, eid, key))

    tiles: Dict[Tuple[int, int], List[ELLTile]] = {}
    uniq = np.unique(key)
    lows = np.searchsorted(key, uniq, side="left")
    highs = np.searchsorted(key, uniq, side="right")
    for uk, lo, hi in zip(uniq, lows, highs):
        j, k = int(uk // nb), int(uk % nb)
        ls = (src[lo:hi] - k * n1).astype(np.int32)
        ld = (dst[lo:hi] - j * n1).astype(np.int32)
        lw = w[lo:hi]
        le = eid[lo:hi]
        # rows are dst-sorted already; per-row slot index:
        counts = np.bincount(ld, minlength=n1)
        row_start = np.zeros(n1 + 1, np.int64)
        np.cumsum(counts, out=row_start[1:])
        slot = (np.arange(hi - lo) - row_start[ld]).astype(np.int64)
        full_width = int(counts.max())
        slices = []
        for s0 in range(0, full_width, cfg.width_cap):
            sel = (slot >= s0) & (slot < s0 + cfg.width_cap)
            if not sel.any():
                continue
            sw = int(counts.clip(s0, s0 + cfg.width_cap).max() - s0)
            width = max(LANE, int(math.ceil(sw / LANE) * LANE))
            cols = np.zeros((n1, width), np.int32)
            vals = np.zeros((n1, width), np.float32)
            epos = np.full((n1, width), -1, np.int32)
            r, c = ld[sel], (slot[sel] - s0).astype(np.int64)
            cols[r, c] = ls[sel]
            vals[r, c] = lw[sel]
            epos[r, c] = le[sel]
            slices.append(ELLTile(j, k, cols, vals, epos,
                                  nnz=int(sel.sum())))
        tiles[(j, k)] = slices

    indeg = np.bincount(g.dst, minlength=nb * n1).astype(np.float32)
    inv = 1.0 / np.maximum(indeg, 1.0)
    return PartitionedGraph(
        config=cfg, n_vertices=g.n_vertices, n_edges=g.n_edges,
        n_blocks=nb, tiles=tiles, inv_in_degree=inv.astype(np.float32),
    )
