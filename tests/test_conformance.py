"""repro.obs conformance + attribution — PR 8 acceptance tests.

  * ``predict_loh`` residency terms: device < host-streaming, overlap
    helps, constants injectable, unknown residency refused;
  * ``ExecStats.per_layer`` populated on device and host paths and
    merged (not clobbered) by ``ExecStats.add``;
  * a synthetic 4-thread trace round-trips through the span DAG with
    the critical path exactly matching the known span nesting;
  * overlapped ``stage`` spans induce ~0 stall, serialized ones expose
    the staging time;
  * on a real traced host-streaming run the least-squares-calibrated
    model error is strictly lower than the uncalibrated error;
  * the attribution table joins wall time / staged bytes back to
    decoded instruction index ranges;
  * the trajectory gate prices the new ``model_error`` metrics.
"""
import json
import types

import jax.numpy as jnp
import pytest

from repro.core import graph as G
from repro.core.passes.partition import PartitionConfig
from repro.core.perfmodel import (ModelConstants, block_costs, layer_costs,
    predict_loh)
from repro.engine import Engine
from repro.engine.executor import ExecStats
from repro.obs import (DEFAULT_SPECS, attribution_table, build_dag,
                       build_report, fit_stage_bw, ls_scale, nrmse,
                       parse_spans, tracing)

GEOM = PartitionConfig(n1=32, n2=8)


def _g(nv=90, ne=340, f=8, c=3, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _compiled(eng, name, g):
    prog = eng.compile(name, g)
    if prog.source is None:          # program-cache hit returns a slim copy
        prog = eng.compile(name, g, use_cache=False)
    return prog


# --------------------------------------------------------------------------- #
# perfmodel: residency-aware predict_loh (satellite).
# --------------------------------------------------------------------------- #
def _program():
    eng = Engine(geometry=GEOM, n_pes=4)
    return _compiled(eng, "b1", _g()).source.program


def test_predict_loh_host_streaming_adds_staging_time():
    prog = _program()
    t_dev = predict_loh(prog, residency="device")
    t_host = predict_loh(prog, residency="host")
    t_host_serial = predict_loh(prog, residency="host", overlap=False)
    assert 0 < t_dev < t_host <= t_host_serial


def test_predict_loh_constants_injection():
    prog = _program()
    slow_pcie = ModelConstants(stage_bw=1e9)
    assert predict_loh(prog, residency="host", constants=slow_pcie) \
        > predict_loh(prog, residency="host")
    # stage bandwidth is a host-path term only: device time unchanged
    assert predict_loh(prog, residency="device", constants=slow_pcie) \
        == pytest.approx(predict_loh(prog, residency="device"))


def test_predict_loh_unknown_residency_refused():
    prog = _program()
    with pytest.raises(ValueError):
        predict_loh(prog, residency="accelerator")


def test_layer_costs_sum_to_predict_loh_and_expose_blocks():
    prog = _program()
    lcs = layer_costs(prog, residency="host")
    assert sum(lc.t for lc in lcs) == pytest.approx(
        predict_loh(prog, residency="host"))
    bcs = block_costs(prog)
    assert sum(b.flops for b in bcs) == pytest.approx(
        sum(lc.flops for lc in lcs))
    assert all(b.t >= max(b.t_compute, b.t_memory) - 1e-18 for b in bcs)


# --------------------------------------------------------------------------- #
# ExecStats.per_layer: populated everywhere, merged by add (satellite).
# --------------------------------------------------------------------------- #
def test_per_layer_populated_on_device_and_host_paths():
    g = _g()
    x = jnp.asarray(G.random_features(g, seed=1))
    eng = Engine(geometry=GEOM, n_pes=4)
    prog = _compiled(eng, "b1", g)
    for residency in ("device", "host"):
        eng.run(prog, x, residency=residency)
        rows = eng.exec_stats.per_layer
        assert rows, residency
        assert {r["kernel"] for r in rows} \
            <= {"gemm", "spdmm", "sddmm", "vadd", "act"}
        for r in rows:
            assert r["wall_s"] > 0
            assert 0 <= r["instr_lo"] <= r["instr_hi"]
        if residency == "host":
            assert sum(r.get("h2d_bytes", 0) for r in rows) \
                == eng.exec_stats.h2d_bytes > 0


def test_exec_stats_add_merges_per_layer():
    a, b = ExecStats(), ExecStats()
    a.note_layer(layer=0, kernel="gemm", step=0, instr_lo=1, instr_hi=4,
                 wall_s=0.5, tile_ops=10)
    b.note_layer(layer=0, kernel="gemm", step=0, instr_lo=1, instr_hi=4,
                 wall_s=0.25, tile_ops=5)
    b.note_layer(layer=1, kernel="spdmm", step=1, instr_lo=5, instr_hi=9,
                 wall_s=1.0, tile_ops=7)
    b.halo_gather_bytes = 64
    a.add(b)
    assert a.halo_gather_bytes == 64
    assert len(a.per_layer) == 2
    gemm = next(r for r in a.per_layer if r["kernel"] == "gemm")
    assert gemm["wall_s"] == pytest.approx(0.75)   # accumulated
    assert gemm["tile_ops"] == 15
    assert gemm["instr_lo"] == 1                   # identity, not summed


# --------------------------------------------------------------------------- #
# Span DAG round-trip: 4 interleaved threads, known nesting (satellite).
# --------------------------------------------------------------------------- #
def _ev(name, ts, dur, tid, **args):
    return {"ph": "X", "name": name, "cat": "t", "ts": float(ts),
            "dur": float(dur), "pid": 1, "tid": tid, "args": args}


def test_trace_dag_four_thread_round_trip_critical_path():
    evs = [
        {"ph": "M", "name": "thread_name", "pid": 1, "tid": 0,
         "args": {"name": "main"}},
        _ev("root", 0, 1000, 0),
        _ev("c1", 10, 190, 0),
        _ev("c2", 200, 200, 0),
        _ev("c3", 400, 250, 0),
        _ev("c4", 650, 340, 0),
        # three other threads, alive across c1..c4's starts, so neither
        # containment nor the last-predecessor fallback can pull the
        # walk off the known chain
        _ev("w1", 5, 900, 1),
        _ev("w2", 5, 900, 2),
        _ev("w3", 5, 900, 3),
    ]
    # JSON round trip: analyze the serialized trace, not live dicts
    doc = json.loads(json.dumps({"traceEvents": evs}))
    spans = parse_spans(doc)
    assert [s.track for s in spans if s.name == "root"] == ["main"]
    dag = build_dag(doc)
    root = next(s for s in dag.spans if s.name == "root")
    kids = [dag.spans[i].name for i in root.children]
    assert kids == ["c1", "c2", "c3", "c4"]
    assert all(dag.spans[i].parent == root.index for i in root.children)

    cp = [s.name for s in dag.critical_path()]
    # the critical path IS the known nesting: the sequential child chain
    # explaining root's span, nothing from the overlapping threads
    assert cp == ["c1", "c2", "c3", "c4", "root"]
    summ = dag.summary()
    assert summ["makespan_us"] == pytest.approx(1000.0)
    assert summ["critical_path_us"] == pytest.approx(1000.0)
    assert summ["n_spans"] == 8


def test_stage_overlap_induces_zero_stall_serialization_exposes_it():
    def trace(stage_ts, compute1_ts):
        return {"traceEvents": [
            _ev("compute", 0, 100, 0, shard=0, layer=1),
            _ev("compute", compute1_ts, 100, 0, shard=1, layer=1),
            _ev("stage", stage_ts, 40, 1, shard=1, layer=1, bytes=4096),
        ]}

    # overlapped: the stage hid entirely under shard 0's compute
    dag = build_dag(trace(stage_ts=10, compute1_ts=100))
    stage = next(s for s in dag.spans if s.name == "stage")
    assert dag.stall_us()[stage.index] == pytest.approx(0.0, abs=1e-6)
    # producer edge exists either way
    c1 = next(s for s in dag.spans
              if s.name == "compute" and s.args["shard"] == 1)
    assert stage.index in dag.producers[c1.index]

    # serialized: the same transfer after the compute exposes its 40µs
    dag = build_dag(trace(stage_ts=100, compute1_ts=140))
    stage = next(s for s in dag.spans if s.name == "stage")
    assert dag.stall_us()[stage.index] == pytest.approx(40.0, abs=1e-2)


# --------------------------------------------------------------------------- #
# Real traced run: conformance join + calibration (tentpole acceptance).
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def traced_run():
    g = _g(nv=120, ne=460)
    x = jnp.asarray(G.random_features(g, seed=1))
    eng = Engine(geometry=GEOM, n_pes=4)
    prog = _compiled(eng, "b3", g)
    eng.run(prog, x, residency="host")          # warm (jit compiles)
    with tracing() as t:
        eng.run(prog, x, residency="host")
    return prog, eng, t.events()


def test_calibrated_error_strictly_lower(traced_run):
    prog, eng, events = traced_run
    rep = build_report(prog, eng.exec_stats, residency="host",
                       events=events)
    assert rep.per_layer and rep.measured_s > 0
    # per-mode: the through-origin LS fit can never lose
    for m, e in rep.model_error.items():
        assert rep.model_error_calibrated[m] <= e + 1e-12
        assert rep.scales[m] > 0
    # overall: strictly lower (wall-clock noise makes exact fits
    # impossible, so the fitted scale must strictly reduce the error)
    assert rep.model_error_overall_calibrated < rep.model_error_overall
    # effective constants cover the modes seen + the traced staging fit
    assert "stage_bw" in rep.calibrated_constants
    assert set(rep.calibrated_constants) <= set(rep.constants)
    # the report serializes (CI writes it into BENCH_fullgraph.json)
    d = json.loads(json.dumps(rep.to_dict()))
    assert d["model_error_overall_calibrated"] \
        == pytest.approx(rep.model_error_overall_calibrated)
    md = rep.to_markdown()
    assert "Cost-model conformance" in md and "| mode |" in md


def test_fit_stage_bw_from_traced_stage_spans(traced_run):
    _, eng, events = traced_run
    bw = fit_stage_bw(events)
    assert bw is not None and bw > 0
    # sanity: a synthetic 1 GB/s trace fits exactly
    evs = [_ev("stage", 0, 1000, 0, bytes=10 ** 6),
           _ev("stage", 2000, 2000, 0, bytes=2 * 10 ** 6)]
    assert fit_stage_bw(evs) == pytest.approx(1e9)


def test_attribution_table_joins_instruction_ranges(traced_run):
    prog, eng, events = traced_run
    rows = attribution_table(events)
    layer_rows = [r for r in rows if r["shard"] is None]
    shard_rows = [r for r in rows if r["shard"] is not None]
    assert layer_rows and shard_rows
    for r in layer_rows:
        assert 0 <= r["instr_lo"] <= r["instr_hi"]
        assert r["wall_us"] > 0
    # staged bytes attribute to the decoded layers that streamed them
    assert sum(r["staged_bytes"] for r in layer_rows) \
        == eng.exec_stats.h2d_bytes > 0
    # the critical path of the same trace stays within the makespan
    summ = build_dag(events).summary()
    assert 0 < summ["critical_path_us"] <= summ["makespan_us"] + 1e-3


def test_build_report_refuses_slim_or_unrun_programs(traced_run):
    prog, eng, _ = traced_run
    with pytest.raises(ValueError, match="use_cache=False"):
        build_report(types.SimpleNamespace(source=None), eng.exec_stats)
    with pytest.raises(ValueError, match="per_layer"):
        build_report(prog, ExecStats())


# --------------------------------------------------------------------------- #
# LS helpers + trajectory gate wiring (satellite).
# --------------------------------------------------------------------------- #
def test_ls_scale_is_exact_minimizer():
    pairs = [(1.0, 2.1), (2.0, 3.9), (3.0, 6.3)]
    a = ls_scale(pairs)
    for probe in (a * 0.9, a * 1.1, 1.0):
        assert nrmse(pairs, a) <= nrmse(pairs, probe) + 1e-12
    assert ls_scale([]) == 1.0
    assert nrmse([]) == 0.0


def test_trajectory_gate_prices_model_error():
    specs = {s.path: s for s in DEFAULT_SPECS["BENCH_fullgraph.json"]}
    for mode in ("gemm", "spdmm"):
        s = specs[f"models.0.conformance.model_error.{mode}"]
        assert s.direction == "lower"
    assert specs["models.0.conformance.model_error_overall"].direction \
        == "lower"
    # calibration must keep strictly reducing the error (gain >= 0)
    assert specs["models.0.conformance.calibration_gain"].direction \
        == "higher"
