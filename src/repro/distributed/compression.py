"""Gradient compression with error feedback (1-bit-Adam-family trick).

``compress``/``decompress`` implement per-leaf symmetric int8 quantization
with a power-of-two-free scale (max-abs / 127).  ``ef_transform`` wraps a
gradient pytree with error-feedback residual state so the quantization
error is carried to the next step instead of being lost — the standard
requirement for convergence under compressed communication.

Deployment note: under GSPMD the data-parallel reduction is emitted by
XLA, so the wire format is not directly programmable from here; on a real
cluster this module pairs with a shard_map reduce-scatter over the int8
payload (see distributed/pipeline.py for the manual-collective pattern).
In this repo the compression path is numerically exercised end-to-end
(quantize -> dequantize -> optimizer) and its convergence is covered by
tests/test_distributed.py.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress(q: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def ef_transform(grads: Any, ef_state: Any) -> Tuple[Any, Any]:
    """Simulate int8 communication of (grads + residual); returns the
    dequantized gradients and the updated residual state."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = compress(corrected)
        deq = decompress(q, s)
        return deq.astype(g.dtype), corrected - deq

    gl, treedef = jax.tree.flatten(grads)
    el = jax.tree.leaves(ef_state)
    pairs = [one(g, e) for g, e in zip(gl, el)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))
