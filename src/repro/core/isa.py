"""GraphAGILE instruction set (paper §5.3, Fig. 3).

Every high-level instruction is 128 bits, packed as ``uint32[4]``:

  word0: opcode(8) | pe_id(8) | act(6) | act_en(1) | on_edges(1) | flags(8)
  word1: arg0(16) | arg1(16)
  word2: arg2(16) | arg3(16)
  word3: arg4(32)          (sizes that may exceed 16 bits: nnz, counts)

The flags byte carries the double-buffer mutex annotations the compiler
emits (paper §6.6): LOCK marks a memory-read that acquires a buffer,
UNLOCK marks the compute instruction that releases it.

Since format VERSION 3 the binary is *load-bearing*: the runtime
(`repro.engine`) executes a program by decoding this stream, so every
dispatch decision is encoded in instruction fields.  Per-opcode argument
conventions:

  CSI     args=(layer_id, layer_type, f_in, f_out)  arg4=#tiling blocks
          act carries the layer's mode selector: the AggOp for AGGREGATE
          layers, the Activation for ACTIVATION layers, 1 for pair-sum
          VECTOR_INNER layers; on_edges set for edge-valued layers.
  GEMM    args=(j, k, i, 0)        out row-block j, reduction fiber k,
                                   output fiber i; arg4 = n1*n2*n2 MACs
  SPDMM   args=(j, k, i, s<<1|dyn) sub-shard A(j,k) ELL slice s, input
                                   fiber i, dyn=per-edge weights; arg4=nnz
  SDDMM   args=(j, k, i, s)        arg4 = nnz
  VADD    args=(i, j, 0, 0)
  ACT/AFFINE (standalone layers)   vertex: args=(layer_id, i, j, 0)
                                   edge:   args=(layer_id, j, k, s)
  ACT/AFFINE (fused epilogue)      args=(layer_id, 0, 0, 0); applied to
                                   the tiling block's accumulator
  MEM_WR  args=(Buf.RESULT, region, i, j) / (.., OUT_EDGE, j, k);
          FLAG_LAST terminates the enclosing tiling block.

A tiling block is the instruction span up to (and including) the first
FLAG_LAST; a layer block is a CSI plus its arg4-announced tiling blocks;
HALT ends the program.
"""
from __future__ import annotations

import dataclasses
import enum
import struct
from typing import List, Tuple

import numpy as np

MAGIC = 0x47414749  # "GAGI"
VERSION = 3         # v3: self-describing coordinates (see module docstring)
HEADER_BYTES = 16
INSTR_BYTES = 16


class Opcode(enum.IntEnum):
    NOP = 0
    CSI = 1        # control & scheduling: heads a Layer Block
    MEM_RD = 2
    MEM_WR = 3
    GEMM = 4
    SPDMM = 5
    SDDMM = 6
    VADD = 7
    ACT = 8
    AFFINE = 9     # standalone batchnorm (only when fusion disabled)
    HALT = 10


class Buf(enum.IntEnum):
    EDGE = 0
    FEATURE = 1
    WEIGHT = 2
    RESULT = 3


class Region(enum.IntEnum):
    SUBSHARD = 0       # A(j, k)
    SUBFIBER = 1       # H(i, j)   (fiber i, row-block j)
    WEIGHT_BLOCK = 2   # W(k, i)
    EDGE_WEIGHTS = 3   # per-edge scalar array segment
    OUT_SUBFIBER = 4
    OUT_EDGE = 5


FLAG_LOCK = 1
FLAG_UNLOCK = 2
FLAG_ACC = 4        # accumulate into result buffer
FLAG_LAST = 8       # last instruction of a tiling block


@dataclasses.dataclass
class Instr:
    op: Opcode
    pe: int = 0
    act: int = 0
    act_en: bool = False
    on_edges: bool = False
    flags: int = 0
    args: Tuple[int, int, int, int] = (0, 0, 0, 0)
    arg4: int = 0

    # ------------------------------------------------------------------ #
    def encode(self) -> np.ndarray:
        # Since ISA v3 these fields drive execution, so out-of-range
        # values must fail loudly at codegen instead of silently wrapping
        # into a wrong-but-decodable binary.
        for name, val, hi in (("pe", self.pe, 0xFF), ("act", self.act, 0x3F),
                              ("flags", self.flags, 0xFF),
                              ("arg4", self.arg4, 0xFFFFFFFF),
                              *((f"args[{i}]", a, 0xFFFF)
                                for i, a in enumerate(self.args))):
            if not 0 <= int(val) <= hi:
                raise ValueError(
                    f"{self.op.name}: field {name}={val} exceeds its "
                    f"encoding range [0, {hi}] — model/graph too large "
                    "for the 128-bit instruction format")
        w0 = ((int(self.op) & 0xFF)
              | (self.pe & 0xFF) << 8
              | (self.act & 0x3F) << 16
              | (1 << 22 if self.act_en else 0)
              | (1 << 23 if self.on_edges else 0)
              | (self.flags & 0xFF) << 24)
        a = [int(x) & 0xFFFF for x in self.args]
        w1 = a[0] | a[1] << 16
        w2 = a[2] | a[3] << 16
        w3 = int(self.arg4) & 0xFFFFFFFF
        return np.array([w0, w1, w2, w3], dtype=np.uint32)

    @staticmethod
    def decode(words: np.ndarray) -> "Instr":
        w0, w1, w2, w3 = (int(w) for w in words)
        opcode = w0 & 0xFF
        try:
            op = Opcode(opcode)
        except ValueError:
            raise ValueError(
                f"unknown opcode {opcode} (word0=0x{w0:08X}); valid "
                f"opcodes are 0..{max(Opcode)}") from None
        return Instr(
            op=op,
            pe=(w0 >> 8) & 0xFF,
            act=(w0 >> 16) & 0x3F,
            act_en=bool(w0 >> 22 & 1),
            on_edges=bool(w0 >> 23 & 1),
            flags=(w0 >> 24) & 0xFF,
            args=(w1 & 0xFFFF, w1 >> 16, w2 & 0xFFFF, w2 >> 16),
            arg4=w3,
        )

    def __repr__(self) -> str:  # compact trace form
        f = "".join(c for c, m in zip("LUAZ", (1, 2, 4, 8)) if self.flags & m)
        return (f"{self.op.name}(pe{self.pe} args={list(self.args)} "
                f"a4={self.arg4}{' ' + f if f else ''})")


# --------------------------------------------------------------------------- #
def assemble(instrs: List[Instr]) -> bytes:
    """Binary file: 16-byte header + 16 bytes per instruction (Table 8)."""
    header = struct.pack("<IIII", MAGIC, VERSION, len(instrs), 0)
    if not instrs:
        return header
    body = np.stack([i.encode() for i in instrs]).astype("<u4").tobytes()
    return header + body


def disassemble(blob: bytes) -> List[Instr]:
    """Decode a binary produced by :func:`assemble`.

    Raises ``ValueError`` (never a bare assert / struct.error / numpy
    reshape crash) on a wrong magic, an incompatible format version, a
    payload that disagrees with the header's instruction count in
    EITHER direction (truncation or trailing bytes), a body that is not
    a whole number of 16-byte instructions, or an out-of-range opcode —
    each error names the byte offset / instruction index at fault.
    """
    if len(blob) < HEADER_BYTES:
        raise ValueError(
            f"GraphAGILE binary too short: {len(blob)} bytes, need at "
            f"least the {HEADER_BYTES}-byte header")
    magic, version, n, _ = struct.unpack_from("<IIII", blob, 0)
    if magic != MAGIC:
        raise ValueError(
            f"bad magic 0x{magic:08X} at offset 0: not a GraphAGILE "
            f"binary (expected 0x{MAGIC:08X} 'GAGI')")
    if version != VERSION:
        raise ValueError(
            f"unsupported GraphAGILE binary version {version} at "
            f"offset 4 (this runtime decodes version {VERSION})")
    expected = HEADER_BYTES + n * INSTR_BYTES
    if len(blob) < expected:
        raise ValueError(
            f"truncated GraphAGILE binary: header announces {n} "
            f"instructions ({expected} bytes) but only {len(blob)} "
            "bytes are present")
    if len(blob) > expected:
        raise ValueError(
            f"oversized GraphAGILE binary: header announces {n} "
            f"instructions ({expected} bytes) but {len(blob)} bytes are "
            f"present — {len(blob) - expected} trailing byte(s) at "
            f"offset {expected}")
    words = np.frombuffer(blob, dtype="<u4", offset=HEADER_BYTES,
                          count=n * 4).reshape(n, 4)
    out: List[Instr] = []
    for idx, w in enumerate(words):
        try:
            out.append(Instr.decode(w))
        except ValueError as e:
            raise ValueError(
                f"instruction {idx} (byte offset "
                f"{HEADER_BYTES + idx * INSTR_BYTES}): {e}") from None
    return out
