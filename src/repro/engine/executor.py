"""Binary-driven overlay executor (paper Alg. 9, ISA v3 runtime).

Unlike the original object-graph executor, this one consumes ONLY:

  * the decoded 128-bit instruction stream (layer/tiling-block dispatch,
    kernel kinds, tile coordinates, reduction order, fused epilogues,
    PE assignment),
  * the program manifest (weight-key indirections, dataflow operands,
    scalar coefficients), and
  * the DDR payload (weight arrays + fiber-shard ELL tiles).

No in-memory ``Program``/``LayerIR`` objects appear on the hot path, so a
``CompiledProgram`` loaded from a ``.gagi`` file executes identically to
one compiled in-process — the overlay contract: one fixed substrate, any
(model, graph) pair, driven purely by its binary.

Execution is layer by layer; within a layer, tiling blocks are issued in
PE-interleaved order (round-robin across the PE streams the scheduler
encoded into the instructions).  ``overlap=True`` dispatches tile ops
asynchronously (the double-buffering analogue); ``overlap=False`` forces
every tiling block to completion (Fig. 16 ablation baseline).

Graph-as-data mode: ``run``/``run_batch`` accept an optional
``graph_data`` structure that *replaces the program's baked ELL tiles at
runtime* — the Dynasparse-style normalization the sampling layer uses.
The program is compiled once per geometry bucket (against the bucket's
canonical template, ``repro.sampling.buckets``), and each request ships
its actual topology as arrays matching the canonical layout::

    {"tiles": {"j:k:s": {"cols": int32 [n1, w], "vals": float32 [n1, w],
                         "mask": bool  [n1, w], "epos": int32  [n1, w]}},
     "inv_in_degree": float32 [nb * n1]}

``epos`` uses the same convention as the baked tiles (original COO edge
index, ``-1`` on pad slots).  In ``run_batch`` the structure is stacked
with a leading batch axis and vmapped together with the features, so N
*different* subgraphs sharing one bucket execute as ONE binary pass.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ack import ACK
from repro.core.ir import Activation, AggOp, LayerType
from repro.core.reference import apply_activation

from .decoder import LayerPlan, TilePlan
from .program import CompiledProgram


def _tile_arrays(pg, gtiles, j: int, k: int, s: int):
    """(cols, vals, mask, epos) of tile (j, k, s) — from the runtime
    ``graph_data`` when present, else from the program's baked tiles.
    Shapes agree by the canonical-layout contract, so the same traced
    computation serves both sources.  Baked arrays stay on the host
    (numpy) — consumers device-convert implicitly on use, so unused
    elements cost nothing on the eager path."""
    if gtiles is None:
        t = pg.tiles[(j, k)][s]
        return t.cols, t.vals, t.edge_pos >= 0, t.edge_pos
    d = gtiles[f"{j}:{k}:{s}"]
    return d["cols"], d["vals"], d["mask"], d["epos"]


@dataclasses.dataclass
class ExecStats:
    tile_ops: int = 0
    layers: int = 0
    runs: int = 0

    def add(self, other: "ExecStats") -> None:
        self.tile_ops += other.tile_ops
        self.layers += other.layers
        self.runs += other.runs


class BinaryExecutor:
    """Executes a CompiledProgram by interpreting its decoded binary.

    ``stats`` holds the counters of the most recent :meth:`run` only
    (reset at entry); ``total`` accumulates across the executor's
    lifetime.  A batched :meth:`run_batch` counts as ONE pass: the
    instruction stream is traversed once, whatever the batch size.
    """

    def __init__(self, backend: str = "xla", overlap: bool = True,
                 interpret: bool = True) -> None:
        self.ack = ACK(backend=backend, interpret=interpret)
        self.overlap = overlap
        self.stats = ExecStats()        # per-run (last run)
        self.total = ExecStats()        # lifetime accumulation

    # ------------------------------------------------------------------ #
    def run(self, prog: CompiledProgram, x: jnp.ndarray,
            weights: Optional[Dict[str, np.ndarray]] = None,
            graph_data: Optional[dict] = None) -> jnp.ndarray:
        self.stats = ExecStats(runs=1)
        plan = prog.plan()
        man = prog.manifest
        pg = prog.pgraph
        gtiles = graph_data["tiles"] if graph_data is not None else None
        weights = weights if weights is not None else prog.weights
        lmeta = man["layers"]
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        vp = nb * n1
        nv = pg.n_vertices

        def f_pad(f: int) -> int:
            return ((max(f, 1) + n2 - 1) // n2) * n2

        def pad_vertex(a: jnp.ndarray, fp: int) -> jnp.ndarray:
            a = jnp.asarray(a, jnp.float32)
            return jnp.pad(a, ((0, vp - a.shape[0]),
                               (0, fp - a.shape[1])))

        fin_pad0 = f_pad(plan.layers[0].f_in)
        x_pad = pad_vertex(x, max(fin_pad0,
                                  ((x.shape[1] + n2 - 1) // n2) * n2))
        vals: Dict[int, jnp.ndarray] = {}       # layer -> padded output
        edge_vals: Dict[int, jnp.ndarray] = {}  # layer -> (E,) edge scores
        inv_deg = jnp.asarray(graph_data["inv_in_degree"]
                              if graph_data is not None
                              else pg.inv_in_degree)

        for lp in plan.layers:
            meta = lmeta[str(lp.layer_id)]
            self.stats.layers += 1
            ewl = meta.get("edge_weight_layer")
            feat_parents = [p for p in meta["parents"] if p != ewl]
            h_in = (vals.get(feat_parents[0], x_pad) if feat_parents
                    else x_pad)
            lt = lp.layer_type

            if lt == LayerType.AGGREGATE:
                vals[lp.layer_id] = self._run_aggregate(
                    lp, meta, pg, h_in, edge_vals, inv_deg, weights,
                    gtiles)
            elif lt == LayerType.LINEAR:
                vals[lp.layer_id] = self._run_linear(
                    lp, meta, pg, h_in, weights)
            elif lt == LayerType.VECTOR_INNER:
                edge_vals[lp.layer_id] = self._run_vector_inner(
                    lp, meta, pg, h_in, weights, gtiles)
            elif lt == LayerType.VECTOR_ADD:
                a_id, b_id = meta["operands"]
                xa = x_pad if a_id == -1 else vals[a_id]
                xb = x_pad if b_id == -1 else vals[b_id]
                vals[lp.layer_id] = self._run_vadd(
                    lp, meta, pg, xa, xb, weights)
            elif lt in (LayerType.ACTIVATION, LayerType.BATCHNORM):
                if lp.on_edges:
                    src = edge_vals[feat_parents[0]]
                    edge_vals[lp.layer_id] = self._run_edge_act(
                        lp, pg, src, gtiles)
                else:
                    vals[lp.layer_id] = self._run_vertex_act(
                        lp, meta, pg, h_in, weights)
            else:
                raise ValueError(lt)
            if not self.overlap:
                tree = vals.get(lp.layer_id, edge_vals.get(lp.layer_id))
                jax.block_until_ready(tree)

        sink = man["sink"]
        self.total.add(self.stats)
        return vals[sink][:nv, :man["sink_f_out"]]

    # ------------------------------------------------------------------ #
    def run_batch(self, prog: CompiledProgram, xs: jnp.ndarray,
                  weights: Optional[Dict[str, np.ndarray]] = None,
                  graph_data: Optional[dict] = None) -> jnp.ndarray:
        """Execute ONE binary pass for a stacked ``[N, V, F]`` batch.

        The instruction stream is decoded and traversed once; every tile
        op is vectorized over the leading batch axis (``jax.vmap``), so N
        requests that share a compiled program pay the Python-side
        dispatch cost of a single request.  Per-run ``stats`` therefore
        report one pass worth of tile ops, matching the hardware story:
        the overlay executes the same binary, on wider data.

        The traced-and-jitted batched pass is memoized **on the
        program** per (batch shape, executor config): steady-state
        traffic — repeated batches of the same deployed (model, graph)
        pair — replays a compiled whole-program executable with zero
        Python-side instruction dispatch, which is what lets the
        serving runtime saturate the substrate.  (A ``weights``
        override bypasses the memo: the executable closes over the
        program's own weights.)
        """
        if xs.ndim != 3:
            raise ValueError(
                f"run_batch expects stacked [N, V, F] features, got "
                f"shape {tuple(xs.shape)}")
        if weights is not None:
            if graph_data is not None:
                return jax.vmap(lambda x, gd: self.run(
                    prog, x, weights=weights, graph_data=gd)
                )(xs, graph_data)
            return jax.vmap(lambda x: self.run(prog, x,
                                               weights=weights))(xs)
        # graph_data shapes are fixed by the program's canonical layout,
        # so (batch shape, presence flag) fully keys the executable.
        key = (tuple(xs.shape), str(xs.dtype), graph_data is not None,
               self.ack.backend, self.ack.interpret, self.overlap)
        cache = prog.__dict__.setdefault("_batch_exec", {})
        entry = cache.get(key)
        if entry is None:
            if graph_data is not None:
                fn = jax.jit(jax.vmap(
                    lambda x, gd: self.run(prog, x, graph_data=gd)))
                y = fn(xs, graph_data)  # traces now; run() sets stats
            else:
                fn = jax.jit(jax.vmap(lambda x: self.run(prog, x)))
                y = fn(xs)
            cache[key] = (fn, dataclasses.replace(self.stats))
            return y
        fn, stats = entry
        self.stats = dataclasses.replace(stats)
        self.total.add(self.stats)
        return fn(xs, graph_data) if graph_data is not None else fn(xs)

    # ------------------------------------------------------------------ #
    def _epilogue(self, tp: TilePlan, meta: dict, tile: jnp.ndarray,
                  weights, lo: int, hi: int) -> jnp.ndarray:
        """Fused scale/shift + activation, in decoded instruction order."""
        for kind, act_id in tp.epilogue:
            if kind == "affine":
                sc = jnp.asarray(np.asarray(
                    weights[meta["fused_scale"]], np.float32))
                sh = jnp.asarray(np.asarray(
                    weights[meta["fused_shift"]], np.float32))
                sc = jnp.pad(sc, (0, max(0, hi - sc.shape[0])))[lo:hi]
                sh = jnp.pad(sh, (0, max(0, hi - sh.shape[0])))[lo:hi]
                tile = self.ack.affine(tile, sc, sh)
            else:
                tile = self.ack.act(tile, Activation(act_id))
        return tile

    def _assemble(self, tiles: Dict[Tuple[int, int], jnp.ndarray], nb: int,
                  nf: int) -> jnp.ndarray:
        rows = []
        for j in range(nb):
            rows.append(jnp.concatenate([tiles[(i, j)] for i in range(nf)],
                                        axis=1))
        return jnp.concatenate(rows, axis=0)

    def _block_order(self, lp: LayerPlan) -> List[TilePlan]:
        """PE-interleaved issue order (round-robin across PE streams)."""
        streams: Dict[int, List[TilePlan]] = {}
        for tp in lp.tiles:
            streams.setdefault(tp.pe, []).append(tp)
        order: List[TilePlan] = []
        idx = 0
        keys = sorted(streams)
        while any(streams[k] for k in keys):
            k = keys[idx % len(keys)]
            if streams[k]:
                order.append(streams[k].pop(0))
            idx += 1
        return order

    # ------------------------------------------------------------------ #
    def _run_aggregate(self, lp, meta, pg, h_in, edge_vals, inv_deg,
                       weights, gtiles=None) -> jnp.ndarray:
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        nf = ((max(lp.f_in, 1) + n2 - 1) // n2)
        op = {AggOp.SUM: "sum", AggOp.MEAN: "mean",
              AggOp.MAX: "max", AggOp.MIN: "min"}[AggOp(lp.mode)]
        ewl = meta.get("edge_weight_layer")
        ew = edge_vals[ewl] if ewl is not None else None
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        init = (jnp.full((n1, n2), -3.4e38, jnp.float32) if op == "max" else
                jnp.full((n1, n2), 3.4e38, jnp.float32) if op == "min" else
                jnp.zeros((n1, n2), jnp.float32))
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            acc = init
            flag = jnp.zeros((n1,), bool)
            for ins in tp.compute:           # SPDMM steps, stream order
                jj, k, ii = ins.args[0], ins.args[1], ins.args[2]
                s, dyn = ins.args[3] >> 1, ins.args[3] & 1
                h_tile = jax.lax.dynamic_slice(
                    h_in, (k * n1, ii * n2), (n1, n2))
                cols, v, mask, epos = _tile_arrays(pg, gtiles, jj, k, s)
                if dyn:
                    v = jnp.where(mask, ew[jnp.maximum(epos, 0)], 0.0)
                acc, flag = self.ack.spdmm(h_tile, cols, v, mask, acc,
                                           flag, op)
                self.stats.tile_ops += 1
            if op in ("max", "min"):
                acc = jnp.where(flag[:, None], acc, 0.0)
            elif op == "mean":
                scale = jax.lax.dynamic_slice(inv_deg, (j * n1,), (n1,))
                acc = acc * scale[:, None]
            acc = self._epilogue(tp, meta, acc, weights,
                                 i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = acc
            if not self.overlap:
                jax.block_until_ready(acc)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_linear(self, lp, meta, pg, h_in, weights):
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        fi_pad = ((max(lp.f_in, 1) + n2 - 1) // n2) * n2
        fo_pad = ((max(lp.f_out, 1) + n2 - 1) // n2) * n2
        W = np.zeros((fi_pad, fo_pad), np.float32)
        W0 = np.asarray(weights[meta["W"]], np.float32)
        W[: W0.shape[0], : W0.shape[1]] = W0
        Wj = jnp.asarray(W)
        b = None
        if "b" in meta:
            b0 = np.asarray(weights[meta["b"]], np.float32)
            b = jnp.asarray(np.pad(b0, (0, fo_pad - b0.shape[0])))
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            acc = jnp.zeros((n1, n2), jnp.float32)
            for ins in tp.compute:           # GEMM steps: args=(j, k, i)
                k = ins.args[1]
                h_tile = jax.lax.dynamic_slice(
                    h_in, (j * n1, k * n2), (n1, n2))
                w_tile = jax.lax.dynamic_slice(
                    Wj, (k * n2, i * n2), (n2, n2))
                acc = self.ack.gemm(h_tile, w_tile, acc)
                self.stats.tile_ops += 1
            if b is not None:
                acc = acc + jax.lax.dynamic_slice(b, (i * n2,), (n2,))
            acc = self._epilogue(tp, meta, acc, weights,
                                 i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = acc
            if not self.overlap:
                jax.block_until_ready(acc)
        return self._assemble(out_tiles, nb, fo_pad // n2)

    # ------------------------------------------------------------------ #
    def _run_vector_inner(self, lp, meta, pg, h_in, weights, gtiles=None):
        n1, n2 = pg.config.n1, pg.config.n2
        pair = lp.mode == 1          # CSI mode bit — the binary decides
        ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
        for tp in self._block_order(lp):
            j, k, s = tp.out_j, tp.tile_k, tp.slice_id
            cols, _, mask, epos = _tile_arrays(pg, gtiles, j, k, s)
            acc = jnp.zeros(cols.shape, jnp.float32)
            for ins in tp.compute:           # SDDMM steps: args=(j,k,i,s)
                i = ins.args[2]
                h_dst = jax.lax.dynamic_slice(h_in, (j * n1, i * n2),
                                              (n1, n2))
                h_src = jax.lax.dynamic_slice(h_in, (k * n1, i * n2),
                                              (n1, n2))
                acc = self.ack.sddmm(h_dst, h_src, cols, mask, acc,
                                     pair_sum=pair)
                self.stats.tile_ops += 1
            acc = self._epilogue(tp, meta, acc, weights, 0, n2)
            idx = jnp.where(mask, epos, pg.n_edges)
            ew = ew.at[idx.ravel()].set(acc.ravel())
            if not self.overlap:
                jax.block_until_ready(ew)
        return ew[: pg.n_edges]

    # ------------------------------------------------------------------ #
    def _run_vadd(self, lp, meta, pg, xa, xb, weights):
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        alpha, beta = meta["alpha"], meta["beta"]
        fi_pad = max(xa.shape[1], xb.shape[1])
        nf = fi_pad // n2
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            ta = jax.lax.dynamic_slice(xa, (j * n1, i * n2), (n1, n2))
            tc = jax.lax.dynamic_slice(xb, (j * n1, i * n2), (n1, n2))
            t = self.ack.vadd(ta, tc, alpha, beta)
            self.stats.tile_ops += 1
            t = self._epilogue(tp, meta, t, weights, i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = t
            if not self.overlap:
                jax.block_until_ready(t)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_vertex_act(self, lp, meta, pg, h_in, weights):
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        fi_pad = ((max(lp.f_in, 1) + n2 - 1) // n2) * n2
        nf = fi_pad // n2
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            t = jax.lax.dynamic_slice(h_in, (j * n1, i * n2), (n1, n2))
            op = tp.compute[0]               # the ACT / AFFINE instr
            if lp.layer_type == LayerType.BATCHNORM:
                mu, sig, gam, bet = (
                    np.asarray(weights[meta[k]], np.float32)
                    for k in ("mu", "sigma", "gamma", "beta"))
                eps = float(meta.get("eps", 1e-5))
                sc = gam / np.sqrt(sig ** 2 + eps)
                sh = bet - mu * sc
                sc = np.pad(sc, (0, fi_pad - sc.shape[0]))
                sh = np.pad(sh, (0, fi_pad - sh.shape[0]))
                t = self.ack.affine(t, jnp.asarray(sc[i * n2:(i + 1) * n2]),
                                    jnp.asarray(sh[i * n2:(i + 1) * n2]))
            else:
                t = self.ack.act(t, Activation(op.act))
            self.stats.tile_ops += 1
            out_tiles[(i, j)] = t
            if not self.overlap:
                jax.block_until_ready(t)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_edge_act(self, lp, pg, ew_in, gtiles=None):
        """Edge activations; EDGE_SOFTMAX uses the two-pass tile scheme
        (max/sum accumulated per destination row across a shard's tiles,
        the Activation Unit's exp/divide applied per tile)."""
        act = Activation(lp.mode)
        if act != Activation.EDGE_SOFTMAX:
            out = apply_activation(ew_in, act)
            self.stats.tile_ops += len(lp.tiles)
            return out
        n1 = pg.config.n1
        nb = pg.n_blocks
        ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
        for j in range(nb):
            row_tiles = [(k, s) for (jj, k), ts in sorted(pg.tiles.items())
                         if jj == j for s in range(len(ts))]
            if not row_tiles:
                continue
            mx = jnp.full((n1,), -3.4e38, jnp.float32)
            for k, s in row_tiles:
                _, _, mask, epos = _tile_arrays(pg, gtiles, j, k, s)
                sc = jnp.where(mask, ew_in[jnp.maximum(epos, 0)], -3.4e38)
                mx = jnp.maximum(mx, jnp.max(sc, axis=1))
            mx = jnp.where(mx <= -3.4e38, 0.0, mx)
            den = jnp.zeros((n1,), jnp.float32)
            exps = []
            for k, s in row_tiles:
                _, _, mask, epos = _tile_arrays(pg, gtiles, j, k, s)
                e = jnp.where(mask, jnp.exp(ew_in[jnp.maximum(epos, 0)]
                                            - mx[:, None]), 0.0)
                den = den + jnp.sum(e, axis=1)
                exps.append((mask, epos, e))
                self.stats.tile_ops += 1
            den = jnp.maximum(den, 1e-12)
            for mask, epos, e in exps:
                out_t = e / den[:, None]
                idx = jnp.where(mask, epos, pg.n_edges)
                ew = ew.at[idx.ravel()].set(
                    jnp.where(mask, out_t, 0.0).ravel())
        return ew[: pg.n_edges]
