"""Parameter/activation sharding rules for the (pod, data, model) mesh.

MaxText-style logical rules, resolved by parameter *name*: tensor-parallel
dimensions (vocab, heads, ffn, experts) map to the ``model`` axis; batch
maps to ``(pod, data)``; everything small is replicated.  Leading stacked-
layer dimensions (from scan-over-layers) are never sharded.

ZeRO-1: `zero_spec` additionally shards optimizer-state copies along the
first divisible dimension over ``data`` (see distributed/zero.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

BATCH_AXES = ("pod", "data")
MODEL_AXIS = "model"

# rule: parameter leaf name -> base PartitionSpec (without stacked dims)
_NAME_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / head
    "embed": (MODEL_AXIS, None),
    "head": (None, MODEL_AXIS),
    # attention
    "wq": (None, MODEL_AXIS, None),
    "wk": (None, MODEL_AXIS, None),
    "wv": (None, MODEL_AXIS, None),
    "wo": (MODEL_AXIS, None),
    # mlp
    "wi": (None, MODEL_AXIS),
    "wg": (None, MODEL_AXIS),
    # moe (3D: d, E, f / f, E, d) — expert parallelism over model axis
    "moe_wi": (None, MODEL_AXIS, None),
    "moe_wg": (None, MODEL_AXIS, None),
    "moe_wo": (None, MODEL_AXIS, None),
    "router": (None, None),
    # mla
    "w_dq": (None, None),
    "w_uq": (None, MODEL_AXIS, None),
    "w_dkv": (None, None),
    "w_uk": (None, MODEL_AXIS, None),
    "w_uv": (None, MODEL_AXIS, None),
    # ssm / xlstm
    "w_in": (None, MODEL_AXIS, None),
    "w_out": (MODEL_AXIS, None),
    "w_up": (None, MODEL_AXIS),
    "w_down": (MODEL_AXIS, None),
    "w_q": (None, MODEL_AXIS, None),
    "w_k": (None, MODEL_AXIS, None),
    "w_v": (None, MODEL_AXIS, None),
    "w_z": (None, MODEL_AXIS, None),
    "w_o": (None, MODEL_AXIS, None),
}


def _rule_for(path: Tuple[str, ...], shape: Tuple[int, ...],
              mesh: Optional[Mesh]) -> P:
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    key = name
    if parent == "moe" and name in ("wi", "wg", "wo"):
        key = f"moe_{name}"
    base = _NAME_RULES.get(key)
    ndim = len(shape)
    if base is None or len(base) > ndim:
        return P()
    # prepend None for stacked layer dims
    pad = ndim - len(base)
    spec = list((None,) * pad + tuple(base))
    if mesh is not None:
        for i, ax in enumerate(spec):
            if ax is not None and (ax not in mesh.axis_names
                                   or shape[i] % mesh.shape[ax] != 0):
                spec[i] = None   # replicate non-divisible dims
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        else:
            out.append(str(p))
    return tuple(out)


def param_specs(params_or_specs, mesh: Optional[Mesh] = None) -> Any:
    """Pytree of PartitionSpec matching a params pytree (by leaf name);
    with a mesh, non-divisible dims fall back to replication."""
    def leaf_spec(path, leaf):
        return _rule_for(_path_names(path), tuple(leaf.shape), mesh)
    return jax.tree_util.tree_map_with_path(leaf_spec, params_or_specs)


def param_shardings(mesh: Mesh, params_or_specs) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        param_specs(params_or_specs, mesh))


# --------------------------------------------------------------------------- #
def mesh_batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in BATCH_AXES if a in mesh.axis_names)


def _batch_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in mesh_batch_axes(mesh)]))


def batch_spec(mesh: Mesh, batch: int, extra_dims: int = 1) -> P:
    """Spec for [B, ...] arrays: shard batch over (pod, data) if it
    divides, else replicate."""
    ba = mesh_batch_axes(mesh)
    ok = batch % _batch_size(mesh) == 0
    return P(ba if ok else None, *([None] * extra_dims))


def kv_cache_spec(batch: int, mesh: Mesh, n_kv: int,
                  seq_len: int = 0) -> P:
    """[B, S, Kh, hd] caches: batch over (pod, data) when divisible, else
    sequence; heads over model when divisible — otherwise shard the
    SEQUENCE over model (flash-decode style: attention reduces partial
    softmax stats over the model axis).  Without this, GQA models whose
    kv heads don't divide the model axis (kimi/granite kv=8 vs 16) carry
    fully replicated caches (57 GB/device for kimi decode_32k)."""
    ba = mesh_batch_axes(mesh)
    msz = mesh.shape[MODEL_AXIS]
    heads_divide = n_kv % msz == 0
    seq_divides = seq_len > 0 and seq_len % msz == 0
    if batch % _batch_size(mesh) == 0:
        if heads_divide:
            return P(ba, None, MODEL_AXIS, None)
        if seq_divides:
            return P(ba, MODEL_AXIS, None, None)
        return P(ba, None, None, None)
    if heads_divide:
        return P(None, ba, MODEL_AXIS, None)
    if seq_divides:
        return P(None, (MODEL_AXIS,) + ba, None, None)
    return P(None, ba, None, None)


def latent_cache_spec(batch: int, mesh: Mesh) -> P:
    """[B, S, R] MLA latent caches (no head dim)."""
    ba = mesh_batch_axes(mesh)
    if batch % _batch_size(mesh) == 0:
        return P(ba, None, None)
    return P(None, ba, None)


def state_cache_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    """SSM/xLSTM state leaves [B, H, ...]: batch over (pod,data) when
    divisible, heads over model when divisible."""
    ba = mesh_batch_axes(mesh)
    parts = [None] * len(shape)
    if shape and shape[0] % _batch_size(mesh) == 0:
        parts[0] = ba
    if len(shape) > 1 and shape[1] % mesh.shape[MODEL_AXIS] == 0:
        parts[1] = MODEL_AXIS
    return P(*parts)
