"""Unit tests for the IR (paper Table 2) and its graph surgery."""

from repro.core import gnn_builders as B
from repro.core import graph as G
from repro.core.ir import AggOp, LayerIR, LayerType


def _g(nv=50, ne=120, f=8, c=3, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def test_builders_validate():
    g = _g()
    for name in B.BENCHMARKS:
        m = B.build(name, g)
        m.validate()
        assert m.num_layers >= 3
        # IR must end in the class dimension
        sinks = [l for l in m.layers.values() if not l.child_ids]
        assert sinks[-1].f_out == g.n_classes


def test_topo_order_is_topological():
    g = _g()
    m = B.build("b8", g)
    order = m.topo_order()
    pos = {lid: i for i, lid in enumerate(order)}
    for lid, l in m.layers.items():
        for c in l.child_ids:
            assert pos[lid] < pos[c]


def test_complexity_formulas():
    # Eq. 10/11 of the paper.
    agg = LayerIR(LayerType.AGGREGATE, 1, f_in=16, f_out=16,
                  n_vertices=100, n_edges=400, agg_op=AggOp.SUM)
    lin = LayerIR(LayerType.LINEAR, 2, f_in=16, f_out=4, n_vertices=100,
                  n_edges=400)
    assert agg.complexity() == 2 * 16 * 400
    assert lin.complexity() == 2 * 16 * 4 * 100


def test_exchange_rewires_and_resizes():
    g = _g()
    m = B.build("b1", g)   # Aggregate(f) -> Linear(f->16) -> ...
    order = m.topo_order()
    a_id = order[0]
    l_id = order[1]
    assert m.layers[a_id].layer_type == LayerType.AGGREGATE
    assert m.layers[l_id].layer_type == LayerType.LINEAR
    f_out = m.layers[l_id].f_out
    m.exchange(a_id, l_id)
    m.validate()
    # Linear now first; Aggregate operates at the output width.
    assert m.topo_order()[0] == l_id
    assert m.layers[a_id].f_in == f_out


def test_linear_aggop_definition():
    assert AggOp.SUM.is_linear and AggOp.MEAN.is_linear
    assert not AggOp.MAX.is_linear and not AggOp.MIN.is_linear


def test_remove_layer_splices():
    g = _g()
    m = B.build("b1", g)
    order = m.topo_order()
    mid = order[2]  # activation
    parents = list(m.layers[mid].parent_ids)
    children = list(m.layers[mid].child_ids)
    m.remove_layer(mid)
    m.validate()
    for p in parents:
        for c in children:
            assert c in m.layers[p].child_ids
