"""Incremental fiber-shard tile patching (the live half of Step 3).

``core/passes/partition.py`` turns a COO graph into (j, k) blocked-ELL
sub-shard tiles.  A delta only touches the tiles its edges fall in —
edge (u, v) lives in exactly tile (v//N1, u//N1) — so this module keeps
the per-tile edge lists as first-class state (:class:`TileStore`) and
rebuilds ONLY the touched tiles, reusing the partitioner's exact layout
rules (dst-major rows, LANE-rounded widths, width_cap slicing).

Two signatures fall out of the per-tile content hashes:

  * **structural signature** — tile grid geometry + the set of
    (j, k, n_slices) entries (+ feat_dim/n_classes, which size builder
    models).  This is everything the *instruction binary* depends on:
    ``kernel_map`` emits instructions per tile slice, and residency /
    placement schedules derive from the same structure.  It is what
    ``engine.graph_signature`` returns for a live version, so the
    program-cache key only changes when the padded geometry actually
    changes — a content-only delta is a guaranteed cache hit.
  * **content signature** — a Merkle-style root over the per-tile
    hashes.  Unchanged tiles keep their hash (they are shared by
    reference across versions), so a delta re-hashes O(touched) tiles,
    not O(all).  It identifies the exact graph *contents* for
    version-skew observability.

Bit-identity with a cold compile is by construction: every edge carries
a birth sequence number (its position in the canonical COO order that
``GraphDelta.apply_to`` produces), rows are ordered (dst, src, seq) —
precisely the stable (dst, src, original-position) order
``partition_graph`` emits — and edge ids (the ``edge_pos`` ELL plane)
come from a stable allocator, free ids reused smallest-first.  Edge-id
*values* differ from a cold compile's, but the executor only requires
them to be internally consistent and collision-free below
``PartitionedGraph.n_edges`` (which the store sets to the id-space
capacity), so outputs match bit for bit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from typing import Dict, List, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.core.passes.partition import (LANE, ELLTile, PartitionConfig,
                                         PartitionedGraph)

TileKey = Tuple[int, int]


@dataclasses.dataclass
class TileEdges:
    """Live edges of one (j, k) sub-shard, in ELL emission order
    (sorted by (dst, src, birth-seq); global vertex ids)."""

    src: np.ndarray      # int32 [n]
    dst: np.ndarray      # int32 [n]
    weight: np.ndarray   # float32 [n]
    eid: np.ndarray      # int32 [n]  stable edge ids (the epos plane)
    seq: np.ndarray      # int64 [n]  birth order (canonical COO order)

    @property
    def n(self) -> int:
        return int(self.src.shape[0])


@dataclasses.dataclass
class PatchStats:
    """What one delta application did to the tile grid."""

    edges_added: int = 0
    edges_removed: int = 0
    vertices_added: int = 0
    tiles_before: int = 0
    tiles_after: int = 0
    tiles_patched: int = 0        # rebuilt in place (key existed before)
    tiles_created: int = 0
    tiles_dropped: int = 0
    structural_change: bool = False
    # "j:k" -> {"nnz", "slices", "width", "density"} for rebuilt tiles
    patched: Dict[str, dict] = dataclasses.field(default_factory=dict)

    @property
    def tiles_retained(self) -> int:
        """Tiles shared by reference with the previous version."""
        return self.tiles_after - self.tiles_patched - self.tiles_created

    @property
    def retention(self) -> float:
        return self.tiles_retained / self.tiles_after \
            if self.tiles_after else 1.0

    def as_dict(self) -> dict:
        return {
            "edges_added": self.edges_added,
            "edges_removed": self.edges_removed,
            "vertices_added": self.vertices_added,
            "tiles_before": self.tiles_before,
            "tiles_after": self.tiles_after,
            "tiles_patched": self.tiles_patched,
            "tiles_created": self.tiles_created,
            "tiles_dropped": self.tiles_dropped,
            "tiles_retained": self.tiles_retained,
            "retention": round(self.retention, 6),
            "structural_change": self.structural_change,
        }


# --------------------------------------------------------------------------- #
# Layout helpers — the partitioner's inner loop, factored per tile.
# --------------------------------------------------------------------------- #
def ell_slices(j: int, k: int, te: TileEdges,
               cfg: PartitionConfig) -> List[ELLTile]:
    """One (j, k) edge list -> blocked-ELL slices, bit-identical to the
    corresponding tile of :func:`partition_graph` (same row order, same
    LANE-rounded widths, same width_cap slicing)."""
    n1 = cfg.n1
    ls = (te.src - k * n1).astype(np.int32)
    ld = (te.dst - j * n1).astype(np.int32)
    counts = np.bincount(ld, minlength=n1)
    row_start = np.zeros(n1 + 1, np.int64)
    np.cumsum(counts, out=row_start[1:])
    slot = (np.arange(te.n) - row_start[ld]).astype(np.int64)
    full_width = int(counts.max()) if te.n else 0
    slices: List[ELLTile] = []
    for s0 in range(0, full_width, cfg.width_cap):
        sel = (slot >= s0) & (slot < s0 + cfg.width_cap)
        if not sel.any():
            continue
        sw = int(counts.clip(s0, s0 + cfg.width_cap).max() - s0)
        width = max(LANE, int(math.ceil(sw / LANE) * LANE))
        cols = np.zeros((n1, width), np.int32)
        vals = np.zeros((n1, width), np.float32)
        epos = np.full((n1, width), -1, np.int32)
        r, c = ld[sel], (slot[sel] - s0).astype(np.int64)
        cols[r, c] = ls[sel]
        vals[r, c] = te.weight[sel]
        epos[r, c] = te.eid[sel]
        slices.append(ELLTile(j, k, cols, vals, epos, nnz=int(sel.sum())))
    return slices


def tile_hash(slices: List[ELLTile]) -> str:
    """Content hash of one tile (all its slices)."""
    h = hashlib.sha1()
    for t in slices:
        h.update(np.int64([t.cols.shape[1], t.nnz]).tobytes())
        h.update(np.ascontiguousarray(t.cols).tobytes())
        h.update(np.ascontiguousarray(t.vals).tobytes())
        h.update(np.ascontiguousarray(t.edge_pos).tobytes())
    return h.hexdigest()


def as_graph_data(pg: PartitionedGraph) -> dict:
    """A PartitionedGraph as runtime ``graph_data`` (the executor's
    Dynasparse-style graph-as-data structure): patched live tiles can
    ride a structurally-matching program as *data* instead of being
    bound in — the route the sampling layer's bucketed serving uses."""
    tiles = {}
    for (j, k), slices in pg.tiles.items():
        for s, t in enumerate(slices):
            tiles[f"{j}:{k}:{s}"] = {
                "cols": t.cols, "vals": t.vals,
                "mask": t.edge_pos >= 0, "epos": t.edge_pos,
            }
    return {"tiles": tiles, "inv_in_degree": pg.inv_in_degree}


def tile_density_stats(pg: PartitionedGraph) -> dict:
    """Per-tile nnz/density summary (manifest ``tile_stats`` section).

    Cheap to compute from the ELL metadata and recorded at every
    compile *and* every live-tile rebind — the bind-time observability
    a Dynasparse-style kernel remapper needs (see ROADMAP)."""
    n1 = pg.config.n1
    tiles: Dict[str, dict] = {}
    total_nnz = 0
    padded_slots = 0
    for (j, k) in sorted(pg.tiles):
        slices = pg.tiles[(j, k)]
        nnz = sum(t.nnz for t in slices)
        width = sum(t.width for t in slices)
        slots = n1 * width
        total_nnz += nnz
        padded_slots += slots
        tiles[f"{j}:{k}"] = {
            "nnz": int(nnz),
            "slices": len(slices),
            "width": int(width),
            "density": round(nnz / slots, 6) if slots else 0.0,
        }
    return {
        "n_tiles": len(tiles),
        "total_nnz": int(total_nnz),
        "padded_slots": int(padded_slots),
        "mean_density": round(total_nnz / padded_slots, 6)
        if padded_slots else 0.0,
        "tiles": tiles,
    }


# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class TileStore:
    """Per-tile edge lists + their ELL form + content hashes.

    Immutable by convention: :meth:`apply` returns a NEW store sharing
    every untouched tile (edge lists, ELL slices, hashes) by reference
    — the copy-on-write substrate of ``GraphVersionStore``.
    """

    cfg: PartitionConfig
    n_vertices: int
    n_blocks: int
    feat_dim: int
    n_classes: int
    name: str
    edges: Dict[TileKey, TileEdges]
    tiles: Dict[TileKey, List[ELLTile]]
    hashes: Dict[TileKey, str]
    indeg: np.ndarray            # int64 [nb * n1] live in-degrees
    eid_capacity: int            # edge-id space size (== pgraph.n_edges)
    free_eids: np.ndarray        # int64, sorted ascending
    next_seq: int
    live_edges: int

    # ------------------------------------------------------------------ #
    @classmethod
    def from_graph(cls, g: Graph, cfg: PartitionConfig) -> "TileStore":
        """Initial build — same grouping as :func:`partition_graph`;
        edge ids and birth seqs start as the canonical COO positions."""
        n1 = cfg.n1
        nb = cfg.n_blocks(g.n_vertices)
        order = np.lexsort((g.src, g.dst)).astype(np.int64)
        src, dst = g.src[order], g.dst[order]
        w, eid = g.weight[order], order
        key = (dst // n1).astype(np.int64) * nb + (src // n1)
        korder = np.argsort(key, kind="stable")
        src, dst, w, eid, key = (a[korder]
                                 for a in (src, dst, w, eid, key))
        edges: Dict[TileKey, TileEdges] = {}
        tiles: Dict[TileKey, List[ELLTile]] = {}
        hashes: Dict[TileKey, str] = {}
        uniq = np.unique(key)
        lows = np.searchsorted(key, uniq, side="left")
        highs = np.searchsorted(key, uniq, side="right")
        for uk, lo, hi in zip(uniq, lows, highs):
            jk = (int(uk // nb), int(uk % nb))
            te = TileEdges(src=src[lo:hi].astype(np.int32),
                           dst=dst[lo:hi].astype(np.int32),
                           weight=w[lo:hi].astype(np.float32),
                           eid=eid[lo:hi].astype(np.int32),
                           seq=eid[lo:hi].astype(np.int64))
            edges[jk] = te
            tiles[jk] = ell_slices(jk[0], jk[1], te, cfg)
            hashes[jk] = tile_hash(tiles[jk])
        indeg = np.bincount(g.dst, minlength=nb * n1).astype(np.int64)
        return cls(cfg=cfg, n_vertices=g.n_vertices, n_blocks=nb,
                   feat_dim=g.feat_dim, n_classes=g.n_classes,
                   name=g.name, edges=edges, tiles=tiles, hashes=hashes,
                   indeg=indeg, eid_capacity=g.n_edges,
                   free_eids=np.empty(0, np.int64),
                   next_seq=g.n_edges, live_edges=g.n_edges)

    # ------------------------------------------------------------------ #
    def _tile_key(self, u: int, v: int) -> TileKey:
        return (v // self.cfg.n1, u // self.cfg.n1)

    def apply(self, cd) -> Tuple["TileStore", PatchStats]:
        """One coalesced delta -> (new store, patch stats).  O(touched
        tiles + |V|) — untouched tiles are shared by reference."""
        n1 = self.cfg.n1
        nv = self.n_vertices + cd.n_new_vertices
        nb = max(self.n_blocks, self.cfg.n_blocks(nv))
        stats = PatchStats(vertices_added=cd.n_new_vertices,
                           tiles_before=len(self.edges))

        # Group the delta by touched tile, preserving add arrival order.
        rm_by_tile: Dict[TileKey, List[Tuple[int, int]]] = {}
        for (u, v) in cd.removed_pairs:
            if u >= self.n_vertices or v >= self.n_vertices:
                raise KeyError(f"remove_edge({u}, {v}): endpoint beyond "
                               f"base graph ({self.n_vertices} vertices)")
            rm_by_tile.setdefault(self._tile_key(u, v), []).append((u, v))
        add_by_tile: Dict[TileKey, List[int]] = {}
        for i in range(cd.n_adds):
            jk = self._tile_key(int(cd.add_src[i]), int(cd.add_dst[i]))
            add_by_tile.setdefault(jk, []).append(i)
        touched = sorted(set(rm_by_tile) | set(add_by_tile))

        # Pass 1 — keep masks + freed edge ids per touched tile.
        keep_masks: Dict[TileKey, np.ndarray] = {}
        freed: List[np.ndarray] = []
        removed_dst: List[np.ndarray] = []
        for jk in touched:
            old = self.edges.get(jk)
            pairs = rm_by_tile.get(jk, [])
            if old is None:
                for (u, v) in pairs:
                    if cd.must_exist[(u, v)]:
                        raise KeyError(f"remove_edge({u}, {v}): no such "
                                       f"edge in {self.name!r}")
                continue
            keep = np.ones(old.n, bool)
            if pairs:
                okey = old.src.astype(np.int64) * nv + old.dst
                dead = np.array([u * nv + v for u, v in pairs], np.int64)
                hit = np.isin(okey, dead)
                present = set(np.unique(okey[hit]).tolist())
                for (u, v) in pairs:
                    if cd.must_exist[(u, v)] \
                            and u * nv + v not in present:
                        raise KeyError(f"remove_edge({u}, {v}): no such "
                                       f"edge in {self.name!r}")
                keep = ~hit
                freed.append(old.eid[hit].astype(np.int64))
                removed_dst.append(old.dst[hit])
            keep_masks[jk] = keep

        # Allocate stable edge ids for the adds: reuse freed ids
        # smallest-first (ids freed by THIS delta included), then grow
        # the capacity — keeps the id space (and the executor's
        # edge-valued buffers) near the live edge count under churn.
        pool = np.sort(np.concatenate([self.free_eids] + freed)) \
            if freed else self.free_eids
        n_add = cd.n_adds
        reuse = min(n_add, pool.shape[0])
        fresh = n_add - reuse
        add_eids = np.concatenate([
            pool[:reuse],
            np.arange(self.eid_capacity, self.eid_capacity + fresh,
                      dtype=np.int64)])
        free_eids = pool[reuse:]
        eid_capacity = self.eid_capacity + fresh
        add_seq = np.arange(self.next_seq, self.next_seq + n_add,
                            dtype=np.int64)

        # Pass 2 — rebuild touched tiles (everything else is shared).
        edges = dict(self.edges)
        tiles = dict(self.tiles)
        hashes = dict(self.hashes)
        for jk in touched:
            old = self.edges.get(jk)
            keep = keep_masks.get(jk)
            ai = np.array(add_by_tile.get(jk, []), np.int64)
            parts_src = [cd.add_src[ai]]
            parts_dst = [cd.add_dst[ai]]
            parts_w = [cd.add_weight[ai]]
            parts_eid = [add_eids[ai].astype(np.int32)]
            parts_seq = [add_seq[ai]]
            if old is not None:
                parts_src.insert(0, old.src[keep])
                parts_dst.insert(0, old.dst[keep])
                parts_w.insert(0, old.weight[keep])
                parts_eid.insert(0, old.eid[keep])
                parts_seq.insert(0, old.seq[keep])
            te = TileEdges(
                src=np.concatenate(parts_src).astype(np.int32),
                dst=np.concatenate(parts_dst).astype(np.int32),
                weight=np.concatenate(parts_w).astype(np.float32),
                eid=np.concatenate(parts_eid).astype(np.int32),
                seq=np.concatenate(parts_seq))
            if te.n == 0:
                if old is None:
                    # Adds and removes netted to zero inside a tile
                    # that never existed — nothing to keep.
                    stats.tiles_dropped += 1
                    continue
                # An existing tile emptied by the delta keeps its slice
                # COUNT as zero-nnz LANE-wide slices: the structural
                # signature (and with it the program-cache key and each
                # binary's per-slice instruction addressing) depends on
                # (j, k, n_slices), so preserving the count turns
                # "tile went empty" into a content-only delta.  The
                # bind-time remapper elides these slices as SKIP.
                empty = [
                    ELLTile(jk[0], jk[1],
                            np.zeros((n1, LANE), np.int32),
                            np.zeros((n1, LANE), np.float32),
                            np.full((n1, LANE), -1, np.int32), nnz=0)
                    for _ in self.tiles[jk]]
                edges[jk] = te
                tiles[jk] = empty
                hashes[jk] = tile_hash(empty)
                stats.tiles_patched += 1
                stats.patched[f"{jk[0]}:{jk[1]}"] = {
                    "nnz": 0, "slices": len(empty),
                    "width": sum(t.width for t in empty),
                    "density": 0.0,
                }
                continue
            # (dst, src, birth-seq): the partitioner's stable
            # (dst, src, COO-position) order, reproduced incrementally.
            order = np.lexsort((te.seq, te.src, te.dst))
            te = TileEdges(src=te.src[order], dst=te.dst[order],
                           weight=te.weight[order], eid=te.eid[order],
                           seq=te.seq[order])
            edges[jk] = te
            tiles[jk] = ell_slices(jk[0], jk[1], te, self.cfg)
            hashes[jk] = tile_hash(tiles[jk])
            if old is None:
                stats.tiles_created += 1
            else:
                stats.tiles_patched += 1
            width = sum(t.width for t in tiles[jk])
            stats.patched[f"{jk[0]}:{jk[1]}"] = {
                "nnz": te.n, "slices": len(tiles[jk]), "width": width,
                "density": round(te.n / (n1 * width), 6) if width else 0.0,
            }

        n_removed = int(sum(a.shape[0] for a in freed))
        stats.edges_added = n_add
        stats.edges_removed = n_removed
        stats.tiles_after = len(edges)

        indeg = np.zeros(nb * n1, np.int64)
        indeg[:self.indeg.shape[0]] = self.indeg
        for d in removed_dst:
            np.subtract.at(indeg, d, 1)
        if n_add:
            np.add.at(indeg, cd.add_dst, 1)

        new = TileStore(
            cfg=self.cfg, n_vertices=nv, n_blocks=nb,
            feat_dim=self.feat_dim, n_classes=self.n_classes,
            name=self.name, edges=edges, tiles=tiles, hashes=hashes,
            indeg=indeg, eid_capacity=eid_capacity, free_eids=free_eids,
            next_seq=self.next_seq + n_add,
            live_edges=self.live_edges + n_add - n_removed)
        stats.structural_change = \
            new.structural_signature() != self.structural_signature()
        return new, stats

    # ------------------------------------------------------------------ #
    # Signatures (see module docstring).
    # ------------------------------------------------------------------ #
    def structural_signature(self) -> str:
        """Everything the instruction binary depends on; memoized —
        stores are immutable after construction."""
        cached = self.__dict__.get("_structural_sig")
        if cached is None:
            h = hashlib.sha1()
            h.update(f"live|{self.cfg.n1}:{self.cfg.n2}:"
                     f"{self.cfg.width_cap}|{self.n_blocks}|"
                     f"{self.feat_dim}:{self.n_classes}".encode())
            for (j, k) in sorted(self.tiles):
                h.update(f"|{j}:{k}:{len(self.tiles[(j, k)])}".encode())
            cached = h.hexdigest()
            self.__dict__["_structural_sig"] = cached
        return cached

    def content_signature(self) -> str:
        """Merkle-style root over the per-tile hashes (memoized).
        Unchanged tiles keep their leaf hash across versions, so a
        delta re-hashes O(touched) leaves + one O(tiles) fold."""
        cached = self.__dict__.get("_content_sig")
        if cached is None:
            h = hashlib.sha1(self.structural_signature().encode())
            for jk in sorted(self.hashes):
                h.update(f"|{jk[0]}:{jk[1]}:{self.hashes[jk]}".encode())
            cached = h.hexdigest()
            self.__dict__["_content_sig"] = cached
        return cached

    # ------------------------------------------------------------------ #
    def build_pgraph(self) -> PartitionedGraph:
        """Assemble the PartitionedGraph the executor consumes.

        ``n_edges`` is the edge-id *capacity*, not the live edge count:
        the executor sizes edge-valued buffers ``n_edges + 1`` and pads
        with index ``n_edges``, so every stable edge id stays in range
        and the pad slot never collides with a live id."""
        inv = (1.0 / np.maximum(self.indeg.astype(np.float32), 1.0)
               ).astype(np.float32)
        return PartitionedGraph(
            config=self.cfg, n_vertices=self.n_vertices,
            n_edges=self.eid_capacity, n_blocks=self.n_blocks,
            tiles=dict(self.tiles), inv_in_degree=inv)

    def as_coo(self) -> Graph:
        """Materialize the canonical COO graph (edges in birth order) —
        identical, edge for edge, to chaining ``GraphDelta.apply_to``
        over the version history."""
        if self.edges:
            src = np.concatenate([te.src for te in self.edges.values()])
            dst = np.concatenate([te.dst for te in self.edges.values()])
            w = np.concatenate([te.weight for te in self.edges.values()])
            seq = np.concatenate([te.seq for te in self.edges.values()])
            order = np.argsort(seq, kind="stable")
            src, dst, w = src[order], dst[order], w[order]
        else:
            src = np.empty(0, np.int32)
            dst = np.empty(0, np.int32)
            w = np.empty(0, np.float32)
        return Graph(n_vertices=self.n_vertices, src=src, dst=dst,
                     weight=w, feat_dim=self.feat_dim,
                     n_classes=self.n_classes, name=self.name)
