"""Intermediate representation of GraphAGILE (paper Table 2, Listing 2).

A GNN model is decomposed into a DAG of *computation layers*, each one of six
types.  The compiler passes (order optimization, fusion, partitioning, kernel
mapping) all operate on this IR.
"""
from __future__ import annotations

import dataclasses
import enum
from collections import OrderedDict
from typing import Any, Dict, List, Optional


class LayerType(enum.IntEnum):
    AGGREGATE = 0
    LINEAR = 1
    VECTOR_INNER = 2
    VECTOR_ADD = 3
    ACTIVATION = 4
    BATCHNORM = 5


class AggOp(enum.IntEnum):
    """Aggregation operators.  SUM and MEAN are linear (Definition 1)."""

    MAX = 0
    SUM = 1
    MIN = 2
    MEAN = 3

    @property
    def is_linear(self) -> bool:
        # Mean is linear w.r.t. features (the 1/deg coefficients are constants
        # of the graph); Max/Min are not.
        return self in (AggOp.SUM, AggOp.MEAN)


class Activation(enum.IntEnum):
    NONE = 0
    RELU = 1
    PRELU = 2
    SWISH = 3
    EXP = 4
    LRELU = 5
    SIGMOID = 6
    EDGE_SOFTMAX = 7  # segment softmax of edge weights over destination
    GELU = 8
    SILU = 9


@dataclasses.dataclass
class LayerIR:
    """IR of one computation layer (paper Table 2)."""

    layer_type: LayerType
    layer_id: int
    parent_ids: List[int] = dataclasses.field(default_factory=list)
    child_ids: List[int] = dataclasses.field(default_factory=list)
    f_in: int = 0
    f_out: int = 0
    n_vertices: int = 0
    n_edges: int = 0
    agg_op: Optional[AggOp] = None
    act: Activation = Activation.NONE
    act_enabled: bool = False
    batch_enabled: bool = False
    # Free-form attributes: weight keys, edge-weight source layer, notes.
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    # ------------------------------------------------------------------ #
    def complexity(self) -> float:
        """Theoretical computation complexity (paper Eq. 10/11)."""
        t = self.layer_type
        if t == LayerType.AGGREGATE:
            return 2.0 * self.f_in * self.n_edges
        if t == LayerType.LINEAR:
            return 2.0 * self.f_in * self.f_out * self.n_vertices
        if t == LayerType.VECTOR_INNER:
            return 2.0 * self.f_in * self.n_edges
        if t == LayerType.VECTOR_ADD:
            return 1.0 * self.f_in * self.n_vertices
        if t == LayerType.ACTIVATION:
            n = self.n_edges if self.attrs.get("on_edges") else self.n_vertices
            return 1.0 * max(self.f_in, 1) * n
        if t == LayerType.BATCHNORM:
            return 4.0 * self.f_in * self.n_vertices
        raise ValueError(t)

    def copy(self) -> "LayerIR":
        return dataclasses.replace(
            self,
            parent_ids=list(self.parent_ids),
            child_ids=list(self.child_ids),
            attrs=dict(self.attrs),
        )

    def short(self) -> str:
        extra = ""
        if self.layer_type == LayerType.AGGREGATE:
            extra = f" agg={self.agg_op.name}"
        if self.act_enabled:
            extra += f" act={self.act.name}"
        return (
            f"L{self.layer_id}:{self.layer_type.name}"
            f"({self.f_in}->{self.f_out}){extra}"
        )


class ModelIR:
    """IR of a GNN model: a DAG of LayerIRs (paper Listing 2)."""

    def __init__(self) -> None:
        self.layers: "OrderedDict[int, LayerIR]" = OrderedDict()
        self.graph_meta: Dict[str, Any] = {}
        self.weights: Dict[str, Any] = {}  # name -> array (host numpy/jnp)
        self.name: str = "model"

    # ------------------------------------------------------------------ #
    def add_layer(self, layer: LayerIR) -> None:
        assert layer.layer_id not in self.layers, layer.layer_id
        self.layers[layer.layer_id] = layer

    def next_id(self) -> int:
        return (max(self.layers) + 1) if self.layers else 1

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def total_complexity(self) -> float:
        return sum(l.complexity() for l in self.layers.values())

    # ------------------------------------------------------------------ #
    def topo_order(self) -> List[int]:
        """Topological order of layer ids."""
        indeg = {i: len(l.parent_ids) for i, l in self.layers.items()}
        ready = [i for i, d in indeg.items() if d == 0]
        out: List[int] = []
        while ready:
            ready.sort()
            i = ready.pop(0)
            out.append(i)
            for c in self.layers[i].child_ids:
                indeg[c] -= 1
                if indeg[c] == 0:
                    ready.append(c)
        if len(out) != len(self.layers):
            raise ValueError("cycle in ModelIR")
        return out

    def validate(self) -> None:
        for i, l in self.layers.items():
            assert l.layer_id == i
            for p in l.parent_ids:
                assert i in self.layers[p].child_ids, (i, p)
            for c in l.child_ids:
                assert i in self.layers[c].parent_ids, (i, c)
        self.topo_order()

    # ------------------------------------------------------------------ #
    def exchange(self, a_id: int, b_id: int) -> None:
        """Exchange an adjacent (parent a -> child b) pair in the DAG.

        Used by the order-optimization pass for {Aggregate, Linear} pairs.
        After the exchange, b takes a's position and a becomes b's child.
        Feature dimensions are rewired: the moved Linear keeps its (f_in,
        f_out); the Aggregate layer's width becomes the Linear's f_out
        (Theorem 1: Agg(H)·W == Agg(H·W) for linear AggOp).
        """
        a = self.layers[a_id]
        b = self.layers[b_id]
        assert a.child_ids == [b_id] and b.parent_ids == [a_id]
        # Rewire parents of a -> b, children of b -> a.
        for p in a.parent_ids:
            pl = self.layers[p]
            pl.child_ids = [b_id if c == a_id else c for c in pl.child_ids]
        for c in b.child_ids:
            cl = self.layers[c]
            cl.parent_ids = [a_id if p == b_id else p for p in cl.parent_ids]
        b.parent_ids, a.parent_ids = list(a.parent_ids), [b_id]
        a.child_ids, b.child_ids = list(b.child_ids), [a_id]
        # Downstream consumers referenced the old pair tail (b) by id in
        # attrs (vector-add operands, dynamic edge-weight sources) — the pair
        # output is now produced by a.
        for cid in a.child_ids:
            cl = self.layers[cid]
            if "operands" in cl.attrs:
                cl.attrs["operands"] = [
                    a_id if o == b_id else o for o in cl.attrs["operands"]]
            if cl.attrs.get("edge_weight_layer") == b_id:
                cl.attrs["edge_weight_layer"] = a_id
        # Fix widths: identify which one is the Aggregate.
        agg, lin = (a, b) if a.layer_type == LayerType.AGGREGATE else (b, a)
        assert agg.layer_type == LayerType.AGGREGATE
        assert lin.layer_type == LayerType.LINEAR
        # After exchange the Aggregate operates on the Linear's other side.
        if agg is a:
            # was Agg->Lin, becomes Lin->Agg: Agg now sees lin.f_out features
            agg.f_in = agg.f_out = lin.f_out
        else:
            # was Lin->Agg, becomes Agg->Lin: Agg now sees lin.f_in features
            agg.f_in = agg.f_out = lin.f_in

    # ------------------------------------------------------------------ #
    def replace_refs(self, old_id: int, new_id: int) -> None:
        """Repoint attrs references (vector-add operands, edge-weight
        sources) from ``old_id`` to ``new_id`` in every layer."""
        for l in self.layers.values():
            if "operands" in l.attrs:
                l.attrs["operands"] = [
                    new_id if o == old_id else o for o in l.attrs["operands"]]
            if l.attrs.get("edge_weight_layer") == old_id:
                l.attrs["edge_weight_layer"] = new_id

    def remove_layer(self, lid: int) -> None:
        """Remove a layer, splicing its parents to its children."""
        l = self.layers[lid]
        for p in l.parent_ids:
            pl = self.layers[p]
            pl.child_ids = [c for c in pl.child_ids if c != lid]
            for c in l.child_ids:
                if c not in pl.child_ids:
                    pl.child_ids.append(c)
        for c in l.child_ids:
            cl = self.layers[c]
            cl.parent_ids = [p for p in cl.parent_ids if p != lid]
            for p in l.parent_ids:
                if p not in cl.parent_ids:
                    cl.parent_ids.append(p)
        del self.layers[lid]

    def copy(self) -> "ModelIR":
        m = ModelIR()
        m.layers = OrderedDict((i, l.copy()) for i, l in self.layers.items())
        m.graph_meta = dict(self.graph_meta)
        m.weights = dict(self.weights)
        m.name = self.name
        return m

    def dump(self) -> str:
        return " | ".join(self.layers[i].short() for i in self.topo_order())
