"""Mixture-of-Experts layer (kimi-k2, deepseek-v3).

Two implementations sharing one parameter layout:

* ``dense``  — oracle: computes every expert for every token and combines
  with router weights.  O(E/topk) extra FLOPs; used for smoke tests and as
  the correctness reference for the sharded path.

* ``a2a``    — production path: GShard-style expert parallelism inside
  ``jax.shard_map``.  Tokens are locally dispatched into per-expert
  capacity buffers, exchanged with the expert owners over the ``model``
  mesh axis with ``all_to_all``, processed, and returned.  Capacity-based
  token dropping (capacity_factor) gives static shapes; dropped tokens
  fall back to the residual stream (standard Switch behaviour).

The GraphAGILE view (DESIGN.md §4): the routing matrix is a sparse
adjacency A (tokens -> experts, top-k nonzeros per row) and this layer is
the paper's *Aggregate* executed in SpDMM mode, with the partition pass's
load balancing reappearing as the router's aux loss + capacity factor.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from .layers import Params, dense_init


def moe_init(key, d: int, f: int, n_experts: int, dtype,
             n_shared: int = 0) -> Params:
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(kr, d, n_experts, jnp.float32, std=0.02),
        "wi": dense_init(k1, d, (n_experts, f), dtype),   # stored (d,E,f)
        "wg": dense_init(k2, d, (n_experts, f), dtype),
        "wo": (dense_init(k3, f, (n_experts, d), dtype)),  # (f,E,d)
    }
    if n_shared:
        from .layers import swiglu_init
        p["shared"] = swiglu_init(ks, d, f * n_shared, dtype)
    return p


def _router(p: Params, x: jnp.ndarray, top_k: int):
    """x [N, d] -> (weights [N, k], ids [N, k], aux_loss)."""
    logits = jnp.einsum("nd,de->ne", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # Switch aux loss: E * sum_e f_e * p_e
    e = logits.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)
    return w, ids, aux


# --------------------------------------------------------------------------- #
def moe_dense(p: Params, x: jnp.ndarray, top_k: int) -> Tuple[jnp.ndarray,
                                                              jnp.ndarray]:
    """Oracle: every expert on every token.  x [B, T, d]."""
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    w, ids, aux = _router(p, xf, top_k)
    e = p["router"].shape[-1]
    # combine weight per expert [N, E]
    cw = jnp.zeros((b * t, e), jnp.float32)
    cw = cw.at[jnp.arange(b * t)[:, None], ids].add(w)
    h = jnp.einsum("nd,def->nef", xf, p["wi"])
    g = jnp.einsum("nd,def->nef", xf, p["wg"])
    h = h * jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)
    out = jnp.einsum("nef,fed->ned", h, p["wo"])
    out = jnp.einsum("ned,ne->nd", out.astype(jnp.float32), cw)
    y = out.reshape(b, t, d).astype(x.dtype)
    if "shared" in p:
        from .layers import swiglu
        y = y + swiglu(p["shared"], x)
    return y, aux


# --------------------------------------------------------------------------- #
def _dispatch_local(xf, w, ids, n_experts: int, cap: int):
    """Scatter local tokens into per-expert capacity buffers.

    Returns (buf [E, C, d], combine [N, k] weight, slot [N, k] in [-1, C)).
    """
    n, k = ids.shape
    flat_e = ids.reshape(-1)                                   # [N*k]
    # position of each (token, slot) within its expert, in arrival order
    oh = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)    # [N*k, E]
    pos = jnp.cumsum(oh, axis=0) - oh                          # prior count
    slot = jnp.sum(pos * oh, axis=-1)                          # [N*k]
    keep = slot < cap
    slot = jnp.where(keep, slot, -1)
    d = xf.shape[-1]
    buf = jnp.zeros((n_experts, cap, d), xf.dtype)
    tok_idx = jnp.repeat(jnp.arange(n), k)
    buf = buf.at[flat_e, jnp.maximum(slot, 0)].add(
        jnp.where(keep[:, None], xf[tok_idx], 0).astype(xf.dtype))
    return buf, slot.reshape(n, k), keep.reshape(n, k)


def moe_local(p: Params, x: jnp.ndarray, top_k: int, cap_factor: float,
              mesh, batch_axes=("pod", "data"), expert_axis: str = "model"
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Decode-path expert parallelism WITHOUT all-to-all.

    When tokens are replicated over the expert axis (decode: t == 1, too
    few tokens to sequence-shard), the a2a formulation makes every expert
    column redundantly dispatch identical tokens and exchange them —
    16x wasted expert FLOPs on a 16-way axis (EXPERIMENTS.md §Perf,
    kimi decode_32k).  Here each column filters the routing table to ITS
    local experts, computes only those, and a psum over the expert axis
    combines — collective volume = one [n, d] reduce instead of two
    [E, cap, d] exchanges.
    """
    b, t, d = x.shape
    e = p["router"].shape[-1]
    ax_size = mesh.shape[expert_axis]
    e_loc = e // ax_size
    w, ids, aux = _router(p, x.reshape(b * t, d), top_k)
    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    spec_x = P(batch_axes if b % bsz == 0 and bsz > 1 else None, None,
               None)
    spec_f = P(spec_x[0], None)

    def body(xl, wl, idsl, wi, wg, wo):
        bl, tl, _ = xl.shape
        n = bl * tl
        xf = xl.reshape(n, d)
        col = jax.lax.axis_index(expert_axis)
        loc = idsl.reshape(n, top_k) - col * e_loc
        mine = (loc >= 0) & (loc < e_loc)
        wl_ = jnp.where(mine, wl.reshape(n, top_k), 0.0)
        loc = jnp.where(mine, loc, 0)
        cap = max(1, -(-int(n * top_k * cap_factor) // e))
        buf, slot, keep = _dispatch_local(
            xf, wl_, jnp.where(mine, loc, e_loc), e_loc + 1, cap)
        buf = buf[:e_loc]                       # drop the spill expert
        h = jnp.einsum("ecd,def->ecf", buf, wi)
        g = jnp.einsum("ecd,def->ecf", buf, wg)
        h = h * jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)
        out = jnp.einsum("ecf,fed->ecd", h, wo)
        fe = loc.reshape(-1)
        fs = jnp.maximum(slot.reshape(-1), 0)
        ok = keep.reshape(-1) & mine.reshape(-1)
        got = out[fe, fs] * ok[:, None]
        got = got * wl_.reshape(-1)[:, None].astype(got.dtype)
        tok = jnp.repeat(jnp.arange(n), top_k)
        y = jax.ops.segment_sum(got.astype(jnp.float32), tok,
                                num_segments=n)
        y = jax.lax.psum(y, expert_axis)
        return y.reshape(bl, tl, d).astype(xl.dtype)

    out = shard_map(
        body, mesh=mesh,
        in_specs=(spec_x, spec_f, spec_f, P(None, expert_axis, None),
                  P(None, expert_axis, None), P(None, expert_axis, None)),
        out_specs=spec_x,
    )(x, w.reshape(b, t * top_k), ids.reshape(b, t * top_k),
      p["wi"], p["wg"], p["wo"])
    if "shared" in p:
        from .layers import swiglu
        out = out + swiglu(p["shared"], x)
    return out, aux


def moe_a2a(p: Params, x: jnp.ndarray, top_k: int, cap_factor: float,
            mesh, batch_axes=("pod", "data"), seq_axis: str = "model",
            expert_axis: str = "model") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Expert-parallel MoE.  Experts sharded over ``expert_axis``; tokens
    dispatched from shards of (batch over ``batch_axes``, seq over
    ``seq_axis`` when it divides).  Routing runs outside the shard_map
    (GSPMD land) so the aux loss reduces globally for free."""
    b, t, d = x.shape
    e = p["router"].shape[-1]
    ax_size = mesh.shape[expert_axis]
    e_loc = e // ax_size
    w, ids, aux = _router(p, x.reshape(b * t, d), top_k)
    w = w.reshape(b, t, top_k)
    ids = ids.reshape(b, t, top_k)

    batch_axes = tuple(a for a in batch_axes if a in mesh.axis_names)
    bsz = 1
    for a in batch_axes:
        bsz *= mesh.shape[a]
    use_batch = b % bsz == 0 and bsz > 1
    use_seq = (seq_axis in mesh.axis_names
               and t % mesh.shape[seq_axis] == 0 and t > 1)
    spec_x = P(batch_axes if use_batch else None,
               seq_axis if use_seq else None, None)

    def body(xl, wl, idsl, wi, wg, wo):
        # xl [bl, tl, d]; wi/wg [d, e_loc, f]; wo [f, e_loc, d]
        bl, tl, _ = xl.shape
        n = bl * tl
        xf = xl.reshape(n, d)
        cap = max(4, -(-int(n * top_k * cap_factor) // e))  # ceil, min 4
        buf, slot, keep = _dispatch_local(
            xf, wl.reshape(n, top_k), idsl.reshape(n, top_k), e, cap)
        # exchange: dim0 indexes the destination expert shard
        buf = buf.reshape(ax_size, e_loc, cap, d)
        buf = jax.lax.all_to_all(buf, expert_axis, split_axis=0,
                                 concat_axis=0, tiled=False)
        # now dim0 = source token shard, dim1 = my local experts
        h = jnp.einsum("secd,def->secf", buf, wi)
        g = jnp.einsum("secd,def->secf", buf, wg)
        h = h * jax.nn.sigmoid(g.astype(jnp.float32)).astype(h.dtype)
        out = jnp.einsum("secf,fed->secd", h, wo)
        out = jax.lax.all_to_all(out, expert_axis, split_axis=0,
                                 concat_axis=0, tiled=False)
        out = out.reshape(e, cap, d)     # dim0 back to global expert id
        fe = idsl.reshape(-1)
        fs = jnp.maximum(slot.reshape(-1), 0)
        got = out[fe, fs] * keep.reshape(-1)[:, None]
        got = got * wl.reshape(-1)[:, None].astype(got.dtype)
        tok = jnp.repeat(jnp.arange(n), top_k)
        y = jax.ops.segment_sum(got.astype(jnp.float32), tok,
                                num_segments=n)
        y = y.reshape(bl, tl, d).astype(xl.dtype)
        if not use_seq:
            # tokens were replicated over the expert axis: every column
            # computed the same y; mark it replicated for check_vma.
            y = jax.lax.pmean(y, expert_axis)
        return y

    spec_w = P(batch_axes, seq_axis if use_seq else None, None)
    out = shard_map(
        body, mesh=mesh,
        in_specs=(spec_x, spec_w, spec_w, P(None, expert_axis, None),
                  P(None, expert_axis, None), P(None, expert_axis, None)),
        out_specs=spec_x,
    )(x, w, ids, p["wi"], p["wg"], p["wo"])
    if "shared" in p:
        from .layers import swiglu
        out = out + swiglu(p["shared"], x)
    return out, aux
