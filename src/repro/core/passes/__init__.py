# Pass modules are imported individually (e.g. `from .passes import
# order_opt`); kernel_map/partition/schedule are added by the compiler.
