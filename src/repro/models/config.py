"""Model configuration for the assigned architecture pool.

One dataclass covers every family (dense / moe / hybrid / vlm / audio /
ssm); family-specific fields are optional.  Configs are constructed by
``repro.configs.<arch>`` modules; reduced smoke variants by their
``smoke_config()``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | vlm | audio | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads

    # attention
    attn_pattern: str = "full"   # full | local_global (gemma3 5:1)
    local_window: int = 1024
    local_global_ratio: int = 6  # one global layer per this many layers
    qk_norm: bool = False
    rope_theta: float = 10000.0
    attn_chunk: int = 1024       # query-chunked online-softmax threshold

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_moe: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # MLA (deepseek-v3)
    mla: bool = False
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head_dim: int = 128
    mtp: bool = False            # multi-token-prediction extra head

    # hybrid SSM (hymba) / ssm (xlstm)
    ssm_state: int = 0
    ssm_heads: int = 0           # hymba: parallel SSM heads per layer
    ssm_expand: float = 1.0
    ssm_impl: str = "ssd"        # ssd (mamba2 dual) | assoc (chunked scan)
    xlstm: bool = False          # alternate mLSTM/sLSTM blocks

    # vlm (llama-3.2-vision): cross-attn every k-th layer
    cross_attn_every: int = 0
    n_vision_tokens: int = 1601
    vision_dim: int = 1280

    # audio (whisper): encoder-decoder
    encoder_decoder: bool = False
    n_encoder_layers: int = 0
    decoder_target_len: int = 448

    # numerics / training
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    remat: str = "full"          # full | dots | none

    # ------------------------------------------------------------------ #
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def n_params(self) -> int:
        """Total parameter count (embeddings included)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hd = self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.xlstm:
            # mLSTM: up(2d^2) + qkv at du=2d (12d^2) + down(2d^2) ~ 16d^2
            # sLSTM: z/o/r (3d^2) + up/down at pf 4/3 (~2.7d^2)  ~  6d^2
            per_pair = 16 * d * d + 6 * d * d
            return emb + (L // 2) * per_pair
        if self.mla:
            attn = (d * self.q_lora
                    + self.q_lora * self.n_heads * (self.qk_nope
                                                    + self.qk_rope)
                    + d * (self.kv_lora + self.qk_rope)
                    + self.kv_lora * self.n_heads * (self.qk_nope
                                                     + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d
        dense_mlp = 3 * d * f
        if self.is_moe:
            moe_mlp = 3 * d * self.d_ff_moe * (self.n_experts
                                               + self.n_shared_experts)
            router = d * self.n_experts
            n_dense = self.first_k_dense
            n_moe = L - n_dense
            ff_dense = f if f else self.d_ff_moe * (
                self.n_experts // 16)  # fallback
            body = (n_dense * (attn + 3 * d * ff_dense)
                    + n_moe * (attn + moe_mlp + router))
        else:
            body = L * (attn + dense_mlp)
        if self.ssm_heads:
            body += L * (3 * d * d)  # ssm in/out/dt projections (approx)
        if self.cross_attn_every:
            n_x = L // self.cross_attn_every
            body += n_x * (2 * self.d_model * self.n_kv_heads * hd
                           + d * self.n_heads * hd + self.n_heads * hd * d)
        if self.encoder_decoder:
            enc = self.n_encoder_layers * (attn + dense_mlp)
            body += enc + L * (attn)  # decoder cross-attn approx
        return emb + body

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only top-k experts)."""
        if not self.is_moe:
            return self.n_params()
        d = self.d_model
        full = self.n_params()
        moe_all = 3 * d * self.d_ff_moe * self.n_experts
        moe_active = 3 * d * self.d_ff_moe * self.top_k
        n_moe = self.n_layers - self.first_k_dense
        return full - n_moe * (moe_all - moe_active)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (architecture x input-shape) dry-run cell."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
