"""hymba-1.5b [hybrid] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attn+mamba heads
[arXiv:2411.13676; hf]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
        n_heads=25, n_kv_heads=5, d_ff=5504, vocab=32001,
        ssm_state=16, ssm_heads=25, local_window=2048,
        rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=80, n_heads=5, n_kv_heads=5,
        d_ff=128, vocab=256, ssm_heads=5, local_window=8, attn_chunk=0,
        remat="none")
