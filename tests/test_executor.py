"""Binary-driven overlay execution vs. the pure-jnp reference (the paper's
correctness claim: same results, no reconfiguration across models/graphs).

All execution goes through ``repro.engine.Engine`` — i.e. every check here
exercises the decode-the-128-bit-binary path, not in-memory IR walking.
"""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ack
from repro.core import gnn_builders as B
from repro.core import graph as G
from repro.core import reference as R
from repro.core.ir import AggOp
from repro.core.passes.partition import PartitionConfig
from repro.engine import Engine

GEOM = PartitionConfig(n1=32, n2=8)


def _engine(backend="xla", **kw) -> Engine:
    return Engine(geometry=GEOM, n_pes=4, backend=backend, **kw)


def _g(nv=90, ne=400, f=12, c=4, seed=0, degree="uniform", norm="gcn"):
    g = G.random_graph(nv, ne, seed=seed, degree=degree)
    if norm == "gcn":
        g = g.gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _check(name, g, engine=None, **compile_kw):
    x = jnp.asarray(G.random_features(g, seed=2))
    m = B.build(name, g)
    y_ref = R.run_reference(m, g, x)
    eng = engine or _engine()
    prog = eng.compile(m, g, **compile_kw)
    y = eng.run(prog, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    return prog


@pytest.mark.parametrize("name", list(B.BENCHMARKS))
def test_all_benchmarks_match_reference(name):
    _check(name, _g())


@pytest.mark.parametrize("name", ["b1", "b3", "b6"])
def test_powerlaw_graphs(name):
    _check(name, _g(nv=150, ne=1200, degree="powerlaw", seed=5))


def test_no_opt_path_matches():
    g = _g(seed=7)
    _check("b5", g, order_opt=False, fusion=False)


def test_overlap_off_matches():
    _check("b2", _g(seed=3), engine=_engine(overlap=False))


def test_pallas_backend_matches():
    eng = _engine(backend="pallas")
    _check("b1", _g(nv=64, ne=200, f=8), engine=eng)
    _check("b6", _g(nv=64, ne=200, f=8), engine=eng)


def test_max_min_aggregation():
    g = _g(seed=9)
    x = jnp.asarray(G.random_features(g, seed=4))
    eng = _engine()
    for op in (AggOp.MAX, AggOp.MIN):
        m = B.build_gcn(g, 8, 2)
        for l in m.layers.values():
            if l.layer_type.name == "AGGREGATE":
                l.agg_op = op
        y_ref = R.run_reference(m, g, x)
        prog = eng.compile(m, g)
        y = eng.run(prog, x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-5)


def test_overlay_property_no_recompile_across_models():
    """Changing model/graph must not grow the jit cache when tile shapes
    are unchanged — the FPGA 'no reconfiguration' claim, XLA edition."""
    g1 = _g(seed=11)
    g2 = _g(nv=120, ne=700, f=12, c=4, seed=12)
    eng = _engine()
    x1 = jnp.asarray(G.random_features(g1, seed=1))
    x2 = jnp.asarray(G.random_features(g2, seed=1))

    eng.run(eng.compile(B.build("b2", g1), g1), x1)
    ack.reset_counter()
    # same tile geometry, different model AND different graph:
    eng.run(eng.compile(B.build("b3", g2), g2), x2)
    counts = ack.counter_snapshot()
    gemm_keys = {k for k in counts if k[0] == "gemm"}
    spdmm_keys = {k for k in counts if k[0] == "spdmm"}
    # tile geometry is fixed by (n1, n2): one gemm variant, spdmm variants
    # only differ in ELL width (graph-dependent, lane-quantized).
    assert len(gemm_keys) <= 1
    assert all(k[1] == (32, 8) for k in gemm_keys | spdmm_keys)


def test_executor_handles_isolated_vertices():
    g = _g(nv=100, ne=30, seed=13)  # most vertices have no edges
    _check("b1", g)
    _check("b5", g)


def test_deprecated_shims_still_work():
    """compile_model + OverlayExecutor must keep working (and warn)."""
    from repro.core.compiler import CompileOptions, compile_model
    from repro.core.executor import OverlayExecutor

    g = _g(seed=17)
    x = jnp.asarray(G.random_features(g, seed=2))
    m = B.build("b1", g)
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cr = compile_model(m, g, CompileOptions(partition=GEOM, n_pes=4))
        ex = OverlayExecutor()
        assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    y = ex.run(cr.program, x)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(R.run_reference(m, g, x)),
                               rtol=2e-4, atol=2e-5)
