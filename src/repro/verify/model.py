"""Def/use model of a decoded GraphAGILE program.

The 128-bit binary is the runtime's only dispatch source, so the static
analyzer re-derives what each Tiling Block *reads* and *writes* purely
from decoded instruction fields (plus the manifest's layer table for
operand indirections the ISA cannot carry: parent ids, vector-add
operands, the edge-weight layer).  Values are tile-granular:

  ("v", lid, i, j)      vertex sub-fiber tile: fiber i, row block j of
                        layer ``lid``'s output (lid = -1: input features)
  ("e", lid, j, k, s)   edge-valued output of layer ``lid`` for graph
                        tile (j, k), ELL width slice s
  ("g", j, k, s)        graph ELL tile (read-only input)
  ("w", lid, k, i)      weight block W(k, i) of a LINEAR layer

This is exactly the granularity the executor dispatches at, so RAW
edges over these values are the true inter-instruction dependencies —
the scoreboard input the ROADMAP's ISA-v4 item needs.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Set, Tuple

from repro.core.ir import LayerType
from repro.engine.decoder import ExecutionPlan, LayerPlan, TilePlan

ValueKey = Tuple


def _fibers(f: int, n2: int) -> int:
    return max(1, math.ceil(max(f, 0) / n2))


def layer_consumes(meta: dict, layer_type: LayerType) -> List[int]:
    """Value ids a layer reads (-1 = input features), mirroring
    ``repro.core.passes.schedule._layer_consumes`` but reading the
    manifest layer table instead of IR attrs."""
    ewl = meta.get("edge_weight_layer")
    feat_parents = [p for p in meta.get("parents", []) if p != ewl]
    if layer_type == LayerType.VECTOR_ADD:
        consumed = [int(o) for o in meta.get("operands", [])]
    else:
        consumed = [int(feat_parents[0]) if feat_parents else -1]
    if ewl is not None:
        consumed.append(int(ewl))
    return consumed


@dataclasses.dataclass
class TileOp:
    """One Tiling Block as a def/use node."""

    node_id: int                 # stream-ordered
    layer_id: int
    step: int                    # layer position in the stream
    tile_idx: int                # position within the layer
    pe: int
    kind: str                    # spdmm | gemm | sddmm | vadd | act | affine
    instr_lo: int
    instr_hi: int
    defs: List[ValueKey] = dataclasses.field(default_factory=list)
    uses: List[ValueKey] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class DefUseModel:
    plan: ExecutionPlan
    ops: List[TileOp]
    predefined: Set[ValueKey]            # inputs, graph tiles, weights
    n1: int
    n2: int
    nb: int
    # lid -> "v" (vertex-valued output) or "e" (edge-valued output)
    layer_kind: Dict[int, str] = dataclasses.field(default_factory=dict)
    # False when no tile universe was supplied: ("g", ...) uses are then
    # treated as always-defined (existence unverifiable).
    graph_tiles_known: bool = True

    def ops_of_layer(self, lid: int) -> List[TileOp]:
        return [op for op in self.ops if op.layer_id == lid]


_TILE_KINDS = {
    LayerType.AGGREGATE: "spdmm",
    LayerType.LINEAR: "gemm",
    LayerType.VECTOR_INNER: "sddmm",
    LayerType.VECTOR_ADD: "vadd",
    LayerType.ACTIVATION: "act",
    LayerType.BATCHNORM: "affine",
}


def _tile_defs_uses(lp: LayerPlan, tp: TilePlan, meta: dict
                    ) -> Tuple[List[ValueKey], List[ValueKey]]:
    """Defs and uses of one decoded Tiling Block, from instruction
    fields + the layer's manifest entry."""
    lid = lp.layer_id
    lt = lp.layer_type
    ewl = meta.get("edge_weight_layer")
    feat_parents = [p for p in meta.get("parents", []) if p != ewl]
    parent = int(feat_parents[0]) if feat_parents else -1

    defs: List[ValueKey] = []
    uses: List[ValueKey] = []
    if lt == LayerType.AGGREGATE:
        defs.append(("v", lid, tp.out_i, tp.out_j))
        for ins in tp.compute:
            j, k, i, packed = ins.args
            s, dyn = packed >> 1, packed & 1
            uses.append(("v", parent, i, k))
            uses.append(("g", j, k, s))
            if dyn:
                uses.append(("e", int(ewl) if ewl is not None else -1,
                             j, k, s))
    elif lt == LayerType.LINEAR:
        defs.append(("v", lid, tp.out_i, tp.out_j))
        for ins in tp.compute:
            j, k, i, _ = ins.args
            uses.append(("v", parent, k, j))
            uses.append(("w", lid, k, i))
    elif lt == LayerType.VECTOR_INNER:
        defs.append(("e", lid, tp.out_j, tp.tile_k, tp.slice_id))
        for ins in tp.compute:
            j, k, i, s = ins.args
            uses.append(("v", parent, i, j))
            uses.append(("v", parent, i, k))
        if tp.compute:
            uses.append(("g", tp.out_j, tp.tile_k, tp.slice_id))
    elif lt == LayerType.VECTOR_ADD:
        defs.append(("v", lid, tp.out_i, tp.out_j))
        ops = [int(o) for o in meta.get("operands", [])]
        for o in ops:
            uses.append(("v", o, tp.out_i, tp.out_j))
    elif lt in (LayerType.ACTIVATION, LayerType.BATCHNORM):
        if lp.on_edges:
            defs.append(("e", lid, tp.out_j, tp.tile_k, tp.slice_id))
            uses.append(("e", parent, tp.out_j, tp.tile_k, tp.slice_id))
        else:
            defs.append(("v", lid, tp.out_i, tp.out_j))
            uses.append(("v", parent, tp.out_i, tp.out_j))
    # Deduplicate uses, preserving order (a fiber re-read costs nothing
    # and would double-count hazard edges).
    seen: Set[ValueKey] = set()
    uses = [u for u in uses if not (u in seen or seen.add(u))]
    return defs, uses


def build_model(plan: ExecutionPlan, lmeta: dict, geometry: dict,
                pgraph=None,
                tile_slices: Optional[Dict[Tuple[int, int], int]] = None
                ) -> DefUseModel:
    """Decode plan + manifest layer table -> def/use model.

    ``geometry`` is the manifest ``geometry`` section (n1/n2/n_blocks);
    ``pgraph`` (optional) contributes the exact graph-tile universe —
    without it, pass ``tile_slices`` (see
    :func:`tile_slices_from_stats`) or graph-tile existence goes
    unchecked.
    """
    n1, n2 = int(geometry["n1"]), int(geometry["n2"])
    nb = int(geometry["n_blocks"])

    predefined: Set[ValueKey] = set()
    # Graph tiles: the (j, k, s) universe.
    slices: Optional[Dict[Tuple[int, int], int]] = None
    if pgraph is not None:
        slices = {(j, k): len(sl) for (j, k), sl in pgraph.tiles.items()}
    elif tile_slices is not None:
        slices = tile_slices
    graph_tiles_known = slices is not None
    if slices is not None:
        for (j, k), n in slices.items():
            for s in range(n):
                predefined.add(("g", j, k, s))

    layer_kind: Dict[int, str] = {}
    for lp in plan.layers:
        edge = (lp.layer_type == LayerType.VECTOR_INNER or lp.on_edges)
        layer_kind[lp.layer_id] = "e" if edge else "v"
        meta = lmeta.get(str(lp.layer_id), {})
        # Input features: every (i, j) fiber tile a -1 consumer can read.
        if -1 in layer_consumes(meta, lp.layer_type):
            for i in range(_fibers(lp.f_in, n2)):
                for j in range(nb):
                    predefined.add(("v", -1, i, j))
        # Weight blocks of LINEAR layers are manifest payload, always
        # present for the announced (f_in, f_out) grid.
        if lp.layer_type == LayerType.LINEAR:
            for k in range(_fibers(lp.f_in, n2)):
                for i in range(_fibers(lp.f_out, n2)):
                    predefined.add(("w", lp.layer_id, k, i))

    ops: List[TileOp] = []
    for step, lp in enumerate(plan.layers):
        meta = lmeta.get(str(lp.layer_id), {})
        kind = _TILE_KINDS.get(lp.layer_type, "?")
        for t_idx, tp in enumerate(lp.tiles):
            defs, uses = _tile_defs_uses(lp, tp, meta)
            ops.append(TileOp(
                node_id=len(ops), layer_id=lp.layer_id, step=step,
                tile_idx=t_idx, pe=tp.pe, kind=kind,
                instr_lo=tp.instr_lo, instr_hi=tp.instr_hi,
                defs=defs, uses=uses))
    return DefUseModel(plan=plan, ops=ops, predefined=predefined,
                       n1=n1, n2=n2, nb=nb, layer_kind=layer_kind,
                       graph_tiles_known=graph_tiles_known)


def tile_slices_from_stats(tile_stats: dict
                           ) -> Dict[Tuple[int, int], int]:
    """(j, k) -> slice count from a manifest ``tile_stats`` section —
    the graph-tile universe when no :class:`PartitionedGraph` is at
    hand (bytes + manifest verification)."""
    out: Dict[Tuple[int, int], int] = {}
    for key, rec in tile_stats.get("tiles", {}).items():
        j, k = key.split(":")
        out[(int(j), int(k))] = int(rec.get("slices", 1))
    return out
