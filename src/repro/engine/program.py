"""CompiledProgram — the serializable unit the :class:`Engine` executes.

A compiled program is exactly what the paper ships to the accelerator:

  * the 128-bit instruction binary (``isa.assemble`` output) — the only
    thing the runtime *dispatches* from;
  * a weights + graph-metadata manifest — the DDR payload: model weights,
    the fiber-shard ELL tiles of the input graph, and the per-layer
    dataflow facts that do not belong in instructions (weight key names,
    vector-add operands, scalar coefficients).

``save``/``load`` round-trip the pair through a single ``.gagi`` file
(a zip of ``program.bin`` + ``manifest.json`` + ``data.npz``), so a model
compiled once can serve later sessions with zero recompilation.
"""
from __future__ import annotations

import dataclasses
import io
import json
import zipfile
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ir import ModelIR
from repro.core.passes.kernel_map import Program
from repro.core.passes.partition import (ELLTile, PartitionConfig,
                                         PartitionedGraph)

MANIFEST_FORMAT = "gagi-program"
MANIFEST_VERSION = 1

# Layer attrs copied verbatim into the manifest: weight-key indirections
# and scalar coefficients the ISA cannot carry.
_WEIGHT_ATTRS = ("W", "b", "fused_scale", "fused_shift",
                 "mu", "sigma", "gamma", "beta")


def _layer_manifest(model: ModelIR) -> Dict[str, Dict[str, Any]]:
    layers: Dict[str, Dict[str, Any]] = {}
    for lid, l in model.layers.items():
        meta: Dict[str, Any] = {
            "parents": [int(p) for p in l.parent_ids],
        }
        ewl = l.attrs.get("edge_weight_layer")
        if ewl is not None:
            meta["edge_weight_layer"] = int(ewl)
        for k in _WEIGHT_ATTRS:
            if k in l.attrs:
                meta[k] = l.attrs[k]
        if "fused_act" in l.attrs:
            meta["fused_act"] = int(l.attrs["fused_act"])
        if "operands" in l.attrs:
            meta["operands"] = [int(o) for o in l.attrs["operands"]]
        if "alpha" in l.attrs:
            meta["alpha"] = float(l.attrs["alpha"])
            meta["beta"] = float(l.attrs["beta"])
        if "eps" in l.attrs:
            meta["eps"] = float(l.attrs["eps"])
        if "mode" in l.attrs:
            meta["mode"] = l.attrs["mode"]
        layers[str(lid)] = meta
    return layers


def build_manifest(program: Program, graph_name: str = "graph",
                   n_devices: Optional[int] = None) -> dict:
    """Everything `engine.run` needs beyond the binary + arrays.

    ``n_devices`` (set when the program is compiled for a mesh) adds a
    ``placement`` section: the per-device shard orders and halo sets of
    the multi-device executor.  Programs compiled without it still run
    on a mesh — the executor derives the placement from the binary, the
    same backward-compat path old ``.gagi`` bundles take.

    ``tile_stats`` records per-tile nnz/density from the ELL metadata —
    refreshed whenever ``repro.livegraph`` rebinds a program to patched
    tiles, and the observability a Dynasparse-style bind-time kernel
    remapper would key on (see ROADMAP)."""
    from repro.core.passes.schedule import (placement_schedule,
                                            residency_schedule)
    from repro.livegraph.tiles import tile_density_stats
    m, pg = program.model, program.pgraph
    sinks = [i for i, l in m.layers.items() if not l.child_ids]
    sink = sinks[-1] if sinks else m.topo_order()[-1]
    residency = residency_schedule(program)
    placement = (placement_schedule(program, n_devices, residency)
                 if n_devices is not None else None)
    return {
        "residency": residency,
        **({"placement": placement} if placement is not None else {}),
        "format": MANIFEST_FORMAT,
        "version": MANIFEST_VERSION,
        "model_name": m.name,
        "graph_name": graph_name,
        "geometry": {
            "n1": pg.config.n1,
            "n2": pg.config.n2,
            "width_cap": pg.config.width_cap,
            "n_blocks": pg.n_blocks,
            "n_vertices": pg.n_vertices,
            "n_edges": pg.n_edges,
            "n_pes": program.n_pes,
        },
        "sink": int(sink),
        "sink_f_out": int(m.layers[sink].f_out),
        "tile_stats": tile_density_stats(pg),
        "layers": _layer_manifest(m),
    }


@dataclasses.dataclass
class CompiledProgram:
    """A (binary, manifest, weights, tiles) bundle ready to execute.

    ``source`` optionally keeps the in-process :class:`CompileResult`
    (pass reports, the object-graph Program) for introspection and the
    analytic perf model; it is *never* touched by the execution path and
    is dropped by ``save``/``load``.
    """

    binary: bytes
    manifest: dict
    weights: Dict[str, np.ndarray]
    pgraph: PartitionedGraph
    t_loc: float = 0.0
    cache_key: str = ""
    # Execution-mode default ("device" | "host") set by
    # ``Engine.compile(residency=...)``; never serialized — a loaded
    # program runs device-resident unless the caller asks otherwise.
    default_residency: Optional[str] = dataclasses.field(
        default=None, compare=False)
    source: Optional[Any] = dataclasses.field(
        default=None, repr=False, compare=False)
    _plan: Optional[Any] = dataclasses.field(
        default=None, repr=False, compare=False)

    # ------------------------------------------------------------------ #
    @property
    def model_name(self) -> str:
        return self.manifest.get("model_name", "model")

    @property
    def graph_name(self) -> str:
        return self.manifest.get("graph_name", "graph")

    @property
    def binary_bytes(self) -> int:
        return len(self.binary)

    def instruction_count(self) -> int:
        import struct
        return struct.unpack_from("<IIII", self.binary, 0)[2]

    def plan(self):
        """Decode the binary into an execution plan (cached)."""
        if self._plan is None:
            from .decoder import decode_binary
            self._plan = decode_binary(self.binary)
        return self._plan

    # ------------------------------------------------------------------ #
    def save(self, path: str) -> None:
        """Serialize to a ``.gagi`` file (binary + manifest + arrays)."""
        arrays: Dict[str, np.ndarray] = {
            "inv_in_degree": np.asarray(self.pgraph.inv_in_degree),
        }
        for name, w in self.weights.items():
            arrays[f"w:{name}"] = np.asarray(w)
        for (j, k), slices in self.pgraph.tiles.items():
            for s, t in enumerate(slices):
                stem = f"t:{j}:{k}:{s}"
                arrays[stem + ":cols"] = t.cols
                arrays[stem + ":vals"] = t.vals
                arrays[stem + ":epos"] = t.edge_pos
        buf = io.BytesIO()
        np.savez_compressed(buf, **arrays)
        with zipfile.ZipFile(path, "w", zipfile.ZIP_STORED) as z:
            z.writestr("program.bin", self.binary)
            z.writestr("manifest.json", json.dumps(self.manifest, indent=1))
            z.writestr("data.npz", buf.getvalue())

    @staticmethod
    def load(path: str) -> "CompiledProgram":
        """Rebuild a program saved with :meth:`save`.

        The result carries no in-memory IR at all — execution is driven
        purely by the decoded binary plus the manifest arrays.
        """
        with zipfile.ZipFile(path, "r") as z:
            binary = z.read("program.bin")
            manifest = json.loads(z.read("manifest.json"))
            data = np.load(io.BytesIO(z.read("data.npz")))
        if manifest.get("format") != MANIFEST_FORMAT:
            raise ValueError(f"{path}: not a GraphAGILE program bundle")
        if manifest.get("version") != MANIFEST_VERSION:
            raise ValueError(
                f"{path}: manifest version {manifest.get('version')} "
                f"unsupported (expected {MANIFEST_VERSION})")

        weights: Dict[str, np.ndarray] = {}
        tile_parts: Dict[Tuple[int, int, int], Dict[str, np.ndarray]] = {}
        for key in data.files:
            if key.startswith("w:"):
                weights[key[2:]] = data[key]
            elif key.startswith("t:"):
                _, j, k, s, part = key.split(":")
                tile_parts.setdefault(
                    (int(j), int(k), int(s)), {})[part] = data[key]

        tiles: Dict[Tuple[int, int], List[ELLTile]] = {}
        for (j, k, s) in sorted(tile_parts):
            p = tile_parts[(j, k, s)]
            t = ELLTile(shard_row=j, shard_col=k, cols=p["cols"],
                        vals=p["vals"], edge_pos=p["epos"],
                        nnz=int((p["epos"] >= 0).sum()))
            tiles.setdefault((j, k), []).append(t)

        geo = manifest["geometry"]
        cfg = PartitionConfig(n1=int(geo["n1"]), n2=int(geo["n2"]),
                              width_cap=int(geo["width_cap"]))
        pg = PartitionedGraph(
            config=cfg, n_vertices=int(geo["n_vertices"]),
            n_edges=int(geo["n_edges"]), n_blocks=int(geo["n_blocks"]),
            tiles=tiles, inv_in_degree=data["inv_in_degree"])
        return CompiledProgram(binary=binary, manifest=manifest,
                               weights=weights, pgraph=pg)


def from_program(program: Program, binary: Optional[bytes] = None,
                 t_loc: float = 0.0, cache_key: str = "",
                 graph_name: str = "graph",
                 source: Optional[Any] = None,
                 n_devices: Optional[int] = None) -> CompiledProgram:
    """Wrap an object-graph :class:`Program` into a CompiledProgram.

    The manifest gains a ``dep_graph`` section — the RAW/WAR/WAW hazard
    DAG re-derived from the freshly assembled binary (see
    :mod:`repro.verify.hazards`) — so every ``.gagi`` bundle carries its
    own dependence structure for downstream schedulers and the trace
    race detector."""
    from repro.core.isa import assemble
    if binary is None:
        binary = assemble(program.all_instrs())
    weights = {k: np.asarray(v) for k, v in program.model.weights.items()}
    manifest = build_manifest(program, graph_name, n_devices=n_devices)
    manifest["dep_graph"] = _dep_graph_section(binary, manifest,
                                               program.pgraph)
    return CompiledProgram(
        binary=binary, manifest=manifest,
        weights=weights, pgraph=program.pgraph, t_loc=t_loc,
        cache_key=cache_key, source=source)


def _dep_graph_section(binary: bytes, manifest: dict, pgraph) -> dict:
    from repro.verify.hazards import dep_graph_manifest
    from repro.verify.model import build_model

    from .decoder import decode_binary
    plan = decode_binary(binary)
    model = build_model(plan, manifest["layers"], manifest["geometry"],
                        pgraph=pgraph)
    return dep_graph_manifest(model, manifest["layers"])
