"""Fiber-shard partitioning invariants (paper §6.5), property-based."""
import numpy as np
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import graph as G
from repro.core.passes.partition import (PartitionConfig, choose_partition,
                                         partition_graph)


def _edges_from_tiles(pg):
    n1 = pg.config.n1
    out = []
    for (j, k), ts in pg.tiles.items():
        for t in ts:
            r, c = np.nonzero(t.edge_pos >= 0)
            src = k * n1 + t.cols[r, c]
            dst = j * n1 + r
            out.append(np.stack([src, dst, t.vals[r, c],
                                 t.edge_pos[r, c]], axis=1))
    if not out:
        return np.zeros((0, 4))
    return np.concatenate(out, axis=0)


@settings(max_examples=25, deadline=None)
@given(
    nv=st.integers(10, 300),
    ne=st.integers(1, 900),
    n1=st.sampled_from([8, 16, 64]),
    cap=st.sampled_from([8, 16, 512]),
    degree=st.sampled_from(["uniform", "powerlaw"]),
    seed=st.integers(0, 3),
)
def test_partition_covers_every_edge_exactly_once(nv, ne, n1, cap, degree,
                                                  seed):
    g = G.random_graph(nv, ne, seed=seed, degree=degree)
    g.weight = np.random.default_rng(seed).normal(
        0, 1, g.n_edges).astype(np.float32)
    cfg = PartitionConfig(n1=n1, n2=8, width_cap=cap)
    pg = partition_graph(g, cfg)
    assert pg.total_nnz() == g.n_edges
    rec = _edges_from_tiles(pg)
    assert rec.shape[0] == g.n_edges
    # Every original (src, dst, w) appears exactly once, via edge_pos.
    eid = rec[:, 3].astype(np.int64)
    assert len(np.unique(eid)) == g.n_edges
    np.testing.assert_array_equal(rec[:, 0].astype(np.int64), g.src[eid])
    np.testing.assert_array_equal(rec[:, 1].astype(np.int64), g.dst[eid])
    np.testing.assert_allclose(rec[:, 2], g.weight[eid], rtol=1e-6)


@settings(max_examples=15, deadline=None)
@given(nv=st.integers(10, 200), ne=st.integers(1, 600),
       cap=st.sampled_from([8, 16, 32]), seed=st.integers(0, 3))
def test_width_cap_respected(nv, ne, cap, seed):
    g = G.random_graph(nv, ne, seed=seed, degree="powerlaw")
    pg = partition_graph(g, PartitionConfig(n1=16, n2=8, width_cap=cap))
    for ts in pg.tiles.values():
        for t in ts:
            assert t.width <= max(cap, 8)


def test_inv_in_degree():
    g = G.random_graph(40, 200, seed=0)
    pg = partition_graph(g, PartitionConfig(n1=16, n2=8))
    deg = np.bincount(g.dst, minlength=40)
    np.testing.assert_allclose(
        pg.inv_in_degree[:40], 1.0 / np.maximum(deg, 1.0), rtol=1e-6)


def test_choose_partition_fits_budget():
    for f in [4, 64, 500, 4096]:
        cfg = choose_partition(100000, f, vmem_budget_bytes=1 << 20)
        assert cfg.n1 * cfg.n2 * 4 <= (1 << 20)
        assert cfg.n1 >= 8 and cfg.n2 >= 8


def test_dst_sorting_within_rows():
    """Compile-time RAW elimination: per tile, each row's edges are
    contiguous; row ownership is unique per output row (DESIGN.md §2)."""
    g = G.random_graph(60, 400, seed=1)
    pg = partition_graph(g, PartitionConfig(n1=16, n2=8))
    for (j, k), ts in pg.tiles.items():
        for t in ts:
            valid = t.edge_pos >= 0
            # no valid entry may appear after an invalid one in a row
            for r in range(valid.shape[0]):
                row = valid[r]
                if row.any():
                    last = np.max(np.nonzero(row))
                    assert row[: last + 1].all()
