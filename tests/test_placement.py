"""Placement-aware multi-device execution (compiler placement schedule +
mesh executor).

Covers the tentpole acceptance criteria:
  * the placement schedule (LPT shard -> device assignment, per-device
    greedy max-overlap shard orders, per-layer halo sets) is structurally
    sound, deterministic, and round-trips ``.gagi``;
  * ``derive_placement`` (the backward-compat fallback for bundles
    written before manifests carried a ``placement`` section) reproduces
    the compiler-emitted schedule exactly;
  * the multi-device path (``mesh=`` knob) is BIT-identical to the
    single-device executor for every benchmark model (b1..b8) on two
    graphs — the dedicated CI job runs this file under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the
    per-device schedules and the halo-exchange collective actually span
    four devices;
  * per-device ``ExecStats``: halo bytes, shard counts, load imbalance.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.ir import LayerType
from repro.core.passes.partition import PartitionConfig, halo_sets
from repro.core.passes.schedule import lpt_assign
from repro.engine import Engine, derive_placement, ensure_placement

GEOM = PartitionConfig(n1=32, n2=8)
N_DEV = min(4, jax.local_device_count())


def _g(nv=160, ne=800, f=12, c=4, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _engine(**kw) -> Engine:
    return Engine(geometry=GEOM, n_pes=4, **kw)


# --------------------------------------------------------------------------- #
# Placement schedule structure (pure compiler output — no devices needed).
# --------------------------------------------------------------------------- #
def test_placement_schedule_structure():
    g = _g(seed=11)
    prog = _engine().compile("b6", g, mesh=4)
    pl = prog.manifest["placement"]
    nb = prog.pgraph.n_blocks
    assert pl["n_devices"] == 4
    assert len(pl["assignment"]) == nb
    assert all(0 <= d < 4 for d in pl["assignment"])
    assert len(pl["loads"]) == 4
    owned = [set() for _ in range(4)]
    for j, d in enumerate(pl["assignment"]):
        owned[d].add(j)
    res = prog.manifest["residency"]
    plan = prog.plan()
    types = {lp.layer_id: lp.layer_type for lp in plan.layers}
    for lid, lpl in pl["layers"].items():
        sources = res["layers"][lid]["sources"]
        for d in range(4):
            order = lpl["order"][str(d)]
            # each device's order is a permutation of ITS sourced shards
            assert sorted(order) == sorted(
                int(j) for j in sources if pl["assignment"][int(j)] == d)
            halo = set(lpl["halo"][str(d)])
            # halo is needed-minus-owned: disjoint from owned blocks,
            # and every halo block is some sourced block of this device
            assert not (halo & owned[d])
            need = set()
            for j in order:
                need.update(sources[str(j)])
            assert halo == need - owned[d]
        # row-local layers (GEMM / vadd / activations) exchange nothing
        if types[int(lid)] in (LayerType.LINEAR, LayerType.VECTOR_ADD,
                               LayerType.ACTIVATION, LayerType.BATCHNORM):
            assert all(not lpl["halo"][str(d)] for d in range(4))
        # halo_bytes arithmetic: blocks x (n1 * padded f_in * 4)
        fp = ((max(
            next(lp.f_in for lp in plan.layers
                 if lp.layer_id == int(lid)), 1) + GEOM.n2 - 1)
            // GEOM.n2) * GEOM.n2
        for d in range(4):
            assert lpl["halo_bytes"][str(d)] == \
                len(lpl["halo"][str(d)]) * GEOM.n1 * fp * 4
    assert pl["halo_bytes_total"] == sum(
        lpl["halo_bytes"][str(d)]
        for lpl in pl["layers"].values() for d in range(4))


def test_placement_is_deterministic_and_lpt_balanced():
    g = _g(seed=13)
    e1, e2 = _engine(), _engine()
    prog = e1.compile("b1", g, mesh=3)
    p1 = prog.manifest["placement"]
    p2 = e2.compile("b1", g, mesh=3).manifest["placement"]
    assert p1 == p2
    # Recompute the per-block costs the pass uses (compute-instruction
    # counts per destination row block) and check the recorded loads
    # really are that assignment's bin loads, with the classic LPT
    # balance guarantee: max load <= mean + the largest single item.
    costs = [0.0] * prog.pgraph.n_blocks
    for lp in prog.plan().layers:
        for tp in lp.tiles:
            if tp.out_j >= 0:
                costs[tp.out_j] += len(tp.compute)
    loads = p1["loads"]
    assert len(loads) == 3 and sum(loads) == sum(costs) > 0
    for d in range(3):
        assert loads[d] == sum(c for j, c in enumerate(costs)
                               if p1["assignment"][j] == d)
    assert max(loads) <= sum(costs) / 3 + max(costs)
    # single-device placement owns everything, exchanges nothing
    p1d = derive_placement(
        e1.compile("b1", g).plan(),
        e1.compile("b1", g).manifest["residency"],
        e1.compile("b1", g).manifest["geometry"], 1)
    assert p1d["assignment"] == [0] * len(p1d["assignment"])
    assert p1d["halo_bytes_total"] == 0


def test_halo_sets_helper():
    # two devices, blocks 0,1 -> dev0, block 2 -> dev1; shard 0 reads
    # {0,2}, shard 2 reads {1,2}
    halos = halo_sets([0, 0, 1], {"0": [0, 2], "2": [1, 2]}, 2)
    assert halos == [[2], [1]]


def test_lpt_assign_reused_for_placement():
    # placement uses the same greedy LPT as the PE scheduler: heaviest
    # shard lands alone when it dominates
    assignment, loads = lpt_assign([10.0, 1.0, 1.0, 1.0], 2)
    assert assignment[0] == 0 and set(assignment[1:]) == {1}


# --------------------------------------------------------------------------- #
# Round-trip + derivation fallback.
# --------------------------------------------------------------------------- #
def test_gagi_roundtrips_placement(tmp_path):
    g = _g(seed=17)
    eng = _engine()
    prog = eng.compile("b6", g, mesh=4)
    path = os.path.join(str(tmp_path), "gat_mesh.gagi")
    prog.save(path)
    loaded = _engine().load(path)
    assert loaded.manifest["placement"] == prog.manifest["placement"]


def test_pre_placement_bundle_falls_back_to_derivation(tmp_path):
    """A .gagi written before manifests carried a placement section
    still runs on a mesh: the executor derives the schedule from the
    binary — and the derived schedule equals what the compiler emits."""
    g = _g(seed=19)
    eng = _engine()
    prog = eng.compile("b6", g, mesh=4)
    emitted = prog.manifest["placement"]
    path = os.path.join(str(tmp_path), "old_mesh.gagi")
    prog.save(path)
    loaded = _engine().load(path)
    loaded.manifest.pop("placement")     # simulate an old bundle
    derived = ensure_placement(loaded, 4)
    assert derived == emitted
    # ensure_placement attaches the derived schedule for future saves
    assert loaded.manifest["placement"] == emitted


def test_compile_without_mesh_emits_no_placement_then_derives():
    g = _g(seed=23)
    eng = _engine()
    prog = eng.compile("b1", g)
    assert "placement" not in prog.manifest
    pl = ensure_placement(prog, 2)
    assert pl["n_devices"] == 2
    # a cached recompile with the mesh knob reuses/attaches the schedule
    prog2 = eng.compile("b1", g, mesh=2)
    assert prog2.manifest["placement"]["n_devices"] == 2


# --------------------------------------------------------------------------- #
# Multi-device execution is bit-identical to the single-device executor.
# The forced-4-virtual-device CI job runs these with N_DEV == 4; on a
# single-device host they still exercise the mesh machinery with D=1.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["b1", "b2", "b3", "b4", "b6", "b7"])
@pytest.mark.parametrize("gseed", [3, 21])
def test_mesh_is_bit_identical(name, gseed):
    g = _g(seed=gseed)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile(name, g, mesh=N_DEV)
    y_dev = np.asarray(eng.run(prog, x))
    y_mesh = np.asarray(eng.run(prog, x, mesh=N_DEV))
    assert np.array_equal(y_dev, y_mesh)


@pytest.mark.slow
@pytest.mark.parametrize("name", ["b5", "b8"])
@pytest.mark.parametrize("gseed", [3, 21])
def test_mesh_is_bit_identical_deep(name, gseed):
    """The deep stacks (GIN b5, GraphGym b8) — slow-marked to cap the
    tier-1 gate; the forced-device CI job runs them unfiltered."""
    g = _g(seed=gseed)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile(name, g, mesh=N_DEV)
    y_dev = np.asarray(eng.run(prog, x))
    y_mesh = np.asarray(eng.run(prog, x, mesh=N_DEV))
    assert np.array_equal(y_dev, y_mesh)


def test_mesh_run_batch_matches_device():
    g = _g(seed=7)
    x = jnp.asarray(G.random_features(g, seed=4))
    xs = jnp.stack([x, x * 0.5, x * -1.0])
    eng = _engine()
    prog = eng.compile("b1", g)
    yd = np.asarray(eng.run_batch(prog, xs))
    ym = np.asarray(eng.run_batch(prog, xs, mesh=N_DEV))
    assert np.array_equal(yd, ym)
    assert eng.exec_stats.runs == 1      # one logical batched pass


def test_mesh_derivation_path_is_bit_identical():
    """Programs compiled WITHOUT the mesh knob (or loaded from old
    bundles) run on a mesh through the derived placement."""
    g = _g(seed=29)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile("b3", g)          # no placement section
    y_dev = np.asarray(eng.run(prog, x))
    y_mesh = np.asarray(eng.run(prog, x, mesh=N_DEV))
    assert np.array_equal(y_dev, y_mesh)


# --------------------------------------------------------------------------- #
# Per-device ExecStats.
# --------------------------------------------------------------------------- #
def test_mesh_exec_stats_per_device():
    g = _g(seed=31)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile("b6", g, mesh=N_DEV)
    dev_ops = None
    y = eng.run(prog, x)
    dev_ops = eng.exec_stats.tile_ops
    eng.run(prog, x, mesh=N_DEV)
    st = eng.exec_stats
    assert st.n_devices == N_DEV
    assert st.per_device is not None and len(st.per_device) == N_DEV
    # every tile executes on exactly one device
    assert sum(d["tile_ops"] for d in st.per_device) == dev_ops == \
        st.tile_ops
    assert sum(d["blocks"] for d in st.per_device) == prog.pgraph.n_blocks
    assert st.device_imbalance >= 1.0
    # GAT aggregates across blocks: with >1 device some sub-fibers must
    # cross the mesh, and the exchange volume matches the manifest
    pl = prog.manifest["placement"]
    assert st.halo_bytes == pl["halo_bytes_total"]
    if N_DEV > 1:
        assert st.halo_bytes > 0
    assert st.peak_device_bytes > 0
    del y


@pytest.mark.skipif(N_DEV < 2, reason="needs >1 device (CI forces 4 "
                    "with XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=4)")
def test_mesh_spreads_work_across_devices():
    g = _g(seed=37)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile("b1", g, mesh=N_DEV)
    eng.run(prog, x, mesh=N_DEV)
    busy = [d for d in eng.exec_stats.per_device if d["tile_ops"] > 0]
    assert len(busy) == min(N_DEV, prog.pgraph.n_blocks)


# --------------------------------------------------------------------------- #
# Knob validation.
# --------------------------------------------------------------------------- #
def test_mesh_rejects_graph_data_and_host_residency():
    g = _g(seed=41)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile("b1", g)
    with pytest.raises(ValueError, match="device-resident"):
        eng.run(prog, x, graph_data={"tiles": {}}, mesh=N_DEV)
    with pytest.raises(ValueError, match="does not compose"):
        eng.run(prog, x, residency="host", mesh=N_DEV)


def test_make_device_mesh_validates():
    from repro.launch.mesh import make_device_mesh
    with pytest.raises(ValueError):
        make_device_mesh(jax.local_device_count() + 1)
    m = make_device_mesh()
    assert m.axis_names == ("dev",)
