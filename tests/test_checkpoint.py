"""Checkpoint: atomic save/restore, resume, elastic re-mesh, crash safety."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save

REPO = os.path.join(os.path.dirname(__file__), "..")


def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(0, 1, (4, 8)).astype(np.float32)),
            "b": {"c": jnp.asarray(r.integers(0, 9, (3,)).astype(np.int32)),
                  "d": [jnp.ones((2, 2), jnp.bfloat16)] * 2}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 7, t, meta={"x": 1})
    t2, step, meta = restore(str(tmp_path), t)
    assert step == 7 and meta == {"x": 1}
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, t, keep=2)
    assert latest_step(str(tmp_path)) == 5
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_00000004", "step_00000005"]


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    save(str(tmp_path), 1, t)
    # simulate a crash mid-save: manifest without the complete flag
    bad = tmp_path / "step_00000002"
    bad.mkdir()
    (bad / "manifest.json").write_text(json.dumps({"step": 2}))
    assert latest_step(str(tmp_path)) == 1


def test_elastic_restore_resharded(tmp_path):
    """Saved unsharded; restored with an explicit 2x4 mesh sharding."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.compat import make_mesh
from repro.checkpoint import save, restore
t = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
save({str(tmp_path)!r}, 3, t)
mesh = make_mesh((2, 4), ("data", "model"))
sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
t2, step, _ = restore({str(tmp_path)!r}, t, shardings=sh)
assert step == 3
assert t2["w"].sharding == sh["w"]
np.testing.assert_array_equal(np.asarray(t2["w"]), np.asarray(t["w"]))
print("OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


@pytest.mark.slow          # two full training subprocesses
def test_crash_restart_loss_continuity(tmp_path):
    """launch.train: crash at step 12, relaunch with --resume auto; the
    run completes and the data stream stays deterministic."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "qwen3-0.6b", "--smoke", "--steps", "20", "--batch", "2",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every",
            "5", "--log-every", "5", "--resume", "auto"]
    r1 = subprocess.run(args + ["--crash-at", "12"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert r1.returncode == 42  # simulated failure
    assert latest_step(str(tmp_path)) == 10
    r2 = subprocess.run(args, env=env, capture_output=True, text=True,
                        timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] restored step 10" in r2.stdout
    assert "done: 20 steps" in r2.stdout
