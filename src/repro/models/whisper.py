"""Whisper-style encoder-decoder (arXiv:2212.04356), backbone only.

Per the assignment, the conv frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings [B, S_frames, D].  The encoder is a
bidirectional transformer over frames; the decoder is a causal LM with
cross-attention to the encoder output in every layer (implemented by
reusing DecoderLM with cross_attn_every=1).
"""
from __future__ import annotations

from typing import Optional, Tuple

import dataclasses
import jax
import jax.numpy as jnp

from . import attention as A
from .config import ModelConfig
from .layers import Params, rms_norm, swiglu, swiglu_init
from .transformer import DecoderLM, _remat


class WhisperModel:
    """Encoder (n_encoder_layers) + decoder (n_layers) transformer."""

    def __init__(self, cfg: ModelConfig, moe_impl: str = "dense",
                 mesh=None) -> None:
        assert cfg.encoder_decoder
        self.cfg = cfg
        dec_cfg = dataclasses.replace(cfg, cross_attn_every=1,
                                      encoder_decoder=False)
        self.decoder = DecoderLM(dec_cfg, moe_impl=moe_impl, mesh=mesh)

    # ------------------------------------------------------------------ #
    def _enc_block_init(self, key) -> Params:
        cfg = self.cfg
        dt = cfg.jdtype
        k1, k2 = jax.random.split(key)
        return {
            "ln1": jnp.zeros((cfg.d_model,), dt),
            "attn": A.attn_init(k1, cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.hd, dt),
            "ln2": jnp.zeros((cfg.d_model,), dt),
            "mlp": swiglu_init(k2, cfg.d_model, cfg.d_ff, dt),
        }

    def init_params(self, key) -> Params:
        cfg = self.cfg
        ke, kd = jax.random.split(key)
        enc_keys = jax.random.split(ke, cfg.n_encoder_layers)
        enc = jax.vmap(self._enc_block_init)(enc_keys)
        p = {"encoder": {"blocks": enc,
                         "final_norm": jnp.zeros((cfg.d_model,),
                                                 cfg.jdtype)}}
        p["decoder"] = self.decoder.init_params(kd)
        return p

    def param_specs(self):
        return jax.eval_shape(
            lambda: self.init_params(jax.random.PRNGKey(0)))

    # ------------------------------------------------------------------ #
    def encode(self, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        """frames [B, S, D] (stub frontend output) -> encoder states."""
        cfg = self.cfg
        x = frames.astype(cfg.jdtype)
        positions = jnp.arange(x.shape[1], dtype=jnp.int32)

        def body(xx, bp):
            h = rms_norm(xx, bp["ln1"], cfg.norm_eps)
            xx = xx + A.attention(bp["attn"], h, positions, causal=False,
                                  rope_theta=cfg.rope_theta,
                                  chunk=cfg.attn_chunk)
            xx = xx + swiglu(bp["mlp"], rms_norm(xx, bp["ln2"],
                                                 cfg.norm_eps))
            return xx, None

        body = _remat(body, cfg.remat)
        x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
        return rms_norm(x, params["encoder"]["final_norm"], cfg.norm_eps)

    def forward(self, params: Params, frames: jnp.ndarray,
                targets: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Teacher-forced enc-dec forward -> (logits, aux)."""
        enc = self.encode(params, frames)
        return self.decoder.forward(params["decoder"], targets,
                                    cross_kv_x=enc)

    # ------------------------------------------------------------------ #
    def init_cache(self, batch: int, seq_len: int, zeros: bool = True,
                   cross_len: Optional[int] = None):
        """Decoder cache; ``seq_len`` = decoder target capacity;
        ``cross_len`` = number of encoder frames attended to."""
        return self.decoder.init_cache(batch, seq_len, zeros=zeros,
                                       cross_len=cross_len)

    def decode_step(self, params: Params, cache, token, pos):
        return self.decoder.decode_step(params["decoder"], cache, token,
                                        pos)
