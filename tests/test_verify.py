"""repro.verify: static binary verification + trace race detection.

Covers the tentpole acceptance criteria:
  * the verifier passes every benchmark model b1..b8 compiled against
    device, host-streaming, and mesh placements, plus a livegraph
    rebind;
  * >= 6 distinct hand-corrupted programs are each rejected with the
    expected check name;
  * the hazard `dep_graph` manifest section round-trips through .gagi;
  * the race detector validates a recorded streaming-overlap trace and
    flags a synthetically reordered one;
  * `decode`/`disassemble` raise clean ValueErrors (offset + expected /
    actual) on every malformed input — property-fuzzed when hypothesis
    is installed.
"""
import copy
import json
import re
import struct

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import graph as G
from repro.core.isa import (HEADER_BYTES, MAGIC, VERSION, Instr, Opcode,
    assemble, disassemble)
from repro.core.passes.partition import PartitionConfig
from repro.engine import CompiledProgram, Engine
from repro.livegraph import GraphDelta, GraphVersionStore, LiveGraphServer
from repro.obs import tracing
from repro.verify import (ALL_CHECKS, VerifyError, check_trace, verify,
                          verify_binary, verify_program)

GEOM = PartitionConfig(n1=32, n2=8)
BENCHES = ["b1", "b2", "b3", "b4", "b5", "b6", "b7", "b8"]


def _g(nv=90, ne=400, f=12, c=4, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _engine(**kw) -> Engine:
    return Engine(geometry=GEOM, n_pes=4, **kw)


@pytest.fixture(scope="module")
def graph():
    return _g()


@pytest.fixture(scope="module")
def programs(graph):
    """b1..b8 compiled once (device placement) for the whole module."""
    eng = _engine()
    return {name: eng.compile(name, graph) for name in BENCHES}


def _reassemble(prog, mutate):
    """Disassemble -> mutate the instruction list in place -> assemble."""
    instrs = disassemble(prog.binary)
    mutate(instrs)
    return assemble(instrs)


# --------------------------------------------------------------------------- #
# Positives: every placement, every bench, rebinds.
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", BENCHES)
def test_verifier_passes_all_benches(name, programs):
    rep = verify(programs[name])
    assert rep.ok, rep.to_markdown()
    # Placement is absent on single-device programs; everything else ran.
    assert set(rep.checks_run) == set(ALL_CHECKS) - {"halo_completeness"}
    assert rep.stats["hazard_edges"]["RAW"] > 0
    assert rep.stats["hazard_edges"]["WAW"] == 0


@pytest.mark.parametrize("name", ["b1", "b6"])
def test_verifier_passes_mesh_and_host_placements(name, graph):
    eng = _engine()
    mesh_prog = eng.compile(name, graph, mesh=4)
    rep = verify(mesh_prog)
    assert rep.ok, rep.to_markdown()
    assert set(rep.checks_run) == set(ALL_CHECKS)   # halo check ran
    host_prog = eng.compile(name, graph, residency="host",
                            use_cache=False)
    rep = verify(host_prog)
    assert rep.ok, rep.to_markdown()


def test_verifier_passes_livegraph_rebind(graph):
    store = GraphVersionStore(graph, geometry=GEOM)
    live = LiveGraphServer(store)
    eng = _engine()
    assert verify(eng.compile("b1", live)).ok
    # Content delta: same binary, patched tile values.
    i = 9
    d = GraphDelta(graph.n_vertices)
    d.remove_edge(int(graph.src[i]), int(graph.dst[i]))
    d.add_edge(int(graph.src[i]), int(graph.dst[i]), 123.0)
    live.apply(d)
    p1 = eng.compile("b1", live)
    assert p1.manifest.get("graph_version") == 1
    assert verify(p1).ok
    # Structural delta that fits the spare ELL capacity: nnz in the
    # binary goes stale; capacity-relaxed legality must still pass.
    live.apply(GraphDelta(live.n_vertices).add_edge(1, 2, 0.5))
    p2 = eng.compile("b1", live)
    assert verify(p2).ok


def test_bytes_only_verification_runs_structure_check(programs):
    rep = verify_binary(programs["b1"].binary)
    assert rep.ok
    assert rep.checks_run == ["structure"]
    assert set(rep.checks_skipped) == set(ALL_CHECKS) - {"structure"}


def test_bytes_plus_manifest_runs_semantic_checks(programs):
    prog = programs["b3"]
    rep = verify_binary(prog.binary, manifest=prog.manifest)
    assert rep.ok, rep.to_markdown()
    assert "def_before_use" in rep.checks_run
    assert "partition_coverage" in rep.checks_run
    assert "kernel_legality" in rep.checks_run
    assert "liveness_schedule" in rep.checks_run
    assert "resident_budget" in rep.checks_skipped   # needs weights/tiles


# --------------------------------------------------------------------------- #
# dep_graph manifest section.
# --------------------------------------------------------------------------- #
def test_dep_graph_round_trips_through_gagi(programs, tmp_path):
    prog = programs["b1"]
    dg = prog.manifest["dep_graph"]
    assert dg["version"] == 1
    assert dg["n_tile_nodes"] == sum(
        len(lp.tiles) for lp in prog.plan().layers)
    assert dg["edge_counts"]["WAW"] == 0 and dg["edge_counts"]["WAR"] == 0
    assert dg["layer_edges"], "multi-layer model must have RAW edges"
    path = str(tmp_path / "b1.gagi")
    prog.save(path)
    loaded = CompiledProgram.load(path)
    assert loaded.manifest["dep_graph"] == dg
    assert verify(loaded).ok


def test_dep_graph_layer_edges_follow_manifest_parents(programs):
    prog = programs["b2"]
    dg = prog.manifest["dep_graph"]
    ids = {layer["id"] for layer in dg["layers"]}
    for a, b, kind in dg["layer_edges"]:
        assert kind == "RAW"
        assert a in ids and b in ids
        steps = {layer["id"]: layer["step"] for layer in dg["layers"]}
        assert steps[a] < steps[b], "producer must precede consumer"


# --------------------------------------------------------------------------- #
# Negatives: >= 6 distinct corruptions, each caught by the named check.
# --------------------------------------------------------------------------- #
def test_rejects_duplicated_output_tile(programs):
    """Retarget one tiling block's MEM_WR shard: partition_coverage."""
    prog = programs["b1"]

    def mutate(instrs):
        for ins in instrs:
            if ins.op == Opcode.MEM_WR and ins.flags:
                ins.args = (ins.args[0], ins.args[1], ins.args[2],
                            (ins.args[3] + 1) % 3)
                return
    rep = verify_binary(_reassemble(prog, mutate),
                        manifest=prog.manifest, pgraph=prog.pgraph)
    assert not rep.ok
    assert "partition_coverage" in rep.checks_failed
    assert any(v.instr_lo >= 0 for v in rep.violations)


def test_rejects_out_of_range_gather_source(programs):
    """SPDMM reading a nonexistent source block: def_before_use."""
    prog = programs["b1"]

    def mutate(instrs):
        for ins in instrs:
            if ins.op == Opcode.SPDMM:
                ins.args = (ins.args[0], 99, ins.args[2], ins.args[3])
                return
    rep = verify_binary(_reassemble(prog, mutate),
                        manifest=prog.manifest, pgraph=prog.pgraph)
    assert not rep.ok
    assert "def_before_use" in rep.checks_failed


def test_rejects_wrong_mac_count(programs):
    """GEMM announcing the wrong MAC volume: kernel_legality."""
    prog = programs["b1"]

    def mutate(instrs):
        for ins in instrs:
            if ins.op == Opcode.GEMM:
                ins.arg4 = ins.arg4 + 1
                return
    rep = verify_binary(_reassemble(prog, mutate),
                        manifest=prog.manifest, pgraph=prog.pgraph)
    assert not rep.ok
    assert rep.checks_failed == ["kernel_legality"]


def test_rejects_stale_nnz_on_non_rebound_program(programs):
    """SPDMM nnz disagreeing with the ELL tile: kernel_legality (exact
    check — only rebound programs get the capacity relaxation)."""
    prog = programs["b1"]

    def mutate(instrs):
        for ins in instrs:
            if ins.op == Opcode.SPDMM and ins.arg4 > 0:
                ins.arg4 = ins.arg4 - 1
                return
    rep = verify_binary(_reassemble(prog, mutate),
                        manifest=prog.manifest, pgraph=prog.pgraph)
    assert not rep.ok
    assert "kernel_legality" in rep.checks_failed


def test_rejects_instructions_after_halt(programs):
    prog = programs["b1"]

    def mutate(instrs):
        instrs.append(Instr(op=Opcode.NOP))
    rep = verify_binary(_reassemble(prog, mutate),
                        manifest=prog.manifest, pgraph=prog.pgraph)
    assert not rep.ok
    assert "structure" in rep.checks_failed


def test_rejects_freed_value_read(programs):
    """Shrink a value's manifest last_use below its real last reader:
    use_after_free (and the schedule-equality check fires too)."""
    prog = programs["b1"]
    man = copy.deepcopy(prog.manifest)
    # A producer with a downstream reader (first RAW layer edge): its
    # consumer executes at step >= 1, so freeing at step 0 is too early.
    producer = man["dep_graph"]["layer_edges"][0][0]
    man["residency"]["last_use"][str(producer)] = 0
    rep = verify_binary(prog.binary, manifest=man, pgraph=prog.pgraph)
    assert not rep.ok
    assert "use_after_free" in rep.checks_failed
    assert "liveness_schedule" in rep.checks_failed


def test_rejects_incomplete_halo_set(graph):
    prog = _engine().compile("b1", graph, mesh=2)
    man = copy.deepcopy(prog.manifest)
    stripped = False
    for rec in man["placement"]["layers"].values():
        for d, ks in rec["halo"].items():
            if ks:
                rec["halo"][d] = ks[1:]
                stripped = True
                break
        if stripped:
            break
    assert stripped, "mesh=2 placement should have a nonempty halo"
    rep = verify_binary(prog.binary, manifest=man, pgraph=prog.pgraph)
    assert not rep.ok
    assert "halo_completeness" in rep.checks_failed


def test_rejects_residency_drift_from_budget_estimate(programs):
    """Extending a value's manifest lifetime inflates the executor's
    budget estimate past the binary's re-derived peak: resident_budget."""
    prog = programs["b1"]
    tampered = CompiledProgram(
        binary=prog.binary, manifest=copy.deepcopy(prog.manifest),
        weights=prog.weights, pgraph=prog.pgraph)
    last = tampered.manifest["residency"]["last_use"]
    lid = min(int(k) for k in last if int(k) >= 0)
    last[str(lid)] = len(tampered.manifest["dep_graph"]["layers"]) + 5
    rep = verify_program(tampered)
    assert not rep.ok
    assert "resident_budget" in rep.checks_failed


def test_rejects_wrong_tiling_block_count(programs):
    """CSI announcing more tiling blocks than the stream carries is a
    decode-level failure surfaced as a structure violation."""
    prog = programs["b1"]

    def mutate(instrs):
        for ins in instrs:
            if ins.op == Opcode.CSI:
                ins.arg4 = ins.arg4 + 1
                return
    rep = verify_binary(_reassemble(prog, mutate),
                        manifest=prog.manifest, pgraph=prog.pgraph)
    assert not rep.ok
    assert rep.checks_failed == ["structure"]
    assert "tiling blocks" in rep.violations[0].message


def test_engine_compile_verify_raises_on_corrupt_rebind(graph):
    """Engine.compile(verify=True) runs the suite on livegraph rebinds;
    a binary/tiles mismatch surfaces as VerifyError, not a wrong run."""
    store = GraphVersionStore(graph, geometry=GEOM)
    live = LiveGraphServer(store)
    eng = _engine(verify=True)
    prog = eng.compile("b1", live)

    def mutate(instrs):
        for ins in instrs:
            if ins.op == Opcode.SPDMM:
                ins.args = (ins.args[0], 99, ins.args[2], ins.args[3])
                return
    bad = CompiledProgram(
        binary=_reassemble(prog, mutate), manifest=prog.manifest,
        weights=prog.weights, pgraph=prog.pgraph, cache_key=prog.cache_key)
    eng.cache.put(prog.cache_key, bad)
    with pytest.raises(VerifyError) as ei:
        eng.compile("b1", live)
    assert "def_before_use" in str(ei.value)


# --------------------------------------------------------------------------- #
# Decoder robustness: clean ValueErrors on malformed bytes.
# --------------------------------------------------------------------------- #
def test_disassemble_rejects_truncated_payload(programs):
    blob = programs["b1"].binary
    with pytest.raises(ValueError, match="truncated"):
        disassemble(blob[:-1])
    with pytest.raises(ValueError, match="header"):
        disassemble(blob[:8])


def test_disassemble_rejects_trailing_bytes(programs):
    blob = programs["b1"].binary
    with pytest.raises(ValueError, match="trailing"):
        disassemble(blob + b"\x00")


def test_disassemble_rejects_count_payload_disagreement(programs):
    blob = programs["b1"].binary
    n = struct.unpack_from("<IIII", blob, 0)[2]
    lying = struct.pack("<IIII", MAGIC, VERSION, n + 1, 0) \
        + blob[HEADER_BYTES:]
    with pytest.raises(ValueError, match=f"announces {n + 1}"):
        disassemble(lying)


def test_disassemble_rejects_out_of_range_opcode(programs):
    blob = bytearray(programs["b1"].binary)
    blob[HEADER_BYTES] = 0xEE                       # instr 0, opcode byte
    with pytest.raises(ValueError) as ei:
        disassemble(bytes(blob))
    msg = str(ei.value)
    assert "opcode" in msg and "instruction 0" in msg
    assert f"byte offset {HEADER_BYTES}" in msg


def test_decode_rejects_unknown_layer_type_and_region(programs):
    instrs = disassemble(programs["b1"].binary)
    from repro.engine.decoder import decode_program
    bad_csi = [Instr(op=Opcode.CSI, args=(0, 13, 8, 8), arg4=0),
               Instr(op=Opcode.HALT)]
    with pytest.raises(ValueError, match="layer type 13"):
        decode_program(bad_csi)
    mutated = list(instrs)
    for i, ins in enumerate(mutated):
        if ins.op == Opcode.MEM_WR:
            mutated[i] = Instr(op=Opcode.MEM_WR, pe=ins.pe,
                               flags=ins.flags,
                               args=(ins.args[0], 15, ins.args[2],
                                     ins.args[3]), arg4=ins.arg4)
            break
    with pytest.raises(ValueError, match="unknown region 15"):
        decode_program(mutated)


def test_verify_binary_never_raises_on_garbage():
    for blob in (b"", b"junk", b"\x00" * 64,
                 struct.pack("<IIII", MAGIC, 99, 0, 0)):
        rep = verify_binary(blob)
        assert not rep.ok
        assert rep.checks_failed == ["structure"]


# --------------------------------------------------------------------------- #
# Property fuzzing (skips without hypothesis; CI installs it).
# --------------------------------------------------------------------------- #
@settings(max_examples=60, deadline=None)
@given(data=st.data())
def test_fuzzed_mutations_never_crash_the_decoder(data, programs):
    """Bit-flip / truncate / splice a pristine binary: the decoder either
    raises a clean ValueError or the verifier returns a report — no
    IndexError, struct.error, or enum crash ever escapes."""
    name = data.draw(st.sampled_from(BENCHES))
    blob = bytearray(programs[name].binary)
    mode = data.draw(st.sampled_from(["flip", "truncate", "splice"]))
    if mode == "flip":
        i = data.draw(st.integers(0, len(blob) - 1))
        bit = data.draw(st.integers(0, 7))
        blob[i] ^= 1 << bit
    elif mode == "truncate":
        blob = blob[:data.draw(st.integers(0, len(blob) - 1))]
    else:
        other = bytearray(
            programs[data.draw(st.sampled_from(BENCHES))].binary)
        cut = data.draw(st.integers(0, min(len(blob), len(other))))
        blob = blob[:cut] + other[cut:]
    prog = programs[name]
    try:
        rep = verify_binary(bytes(blob), manifest=prog.manifest,
                            pgraph=prog.pgraph)
    except ValueError:
        pytest.fail("verify_binary must absorb decode errors")
    if rep.ok:
        # Mutation was semantically invisible (pe/flag bits, spliced
        # with an identical prefix...) — decoding it must then agree
        # instruction-for-instruction with *a* valid program.
        assert disassemble(bytes(blob))


@settings(max_examples=30, deadline=None)
@given(junk=st.binary(max_size=256))
def test_fuzzed_junk_is_rejected_with_valueerror(junk):
    if junk[:4] == struct.pack("<I", MAGIC):
        junk = b"\x00" + junk[1:]
    try:
        disassemble(junk)
    except ValueError:
        pass                      # the contract: ValueError, nothing else
    else:
        pytest.fail("non-GAGI junk must not disassemble")


# --------------------------------------------------------------------------- #
# Race detector: recorded traces vs static hazard edges.
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def host_trace(graph, programs):
    eng = _engine()
    prog = eng.compile("b1", graph)
    x = np.asarray(G.random_features(graph, seed=2))
    with tracing() as t:
        eng.run(prog, x, residency="host")
    return t.to_dict(), prog


def test_race_detector_validates_streaming_overlap(host_trace):
    trace, prog = host_trace
    rep = check_trace(trace, prog)
    assert rep.ok, rep.to_markdown()
    assert "race_layer_order" in rep.checks_run
    assert "race_stage_before_compute" in rep.checks_run
    # The double-buffer evidence: next-shard staging inside a compute
    # window (the streaming path's reason to exist).
    assert rep.stats["overlap_pairs"] > 0


def test_race_detector_flags_stage_after_compute(host_trace):
    trace, prog = host_trace
    trace = json.loads(json.dumps(trace))       # deep copy
    evs = trace["traceEvents"]
    moved = False
    for ev in evs:
        if ev.get("ph") == "X" and ev.get("name") == "stage":
            key = (ev["args"].get("shard"), ev["args"].get("layer"))
            for c in evs:
                if c.get("ph") == "X" and c.get("name") == "compute" \
                        and (c["args"].get("shard"),
                             c["args"].get("layer")) == key:
                    ev["ts"] = c["ts"] + 1.0    # stage starts after
                    moved = True
                    break
        if moved:
            break
    assert moved
    rep = check_trace(trace, prog)
    assert not rep.ok
    assert "race_stage_before_compute" in rep.checks_failed


def test_race_detector_flags_reordered_layer_spans(host_trace):
    trace, prog = host_trace
    trace = json.loads(json.dumps(trace))
    evs = trace["traceEvents"]
    lay = [e for e in evs if e.get("ph") == "X"
           and re.match(r"^layer\d+$", e.get("name", ""))]
    assert len(lay) >= 2
    lay[-1]["ts"] = lay[0]["ts"] - 5.0          # consumer before producer
    rep = check_trace(trace, prog)
    assert not rep.ok
    assert rep.checks_failed == ["race_layer_order"]


def test_race_detector_without_manifest_skips_layer_check(host_trace):
    trace, _ = host_trace
    rep = check_trace(trace)
    assert rep.ok
    assert "race_layer_order" in rep.checks_skipped
    assert "race_stage_before_compute" in rep.checks_run


# --------------------------------------------------------------------------- #
# CLI.
# --------------------------------------------------------------------------- #
def test_cli_verifies_gagi_bundles(programs, tmp_path, capsys):
    from repro.verify.__main__ import main
    for name in ("b1", "b7"):
        programs[name].save(str(tmp_path / f"{name}.gagi"))
    out_json = tmp_path / "report.json"
    out_md = tmp_path / "report.md"
    rc = main([str(tmp_path), "--json", str(out_json),
               "--md", str(out_md)])
    assert rc == 0
    payload = json.loads(out_json.read_text())
    assert payload["ok"] and len(payload["reports"]) == 2
    assert all(r["ok"] for r in payload["reports"])
    assert "PASS" in out_md.read_text()
    assert "[PASS]" in capsys.readouterr().out


def test_cli_fails_on_corrupt_bundle(programs, tmp_path):
    from repro.verify.__main__ import main
    prog = programs["b1"]

    def mutate(instrs):
        for ins in instrs:
            if ins.op == Opcode.GEMM:
                ins.arg4 += 1
                return
    bad = CompiledProgram(
        binary=_reassemble(prog, mutate), manifest=prog.manifest,
        weights=prog.weights, pgraph=prog.pgraph)
    path = str(tmp_path / "bad.gagi")
    bad.save(path)
    out_json = tmp_path / "report.json"
    rc = main([path, "--json", str(out_json), "-q"])
    assert rc == 1
    payload = json.loads(out_json.read_text())
    assert not payload["ok"]
    assert "kernel_legality" in payload["reports"][0]["checks_failed"]
