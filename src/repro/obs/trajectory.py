"""Perf-trajectory comparison — make BENCH_*.json a *gated* artifact.

Every PR in this repo ships benchmark JSON (serve throughput, sampling
tail latency, out-of-core window sizes, live-graph cutover health).
Until now those were write-only: nothing noticed when a change made the
batcher stop coalescing or the streaming window grow.  This module
compares a freshly produced benchmark file against the committed
baseline under **per-metric tolerance bands** and renders a markdown
report; ``benchmarks/check_trajectory.py`` wires it into CI as a gate.

Bands are asymmetric by design: a metric only *fails* when it moves in
its bad direction past its band — improvements are reported, never
blocked.  Wall-clock metrics get wide relative bands (CI hosts are
noisy and heterogeneous); semantic metrics — cache hit rates, batching
pass counts, bit-identity flags, dropped/misrouted request counts,
deterministic byte counters — get tight or zero bands, because those
regress only when the code regresses.

Files are compared only when their ``mode`` field matches (a ``--smoke``
run is not comparable against a committed full-scale run); mismatches
are reported as skipped, not failed.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Sequence

__all__ = [
    "MetricSpec", "MetricResult", "FileReport", "TrajectoryReport",
    "DEFAULT_SPECS", "lookup", "compare_metrics", "compare_docs",
    "compare_dirs",
]


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One gated metric: where it lives, which way is good, how much
    degradation the band tolerates.

    ``rel_tol``/``abs_tol`` define the allowed move in the *bad*
    direction: a higher-is-better metric fails when
    ``fresh < baseline * (1 - rel_tol) - abs_tol``; a lower-is-better
    metric fails when ``fresh > baseline * (1 + rel_tol) + abs_tol``.
    Booleans compare as 1.0/0.0, so a flag with zero tolerances must
    simply never flip the wrong way.
    """

    path: str                   # dotted path; integer segments index lists
    direction: str = "higher"   # "higher" | "lower" is BETTER
    rel_tol: float = 0.25
    abs_tol: float = 0.0
    note: str = ""

    def __post_init__(self) -> None:
        if self.direction not in ("higher", "lower"):
            raise ValueError(
                "direction must be 'higher' or 'lower', "
                f"got {self.direction!r}")


@dataclasses.dataclass
class MetricResult:
    path: str
    status: str                 # ok | improved | regressed | missing | new
    baseline: Optional[float] = None
    fresh: Optional[float] = None
    delta_pct: Optional[float] = None
    band: str = ""
    note: str = ""

    @property
    def failed(self) -> bool:
        return self.status in ("regressed", "missing")


@dataclasses.dataclass
class FileReport:
    name: str
    results: List[MetricResult] = dataclasses.field(default_factory=list)
    skipped: Optional[str] = None     # reason this file was not compared

    @property
    def ok(self) -> bool:
        return not any(r.failed for r in self.results)

    @property
    def regressions(self) -> List[MetricResult]:
        return [r for r in self.results if r.failed]


@dataclasses.dataclass
class TrajectoryReport:
    files: List[FileReport] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(f.ok for f in self.files)

    @property
    def regressions(self) -> List[MetricResult]:
        return [r for f in self.files for r in f.regressions]

    # ------------------------------------------------------------------ #
    def to_markdown(self) -> str:
        """Render the whole comparison as a markdown report."""
        lines = ["# Perf trajectory report", ""]
        lines.append("**PASS** — no metric left its tolerance band."
                     if self.ok else
                     f"**FAIL** — {len(self.regressions)} metric(s) "
                     "regressed past their tolerance bands.")
        lines.append("")
        for f in self.files:
            lines.append(f"## {f.name}")
            lines.append("")
            if f.skipped is not None:
                lines.append(f"_skipped: {f.skipped}_")
                lines.append("")
                continue
            lines.append("| metric | baseline | fresh | Δ | band |"
                         " status |")
            lines.append("|---|---:|---:|---:|---|---|")
            for r in f.results:
                delta = ("" if r.delta_pct is None
                         else f"{r.delta_pct:+.1f}%")
                base = "" if r.baseline is None else f"{r.baseline:g}"
                fresh = "" if r.fresh is None else f"{r.fresh:g}"
                status = {"regressed": "**REGRESSED**",
                          "missing": "**MISSING**"}.get(r.status,
                                                        r.status)
                lines.append(f"| `{r.path}` | {base} | {fresh} | "
                             f"{delta} | {r.band} | {status} |")
            lines.append("")
        return "\n".join(lines)


# --------------------------------------------------------------------------- #
def lookup(doc: Any, path: str) -> Any:
    """Resolve a dotted path; integer segments index into lists.
    Raises ``KeyError`` when any segment is absent."""
    cur = doc
    for seg in path.split("."):
        if isinstance(cur, list):
            try:
                cur = cur[int(seg)]
            except (ValueError, IndexError) as e:
                raise KeyError(path) from e
        elif isinstance(cur, dict):
            if seg not in cur:
                raise KeyError(path)
            cur = cur[seg]
        else:
            raise KeyError(path)
    return cur


def _as_float(v: Any) -> float:
    if isinstance(v, bool):
        return 1.0 if v else 0.0
    return float(v)


def compare_metrics(baseline: dict, fresh: dict,
                    specs: Sequence[MetricSpec]) -> List[MetricResult]:
    """Evaluate every spec against (baseline, fresh) documents."""
    out: List[MetricResult] = []
    for spec in specs:
        band = (f"{spec.direction}-is-better, rel {spec.rel_tol:g}"
                + (f", abs {spec.abs_tol:g}" if spec.abs_tol else ""))
        try:
            b = _as_float(lookup(baseline, spec.path))
        except (KeyError, TypeError, ValueError):
            # Baseline predates this metric: record, never fail.
            try:
                f = _as_float(lookup(fresh, spec.path))
            except (KeyError, TypeError, ValueError):
                f = None
            out.append(MetricResult(spec.path, "new", None, f,
                                    band=band, note=spec.note))
            continue
        try:
            f = _as_float(lookup(fresh, spec.path))
        except (KeyError, TypeError, ValueError):
            out.append(MetricResult(
                spec.path, "missing", b, None, band=band,
                note=spec.note or "metric disappeared from fresh run"))
            continue
        delta_pct = ((f - b) / abs(b) * 100.0) if b else None
        if spec.direction == "higher":
            floor = b * (1.0 - spec.rel_tol) - spec.abs_tol
            status = ("regressed" if f < floor
                      else "improved" if f > b else "ok")
        else:
            ceil = b * (1.0 + spec.rel_tol) + spec.abs_tol
            status = ("regressed" if f > ceil
                      else "improved" if f < b else "ok")
        out.append(MetricResult(spec.path, status, b, f,
                                delta_pct=delta_pct, band=band,
                                note=spec.note))
    return out


def compare_docs(name: str, baseline: Optional[dict],
                 fresh: Optional[dict],
                 specs: Sequence[MetricSpec]) -> FileReport:
    """Compare one benchmark document pair, honoring the mode guard."""
    if baseline is None:
        return FileReport(name, skipped="no committed baseline")
    if fresh is None:
        return FileReport(name, skipped="no fresh run produced this file")
    bm, fm = baseline.get("mode"), fresh.get("mode")
    if bm != fm:
        return FileReport(
            name, skipped=f"mode mismatch (baseline {bm!r} vs fresh "
                          f"{fm!r}): not comparable")
    return FileReport(name, results=compare_metrics(baseline, fresh,
                                                    specs))


def _load(path: str) -> Optional[dict]:
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def compare_dirs(baseline_dir: str, fresh_dir: str,
                 registry: Optional[Dict[str, List[MetricSpec]]] = None,
                 files: Optional[Sequence[str]] = None
                 ) -> TrajectoryReport:
    """Compare every registered benchmark file present in either dir."""
    registry = registry if registry is not None else DEFAULT_SPECS
    names = list(files) if files else sorted(registry)
    report = TrajectoryReport()
    for name in names:
        specs = registry.get(name)
        if specs is None:
            report.files.append(FileReport(
                name, skipped="no metric specs registered"))
            continue
        report.files.append(compare_docs(
            name, _load(os.path.join(baseline_dir, name)),
            _load(os.path.join(fresh_dir, name)), specs))
    return report


# --------------------------------------------------------------------------- #
# The committed trajectory: per-file tolerance bands.
#
# Wall-clock metrics (throughput, percentile latencies, speedups) carry
# wide relative bands — CI hosts vary ~2-3x — chosen so only an
# order-of-magnitude collapse fails the gate.  Semantic metrics (hit
# rates, batching pass counts, identity flags, dropped/misrouted
# counts, deterministic streaming byte counters) are tight: they only
# move when behavior changes.
# --------------------------------------------------------------------------- #
DEFAULT_SPECS: Dict[str, List[MetricSpec]] = {
    "BENCH_serve.json": [
        MetricSpec("traffic.same_key.batched_speedup", "higher", 0.9,
                   note="batching collapse would show here first"),
        MetricSpec("traffic.mixed.batched_speedup", "higher", 0.9),
        MetricSpec("traffic.same_key.batched.throughput_rps",
                   "higher", 0.9),
        MetricSpec("traffic.same_key.batched.p99_ms", "lower", 9.0),
        MetricSpec("traffic.same_key.batched.cache_hit_rate",
                   "higher", 0.0, 0.01,
                   note="repeat traffic must stay fully cached"),
        MetricSpec("traffic.mixed.batched.cache_hit_rate",
                   "higher", 0.0, 0.01),
        MetricSpec("traffic.same_key.batched.binary_passes",
                   "lower", 0.0, 0.0,
                   note="more passes = coalescing broke"),
        MetricSpec("traffic.same_key.batched.batch_occupancy",
                   "higher", 0.0, 0.01),
        MetricSpec("verify.checks_passed", "higher", 0.0, 0.0,
                   note="static verifier coverage must never shrink"),
        MetricSpec("verify.checks_failed", "lower", 0.0, 0.0,
                   note="shipped programs must verify clean"),
    ],
    "BENCH_sample.json": [
        MetricSpec("bucketed_speedup", "higher", 0.9),
        MetricSpec("bucketed_batched.throughput_rps", "higher", 0.9),
        MetricSpec("bucketed_batched.p50_ms", "lower", 9.0),
        MetricSpec("bucketed_batched.p99_ms", "lower", 9.0),
        MetricSpec("bucketed_batched.cache_hit_rate", "higher",
                   0.0, 0.02,
                   note="bucketing must keep cache keys colliding"),
        MetricSpec("bucketed_batched.mean_batch_size", "higher", 0.5),
        MetricSpec("verify.checks_passed", "higher", 0.0, 0.0,
                   note="static verifier coverage must never shrink"),
        MetricSpec("verify.checks_failed", "lower", 0.0, 0.0,
                   note="shipped programs must verify clean"),
    ],
    "BENCH_live.json": [
        MetricSpec("cutover.dropped", "lower", 0.0, 0.0,
                   note="zero-downtime contract"),
        MetricSpec("cutover.misrouted", "lower", 0.0, 0.0,
                   note="zero-downtime contract"),
        MetricSpec("cutover.compiles", "lower", 0.0, 0.0,
                   note="cutovers must rebind, never recompile"),
        MetricSpec("cutover.versions_reclaimed", "higher", 0.0, 0.0,
                   note="drained retirees must be reclaimed"),
        MetricSpec("updates.1.speedup", "higher", 0.9),
        MetricSpec("updates.1.retention", "higher", 0.0, 0.05,
                   note="single-edge delta must retain ~all tiles"),
        MetricSpec("updates.16.retention", "higher", 0.0, 0.15),
        MetricSpec("verify.checks_passed", "higher", 0.0, 0.0,
                   note="static verifier coverage must never shrink"),
        MetricSpec("verify.checks_failed", "lower", 0.0, 0.0,
                   note="shipped programs must verify clean"),
    ],
    "BENCH_fullgraph.json": [
        MetricSpec("models.0.mesh.bit_identical_to_host", "higher",
                   0.0, 0.0, note="mesh equivalence flag"),
        MetricSpec("models.0.host_under_budget.completed", "higher",
                   0.0, 0.0,
                   note="streaming path must fit the budget"),
        MetricSpec("models.0.device_under_budget.completed", "lower",
                   0.0, 0.0,
                   note="device path must keep refusing over-budget "
                        "runs"),
        MetricSpec("models.0.placement.load_imbalance", "lower", 0.5),
        MetricSpec("models.0.host_under_budget.peak_stage_bytes",
                   "lower", 0.1,
                   note="deterministic double-buffered window size"),
        MetricSpec("models.0.host_under_budget.shards_streamed",
                   "lower", 0.0, 0.0,
                   note="deterministic shard schedule length"),
        MetricSpec("models.0.host_under_budget.h2d_bytes",
                   "lower", 0.1,
                   note="deterministic staging traffic"),
        # Cost-model conformance (repro.obs.conformance): normalized
        # RMSE of predicted vs measured per-layer time, per kernel
        # mode.  Bands are asymmetric and generous — wall time on CI
        # hosts is noisy — but a model that drifts to ~3x its committed
        # error has genuinely decoupled from the executor and fails.
        MetricSpec("models.0.conformance.model_error.gemm",
                   "lower", 2.0, 0.5,
                   note="cost-model drift, GEMM mode"),
        MetricSpec("models.0.conformance.model_error.spdmm",
                   "lower", 2.0, 0.5,
                   note="cost-model drift, SpDMM mode"),
        MetricSpec("models.0.conformance.model_error_overall",
                   "lower", 2.0, 0.5,
                   note="cost-model drift, all modes"),
        # rel 1.0: the gain's magnitude tracks run noise, only its SIGN
        # is the invariant — fail when calibration stops reducing the
        # error (fresh < baseline·0 - 0.05, i.e. gain goes negative)
        MetricSpec("models.0.conformance.calibration_gain",
                   "higher", 1.0, 0.05,
                   note="LS calibration must keep reducing model error"),
        # Sparsity-adaptive remapping (repro.core.passes.remap): the
        # re-encoded program must stay at least as fast as the
        # canonical one (wide band — wall clock), and must stay
        # bit-identical across the device/streaming/mesh paths
        # (zero-width — semantic flag).
        MetricSpec("models.0.remap.remap_speedup", "higher", 0.5,
                   note="remapped program must not regress vs "
                        "canonical SpDMM encoding"),
        MetricSpec("models.0.remap.remap_bit_identical", "higher",
                   0.0, 0.0,
                   note="remapped outputs must match the baseline "
                        "across residency paths"),
        MetricSpec("verify.checks_passed", "higher", 0.0, 0.0,
                   note="static verifier coverage must never shrink"),
        MetricSpec("verify.checks_failed", "lower", 0.0, 0.0,
                   note="shipped programs must verify clean"),
    ],
}
