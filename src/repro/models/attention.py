"""GQA attention: full / sliding-window / cross, with query-chunked
online-softmax (XLA flash analogue) for long sequences, plus decode-step
attention against a KV cache.

Shapes: x [B, T, D]; q [B, T, H, hd]; kv [B, S, Kh, hd].  GQA groups
G = H // Kh query heads per KV head.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense_init, rms_norm, rope

NEG = -2.0e38


def attn_init(key, d_model: int, n_heads: int, n_kv: int, hd: int, dtype,
              qk_norm: bool = False, kv_input_dim: Optional[int] = None
              ) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    kvd = kv_input_dim or d_model
    p = {
        "wq": dense_init(kq, d_model, (n_heads, hd), dtype),
        "wk": dense_init(kk, kvd, (n_kv, hd), dtype),
        "wv": dense_init(kv, kvd, (n_kv, hd), dtype),
        "wo": dense_init(ko, n_heads * hd, d_model, dtype,
                         std=(n_heads * hd) ** -0.5),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def _qk_normalize(p: Params, q, k, eps):
    if "q_norm" in p:
        q = rms_norm(q, p["q_norm"], eps)
        k = rms_norm(k, p["k_norm"], eps)
    return q, k


def _mask_bias(qpos, kpos, causal: bool, window: int) -> jnp.ndarray:
    """[Tq, Tk] additive bias from causal/sliding-window visibility."""
    dif = qpos[:, None] - kpos[None, :]
    ok = jnp.ones(dif.shape, bool)
    if causal:
        ok &= dif >= 0
    if window > 0:
        ok &= dif < window
    return jnp.where(ok, 0.0, NEG)


def _sdpa(q, k, v, bias, scale):
    """q [B,Tq,H,hd], k/v [B,Tk,Kh,hd] -> [B,Tq,H,hd] (f32 softmax)."""
    b, tq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, tq, kh, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(b, tq, h, hd).astype(q.dtype)


def _sdpa_chunked(q, k, v, qpos, kpos, causal, window, scale, chunk: int):
    """Query-chunked online-softmax attention (bounded memory; the pure-XLA
    analogue of flash attention; exact).

    Perf notes (EXPERIMENTS.md §Perf, hymba train_4k iteration):
      * the chunk body is rematerialized — without it, backward saves the
        full [n_chunks, B, H, c, T] probability stack to HBM;
      * the softmax normalizer divides the (narrow) output, not the
        (T-wide) probability tensor: ~T/hd x less traffic for that op;
      * probabilities are cast to the value dtype (bf16) for the PV
        matmul with f32 accumulation — halves the widest read.
    """
    b, t, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    n_chunks = t // chunk
    qg = q.reshape(b, n_chunks, chunk, kh, g, hd).swapaxes(0, 1)
    qpos_c = qpos.reshape(n_chunks, chunk)

    @jax.checkpoint
    def one_chunk(qc, pc):
        s = jnp.einsum("bqkgd,bskd->bkgqs", qc, k,
                       preferred_element_type=jnp.float32) * scale
        s = s + _mask_bias(pc, kpos, causal, window)
        m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e30)
        e = jnp.exp(s - m)
        den = jnp.sum(e, axis=-1, keepdims=True)
        o = jnp.einsum("bkgqs,bskd->bkgqd", e.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o / jnp.maximum(den, 1e-30)  # [b,kh,g,chunk,hd]

    o = jax.lax.map(lambda args: one_chunk(*args), (qg, qpos_c))
    # [n_chunks, b, kh, g, chunk, hd] -> [b, t, h, hd]
    o = o.transpose(1, 0, 4, 2, 3, 5).reshape(b, t, h, hd)
    return o.astype(q.dtype)


# --------------------------------------------------------------------------- #
def attention(p: Params, x: jnp.ndarray, positions: jnp.ndarray, *,
              causal: bool = True, window: int = 0,
              rope_theta: float = 1e4, eps: float = 1e-6,
              chunk: int = 0, kv_x: Optional[jnp.ndarray] = None,
              use_rope: bool = True) -> jnp.ndarray:
    """Self (or cross, via kv_x) attention over a full sequence."""
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"])
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dke->bske", src, p["wk"])
    v = jnp.einsum("bsd,dke->bske", src, p["wv"])
    q, k = _qk_normalize(p, q, k, eps)
    hd = q.shape[-1]
    if use_rope and kv_x is None:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    kpos = (positions if kv_x is None
            else jnp.arange(src.shape[1], dtype=jnp.int32))
    scale = hd ** -0.5
    t = x.shape[1]
    if chunk and t > chunk and t % chunk == 0:
        o = _sdpa_chunked(q, k, v, positions, kpos,
                          causal and kv_x is None, window, scale, chunk)
    else:
        bias = _mask_bias(positions, kpos, causal and kv_x is None, window)
        o = _sdpa(q, k, v, bias, scale)
    h = q.shape[2]
    return jnp.einsum("bthe,hed->btd", o, p["wo"].reshape(h, hd, -1))


# --------------------------------------------------------------------------- #
# KV-cache decode path.
# --------------------------------------------------------------------------- #
def init_cache(batch: int, max_len: int, n_kv: int, hd: int, dtype
               ) -> Dict[str, jnp.ndarray]:
    return {
        "k": jnp.zeros((batch, max_len, n_kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, n_kv, hd), dtype),
    }


def decode_attention(p: Params, x: jnp.ndarray, cache: Dict[str, jnp.ndarray],
                     pos: jnp.ndarray, *, window: int = 0,
                     rope_theta: float = 1e4, eps: float = 1e-6,
                     cross: bool = False) -> Tuple[jnp.ndarray, Dict]:
    """One decode step.  x [B, 1, D]; cache k/v [B, S, Kh, hd];
    pos: scalar int32 current position.  Returns (out [B,1,D], cache)."""
    q = jnp.einsum("btd,dhe->bthe", x, p["wq"])
    hd = q.shape[-1]
    if cross:
        # Cross-attention cache holds the projected encoder K/V (static).
        k, v = cache["k"], cache["v"]
        valid = jnp.ones((k.shape[1],), bool)
    else:
        knew = jnp.einsum("btd,dke->btke", x, p["wk"])
        vnew = jnp.einsum("btd,dke->btke", x, p["wv"])
        q, knew = _qk_normalize(p, q, knew, eps)
        posv = jnp.full((x.shape[0], 1), pos, jnp.int32)
        q = rope(q, posv, rope_theta)
        knew = rope(knew, posv, rope_theta)
        s_len = cache["k"].shape[1]
        slot = pos % s_len   # ring buffer; full caches have s_len > pos
        k = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], knew.astype(cache["k"].dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], vnew.astype(cache["v"].dtype), slot, axis=1)
        cache = {"k": k, "v": v}
        # Ring-buffer slot -> absolute position (wraps for window caches);
        # unwritten slots map to negative positions (invalid).
        slots = jnp.arange(s_len, dtype=jnp.int32)
        abs_pos = pos - ((pos - slots) % s_len)
        valid = (abs_pos >= 0) & (abs_pos <= pos)
        if window > 0:
            valid &= abs_pos > pos - window
    b, _, h, _ = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, 1, kh, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    s = jnp.where(valid[None, None, None, None, :], s, NEG)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr, v.astype(jnp.float32))
    o = o.reshape(b, 1, h, hd).astype(x.dtype)
    return jnp.einsum("bthe,hed->btd", o,
                      p["wo"].reshape(h, hd, -1)), cache
