"""Bind-time sparsity-adaptive kernel remapping (Dynasparse-style).

GraphAGILE fixes each layer's ACK mode at compile time from static
geometry (paper §6.6): every AGGREGATE tile runs SpDMM.  But tile density
varies wildly inside one power-law graph — a hub tile at 20% density is
matmul-shaped work being executed as gathers, and a live-graph delta can
empty a tile entirely.  This pass re-prices every AGGREGATE tiling step
against a roofline cost model and **re-encodes the already-assembled
binary in place** — no recompile, no new partition, the program-cache key
survives modulo the recorded ``remap_signature``:

  * ``spdmm``  — leave the canonical encoding alone (or restore it).
  * ``gemm``   — densify the ELL slice into an (n1, n1) adjacency block
    and dispatch the systolic-array GEMM path: the SPDMM compute
    instruction's opcode byte flips to GEMM and its arg4 becomes the
    dense MAC count ``n1*n1*n2``.  Only layers whose AggOp is linear
    (SUM/MEAN) are eligible — max/min have no dense-matmul equivalent,
    so those layers keep SpDMM for a globally-gemm'd tile.
  * ``skip``   — nnz == 0: the whole MEM_RD/compute group is opcode-NOPed
    (args/arg4/flags preserved), so the decoder never materializes the
    tile step and the executor's accumulate-identity is exact for every
    AggOp.

Because NOPed instructions keep their argument fields and the compiler
never emits NOPs itself, a remapped binary is **self-describing**: the
original encoding is recoverable from flags+args patterns alone
(FLAG_UNLOCK ⇒ compute step, FLAG_LOCK+Buf.EDGE ⇒ sub-shard read,
FLAG_LOCK+Buf.FEATURE ⇒ fiber read, flags==0+EDGE_WEIGHTS ⇒ dynamic
edge-weight read).  ``remap_program`` therefore restores-to-canonical
before applying fresh decisions, which makes incremental re-remapping
(``only_tiles=`` — the livegraph rebind path hands in just the tiles a
delta patched) a pure word-level edit on the previous binary.

Cost oracle: two-term rooflines over :class:`ModelConstants` — the
paper-default datasheet numbers, or the *calibrated* effective constants
a ``repro.obs.conformance`` report fitted from measured runs.  With
``probe=True`` the oracle is replaced by direct microbenchmarks of the
two ACK kernels at the program's actual tile geometry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..ir import AggOp, LayerType
from ..isa import (FLAG_UNLOCK, HEADER_BYTES, Buf, Instr, Opcode, Region)
from ..perfmodel import DEFAULT_CONSTANTS, ModelConstants

MODES = ("spdmm", "gemm", "skip")

# float32 operand widths of the roofline traffic terms
_ELL_BYTES_PER_SLOT = 8          # cols (int32) + vals (float32)
_F32 = 4


# --------------------------------------------------------------------------- #
# Cost oracle
# --------------------------------------------------------------------------- #
def resolve_constants(constants: Any = None) -> Tuple[ModelConstants, bool]:
    """Normalize a constants source into ``(ModelConstants, calibrated)``.

    Accepts ``None`` (paper defaults), a :class:`ModelConstants`, a
    ``{field: value}`` dict (a report's ``calibrated_constants``; unknown
    or falsy entries fall back to the default), or any object exposing a
    ``calibrated_constants`` attribute (a ``ConformanceReport``).
    """
    if constants is None:
        return DEFAULT_CONSTANTS, False
    if isinstance(constants, ModelConstants):
        return constants, True
    if isinstance(constants, dict):
        names = {f.name for f in dataclasses.fields(ModelConstants)}
        vals = {k: float(v) for k, v in constants.items()
                if k in names and v}
        return dataclasses.replace(DEFAULT_CONSTANTS, **vals), bool(vals)
    cal = getattr(constants, "calibrated_constants", None)
    if cal is not None:
        return resolve_constants(dict(cal))
    raise TypeError(f"cannot derive ModelConstants from {type(constants)}")


def price_tile(nnz: int, width: int, n_slices: int, n1: int, n2: int,
               c: ModelConstants) -> Tuple[float, float]:
    """(t_spdmm, t_gemm) roofline seconds for one (j, k) aggregate step.

    SpDMM reads the ELL slices (cols+vals) plus one feature tile per
    slice and runs 2·nnz·n2 MACs on the vector path; densified GEMM runs
    one n1×n1×n2 matmul per slice on the systolic path, reading the
    dense block + feature tile and writing the accumulator.
    """
    t_sp = max(2.0 * nnz * n2 / c.vpu_flops,
               (n1 * width * _ELL_BYTES_PER_SLOT
                + max(n_slices, 1) * n1 * n2 * _F32) / c.hbm_bw)
    t_ge_one = max(2.0 * n1 * n1 * n2 / c.peak_flops,
                   (n1 * n1 * _F32 + 2 * n1 * n2 * _F32) / c.hbm_bw)
    return t_sp, max(n_slices, 1) * t_ge_one


def probe_oracle(ack, n1: int, n2: int, widths: Sequence[int],
                 reps: int = 3) -> Dict[str, Any]:
    """Microbenchmark the two ACK kernels at the actual tile geometry.

    Returns ``{"spdmm": {width: seconds}, "gemm": seconds}`` — per-slice
    costs measured min-of-``reps`` on synthetic operands (fixed seed), so
    the decision reflects what the kernels really cost on this backend
    rather than what the datasheet roofline promises.
    """
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    h = jnp.asarray(rng.standard_normal((n1, n2)), jnp.float32)
    acc = jnp.zeros((n1, n2), jnp.float32)
    flag = jnp.zeros((n1,), bool)

    def _time(fn) -> float:
        fn()                                    # compile/warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    per_w: Dict[int, float] = {}
    gemm_t = None
    for w in sorted({int(w) for w in widths if w > 0}):
        cols = jnp.asarray(rng.integers(0, n1, (n1, w)), jnp.int32)
        vals = jnp.asarray(rng.random((n1, w)), jnp.float32)
        mask = jnp.ones((n1, w), bool)
        per_w[w] = _time(lambda: ack.spdmm(
            h, cols, vals, mask, acc, flag, "sum")[0].block_until_ready())
        if gemm_t is None:      # scatter cost is width-marginal; dot dominates
            gemm_t = _time(lambda: ack.gemm_agg(
                cols, vals, h, acc).block_until_ready())
    return {"spdmm": per_w, "gemm": gemm_t if gemm_t is not None else 0.0}


# --------------------------------------------------------------------------- #
# Density sources
# --------------------------------------------------------------------------- #
def resolve_density(prog, source: str = "auto"
                    ) -> Tuple[Dict[str, dict], str]:
    """Per-``"j:k"`` ``{nnz, width, slices, density}`` plus the source name.

    Structure (slice count / widths) always comes from the program's
    partitioned graph; nnz/density are overlaid from the requested
    source: the manifest ``exec_profile`` of a traced run, the
    ``tile_stats`` refreshed at livegraph rebind, or the ELL tiles
    themselves (``pgraph``).  ``auto`` prefers profile, then stats.
    """
    if source not in ("auto", "exec_profile", "tile_stats", "pgraph"):
        raise ValueError(f"unknown density source {source!r}")
    pg = prog.pgraph
    n1 = pg.config.n1
    stats: Dict[str, dict] = {}
    for (j, k), slices in pg.tiles.items():
        width = int(sum(t.cols.shape[1] for t in slices))
        nnz = int(sum(t.nnz for t in slices))
        stats[f"{j}:{k}"] = {
            "nnz": nnz, "width": width, "slices": len(slices),
            "density": nnz / float(n1 * width) if width else 0.0}
    src = "pgraph"
    ep = prog.manifest.get("exec_profile") or {}
    if source in ("auto", "exec_profile") and ep.get("tiles"):
        seen: Dict[str, int] = {}
        for key, t in ep["tiles"].items():
            j, k, _s = key.split(":")
            seen[f"{j}:{k}"] = seen.get(f"{j}:{k}", 0) + int(t.get("nnz", 0))
        for jk, nnz in seen.items():
            if jk in stats:
                w = stats[jk]["width"]
                stats[jk]["nnz"] = nnz
                stats[jk]["density"] = nnz / float(n1 * w) if w else 0.0
        src = "exec_profile"
    elif source in ("auto", "tile_stats") and \
            (prog.manifest.get("tile_stats") or {}).get("tiles"):
        for jk, t in prog.manifest["tile_stats"]["tiles"].items():
            if jk in stats:
                w = stats[jk]["width"]
                stats[jk]["nnz"] = int(t.get("nnz", stats[jk]["nnz"]))
                stats[jk]["density"] = (stats[jk]["nnz"] / float(n1 * w)
                                        if w else 0.0)
        src = "tile_stats"
    elif source in ("exec_profile", "tile_stats"):
        raise ValueError(
            f"density source {source!r} requested but the manifest "
            "carries no such section")
    return stats, src


# --------------------------------------------------------------------------- #
# Binary scan: aggregate tile groups in a remapped-or-canonical stream
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class _Group:
    """One aggregate tile step: its compute instr + member MEM_RDs."""

    j: int
    k: int
    s: int
    dyn: int
    agg: AggOp
    compute: int                 # instruction index
    mem: Tuple[int, ...]         # MEM_RD (or NOPed MEM_RD) indices


def _scan_groups(instrs: List[Instr]) -> List[_Group]:
    """Walk the stream, collecting every AGGREGATE tile group.

    Works on canonical AND previously-remapped binaries: the compiler
    never emits NOP, so any NOP here is an elided group member —
    FLAG_UNLOCK marks the (elided) compute step, everything else a
    (elided) memory read.
    """
    groups: List[_Group] = []
    agg: Optional[AggOp] = None
    pending: List[int] = []
    for idx, ins in enumerate(instrs):
        if ins.op == Opcode.CSI:
            lt = LayerType(ins.args[1])
            agg = AggOp(ins.act) if lt == LayerType.AGGREGATE else None
            pending = []
            continue
        if agg is None:
            continue
        is_compute = (ins.op in (Opcode.SPDMM, Opcode.GEMM)
                      or (ins.op == Opcode.NOP and ins.flags & FLAG_UNLOCK))
        if is_compute:
            j, k, _i, packed = ins.args
            groups.append(_Group(j=j, k=k, s=packed >> 1, dyn=packed & 1,
                                 agg=agg, compute=idx, mem=tuple(pending)))
            pending = []
        elif ins.op in (Opcode.MEM_RD, Opcode.NOP):
            pending.append(idx)
        else:                    # ACT/AFFINE/MEM_WR close any pending run
            pending = []
    return groups


def _set_opcode(words: np.ndarray, idx: int, op: Opcode) -> None:
    words[idx, 0] = (int(words[idx, 0]) & 0xFFFFFF00) | int(op)


def _restore_group(words: np.ndarray, instrs: List[Instr], g: _Group,
                   pg) -> None:
    """Rewrite one group back to its canonical SpDMM encoding."""
    slices = pg.tiles.get((g.j, g.k), [])
    nnz = int(slices[g.s].nnz) if g.s < len(slices) else 0
    _set_opcode(words, g.compute, Opcode.SPDMM)
    words[g.compute, 3] = nnz
    for m in g.mem:
        _set_opcode(words, m, Opcode.MEM_RD)
        ins = instrs[m]
        if ins.args[0] == int(Buf.EDGE) and \
                ins.args[1] == int(Region.SUBSHARD):
            words[m, 3] = nnz


# --------------------------------------------------------------------------- #
# Decision + application
# --------------------------------------------------------------------------- #
def _decide(st: dict, n1: int, n2: int, c: ModelConstants, margin: float,
            allowed: set, probe_t: Optional[dict],
            slice_widths: Sequence[int]) -> Tuple[str, float]:
    """(mode, predicted per-step gain seconds) for one (j, k) tile."""
    nnz, width, n_slices = st["nnz"], st["width"], st["slices"]
    if probe_t is not None:
        t_sp = sum(probe_t["spdmm"].get(int(w), 0.0) for w in slice_widths)
        t_ge = max(n_slices, 1) * probe_t["gemm"]
    else:
        t_sp, t_ge = price_tile(nnz, width, n_slices, n1, n2, c)
    if nnz == 0 and "skip" in allowed:
        return "skip", t_sp
    gemm_ok = ("gemm" in allowed
               and n1 * n1 * n2 <= 0xFFFFFFFF)       # arg4 encoding range
    if gemm_ok and t_ge * (1.0 + margin) < t_sp:
        return "gemm", t_sp - t_ge
    return "spdmm", 0.0


def remap_program(prog, *, source: str = "auto", constants: Any = None,
                  margin: float = 0.1, force: Any = None,
                  modes: Optional[Sequence[str]] = None,
                  only_tiles: Optional[Sequence[str]] = None,
                  probe: bool = False, ack: Any = None):
    """Re-encode ``prog``'s aggregate kernel fields from tile sparsity.

    Returns a new :class:`~repro.engine.program.CompiledProgram` sharing
    weights/pgraph with ``prog`` — only the binary and manifest differ.
    The manifest gains a ``remap`` record (decision per tile, source,
    constants, signature) and a refreshed ``dep_graph``; the cache key is
    untouched.

    ``only_tiles`` limits re-decision to the named ``"j:k"`` tiles (the
    livegraph incremental path); every other tile's encoding — canonical
    or previously remapped — is byte-preserved.  ``force`` pins the mode
    ("gemm" / "spdmm" / "skip", or a per-tile dict) for oracle tests;
    forced skip is only honored on genuinely empty tiles.  ``probe=True``
    replaces the roofline with kernel microbenchmarks via ``ack``.
    """
    from repro.obs.tracer import get_tracer
    t0 = time.perf_counter()
    pg = prog.pgraph
    n1, n2 = pg.config.n1, pg.config.n2
    c, calibrated = resolve_constants(constants)
    stats, src = resolve_density(prog, source)
    allowed = set(modes) if modes is not None else set(MODES)
    bad = allowed - set(MODES)
    if bad:
        raise ValueError(f"unknown remap modes {sorted(bad)}")
    target = set(only_tiles) if only_tiles is not None else None

    probe_t = None
    if probe:
        if ack is None:
            raise ValueError("probe=True needs an ACK instance")
        widths = sorted({int(t.cols.shape[1])
                         for slices in pg.tiles.values() for t in slices})
        probe_t = probe_oracle(ack, n1, n2, widths)

    decisions: Dict[str, dict] = {}
    for jk, st in stats.items():
        if target is not None and jk not in target:
            continue
        j, k = (int(x) for x in jk.split(":"))
        widths = [int(t.cols.shape[1]) for t in pg.tiles.get((j, k), [])]
        mode, gain = _decide(st, n1, n2, c, margin, allowed, probe_t, widths)
        pin = force.get(jk) if isinstance(force, dict) else force
        if pin in ("gemm", "spdmm"):
            mode, gain = pin, 0.0
            if pin == "gemm" and n1 * n1 * n2 > 0xFFFFFFFF:
                mode = "spdmm"
        elif pin == "skip" and st["nnz"] == 0:
            mode = "skip"
        decisions[jk] = {"mode": mode, "density": round(st["density"], 6),
                         "nnz": st["nnz"], "gain_s": gain}

    words = np.frombuffer(prog.binary, dtype="<u4",
                          offset=HEADER_BYTES).reshape(-1, 4).copy()
    instrs = [Instr.decode(w) for w in words]
    groups = _scan_groups(instrs)
    for g in groups:
        d = decisions.get(f"{g.j}:{g.k}")
        if d is None:
            continue                       # outside only_tiles: untouched
        _restore_group(words, instrs, g, pg)
        eff = d["mode"]
        if eff == "gemm" and g.agg not in (AggOp.SUM, AggOp.MEAN):
            eff = "spdmm"                  # max/min stay on the sparse path
        if eff == "gemm":
            _set_opcode(words, g.compute, Opcode.GEMM)
            words[g.compute, 3] = n1 * n1 * n2
        elif eff == "skip":
            for idx in (*g.mem, g.compute):
                _set_opcode(words, idx, Opcode.NOP)
    new_binary = prog.binary[:HEADER_BYTES] + words.tobytes()

    # Merge with a prior record (incremental path), then recount from the
    # final word stream so the record always matches the binary.
    old = prog.manifest.get("remap") or {}
    tiles = dict(old.get("tiles", {})) if target is not None else {}
    tiles.update(decisions)
    counts = {"spdmm": 0, "gemm": 0, "skip": 0}
    for d in tiles.values():
        counts[d["mode"]] += 1
    skipped_ops = remapped_ops = elided = 0
    for g in groups:
        op = int(words[g.compute, 0]) & 0xFF
        if op == int(Opcode.NOP):
            skipped_ops += 1
            elided += 1 + sum(
                1 for m in g.mem if int(words[m, 0]) & 0xFF == 0)
        elif op == int(Opcode.GEMM):
            remapped_ops += 1
    record = {
        "signature": remap_signature(tiles, src, margin, c),
        "source": src,
        "margin": margin,
        "probe": bool(probe),
        "calibrated": bool(calibrated),
        "constants": {"peak_flops": c.peak_flops, "vpu_flops": c.vpu_flops,
                      "hbm_bw": c.hbm_bw},
        "tiles": tiles,
        "counts": counts,
        "remapped_ops": remapped_ops,
        "skipped_tile_ops": skipped_ops,
        "elided_ops": elided,
        "predicted_gain_s": sum(d["gain_s"] for d in tiles.values()),
    }
    new_manifest = dict(prog.manifest)
    new_manifest["remap"] = record
    from repro.engine.program import _dep_graph_section
    new_manifest["dep_graph"] = _dep_graph_section(new_binary, new_manifest,
                                                   pg)
    record["remap_ms"] = round((time.perf_counter() - t0) * 1e3, 3)
    get_tracer().instant(
        "remap", cat="compile",
        args={"source": src, "calibrated": bool(calibrated),
              "probe": bool(probe), "counts": counts,
              "remapped_ops": remapped_ops, "skipped_tile_ops": skipped_ops,
              "incremental": target is not None,
              "tiles_considered": len(decisions),
              "remap_ms": record["remap_ms"]})
    return dataclasses.replace(prog, binary=new_binary,
                               manifest=new_manifest, _plan=None)


def remap_signature(tiles: Dict[str, dict], source: str, margin: float,
                    c: ModelConstants) -> str:
    """Stable digest of a remap decision set (what changed vs the cache
    key's canonical binary)."""
    payload = {
        "tiles": {jk: d["mode"] for jk, d in sorted(tiles.items())},
        "source": source,
        "margin": margin,
        "constants": [c.peak_flops, c.vpu_flops, c.hbm_bw],
    }
    return hashlib.sha1(
        json.dumps(payload, sort_keys=True).encode()).hexdigest()[:16]
