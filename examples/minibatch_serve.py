"""Per-user mini-batch serving demo: sampled ego networks on the pool.

  PYTHONPATH=src python examples/minibatch_serve.py

The realistic heavy-traffic workload: every user asks for labels on a
few target vertices of one big deployed power-law graph.  The request
lifecycle (``repro.sampling``):

  sample  — seeded k-hop fanout sampling extracts the ego network a
            2-layer GNN actually reads (GraphSAGE-style caps);
  bucket  — the subgraph is padded into a power-of-two geometry bucket
            with inert zero padding, laid out canonically, and shipped
            as runtime graph DATA over the bucket's compiled program —
            so every user in a bucket shares one program-cache key;
  batch   — the runtime Batcher coalesces same-bucket users into ONE
            binary pass (topology AND features vmapped);
  overlay — cache-affinity routing picks the overlay that already
            compiled the bucket's program;
  un-pad  — target rows are sliced back out: logits[T, n_classes].

Steady state: program-cache hit rate ~1.0, pure T_LoH latency.
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import graph as G  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402
from repro.sampling import SamplingService, TargetRequest  # noqa: E402


def main() -> None:
    # one deployed graph: RE-class power law, duplicate edges folded
    g = G.random_graph(466, 24000, seed=0, degree="powerlaw", alpha=1.1,
                       dedupe=True)
    g.feat_dim, g.n_classes = 16, 5
    g.name = "RE-class@466"
    X = G.random_features(g, seed=1)

    svc = SamplingService(
        g, X, n_overlays=2, geometry=PartitionConfig(n1=32, n2=8),
        n_pes=4, max_batch=4, max_wait_us=1e6)

    rng = np.random.default_rng(7)
    fanouts = [(6, 4), (4, 2), (6, 2)]

    def user(i: int) -> TargetRequest:
        targets = rng.choice(g.n_vertices,
                             size=int(rng.integers(1, 4)), replace=False)
        return TargetRequest(targets=[int(v) for v in targets],
                             model="b1", fanouts=fanouts[i % 3],
                             request_id=f"user{i}", seed=1000 + i)

    try:
        n_buckets = svc.warm([user(i) for i in range(16)])
        print(f"warmed {n_buckets} geometry buckets "
              f"(programs compiled, batch shapes traced)\n")

        h0 = sum(e.stats.cache_hits for e in svc.pool.engines)
        n0 = sum(e.stats.requests for e in svc.pool.engines)
        t0 = time.perf_counter()
        resps = svc.serve([user(i) for i in range(16, 40)])
        wall = time.perf_counter() - t0
        h1 = sum(e.stats.cache_hits for e in svc.pool.engines)
        n1 = sum(e.stats.requests for e in svc.pool.engines)

        for r in resps[:6]:
            pred = np.argmax(r.logits, axis=1)
            print(f"{r.request_id}: targets={r.targets.tolist()} -> "
                  f"classes {pred.tolist()}  [ego {r.n_vertices}V/"
                  f"{r.n_edges}E -> bucket {r.bucket}, "
                  f"batch={r.batch_size}, hit={r.cache_hit}]")
        print("...")

        snap = svc.stats_snapshot()
        print(f"\n{len(resps)} users in {wall * 1e3:.0f} ms "
              f"({len(resps) / wall:.0f} users/s); steady-state "
              f"program-cache hit rate {(h1 - h0) / (n1 - n0):.0%} "
              f"across {snap['distinct_buckets']} buckets")
        print("bucket census:", snap["buckets"])
    finally:
        svc.shutdown()


if __name__ == "__main__":
    main()
