"""GraphDelta — a validated, coalescible graph-mutation log.

A delta is the unit of change for a *deployed* graph: a batch of edge
additions/removals and vertex additions recorded against a known base
vertex count.  It is a write-ahead log, not a graph: ops are kept in
arrival order, and :meth:`coalesce` folds them into the canonical form
the tile patcher consumes —

  * ``removed_pairs``: (src, dst) pairs whose *base* edges die.  A
    removal kills every live (src, dst) edge at its point in the log
    (multi-edges are one logical adjacency, matching the dedupe story
    in :func:`repro.core.graph.random_graph`), so a later add re-creates
    the edge and a remove *after* an add in the same delta cancels it.
  * ``adds``: surviving additions, in arrival order.  Arrival order is
    load-bearing: the versioned tile store appends new edges in this
    order, which is exactly the edge order a cold compile of
    :meth:`apply_to`'s output sees — the root of the bit-identity
    guarantee (see ``livegraph/tiles.py``).

Vertex additions reserve ids ``base_vertices, base_vertices+1, ...`` in
call order; edges in the same delta may reference them.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class CoalescedDelta:
    """Net effect of a delta log (see module docstring)."""

    removed_pairs: List[Tuple[int, int]]       # kill base edges
    must_exist: Dict[Tuple[int, int], bool]    # pair -> base edge required
    add_src: np.ndarray                        # int32 [A] arrival order
    add_dst: np.ndarray                        # int32 [A]
    add_weight: np.ndarray                     # float32 [A]
    n_new_vertices: int
    new_features: Optional[np.ndarray]         # [n_new, F] or None

    @property
    def n_adds(self) -> int:
        return int(self.add_src.shape[0])


class GraphDelta:
    """Ordered mutation log against a base graph of ``base_vertices``."""

    def __init__(self, base_vertices: int, feat_dim: int = 0) -> None:
        if base_vertices < 0:
            raise ValueError("base_vertices must be >= 0, "
                             f"got {base_vertices}")
        self.base_vertices = int(base_vertices)
        self.feat_dim = int(feat_dim)
        self._ops: List[tuple] = []          # ("add",u,v,w)|("rm",u,v)
        self._new_features: List[np.ndarray] = []
        self._n_new = 0

    # ------------------------------------------------------------------ #
    @property
    def n_vertices(self) -> int:
        """Vertex count after this delta (base + added)."""
        return self.base_vertices + self._n_new

    @property
    def n_ops(self) -> int:
        return len(self._ops) + self._n_new

    def _check_vertex(self, v: int, role: str) -> int:
        v = int(v)
        if not 0 <= v < self.n_vertices:
            raise IndexError(
                f"{role} vertex {v} out of range [0, {self.n_vertices}) "
                f"(base {self.base_vertices} + {self._n_new} added)")
        return v

    def add_edge(self, src: int, dst: int,
                 weight: float = 1.0) -> "GraphDelta":
        w = float(weight)
        if not np.isfinite(w):
            raise ValueError(f"edge weight must be finite, got {weight!r}")
        self._ops.append(("add", self._check_vertex(src, "src"),
                          self._check_vertex(dst, "dst"), w))
        return self

    def remove_edge(self, src: int, dst: int) -> "GraphDelta":
        self._ops.append(("rm", self._check_vertex(src, "src"),
                          self._check_vertex(dst, "dst")))
        return self

    def add_vertex(self, features=None) -> int:
        """Reserve the next vertex id; returns it.  ``features`` is the
        new vertex's ``[feat_dim]`` row (zeros when omitted)."""
        if features is None:
            row = np.zeros(self.feat_dim, np.float32)
        else:
            row = np.asarray(features, np.float32).reshape(-1)
            if self.feat_dim and row.shape[0] != self.feat_dim:
                raise ValueError(
                    f"vertex features have {row.shape[0]} dims, delta "
                    f"declared feat_dim={self.feat_dim}")
        vid = self.n_vertices
        self._new_features.append(row)
        self._n_new += 1
        return vid

    # ------------------------------------------------------------------ #
    def coalesce(self) -> CoalescedDelta:
        """Fold the log into its net effect (order preserved for adds)."""
        pending: "Dict[Tuple[int, int], List[tuple]]" = {}
        removed: Dict[Tuple[int, int], bool] = {}   # pair -> must_exist
        adds: List[tuple] = []                      # surviving add ops
        for op in self._ops:
            pair = (op[1], op[2])
            if op[0] == "add":
                pending.setdefault(pair, []).append(op)
                adds.append(op)
            else:
                live_adds = pending.pop(pair, [])
                for a in live_adds:
                    adds.remove(a)
                if pair in removed:
                    # Second removal of the same base pair: only legal
                    # if an add in between re-created the edge.
                    if not live_adds:
                        raise KeyError(
                            f"remove_edge({pair[0]}, {pair[1]}): edge "
                            "already removed by this delta")
                else:
                    # must_exist: the removal targeted base edges, not
                    # adds from this very delta.
                    removed[pair] = not live_adds
        a_src = np.array([a[1] for a in adds], np.int32)
        a_dst = np.array([a[2] for a in adds], np.int32)
        a_w = np.array([a[3] for a in adds], np.float32)
        feats = (np.stack(self._new_features).astype(np.float32)
                 if self._new_features else None)
        return CoalescedDelta(
            removed_pairs=sorted(removed), must_exist=removed,
            add_src=a_src, add_dst=a_dst, add_weight=a_w,
            n_new_vertices=self._n_new, new_features=feats)

    # ------------------------------------------------------------------ #
    def apply_to(self, g: Graph) -> Graph:
        """Reference application: base COO -> mutated COO.

        The output edge order is *canonical*: surviving base edges in
        their original positions, then the delta's surviving adds in
        arrival order.  The incremental tile patcher reproduces exactly
        this order (via per-edge birth sequence numbers), which is what
        makes incremental and cold-compiled programs bit-identical.

        The base graph object is not mutated, but its cached views are
        invalidated (:meth:`Graph.invalidate_views`): a holder of ``g``
        that thinks of it as "the live graph" must not keep serving a
        pre-delta adjacency out of the memo.
        """
        if g.n_vertices != self.base_vertices:
            raise ValueError(
                f"delta recorded against {self.base_vertices} vertices, "
                f"graph has {g.n_vertices}")
        cd = self.coalesce()
        keep = np.ones(g.n_edges, bool)
        if cd.removed_pairs:
            key = g.src.astype(np.int64) * self.n_vertices + g.dst
            dead = np.array(
                [u * self.n_vertices + v for u, v in cd.removed_pairs],
                np.int64)
            hit = np.isin(key, dead)
            present = set(np.unique(key[hit]).tolist())
            for u, v in cd.removed_pairs:
                k = u * self.n_vertices + v
                if cd.must_exist[(u, v)] and k not in present:
                    raise KeyError(
                        f"remove_edge({u}, {v}): no such edge in "
                        f"{g.name!r}")
            keep &= ~hit
        out = dataclasses.replace(
            g,
            n_vertices=self.n_vertices,
            src=np.concatenate([g.src[keep], cd.add_src]).astype(np.int32),
            dst=np.concatenate([g.dst[keep], cd.add_dst]).astype(np.int32),
            weight=np.concatenate(
                [g.weight[keep], cd.add_weight]).astype(np.float32),
        )
        g.invalidate_views()
        return out
