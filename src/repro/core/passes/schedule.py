"""Compiler Step 4b — task scheduling (paper §6.6, Algorithm 9).

GraphAGILE executes layer by layer.  Within a layer, Tiling Blocks are
assigned to PEs.  The paper does this *dynamically* (idle PE pulls the next
block); in an SPMD software overlay the equivalent is a static balanced
assignment computed at compile time: Longest-Processing-Time (LPT) greedy
bin packing on the per-block cost estimate, which equalizes per-PE work the
same way the idle-PE rule does (and is deterministic, which SPMD needs).
The dynamic behaviour lives in the host serving runtime
(``repro/runtime/serve_loop.py``): its work queue feeds whichever overlay
drains first, and ``repro/runtime/pool.py`` reuses :func:`lpt_assign`
below to place new cache keys on the least-loaded overlay — the idle-PE
rule lifted to request granularity.

Double-buffer overlap: within each PE stream, the MEM_RD instructions of
tiling block t+1 may issue while block t computes (paper's
lock/unlock-annotated WAR protection).  The executor realizes this with
async dispatch; `overlap=False` inserts a barrier after every block
(used by the Fig. 16 ablation).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Set, Tuple

from ..ir import LayerType
from .kernel_map import Program


@dataclasses.dataclass
class ScheduleReport:
    per_layer_imbalance: List[float]   # max/mean PE load per layer

    @property
    def worst_imbalance(self) -> float:
        return max(self.per_layer_imbalance, default=1.0)


def lpt_assign(costs: Sequence[float], n_bins: int,
               initial_loads: Optional[Sequence[float]] = None
               ) -> Tuple[List[int], List[float]]:
    """Longest-Processing-Time greedy bin packing.

    Items are visited in decreasing cost order; each goes to the
    currently least-loaded bin (ties broken by lowest bin index, so the
    assignment is deterministic).  ``initial_loads`` seeds the bins with
    pre-existing work — the serving runtime passes each overlay's
    outstanding load so new keys land on the idle overlay, mirroring the
    paper's idle-PE-pulls-next-block rule.

    Returns ``(assignment, loads)``: the bin index per item (input
    order) and the final per-bin loads.
    """
    loads = list(initial_loads) if initial_loads is not None \
        else [0.0] * n_bins
    if len(loads) != n_bins:
        raise ValueError(f"initial_loads has {len(loads)} bins, "
                         f"expected {n_bins}")
    heap = [(load, b) for b, load in enumerate(loads)]
    heapq.heapify(heap)
    assignment = [0] * len(costs)
    order = sorted(range(len(costs)), key=lambda i: (-costs[i], i))
    for i in order:
        load, b = heapq.heappop(heap)
        assignment[i] = b
        loads[b] = load + costs[i]
        heapq.heappush(heap, (loads[b], b))
    return assignment, loads


# --------------------------------------------------------------------------- #
# Partition-centric residency schedule (paper §6.5, Algorithms 6-8).
#
# The streaming executor works one DESTINATION SHARD at a time: it stages
# shard j's working set (its (j, k) sub-shard tiles plus the source
# sub-fibers k they reference) in the device buffers, computes, writes the
# output sub-fibers back to the host, and meanwhile prefetches shard
# j+1's working set — the paper's computation/communication overlap with
# double-buffered DDR<->BRAM transfers.  This pass emits everything that
# executor needs as *manifest data* so a program loaded from a ``.gagi``
# file streams identically to one compiled in-process:
#
#   * per-layer destination-shard order, greedily sequenced so that
#     consecutive shards share staged source blocks (transfer reuse);
#   * per-shard source-block lists (which sub-fibers to stage);
#   * an interval-liveness table: for every layer output (and the input
#     features, id -1), the position of its LAST consumer in the layer
#     stream — the executor frees each padded output the moment its last
#     consumer has run, so peak memory follows the live-set, not the
#     model depth.
# --------------------------------------------------------------------------- #
def _layer_consumes(l) -> List[int]:
    """Value ids layer ``l`` reads (−1 = the input feature matrix),
    mirroring the executor's operand resolution exactly."""
    ewl = l.attrs.get("edge_weight_layer")
    feat_parents = [p for p in l.parent_ids if p != ewl]
    if l.layer_type == LayerType.VECTOR_ADD:
        consumed = [int(o) for o in l.attrs.get("operands", [])]
    else:
        consumed = [int(feat_parents[0]) if feat_parents else -1]
    if ewl is not None:
        consumed.append(int(ewl))
    return consumed


def _order_shards(sources: Dict[int, Set[int]]) -> List[int]:
    """Greedy max-overlap sequencing of destination shards: start at the
    lowest shard id, then repeatedly pick the unvisited shard sharing
    the most source blocks with the working set just staged (ties to
    the lowest id, so the order is deterministic).  Consecutive shards
    then reuse staged sub-fibers instead of re-transferring them."""
    todo = sorted(sources)
    if not todo:
        return []
    order = [todo.pop(0)]
    while todo:
        prev = sources[order[-1]]
        best = max(todo, key=lambda j: (len(sources[j] & prev), -j))
        todo.remove(best)
        order.append(best)
    return order


def residency_schedule(prog: Program) -> dict:
    """Shard order + source lists + liveness, as JSON-ready manifest data.

    Keys are stringified so the in-process manifest is byte-identical to
    one round-tripped through ``.gagi`` (json object keys are strings).
    """
    last_use: Dict[int, int] = {}
    layers: Dict[str, dict] = {}
    sink_pos = len(prog.layer_blocks)
    for t, lb in enumerate(prog.layer_blocks):
        for c in _layer_consumes(lb.layer):
            last_use[c] = t
        sources: Dict[int, Set[int]] = {}
        for tb in lb.tiling_blocks:
            j = tb.out_j
            if j < 0:
                continue
            e = sources.setdefault(j, set())
            if tb.kind == "spdmm":
                e.update(k for k, _ in tb.k_list)
            elif tb.kind == "sddmm":
                e.add(j)
                e.add(tb.tile_k)
            elif tb.kind in ("act", "affine") and tb.out_i < 0:
                pass                        # edge activation: no fibers
            else:
                e.add(j)                    # gemm/vadd/act: own row block
        order = _order_shards(sources)
        layers[str(lb.layer_id)] = {
            "shard_order": [int(j) for j in order],
            "sources": {str(j): sorted(int(k) for k in ks)
                        for j, ks in sources.items()},
        }
    # The sink is consumed by the final output slice, after every layer.
    if prog.layer_blocks:
        last_use[prog.layer_blocks[-1].layer_id] = sink_pos
    return {
        "last_use": {str(k): int(v) for k, v in sorted(last_use.items())},
        "layers": layers,
    }


# --------------------------------------------------------------------------- #
# Placement schedule — the multi-device generalization of the residency
# schedule.  "Where a shard runs" becomes a compiler output: destination
# row blocks are LPT-assigned to the devices of a mesh (reusing
# :func:`lpt_assign`, the same greedy rule that balances tiling blocks
# over PEs), each device gets its own greedy max-overlap shard order, and
# the per-device HALO sets (source sub-fibers a device gathers from but
# does not own, :func:`repro.core.passes.partition.halo_sets`) are
# recorded per layer so the exchange volume is known at compile time.
# The whole structure is JSON-ready manifest data and round-trips
# ``.gagi`` files; executors derive it from the binary for bundles
# written before manifests carried a ``placement`` section (mirroring
# ``derive_residency``).
# --------------------------------------------------------------------------- #
def shard_block_costs(layer_tiles, n_blocks: int) -> List[float]:
    """Per-destination-row-block load estimate: the number of compute
    instructions targeting the block, summed over all layers.

    ``layer_tiles`` yields per-layer iterables of tiling blocks exposing
    ``out_j`` and a compute-step count.  The metric is chosen so the
    compiler (counting ``k_list`` reduction steps) and the binary
    decoder (counting decoded compute instructions) agree EXACTLY,
    which is what makes the derivation fallback reproduce the emitted
    schedule bit-for-bit."""
    costs = [0.0] * n_blocks
    for tiles in layer_tiles:
        for out_j, n_steps in tiles:
            if out_j >= 0:
                costs[out_j] += n_steps
    return costs


def build_placement(residency: dict, costs: Sequence[float],
                    n_devices: int, n1: int, n2: int,
                    f_in: Dict[str, int]) -> dict:
    """Assemble the placement schedule from its ingredients.

    Shared by :func:`placement_schedule` (compile time, costs from
    TilingBlocks) and ``engine.executor.derive_placement`` (load time,
    costs from the decoded binary) so both produce identical manifests
    given identical inputs.  ``f_in`` maps stringified layer id -> input
    feature width (sizes the halo sub-fibers of that layer)."""
    from .partition import halo_sets
    nb = len(costs)
    assignment, loads = lpt_assign(costs, n_devices)
    layers: Dict[str, dict] = {}
    halo_total = 0
    for lid, rl in residency["layers"].items():
        sources = rl["sources"]
        halos = halo_sets(assignment, sources, n_devices)
        fp = ((max(int(f_in[lid]), 1) + n2 - 1) // n2) * n2
        sub_bytes = n1 * fp * 4
        order: Dict[str, List[int]] = {}
        halo_bytes: Dict[str, int] = {}
        for d in range(n_devices):
            own = {int(j): set(int(k) for k in ks)
                   for j, ks in sources.items()
                   if assignment[int(j)] == d}
            order[str(d)] = [int(j) for j in _order_shards(own)]
            halo_bytes[str(d)] = len(halos[d]) * sub_bytes
            halo_total += halo_bytes[str(d)]
        layers[lid] = {
            "order": order,
            "halo": {str(d): [int(k) for k in halos[d]]
                     for d in range(n_devices)},
            "halo_bytes": halo_bytes,
        }
    return {
        "n_devices": int(n_devices),
        "assignment": [int(a) for a in assignment],
        "loads": [float(l) for l in loads],
        "halo_bytes_total": int(halo_total),
        "layers": layers,
    }


def _tb_steps(tb) -> int:
    """Compute-instruction count of a compiler TilingBlock — matches
    ``len(TilePlan.compute)`` of the same block after decode."""
    return (len(tb.k_list) if tb.kind in ("spdmm", "gemm", "sddmm")
            else 1)


def placement_schedule(prog: Program, n_devices: int,
                       residency: Optional[dict] = None) -> dict:
    """Shard -> device placement + per-device order + halo sets, as
    JSON-ready manifest data (see :func:`build_placement`)."""
    res = residency if residency is not None else residency_schedule(prog)
    costs = shard_block_costs(
        ([(tb.out_j, _tb_steps(tb)) for tb in lb.tiling_blocks]
         for lb in prog.layer_blocks),
        prog.pgraph.n_blocks)
    f_in = {str(lb.layer_id): int(lb.layer.f_in)
            for lb in prog.layer_blocks}
    cfg = prog.pgraph.config
    return build_placement(res, costs, n_devices, cfg.n1, cfg.n2, f_in)


def run(prog: Program, n_pes: int = 8) -> ScheduleReport:
    """LPT-assign tiling blocks to PEs; annotate pe ids on instructions."""
    prog.n_pes = n_pes
    imbalances: List[float] = []
    for lb in prog.layer_blocks:
        tbs = lb.tiling_blocks
        assignment, loads = lpt_assign([tb.cost for tb in tbs], n_pes)
        for tb, pe in zip(tbs, assignment):
            tb.pe = pe
            for ins in tb.instrs:
                ins.pe = pe
        mean = sum(loads) / n_pes
        imbalances.append((max(loads) / mean) if mean > 0 else 1.0)
    return ScheduleReport(per_layer_imbalance=imbalances)
