"""Compiler Step 2 — layer fusion (paper §6.4).

Activation Fusion: an Activation layer is merged into its (single) producer
— Aggregate, Linear, Vector-Inner, or Vector-Add — eliminating one full
round-trip of the |V|xF (or |E|) intermediate through external memory.

BatchNorm Fusion: at inference the BN affine y = (x-mu)/sqrt(s^2+eps)*g + b
is folded into the adjacent Linear's weight and bias.  BN adjacent to a
non-Linear producer is kept but rewritten into a fused scale/shift epilogue.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from ..ir import Activation, LayerType, ModelIR


@dataclasses.dataclass
class FusionReport:
    fused_activations: List[int]
    fused_batchnorms: List[int]
    layers_before: int
    layers_after: int


_FUSABLE_PRODUCERS = {
    LayerType.AGGREGATE,
    LayerType.LINEAR,
    LayerType.VECTOR_INNER,
    LayerType.VECTOR_ADD,
}


def _fuse_activations(m: ModelIR) -> List[int]:
    fused = []
    for lid in list(m.topo_order()):
        if lid not in m.layers:
            continue
        l = m.layers[lid]
        if l.layer_type != LayerType.ACTIVATION:
            continue
        if len(l.parent_ids) != 1:
            continue
        p = m.layers[l.parent_ids[0]]
        if p.layer_type not in _FUSABLE_PRODUCERS:
            continue
        if len(p.child_ids) != 1:       # producer output consumed elsewhere
            continue
        if "fused_act" in p.attrs:      # chain of activations: fuse only one
            # Merge a second point-wise activation only if composable order
            # is preserved; keep it simple: leave the second one standalone.
            continue
        p.attrs["fused_act"] = int(l.act)
        p.act_enabled, p.act = True, l.act
        m.remove_layer(lid)
        m.replace_refs(lid, p.layer_id)
        fused.append(lid)
    return fused


def _fold_batchnorms(m: ModelIR) -> List[int]:
    fused = []
    for lid in list(m.topo_order()):
        if lid not in m.layers:
            continue
        l = m.layers[lid]
        if l.layer_type != LayerType.BATCHNORM:
            continue
        if len(l.parent_ids) != 1:
            continue
        p = m.layers[l.parent_ids[0]]
        if len(p.child_ids) != 1:
            continue
        mu = np.asarray(m.weights[l.attrs["mu"]], np.float32)
        sig = np.asarray(m.weights[l.attrs["sigma"]], np.float32)
        gam = np.asarray(m.weights[l.attrs["gamma"]], np.float32)
        bet = np.asarray(m.weights[l.attrs["beta"]], np.float32)
        eps = float(l.attrs.get("eps", 1e-5))
        scale = gam / np.sqrt(sig ** 2 + eps)
        shift = bet - mu * scale
        if (p.layer_type == LayerType.LINEAR
                and "fused_act" not in p.attrs):
            # Fold into weights: y = (xW + b)*scale + shift.
            W = np.asarray(m.weights[p.attrs["W"]], np.float32) * scale
            m.weights[p.attrs["W"]] = W
            bkey = p.attrs.get("b")
            if bkey is None:
                bkey = f"L{p.layer_id}.b"
                p.attrs["b"] = bkey
                b = np.zeros(p.f_out, np.float32)
            else:
                b = np.asarray(m.weights[bkey], np.float32) * scale
            m.weights[bkey] = b + shift
            m.remove_layer(lid)
            m.replace_refs(lid, p.layer_id)
            fused.append(lid)
        elif (p.layer_type in _FUSABLE_PRODUCERS
                and "fused_act" not in p.attrs
                and "fused_scale" not in p.attrs):
            # Producer is not a Linear (or already has an epilogue):
            # keep the affine as a fused scale/shift epilogue.
            skey, hkey = f"L{lid}.fscale", f"L{lid}.fshift"
            m.weights[skey], m.weights[hkey] = scale, shift
            p.attrs["fused_scale"] = skey
            p.attrs["fused_shift"] = hkey
            m.remove_layer(lid)
            m.replace_refs(lid, p.layer_id)
            fused.append(lid)
    return fused


def run(m: ModelIR, enabled: bool = True) -> FusionReport:
    n0 = m.num_layers
    if not enabled:
        return FusionReport([], [], n0, n0)
    # BN first (so Linear+BN+Act folds fully), then activations.
    bns = _fold_batchnorms(m)
    acts = _fuse_activations(m)
    return FusionReport(acts, bns, n0, m.num_layers)
