"""deepseek-v3-671b [moe] 61L d_model=7168, MLA (128 heads), MoE 256
routed top-8 + 1 shared, first 3 dense, d_ff(moe)=2048, vocab=129280,
MTP head [arXiv:2412.19437; hf]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
        n_heads=128, n_kv_heads=128, d_ff=18432, vocab=129280,
        n_experts=256, top_k=8, d_ff_moe=2048, n_shared_experts=1,
        first_k_dense=3, mla=True, q_lora=1536, kv_lora=512, qk_nope=128,
        qk_rope=64, v_head_dim=128, rope_theta=10000.0, mtp=True)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, n_experts=8, top_k=2, d_ff_moe=32,
        first_k_dense=2, q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8,
        v_head_dim=16, attn_chunk=0, remat="none")
