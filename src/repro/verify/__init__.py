"""repro.verify — static program verification for GraphAGILE binaries.

Decodes a program (bytes, :class:`ExecutionPlan`, ``.gagi`` bundle, or
in-memory :class:`CompiledProgram`) into a def/use model of tile
buffers, derives the RAW/WAR/WAW hazard graph, and runs a checker suite
over it — def-before-use, use-after-free vs the residency schedule,
partition coverage, kernel-mode legality, halo completeness, an
independent re-derivation of the device-resident peak, and structural
binary sanity.  Nothing is executed.  A second consumer
(:func:`check_trace`) turns the hazard edges into a dynamic race
detector over recorded ``repro.obs`` traces.

    from repro.verify import verify
    report = verify(prog)            # or verify(blob), verify("x.gagi")
    assert report.ok, report.to_markdown()

CLI: ``python -m repro.verify program.gagi [--json out] [--md out]``.
"""
from .checks import (check_def_before_use, check_halo_completeness,
                     check_kernel_legality, check_liveness_schedule,
                     check_partition_coverage, check_resident_budget,
                     check_structure, check_use_after_free,
                     derive_last_use, derive_residency_tables,
                     rederive_device_peak_bytes, verify, verify_binary,
                     verify_gagi, verify_plan, verify_program)
from .hazards import (DEP_GRAPH_TILE_EDGE_CAP, HazardGraph,
                      build_hazards, dep_graph_manifest,
                      sources_by_shard)
from .model import (DefUseModel, TileOp, build_model, layer_consumes,
                    tile_slices_from_stats)
from .race import check_trace
from .report import ALL_CHECKS, VerifyError, VerifyReport, Violation

__all__ = [
    "ALL_CHECKS", "VerifyError", "VerifyReport", "Violation",
    "HazardGraph", "DefUseModel", "TileOp", "DEP_GRAPH_TILE_EDGE_CAP",
    "build_model", "build_hazards", "dep_graph_manifest",
    "sources_by_shard", "layer_consumes", "tile_slices_from_stats",
    "check_structure", "check_def_before_use", "check_use_after_free",
    "check_partition_coverage", "check_kernel_legality",
    "check_halo_completeness", "check_resident_budget",
    "check_liveness_schedule", "check_trace",
    "derive_last_use", "derive_residency_tables",
    "rederive_device_peak_bytes",
    "verify", "verify_binary", "verify_gagi", "verify_plan",
    "verify_program",
]
