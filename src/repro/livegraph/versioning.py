"""Copy-on-write graph versions — immutable snapshots sharing tiles.

A :class:`GraphVersionStore` holds the lineage of a deployed graph:
version 0 is the initial partitioning, and every applied
:class:`~repro.livegraph.delta.GraphDelta` appends one immutable
:class:`GraphVersion`.  Versions share everything a delta did not touch
— per-tile edge lists, ELL slices, and content hashes are all held by
reference — so K small deltas cost O(K x touched), not O(K x graph).

A version owns the executor-facing views of its snapshot:

  * ``pgraph``      — the :class:`PartitionedGraph` the executor stages
    (device-resident, host-streaming, and mesh paths all read
    ``prog.pgraph`` at staging time, so patched tiles flow through
    every residency transparently);
  * ``as_graph()``  — the materialized canonical COO (lazy, cached):
    what a cold compile would consume, and what the sampling layer's
    CSR view builds from;
  * ``bind(prog)``  — rebind a structurally-matching compiled program
    to this version's tiles.  The bound copy is cached per program
    cache key: it is a fresh object (so the executor's per-program jit
    memo cannot replay executables that baked older tiles in as
    constants) but a *stable* one (so steady-state batched traffic on
    one version still reuses its jitted executable).  Its manifest is a
    shallow copy carrying this version's ``tile_stats`` and graph name.

The store is NOT the serving cutover mechanism — that is
``livegraph.swap.LiveGraphServer``, which pins versions across request
lifetimes and reclaims drained ones via :meth:`GraphVersionStore.drop`.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

from repro.core.graph import Graph
from repro.core.passes.partition import PartitionConfig

from .delta import GraphDelta
from .tiles import PatchStats, TileStore, tile_density_stats


class GraphVersion:
    """One immutable snapshot of a live graph."""

    def __init__(self, vid: int, store: TileStore,
                 stats: Optional[PatchStats] = None) -> None:
        self.vid = vid
        self.store = store
        self.stats = stats
        self.pgraph = store.build_pgraph()
        self._graph: Optional[Graph] = None
        # key -> (source binary, bound program).  The source binary is
        # kept separately because rebinding may itself rewrite the
        # binary (incremental remap below), so ``bound.binary`` is not
        # a stable identity for "did the caller hand us a new program".
        self._bound: Dict[str, Tuple[bytes, object]] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------ #
    @property
    def n_vertices(self) -> int:
        return self.store.n_vertices

    @property
    def live_edges(self) -> int:
        return self.store.live_edges

    @property
    def graph_name(self) -> str:
        return f"{self.store.name}@v{self.vid}"

    @property
    def structural_signature(self) -> str:
        return self.store.structural_signature()

    @property
    def content_signature(self) -> str:
        return self.store.content_signature()

    # ------------------------------------------------------------------ #
    def as_graph(self) -> Graph:
        """Materialized canonical COO (lazy, cached).  The result
        carries a ``_live_version`` backref, which is how the engine
        recognizes versioned graphs: ``graph_signature`` then returns
        the structural signature (an O(1) lookup — content-only deltas
        keep the program-cache key) and ``compile``/``submit`` rebind
        cache hits to this version's tiles."""
        with self._lock:
            if self._graph is None:
                g = self.store.as_coo()
                g.name = self.graph_name
                g.__dict__["_live_version"] = self
                self._graph = g
            return self._graph

    def bind(self, prog):
        """Rebind a compiled program to this version's tiles (cached
        per program cache key; see module docstring)."""
        if prog.pgraph is self.pgraph:
            return prog
        mine = self.pgraph.config
        theirs = prog.pgraph.config
        if (theirs.n1, theirs.n2, theirs.width_cap) != \
                (mine.n1, mine.n2, mine.width_cap):
            raise ValueError(
                "cannot bind program compiled for tile geometry "
                f"(n1, n2, cap)=({theirs.n1}, {theirs.n2}, "
                f"{theirs.width_cap}) to a live graph partitioned at "
                f"({mine.n1}, {mine.n2}, {mine.width_cap}); give the "
                "Engine and the GraphVersionStore the same geometry")
        key = prog.cache_key or f"id:{id(prog)}"
        with self._lock:
            entry = self._bound.get(key)
            if entry is not None and entry[0] is prog.binary:
                return entry[1]
            manifest = dict(prog.manifest)
            geo = dict(manifest.get("geometry", {}))
            geo.update(n_vertices=self.pgraph.n_vertices,
                       n_edges=self.pgraph.n_edges,
                       n_blocks=self.pgraph.n_blocks)
            manifest["geometry"] = geo
            manifest["graph_name"] = self.graph_name
            manifest["graph_version"] = self.vid
            manifest["content_signature"] = self.content_signature
            manifest["tile_stats"] = tile_density_stats(self.pgraph)
            bound = dataclasses.replace(
                prog, pgraph=self.pgraph, manifest=manifest,
                source=None)
            if manifest.get("remap") is not None:
                bound = self._rebind_remap(bound)
            self._bound[key] = (prog.binary, bound)
            return bound

    def _rebind_remap(self, bound):
        """Re-run the sparsity-adaptive remapper against this version's
        tile densities.  A delta version (``self.stats`` is set) only
        re-prices the tiles its delta actually patched — untouched
        tiles keep their encoded mode and record entry verbatim; a
        version with no patch record re-prices everything."""
        from repro.core.passes.remap import remap_program

        rec = bound.manifest["remap"]
        only = None
        if self.stats is not None:
            only = sorted(self.stats.patched)
            if not only:
                return bound
        return remap_program(
            bound, source="tile_stats",
            constants=rec.get("constants"),
            margin=float(rec.get("margin", 0.1)),
            only_tiles=only)

    def release_bindings(self) -> None:
        """Drop the bound-program cache (reclaim path)."""
        with self._lock:
            self._bound.clear()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"GraphVersion(v{self.vid}, |V|={self.n_vertices}, "
                f"|E|={self.live_edges}, "
                f"tiles={len(self.store.tiles)})")


# --------------------------------------------------------------------------- #
class GraphVersionStore:
    """Lineage of a live graph; apply deltas, hold/share versions.

    ``geometry`` must match the Engine(s) that will serve this graph —
    the store partitions with it, and :meth:`GraphVersion.bind` refuses
    a mismatch.  Thread-safe: ``apply`` serializes writers; readers see
    immutable versions.
    """

    def __init__(self, graph: Graph, geometry: PartitionConfig,
                 name: Optional[str] = None) -> None:
        if geometry is None:
            raise ValueError(
                "GraphVersionStore needs an explicit PartitionConfig "
                "(the same one the serving Engine is fixed at)")
        g = graph if name is None else dataclasses.replace(
            graph, name=name)
        self._lock = threading.Lock()
        v0 = GraphVersion(0, TileStore.from_graph(g, geometry))
        self._versions: Dict[int, GraphVersion] = {0: v0}
        self._head = v0
        self._next_vid = 1

    # ------------------------------------------------------------------ #
    @property
    def head(self) -> GraphVersion:
        return self._head

    def get(self, vid: int) -> Optional[GraphVersion]:
        with self._lock:
            return self._versions.get(vid)

    def versions(self) -> Dict[int, GraphVersion]:
        with self._lock:
            return dict(self._versions)

    def __len__(self) -> int:
        with self._lock:
            return len(self._versions)

    # ------------------------------------------------------------------ #
    def apply(self, delta: GraphDelta) -> GraphVersion:
        """Head + delta -> new head version (copy-on-write).

        Also invalidates the cached views of the previous head's
        materialized graph (CSR adjacency, signature memos): holders of
        "the live graph" re-resolve instead of silently reading the
        pre-delta adjacency out of a memo.
        """
        with self._lock:
            base = self._head
            if delta.base_vertices != base.n_vertices:
                raise ValueError(
                    f"delta recorded against {delta.base_vertices} "
                    f"vertices, head version v{base.vid} has "
                    f"{base.n_vertices}")
            store, stats = base.store.apply(delta.coalesce())
            v = GraphVersion(self._next_vid, store, stats=stats)
            self._next_vid += 1
            self._versions[v.vid] = v
            self._head = v
            if base._graph is not None:
                base._graph.invalidate_views()
            return v

    def drop(self, vid: int) -> bool:
        """Forget a non-head version (its uniquely-owned tiles and
        bound programs become collectable).  Returns True if dropped."""
        with self._lock:
            if vid == self._head.vid:
                return False
            v = self._versions.pop(vid, None)
            if v is not None:
                v.release_bindings()
            return v is not None
