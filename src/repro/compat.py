"""JAX API-generation compatibility shims.

The LM-scaffolding half of the seed (dryrun / distributed / models) was
written against the sharding-in-types API generation (``jax.set_mesh``,
``jax.sharding.AxisType``, ``jax.shard_map``); the GNN overlay half runs
fine on older releases.  This module keeps BOTH halves working on either
generation by dispatching on feature presence, not version strings:

  * :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types=Auto`` when
    the installed JAX has :class:`jax.sharding.AxisType`, without it
    otherwise (Auto is the legacy default, so semantics match);
  * :func:`set_mesh` — ``jax.set_mesh`` / ``jax.sharding.use_mesh`` when
    available, else the legacy ``with mesh:`` context (which is what
    those APIs grew out of);
  * :func:`get_abstract_mesh` — the ambient mesh for soft sharding
    constraints; falls back to the legacy thread-resources physical
    mesh (empty mesh -> ``None``-ish object with no axis names, exactly
    like the new API on a single device);
  * :func:`shard_map` — ``jax.shard_map`` or the experimental module,
    translating the ``check_vma`` keyword to the old ``check_rep``.

Everything degrades to a working single-device no-op, so importing this
module never touches device state.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax

HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices: Optional[Any] = None):
    """``jax.make_mesh`` across API generations (Auto axis types)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPE:
        kwargs["axis_types"] = (
            jax.sharding.AxisType.Auto,) * len(tuple(axis_names))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    # Legacy: Mesh is itself a context manager feeding thread resources.
    return mesh


def get_abstract_mesh():
    """The ambient mesh (set by :func:`set_mesh`), or an empty mesh.

    Callers test ``mesh.axis_names`` before using it, which is exactly
    how the new API signals "no mesh" too.
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is not None:
        return fn()
    try:  # legacy thread-local mesh context
        from jax._src.mesh import thread_resources
        return thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - very old/very new layouts
        return None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across API generations.

    The new API's ``check_vma`` keyword is the old ``check_rep``; both
    toggle the replication/varying-axes checker.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
