"""The host serving loop promised by ``repro.core.passes.schedule``.

The compiler freezes Algorithm 9's dynamic load balance into a static
LPT schedule (SPMD needs determinism); the *dynamic* half lives here: a
bounded work queue feeds whichever overlay drains first, batches form
while overlays are busy, and compile (T_LoC) on one overlay overlaps
execute (T_LoH) on another — the paper's computation/communication
overlap, host edition.

Flow::

    submit(req) --admission--> Batcher --size/deadline flush--> place()
       (QueueFullError on a         (one batch = one cache key)
        full queue = backpressure)        |
                                          v
                              per-overlay FIFO worker
                              (Engine.submit_batch: ONE binary pass)

Determinism: batch composition, flush order, and overlay placement are
all computed in the caller's thread from arrival order alone — thread
timing never changes *what* runs *where*, only when.  With
``overlap_overlays=False`` execution itself is also serialized in
dispatch order (the mode the equivalence tests use).  ``drain()``
returns responses in admission order.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Sequence

from repro.engine import InferenceRequest, InferenceResponse
from repro.obs.tracer import get_tracer

from .batcher import Batch, Batcher
from .metrics import Metrics
from .pool import OverlayPool


class QueueFullError(RuntimeError):
    """Admission control: the bounded request queue is full.

    Online callers should shed load or retry after a drain; the offline
    ``serve()`` helper responds by flushing the queue (backpressure)."""


class ServeLoop:
    """Bounded-queue, batching, multi-overlay serving loop."""

    def __init__(self, pool: OverlayPool, *, max_batch: int = 8,
                 max_wait_us: float = 2000.0, max_queue: int = 256,
                 overlap_overlays: bool = True,
                 clock: Callable[[], float] = time.monotonic,
                 metrics: Optional[Metrics] = None) -> None:
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.pool = pool
        self.max_batch = max_batch
        self.max_queue = max_queue
        self.clock = clock
        self.metrics = metrics if metrics is not None else pool.metrics
        self.batcher = Batcher(max_batch=max_batch,
                               max_wait_us=max_wait_us, clock=clock)
        self._seq = 0
        self._admitted_at: Dict[int, float] = {}
        # Wall-clock admission stamps for tracing only: the loop clock
        # is injectable (tests drive fake clocks), so trace timestamps
        # come from the tracer's perf_counter_ns clock instead.
        self._admitted_ns: Dict[int, int] = {}
        self._results: Dict[int, InferenceResponse] = {}
        self._pins: Dict[int, tuple] = {}    # idx -> (live server, vid)
        self._lock = threading.Lock()
        self._futures: List[Future] = []
        # One single-thread worker per overlay: an overlay's batches run
        # FIFO (it is one device), while different overlays overlap —
        # T_LoC on overlay A under T_LoH on overlay B.
        self._workers: Optional[List[ThreadPoolExecutor]] = None
        if overlap_overlays and len(pool) > 1:
            self._workers = [
                ThreadPoolExecutor(max_workers=1,
                                   thread_name_prefix=f"overlay{i}")
                for i in range(len(pool))]

    # ------------------------------------------------------------------ #
    @property
    def queue_depth(self) -> int:
        return self.batcher.depth

    def submit(self, req: InferenceRequest) -> None:
        """Admit one request (raises :class:`QueueFullError` when the
        queue is at capacity), then dispatch any size- or deadline-due
        batches.

        Live-graph requests (``req.graph`` is a
        ``repro.livegraph.LiveGraphServer`` handle) are resolved HERE,
        at admission: the request pins the version active right now and
        is served on exactly that version's tiles, however many
        cutovers happen before it executes.  The batch key carries the
        version (``OverlayPool.cache_key``), so one batch never mixes
        versions; the pin is released when the response is recorded,
        which is what lets a drained retired version be reclaimed."""
        if self.batcher.depth >= self.max_queue:
            self.metrics.record_rejection()
            raise QueueFullError(
                f"serving queue at capacity ({self.max_queue}); "
                "drain or retry later")
        req, pin = self._resolve_live(req)
        now = self.clock()
        idx = self._seq
        self._seq += 1
        self._admitted_at[idx] = now
        tracer = get_tracer()
        if tracer.enabled:
            self._admitted_ns[idx] = tracer.now_ns()
            tracer.instant("admit", cat="serve", track="queue",
                           args={"request": req.request_id or f"#{idx}",
                                 "depth": self.batcher.depth})
        if pin is not None:
            with self._lock:
                self._pins[idx] = pin
        full = self.batcher.add(self.pool.cache_key(req), req, idx, now)
        self.metrics.record_queue_depth(self.batcher.depth)
        due = ([full] if full is not None else []) + self.batcher.due(now)
        self._dispatch(due)

    @staticmethod
    def _resolve_live(req: InferenceRequest):
        """Swap a live-graph handle for the active version's snapshot,
        pinning the version (see :meth:`submit`)."""
        server = getattr(req.graph, "_live_server", None)
        if server is None:
            return req, None
        version = server.admit()
        return (dataclasses.replace(req, graph=version.as_graph()),
                (server, version.vid))

    def poll(self) -> None:
        """Flush deadline-due batches (call from an idle loop)."""
        self._dispatch(self.batcher.due(self.clock()))

    def flush(self) -> None:
        """Dispatch everything still queued, regardless of deadlines."""
        self._dispatch(self.batcher.flush_all())

    # ------------------------------------------------------------------ #
    def _dispatch(self, batches: Sequence[Batch]) -> None:
        if not batches:
            return
        placements = self.pool.place(batches)
        # prune cleanly-settled futures so online submit()/poll()
        # callers that drain() only periodically don't grow the list
        # without bound; failed ones stay so drain() still raises
        self._futures = [f for f in self._futures
                         if not f.done() or f.exception() is not None]
        for batch, overlay in zip(batches, placements):
            self.metrics.record_batch(batch.key, len(batch))
            if self._workers is not None:
                self._futures.append(self._workers[overlay].submit(
                    self._execute, batch, overlay))
            else:
                self._execute(batch, overlay)

    def _execute(self, batch: Batch, overlay: int) -> None:
        # Clocked at execution start, in the worker: the wait term then
        # covers batching delay AND time spent queued behind earlier
        # batches in this overlay's FIFO — the full experienced latency.
        started = self.clock()
        tracer = get_tracer()
        start_ns = tracer.now_ns() if tracer.enabled else 0
        bspan = tracer.span(
            "batch", cat="serve", track=f"overlay{overlay}",
            args={"key": batch.key[:12], "size": len(batch)})
        resps = self.pool.execute_on(overlay, batch)
        bspan.add(cache_hit=bool(resps and resps[0].cache_hit)).done()
        released = []
        with self._lock:
            for idx, r in zip(batch.indices, resps):
                # experienced latency = queue wait + compile + execute
                wait = started - self._admitted_at.pop(idx)
                self.metrics.record_response(
                    r, wait + r.t_loc + r.t_loh,
                    queue_wait_s=wait, execute_s=r.t_loh,
                    compile_s=r.t_loc)
                adm_ns = self._admitted_ns.pop(idx, None)
                if adm_ns is not None:
                    # Retroactive: admission stamped in the caller's
                    # thread, closed here in the worker at batch start.
                    tracer.complete(
                        "queue_wait", adm_ns, start_ns, cat="serve",
                        track="queue",
                        args={"request": r.request_id,
                              "overlay": overlay})
                self._results[idx] = r
                pin = self._pins.pop(idx, None)
                if pin is not None:
                    released.append(pin)
        # Release version pins outside the loop lock (reclamation takes
        # the live server's own lock; served requests count per version).
        for server, vid in released:
            server.release(vid)

    # ------------------------------------------------------------------ #
    def drain(self) -> List[InferenceResponse]:
        """Flush the queue, wait for all in-flight batches, and return
        every completed response in admission order (resetting the
        completion store).  Online callers must drain periodically:
        completed responses are retained here until collected."""
        self.flush()
        # detach before raising: a failed batch propagates its exception
        # ONCE, instead of poisoning every later drain with a stale error
        futures, self._futures = self._futures, []
        for f in futures:
            f.result()              # propagate worker exceptions
        with self._lock:
            out = [self._results[i] for i in sorted(self._results)]
            self._results.clear()
        return out

    def serve(self, requests: Sequence[InferenceRequest]
              ) -> List[InferenceResponse]:
        """Offline drain of a request stream, responses in request
        order.  A full queue exerts backpressure: the producer blocks on
        a flush instead of raising — nothing is rejected (and nothing
        is counted as rejected in the metrics)."""
        t0 = self.clock()
        for req in requests:
            if self.batcher.depth >= self.max_queue:
                self.flush()
            self.submit(req)
        out = self.drain()
        self.metrics.record_serve_wall(len(out), self.clock() - t0)
        return out

    def shutdown(self) -> None:
        """Stop the per-overlay workers (idempotent)."""
        if self._workers is not None:
            for w in self._workers:
                w.shutdown(wait=True)
            self._workers = None
