"""Binary-driven overlay executor (paper Alg. 9, ISA v3 runtime).

Unlike the original object-graph executor, this one consumes ONLY:

  * the decoded 128-bit instruction stream (layer/tiling-block dispatch,
    kernel kinds, tile coordinates, reduction order, fused epilogues,
    PE assignment),
  * the program manifest (weight-key indirections, dataflow operands,
    scalar coefficients), and
  * the DDR payload (weight arrays + fiber-shard ELL tiles).

No in-memory ``Program``/``LayerIR`` objects appear on the hot path, so a
``CompiledProgram`` loaded from a ``.gagi`` file executes identically to
one compiled in-process — the overlay contract: one fixed substrate, any
(model, graph) pair, driven purely by its binary.

Execution is layer by layer; within a layer, tiling blocks are issued in
PE-interleaved order (round-robin across the PE streams the scheduler
encoded into the instructions).  ``overlap=True`` dispatches tile ops
asynchronously (the double-buffering analogue); ``overlap=False`` forces
every tiling block to completion (Fig. 16 ablation baseline).

Graph-as-data mode: ``run``/``run_batch`` accept an optional
``graph_data`` structure that *replaces the program's baked ELL tiles at
runtime* — the Dynasparse-style normalization the sampling layer uses.
The program is compiled once per geometry bucket (against the bucket's
canonical template, ``repro.sampling.buckets``), and each request ships
its actual topology as arrays matching the canonical layout::

    {"tiles": {"j:k:s": {"cols": int32 [n1, w], "vals": float32 [n1, w],
                         "mask": bool  [n1, w], "epos": int32  [n1, w]}},
     "inv_in_degree": float32 [nb * n1]}

``epos`` uses the same convention as the baked tiles (original COO edge
index, ``-1`` on pad slots).  In ``run_batch`` the structure is stacked
with a leading batch axis and vmapped together with the features, so N
*different* subgraphs sharing one bucket execute as ONE binary pass.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.ack import ACK
from repro.core.ir import Activation, AggOp, LayerType
from repro.core.reference import apply_activation

from .decoder import LayerPlan, TilePlan
from .program import CompiledProgram


def _tile_arrays(pg, gtiles, j: int, k: int, s: int):
    """(cols, vals, mask, epos) of tile (j, k, s) — from the runtime
    ``graph_data`` when present, else from the program's baked tiles.
    Shapes agree by the canonical-layout contract, so the same traced
    computation serves both sources.  Baked arrays stay on the host
    (numpy) — consumers device-convert implicitly on use, so unused
    elements cost nothing on the eager path."""
    if gtiles is None:
        t = pg.tiles[(j, k)][s]
        return t.cols, t.vals, t.edge_pos >= 0, t.edge_pos
    d = gtiles[f"{j}:{k}:{s}"]
    return d["cols"], d["vals"], d["mask"], d["epos"]


class ResidentBudgetError(RuntimeError):
    """Raised when an execution mode cannot honor ``resident_budget_bytes``.

    Device-resident runs raise it up front (from the liveness-aware peak
    estimate); the partition-centric streaming path raises it only if a
    single shard's double-buffered working set exceeds the budget."""


@dataclasses.dataclass
class ExecStats:
    tile_ops: int = 0
    layers: int = 0
    runs: int = 0
    # Liveness / streaming telemetry (peaks are high-water marks).
    peak_live_outputs: int = 0      # layer outputs alive at once
    peak_live_bytes: int = 0        # bytes of those outputs
    shards_streamed: int = 0        # destination shards staged (host mode)
    h2d_bytes: int = 0              # bytes shipped host -> device
    peak_stage_bytes: int = 0       # double-buffered working set peak

    def add(self, other: "ExecStats") -> None:
        self.tile_ops += other.tile_ops
        self.layers += other.layers
        self.runs += other.runs
        self.shards_streamed += other.shards_streamed
        self.h2d_bytes += other.h2d_bytes
        self.peak_live_outputs = max(self.peak_live_outputs,
                                     other.peak_live_outputs)
        self.peak_live_bytes = max(self.peak_live_bytes,
                                   other.peak_live_bytes)
        self.peak_stage_bytes = max(self.peak_stage_bytes,
                                    other.peak_stage_bytes)


def _nbytes(a) -> int:
    """Array bytes; works for numpy arrays, jax arrays, and tracers."""
    return int(a.size) * a.dtype.itemsize


def _layer_out_bytes(lp: LayerPlan, pg) -> int:
    """Bytes of the padded output a layer keeps alive (liveness units)."""
    n1, n2 = pg.config.n1, pg.config.n2
    if lp.layer_type == LayerType.VECTOR_INNER or lp.on_edges:
        return (pg.n_edges + 1) * 4
    f = lp.f_out if lp.layer_type == LayerType.LINEAR else lp.f_in
    fp = ((max(f, 1) + n2 - 1) // n2) * n2
    return pg.n_blocks * n1 * fp * 4


def derive_residency(plan, lmeta: dict) -> dict:
    """Rebuild the residency schedule from the decoded binary alone —
    the fallback for ``.gagi`` bundles written before manifests carried
    a ``residency`` section.  Mirrors
    :func:`repro.core.passes.schedule.residency_schedule` (same greedy
    shard sequencing, same liveness rules) but reads TilePlans instead
    of compiler TilingBlocks."""
    from repro.core.passes.schedule import _order_shards
    last_use: Dict[int, int] = {}
    layers: Dict[str, dict] = {}
    for t, lp in enumerate(plan.layers):
        meta = lmeta[str(lp.layer_id)]
        ewl = meta.get("edge_weight_layer")
        feat_parents = [p for p in meta["parents"] if p != ewl]
        if lp.layer_type == LayerType.VECTOR_ADD:
            consumed = [int(o) for o in meta["operands"]]
        else:
            consumed = [int(feat_parents[0]) if feat_parents else -1]
        if ewl is not None:
            consumed.append(int(ewl))
        for c in consumed:
            last_use[c] = t
        sources: Dict[int, set] = {}
        for tp in lp.tiles:
            j = tp.out_j
            if j < 0:
                continue
            e = sources.setdefault(j, set())
            if lp.layer_type == LayerType.AGGREGATE:
                e.update(ins.args[1] for ins in tp.compute)
            elif lp.layer_type == LayerType.VECTOR_INNER:
                e.add(j)
                e.add(tp.tile_k)
            elif not lp.on_edges:
                e.add(j)
        layers[str(lp.layer_id)] = {
            "shard_order": [int(j) for j in _order_shards(sources)],
            "sources": {str(j): sorted(int(k) for k in ks)
                        for j, ks in sources.items()},
        }
    if plan.layers:
        last_use[plan.layers[-1].layer_id] = len(plan.layers)
    return {"last_use": {str(k): int(v)
                         for k, v in sorted(last_use.items())},
            "layers": layers}


class BinaryExecutor:
    """Executes a CompiledProgram by interpreting its decoded binary.

    ``stats`` holds the counters of the most recent :meth:`run` only
    (reset at entry); ``total`` accumulates across the executor's
    lifetime.  A batched :meth:`run_batch` counts as ONE pass: the
    instruction stream is traversed once, whatever the batch size.
    """

    def __init__(self, backend: str = "xla", overlap: bool = True,
                 interpret: bool = True,
                 resident_budget_bytes: Optional[int] = None) -> None:
        self.ack = ACK(backend=backend, interpret=interpret)
        self.overlap = overlap
        self.resident_budget_bytes = resident_budget_bytes
        # Optional observer called as hook(event, layer_id, live_count)
        # with event in {"alloc", "free"} whenever a layer output is
        # materialized or released (tests count liveness through this).
        self.liveness_hook = None
        self.stats = ExecStats()        # per-run (last run)
        self.total = ExecStats()        # lifetime accumulation

    # ------------------------------------------------------------------ #
    def _residency(self, prog: CompiledProgram) -> dict:
        """Manifest residency section, derived from the binary for
        pre-residency ``.gagi`` bundles (cached on the program)."""
        res = prog.manifest.get("residency")
        if res is None:
            res = prog.__dict__.get("_derived_residency")
            if res is None:
                res = derive_residency(prog.plan(), prog.manifest["layers"])
                prog.__dict__["_derived_residency"] = res
        return res

    def estimate_device_peak_bytes(self, prog: CompiledProgram,
                                   x_cols: Optional[int] = None,
                                   assume_liveness: bool = True,
                                   batch: int = 1) -> int:
        """Liveness-aware peak device bytes of a device-resident run:
        graph tiles + weights + the input feature matrix + the maximum
        over layer steps of the concurrently-live padded outputs.
        ``assume_liveness=False`` prices the pre-liveness executor that
        kept every layer's output alive for the whole pass.  ``batch``
        scales the per-lane parts (features + live outputs) for a
        vmapped ``run_batch`` pass; tiles/weights are broadcast."""
        plan = prog.plan()
        pg = prog.pgraph
        n1, n2 = pg.config.n1, pg.config.n2
        vp = pg.n_blocks * n1
        res = self._residency(prog)
        last_use = {int(k): v for k, v in res["last_use"].items()}
        static = (pg.tile_bytes()
                  + sum(_nbytes(np.asarray(w))
                        for w in prog.weights.values())
                  + _nbytes(np.asarray(pg.inv_in_degree)))
        if not plan.layers:
            return static
        fin_pad0 = ((max(plan.layers[0].f_in, 1) + n2 - 1) // n2) * n2
        xw = fin_pad0 if x_cols is None else max(
            fin_pad0, ((x_cols + n2 - 1) // n2) * n2)
        x_bytes = vp * xw * 4   # kept for the whole pass in device mode
        sizes = {lp.layer_id: _layer_out_bytes(lp, pg)
                 for lp in plan.layers}
        births = {lp.layer_id: t for t, lp in enumerate(plan.layers)}
        n = len(plan.layers)
        if not assume_liveness:
            return static + batch * (x_bytes + sum(sizes.values()))
        peak = 0
        for t in range(n):
            live = sum(sz for lid, sz in sizes.items()
                       if births[lid] <= t <= max(last_use.get(lid, n),
                                                  births[lid]))
            peak = max(peak, live)
        return static + batch * (x_bytes + peak)

    # ------------------------------------------------------------------ #
    def _watermark(self, event: str, layer_id: int, vals: Dict,
                   edge_vals: Dict) -> None:
        live = len(vals) + len(edge_vals)
        if event == "alloc":
            self.stats.peak_live_outputs = max(
                self.stats.peak_live_outputs, live)
            self.stats.peak_live_bytes = max(
                self.stats.peak_live_bytes,
                sum(_nbytes(a) for d in (vals, edge_vals)
                    for a in d.values()))
        if self.liveness_hook is not None:
            self.liveness_hook(event, layer_id, live)

    def _free_dead(self, t: int, sink: int, last_use: Dict[int, int],
                   vals: Dict, edge_vals: Dict) -> None:
        """Release every value whose LAST consumer was step ``t`` —
        interval liveness from the manifest's residency table."""
        for d in (vals, edge_vals):
            for lid in [l for l in d
                        if l != sink and last_use.get(l, -1) == t]:
                del d[lid]
                self._watermark("free", lid, vals, edge_vals)

    def run(self, prog: CompiledProgram, x: jnp.ndarray,
            weights: Optional[Dict[str, np.ndarray]] = None,
            graph_data: Optional[dict] = None,
            residency: str = "device") -> jnp.ndarray:
        if residency not in ("device", "host"):
            raise ValueError(f"residency must be 'device' or 'host', "
                             f"got {residency!r}")
        if residency == "host":
            if graph_data is not None:
                raise ValueError(
                    "graph-as-data execution is device-resident only "
                    "(bucketed subgraphs are small by construction)")
            return self._run_host(prog, x, weights)
        if self.resident_budget_bytes is not None:
            est = self.estimate_device_peak_bytes(prog, int(x.shape[1]))
            if est > self.resident_budget_bytes:
                raise ResidentBudgetError(
                    f"device-resident execution needs ~{est} bytes "
                    f"(liveness-aware peak) but resident_budget_bytes="
                    f"{self.resident_budget_bytes}; re-run with "
                    f"residency='host' to stream shard-by-shard")
        self.stats = ExecStats(runs=1)
        plan = prog.plan()
        man = prog.manifest
        pg = prog.pgraph
        res = self._residency(prog)
        last_use = {int(k): v for k, v in res["last_use"].items()}
        gtiles = graph_data["tiles"] if graph_data is not None else None
        weights = weights if weights is not None else prog.weights
        lmeta = man["layers"]
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        vp = nb * n1
        nv = pg.n_vertices

        def f_pad(f: int) -> int:
            return ((max(f, 1) + n2 - 1) // n2) * n2

        def pad_vertex(a: jnp.ndarray, fp: int) -> jnp.ndarray:
            a = jnp.asarray(a, jnp.float32)
            return jnp.pad(a, ((0, vp - a.shape[0]),
                               (0, fp - a.shape[1])))

        fin_pad0 = f_pad(plan.layers[0].f_in)
        x_pad = pad_vertex(x, max(fin_pad0,
                                  ((x.shape[1] + n2 - 1) // n2) * n2))
        vals: Dict[int, jnp.ndarray] = {}       # layer -> padded output
        edge_vals: Dict[int, jnp.ndarray] = {}  # layer -> (E,) edge scores
        inv_deg = jnp.asarray(graph_data["inv_in_degree"]
                              if graph_data is not None
                              else pg.inv_in_degree)

        sink = man["sink"]
        for t, lp in enumerate(plan.layers):
            meta = lmeta[str(lp.layer_id)]
            self.stats.layers += 1
            ewl = meta.get("edge_weight_layer")
            feat_parents = [p for p in meta["parents"] if p != ewl]
            h_in = (vals.get(feat_parents[0], x_pad) if feat_parents
                    else x_pad)
            lt = lp.layer_type

            if lt == LayerType.AGGREGATE:
                vals[lp.layer_id] = self._run_aggregate(
                    lp, meta, pg, h_in, edge_vals, inv_deg, weights,
                    gtiles)
            elif lt == LayerType.LINEAR:
                vals[lp.layer_id] = self._run_linear(
                    lp, meta, pg, h_in, weights)
            elif lt == LayerType.VECTOR_INNER:
                edge_vals[lp.layer_id] = self._run_vector_inner(
                    lp, meta, pg, h_in, weights, gtiles)
            elif lt == LayerType.VECTOR_ADD:
                a_id, b_id = meta["operands"]
                xa = x_pad if a_id == -1 else vals[a_id]
                xb = x_pad if b_id == -1 else vals[b_id]
                vals[lp.layer_id] = self._run_vadd(
                    lp, meta, pg, xa, xb, weights)
            elif lt in (LayerType.ACTIVATION, LayerType.BATCHNORM):
                if lp.on_edges:
                    src = edge_vals[feat_parents[0]]
                    edge_vals[lp.layer_id] = self._run_edge_act(
                        lp, pg, src, gtiles)
                else:
                    vals[lp.layer_id] = self._run_vertex_act(
                        lp, meta, pg, h_in, weights)
            else:
                raise ValueError(lt)
            if not self.overlap:
                tree = vals.get(lp.layer_id, edge_vals.get(lp.layer_id))
                jax.block_until_ready(tree)
            self._watermark("alloc", lp.layer_id, vals, edge_vals)
            # Interval liveness: drop outputs whose last consumer just
            # ran, so peak memory follows the live-set, not model depth.
            self._free_dead(t, sink, last_use, vals, edge_vals)

        self.total.add(self.stats)
        return vals[sink][:nv, :man["sink_f_out"]]

    # ------------------------------------------------------------------ #
    def run_batch(self, prog: CompiledProgram, xs: jnp.ndarray,
                  weights: Optional[Dict[str, np.ndarray]] = None,
                  graph_data: Optional[dict] = None,
                  residency: str = "device") -> jnp.ndarray:
        """Execute ONE binary pass for a stacked ``[N, V, F]`` batch.

        The instruction stream is decoded and traversed once; every tile
        op is vectorized over the leading batch axis (``jax.vmap``), so N
        requests that share a compiled program pay the Python-side
        dispatch cost of a single request.  Per-run ``stats`` therefore
        report one pass worth of tile ops, matching the hardware story:
        the overlay executes the same binary, on wider data.

        The traced-and-jitted batched pass is memoized **on the
        program** per (batch shape, executor config): steady-state
        traffic — repeated batches of the same deployed (model, graph)
        pair — replays a compiled whole-program executable with zero
        Python-side instruction dispatch, which is what lets the
        serving runtime saturate the substrate.  (A ``weights``
        override bypasses the memo: the executable closes over the
        program's own weights.)
        """
        if xs.ndim != 3:
            raise ValueError(
                f"run_batch expects stacked [N, V, F] features, got "
                f"shape {tuple(xs.shape)}")
        if residency == "host":
            # Streaming mode trades latency for footprint: lanes run
            # sequentially (each an independent shard-streamed pass) so
            # the device never holds more than one working set.
            if graph_data is not None:
                raise ValueError(
                    "graph-as-data execution is device-resident only")
            batch = ExecStats()
            ys = []
            for i in range(xs.shape[0]):
                ys.append(self.run(prog, xs[i], weights=weights,
                                   residency="host"))
                batch.add(self.stats)
            batch.runs = 1                  # one logical batched pass
            self.stats = batch
            return jnp.stack(ys)
        # Budget-gate the vmapped pass at BATCH scale, on every call —
        # per-lane checks inside run() undercount by the batch factor,
        # and memoized replays never re-enter run() at all.
        if self.resident_budget_bytes is not None:
            est = self.estimate_device_peak_bytes(
                prog, int(xs.shape[2]), batch=int(xs.shape[0]))
            if est > self.resident_budget_bytes:
                raise ResidentBudgetError(
                    f"device-resident batch of {int(xs.shape[0])} needs "
                    f"~{est} bytes (liveness-aware peak) but "
                    f"resident_budget_bytes={self.resident_budget_bytes};"
                    f" re-run with residency='host' or a smaller batch")
        if weights is not None:
            if graph_data is not None:
                return jax.vmap(lambda x, gd: self.run(
                    prog, x, weights=weights, graph_data=gd)
                )(xs, graph_data)
            return jax.vmap(lambda x: self.run(prog, x,
                                               weights=weights))(xs)
        # graph_data shapes are fixed by the program's canonical layout,
        # so (batch shape, presence flag) fully keys the executable.
        key = (tuple(xs.shape), str(xs.dtype), graph_data is not None,
               self.ack.backend, self.ack.interpret, self.overlap)
        cache = prog.__dict__.setdefault("_batch_exec", {})
        entry = cache.get(key)
        if entry is None:
            if graph_data is not None:
                fn = jax.jit(jax.vmap(
                    lambda x, gd: self.run(prog, x, graph_data=gd)))
                y = fn(xs, graph_data)  # traces now; run() sets stats
            else:
                fn = jax.jit(jax.vmap(lambda x: self.run(prog, x)))
                y = fn(xs)
            cache[key] = (fn, dataclasses.replace(self.stats))
            return y
        fn, stats = entry
        self.stats = dataclasses.replace(stats)
        self.total.add(self.stats)
        return fn(xs, graph_data) if graph_data is not None else fn(xs)

    # ------------------------------------------------------------------ #
    # Partition-centric out-of-core execution (paper §6.5, Alg. 6-8).
    #
    # Features stay HOST-resident (numpy); the device holds one
    # destination shard's working set at a time — its (j, k) sub-shard
    # tiles plus the source sub-fibers they gather from — while the NEXT
    # shard's working set is already in flight (``jax.device_put`` is
    # async), the software analogue of the paper's double-buffered
    # DDR<->BRAM overlap.  Every tile op runs through the same jitted
    # ACK kernels on the same values in the same order as the
    # device-resident path, so results are bit-identical.
    # ------------------------------------------------------------------ #
    def _stage(self, arrs: Dict[str, np.ndarray]):
        """Ship one working set host -> device; returns (staged, bytes)."""
        staged = {k: jax.device_put(a) for k, a in arrs.items()}
        nbytes = sum(_nbytes(a) for a in arrs.values())
        self.stats.h2d_bytes += nbytes
        return staged, nbytes

    def _stream_shards(self, order, build, compute) -> None:
        """Drive one layer's destination shards through the double
        buffer: stage shard ``order[0]``, then for each shard dispatch
        its tile ops (async), stage the NEXT shard's working set while
        they run, and only then block on the outputs and write them back
        to the host.  ``build(j)`` assembles shard j's working set as
        name -> numpy array; ``compute(j, staged)`` dispatches the tile
        ops and returns ``(write_back, device_value)`` pairs."""
        if not order:
            return
        staged_next, next_bytes = self._stage(build(order[0]))
        for idx, j in enumerate(order):
            staged, cur_bytes = staged_next, next_bytes
            pending = compute(j, staged)
            if idx + 1 < len(order):
                staged_next, next_bytes = self._stage(build(order[idx + 1]))
            else:
                staged_next, next_bytes = None, 0
            window = cur_bytes + next_bytes
            self.stats.peak_stage_bytes = max(
                self.stats.peak_stage_bytes, window)
            if (self.resident_budget_bytes is not None
                    and window + self._static_bytes
                    > self.resident_budget_bytes):
                raise ResidentBudgetError(
                    f"shard working set ({window} bytes double-buffered "
                    f"+ {self._static_bytes} resident weights) exceeds "
                    f"resident_budget_bytes="
                    f"{self.resident_budget_bytes}; recompile with a "
                    f"smaller n1 / width_cap")
            for write, val in pending:
                write(np.asarray(val))          # D2H; blocks shard j only
            self.stats.shards_streamed += 1

    def _run_host(self, prog: CompiledProgram, x,
                  weights: Optional[Dict[str, np.ndarray]] = None
                  ) -> jnp.ndarray:
        self.stats = ExecStats(runs=1)
        plan = prog.plan()
        man = prog.manifest
        pg = prog.pgraph
        res = self._residency(prog)
        weights = weights if weights is not None else prog.weights
        self._static_bytes = sum(_nbytes(np.asarray(w))
                                 for w in weights.values())
        lmeta = man["layers"]
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        vp = nb * n1
        nv = pg.n_vertices
        sink = man["sink"]
        last_use = {int(k): v for k, v in res["last_use"].items()}

        fin_pad0 = ((max(plan.layers[0].f_in, 1) + n2 - 1) // n2) * n2
        x_np = np.asarray(x, np.float32)
        xw = max(fin_pad0, ((x_np.shape[1] + n2 - 1) // n2) * n2)
        x_host = np.zeros((vp, xw), np.float32)
        x_host[: x_np.shape[0], : x_np.shape[1]] = x_np
        vals: Dict[int, np.ndarray] = {}       # layer -> padded output
        edge_vals: Dict[int, np.ndarray] = {}  # layer -> (E,) edge scores

        for t, lp in enumerate(plan.layers):
            meta = lmeta[str(lp.layer_id)]
            rl = res["layers"][str(lp.layer_id)]
            self.stats.layers += 1
            ewl = meta.get("edge_weight_layer")
            feat_parents = [p for p in meta["parents"] if p != ewl]
            h_in = (vals.get(feat_parents[0], x_host) if feat_parents
                    else x_host)
            lt = lp.layer_type

            if lt == LayerType.AGGREGATE:
                vals[lp.layer_id] = self._host_aggregate(
                    lp, meta, pg, h_in, edge_vals, weights, rl)
            elif lt == LayerType.LINEAR:
                vals[lp.layer_id] = self._host_linear(
                    lp, meta, pg, h_in, weights, rl)
            elif lt == LayerType.VECTOR_INNER:
                edge_vals[lp.layer_id] = self._host_vector_inner(
                    lp, meta, pg, h_in, weights, rl)
            elif lt == LayerType.VECTOR_ADD:
                a_id, b_id = meta["operands"]
                xa = x_host if a_id == -1 else vals[a_id]
                xb = x_host if b_id == -1 else vals[b_id]
                vals[lp.layer_id] = self._host_vadd(
                    lp, meta, pg, xa, xb, weights, rl)
            elif lt in (LayerType.ACTIVATION, LayerType.BATCHNORM):
                if lp.on_edges:
                    edge_vals[lp.layer_id] = self._host_edge_act(
                        lp, pg, edge_vals[feat_parents[0]])
                else:
                    vals[lp.layer_id] = self._host_vertex_act(
                        lp, meta, pg, h_in, weights, rl)
            else:
                raise ValueError(lt)
            self._watermark("alloc", lp.layer_id, vals, edge_vals)
            self._free_dead(t, sink, last_use, vals, edge_vals)
            if last_use.get(-1, -1) == t:
                x_host = None          # input's last consumer has run

        out = vals[sink][:nv, : man["sink_f_out"]]
        self.total.add(self.stats)
        return jnp.asarray(out)

    # ------------------------------------------------------------------ #
    def _host_aggregate(self, lp, meta, pg, h_in, edge_vals, weights,
                        rl) -> np.ndarray:
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        nf = (max(lp.f_in, 1) + n2 - 1) // n2
        op = {AggOp.SUM: "sum", AggOp.MEAN: "mean",
              AggOp.MAX: "max", AggOp.MIN: "min"}[AggOp(lp.mode)]
        ewl = meta.get("edge_weight_layer")
        ew = edge_vals[ewl] if ewl is not None else None   # host (E,)
        out = np.zeros((nb * n1, nf * n2), np.float32)
        by_j: Dict[int, List[TilePlan]] = {}
        for tp in self._block_order(lp):
            by_j.setdefault(tp.out_j, []).append(tp)
        order = [j for j in rl["shard_order"] if j in by_j]
        srcs = rl["sources"]
        init = (jnp.full((n1, n2), -3.4e38, jnp.float32) if op == "max" else
                jnp.full((n1, n2), 3.4e38, jnp.float32) if op == "min" else
                jnp.zeros((n1, n2), jnp.float32))

        def build(j):
            arrs = {}
            for k in srcs.get(str(j), []):
                arrs[f"h{k}"] = h_in[k * n1:(k + 1) * n1]
            for k in range(nb):
                for s, tile in enumerate(pg.tiles.get((j, k), [])):
                    arrs[f"c{k}:{s}"] = tile.cols
                    arrs[f"v{k}:{s}"] = tile.vals
                    arrs[f"m{k}:{s}"] = tile.edge_pos >= 0
                    if ew is not None:
                        arrs[f"e{k}:{s}"] = ew[np.maximum(tile.edge_pos,
                                                          0)]
            if op == "mean":
                arrs["deg"] = np.asarray(
                    pg.inv_in_degree[j * n1:(j + 1) * n1])
            return arrs

        def compute(j, staged):
            pending = []
            for tp in by_j[j]:
                i = tp.out_i
                acc = init
                flag = jnp.zeros((n1,), bool)
                for ins in tp.compute:       # SPDMM steps, stream order
                    k, ii = ins.args[1], ins.args[2]
                    s, dyn = ins.args[3] >> 1, ins.args[3] & 1
                    h_tile = jax.lax.dynamic_slice(
                        staged[f"h{k}"], (0, ii * n2), (n1, n2))
                    cols, v, mask = (staged[f"c{k}:{s}"],
                                     staged[f"v{k}:{s}"],
                                     staged[f"m{k}:{s}"])
                    if dyn:
                        v = jnp.where(mask, staged[f"e{k}:{s}"], 0.0)
                    acc, flag = self.ack.spdmm(h_tile, cols, v, mask,
                                               acc, flag, op)
                    self.stats.tile_ops += 1
                if op in ("max", "min"):
                    acc = jnp.where(flag[:, None], acc, 0.0)
                elif op == "mean":
                    acc = acc * staged["deg"][:, None]
                acc = self._epilogue(tp, meta, acc, weights,
                                     i * n2, (i + 1) * n2)

                def write(a, i=i, j=j):
                    out[j * n1:(j + 1) * n1, i * n2:(i + 1) * n2] = a
                pending.append((write, acc))
            return pending

        self._stream_shards(order, build, compute)
        return out

    # ------------------------------------------------------------------ #
    def _host_linear(self, lp, meta, pg, h_in, weights, rl) -> np.ndarray:
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        fi_pad = ((max(lp.f_in, 1) + n2 - 1) // n2) * n2
        fo_pad = ((max(lp.f_out, 1) + n2 - 1) // n2) * n2
        W = np.zeros((fi_pad, fo_pad), np.float32)
        W0 = np.asarray(weights[meta["W"]], np.float32)
        W[: W0.shape[0], : W0.shape[1]] = W0
        Wj = jnp.asarray(W)
        b = None
        if "b" in meta:
            b0 = np.asarray(weights[meta["b"]], np.float32)
            b = jnp.asarray(np.pad(b0, (0, fo_pad - b0.shape[0])))
        out = np.zeros((nb * n1, fo_pad), np.float32)
        by_j: Dict[int, List[TilePlan]] = {}
        for tp in self._block_order(lp):
            by_j.setdefault(tp.out_j, []).append(tp)
        order = [j for j in rl["shard_order"] if j in by_j]

        def build(j):
            return {"h": h_in[j * n1:(j + 1) * n1]}

        def compute(j, staged):
            pending = []
            for tp in by_j[j]:
                i = tp.out_i
                acc = jnp.zeros((n1, n2), jnp.float32)
                for ins in tp.compute:       # GEMM steps: args=(j, k, i)
                    k = ins.args[1]
                    h_tile = jax.lax.dynamic_slice(
                        staged["h"], (0, k * n2), (n1, n2))
                    w_tile = jax.lax.dynamic_slice(
                        Wj, (k * n2, i * n2), (n2, n2))
                    acc = self.ack.gemm(h_tile, w_tile, acc)
                    self.stats.tile_ops += 1
                if b is not None:
                    acc = acc + jax.lax.dynamic_slice(b, (i * n2,), (n2,))
                acc = self._epilogue(tp, meta, acc, weights,
                                     i * n2, (i + 1) * n2)

                def write(a, i=i, j=j):
                    out[j * n1:(j + 1) * n1, i * n2:(i + 1) * n2] = a
                pending.append((write, acc))
            return pending

        self._stream_shards(order, build, compute)
        return out

    # ------------------------------------------------------------------ #
    def _host_vadd(self, lp, meta, pg, xa, xb, weights, rl) -> np.ndarray:
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        alpha, beta = meta["alpha"], meta["beta"]
        fi_pad = max(xa.shape[1], xb.shape[1])
        out = np.zeros((nb * n1, fi_pad), np.float32)
        by_j: Dict[int, List[TilePlan]] = {}
        for tp in self._block_order(lp):
            by_j.setdefault(tp.out_j, []).append(tp)
        order = [j for j in rl["shard_order"] if j in by_j]

        def build(j):
            return {"a": xa[j * n1:(j + 1) * n1],
                    "b": xb[j * n1:(j + 1) * n1]}

        def compute(j, staged):
            pending = []
            for tp in by_j[j]:
                i = tp.out_i
                ta = jax.lax.dynamic_slice(staged["a"], (0, i * n2),
                                           (n1, n2))
                tc = jax.lax.dynamic_slice(staged["b"], (0, i * n2),
                                           (n1, n2))
                v = self.ack.vadd(ta, tc, alpha, beta)
                self.stats.tile_ops += 1
                v = self._epilogue(tp, meta, v, weights,
                                   i * n2, (i + 1) * n2)

                def write(a, i=i, j=j):
                    out[j * n1:(j + 1) * n1, i * n2:(i + 1) * n2] = a
                pending.append((write, v))
            return pending

        self._stream_shards(order, build, compute)
        return out

    # ------------------------------------------------------------------ #
    def _host_vertex_act(self, lp, meta, pg, h_in, weights,
                         rl) -> np.ndarray:
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        fi_pad = ((max(lp.f_in, 1) + n2 - 1) // n2) * n2
        out = np.zeros((nb * n1, fi_pad), np.float32)
        by_j: Dict[int, List[TilePlan]] = {}
        for tp in self._block_order(lp):
            by_j.setdefault(tp.out_j, []).append(tp)
        order = [j for j in rl["shard_order"] if j in by_j]
        if lp.layer_type == LayerType.BATCHNORM:
            mu, sig, gam, bet = (
                np.asarray(weights[meta[k]], np.float32)
                for k in ("mu", "sigma", "gamma", "beta"))
            eps = float(meta.get("eps", 1e-5))
            sc = gam / np.sqrt(sig ** 2 + eps)
            sh = bet - mu * sc
            sc = np.pad(sc, (0, fi_pad - sc.shape[0]))
            sh = np.pad(sh, (0, fi_pad - sh.shape[0]))

        def build(j):
            return {"h": h_in[j * n1:(j + 1) * n1]}

        def compute(j, staged):
            pending = []
            for tp in by_j[j]:
                i = tp.out_i
                v = jax.lax.dynamic_slice(staged["h"], (0, i * n2),
                                          (n1, n2))
                op = tp.compute[0]           # the ACT / AFFINE instr
                if lp.layer_type == LayerType.BATCHNORM:
                    v = self.ack.affine(
                        v, jnp.asarray(sc[i * n2:(i + 1) * n2]),
                        jnp.asarray(sh[i * n2:(i + 1) * n2]))
                else:
                    v = self.ack.act(v, Activation(op.act))
                self.stats.tile_ops += 1

                def write(a, i=i, j=j):
                    out[j * n1:(j + 1) * n1, i * n2:(i + 1) * n2] = a
                pending.append((write, v))
            return pending

        self._stream_shards(order, build, compute)
        return out

    # ------------------------------------------------------------------ #
    def _host_vector_inner(self, lp, meta, pg, h_in, weights,
                           rl) -> np.ndarray:
        n1, n2 = pg.config.n1, pg.config.n2
        pair = lp.mode == 1
        ew_out = np.zeros((pg.n_edges + 1,), np.float32)
        by_j: Dict[int, List[TilePlan]] = {}
        for tp in self._block_order(lp):
            by_j.setdefault(tp.out_j, []).append(tp)
        order = [j for j in rl["shard_order"] if j in by_j]
        srcs = rl["sources"]

        def build(j):
            arrs = {}
            for k in srcs.get(str(j), []):
                arrs[f"h{k}"] = h_in[k * n1:(k + 1) * n1]
            for tp in by_j[j]:
                tile = pg.tiles[(j, tp.tile_k)][tp.slice_id]
                arrs[f"c{tp.tile_k}:{tp.slice_id}"] = tile.cols
                arrs[f"m{tp.tile_k}:{tp.slice_id}"] = tile.edge_pos >= 0
            return arrs

        def compute(j, staged):
            pending = []
            for tp in by_j[j]:
                k, s = tp.tile_k, tp.slice_id
                cols = staged[f"c{k}:{s}"]
                mask = staged[f"m{k}:{s}"]
                acc = jnp.zeros(cols.shape, jnp.float32)
                for ins in tp.compute:     # SDDMM steps: args=(j,k,i,s)
                    i = ins.args[2]
                    h_dst = jax.lax.dynamic_slice(
                        staged[f"h{j}"], (0, i * n2), (n1, n2))
                    h_src = jax.lax.dynamic_slice(
                        staged[f"h{k}"], (0, i * n2), (n1, n2))
                    acc = self.ack.sddmm(h_dst, h_src, cols, mask, acc,
                                         pair_sum=pair)
                    self.stats.tile_ops += 1
                acc = self._epilogue(tp, meta, acc, weights, 0, n2)
                tile = pg.tiles[(j, k)][s]

                def write(a, tile=tile):
                    mask_np = tile.edge_pos >= 0
                    idx = np.where(mask_np, tile.edge_pos, pg.n_edges)
                    ew_out[idx.ravel()] = a.ravel()
                pending.append((write, acc))
            return pending

        self._stream_shards(order, build, compute)
        return ew_out[: pg.n_edges]

    # ------------------------------------------------------------------ #
    def _host_edge_act(self, lp, pg, ew_in) -> np.ndarray:
        """Edge activations on a host-resident (E,) score vector; the
        softmax two-pass scheme stages each destination row's gathered
        per-tile scores and runs the SAME jnp ops as the device path."""
        act = Activation(lp.mode)
        if act != Activation.EDGE_SOFTMAX:
            out = np.asarray(apply_activation(jnp.asarray(ew_in), act))
            self.stats.tile_ops += len(lp.tiles)
            return out
        n1 = pg.config.n1
        nb = pg.n_blocks
        ew_out = np.zeros((pg.n_edges + 1,), np.float32)
        for j in range(nb):
            row_tiles = [(k, s) for (jj, k), ts in sorted(pg.tiles.items())
                         if jj == j for s in range(len(ts))]
            if not row_tiles:
                continue
            arrs = {}
            for k, s in row_tiles:
                tile = pg.tiles[(j, k)][s]
                arrs[f"s{k}:{s}"] = ew_in[np.maximum(tile.edge_pos, 0)]
                arrs[f"m{k}:{s}"] = tile.edge_pos >= 0
            staged, nbytes = self._stage(arrs)
            self.stats.peak_stage_bytes = max(
                self.stats.peak_stage_bytes, nbytes)
            if (self.resident_budget_bytes is not None
                    and nbytes + self._static_bytes
                    > self.resident_budget_bytes):
                raise ResidentBudgetError(
                    f"edge-softmax row working set ({nbytes} bytes + "
                    f"{self._static_bytes} resident weights) exceeds "
                    f"resident_budget_bytes={self.resident_budget_bytes}"
                    f"; recompile with a smaller n1 / width_cap")
            mx = jnp.full((n1,), -3.4e38, jnp.float32)
            for k, s in row_tiles:
                sc = jnp.where(staged[f"m{k}:{s}"], staged[f"s{k}:{s}"],
                               -3.4e38)
                mx = jnp.maximum(mx, jnp.max(sc, axis=1))
            mx = jnp.where(mx <= -3.4e38, 0.0, mx)
            den = jnp.zeros((n1,), jnp.float32)
            exps = []
            for k, s in row_tiles:
                e = jnp.where(staged[f"m{k}:{s}"],
                              jnp.exp(staged[f"s{k}:{s}"] - mx[:, None]),
                              0.0)
                den = den + jnp.sum(e, axis=1)
                exps.append((k, s, e))
                self.stats.tile_ops += 1
            den = jnp.maximum(den, 1e-12)
            for k, s, e in exps:
                out_t = e / den[:, None]
                tile = pg.tiles[(j, k)][s]
                mask_np = tile.edge_pos >= 0
                idx = np.where(mask_np, tile.edge_pos, pg.n_edges)
                masked = jnp.where(staged[f"m{k}:{s}"], out_t, 0.0)
                ew_out[idx.ravel()] = np.asarray(masked).ravel()
            self.stats.shards_streamed += 1
        return ew_out[: pg.n_edges]

    # ------------------------------------------------------------------ #
    def _epilogue(self, tp: TilePlan, meta: dict, tile: jnp.ndarray,
                  weights, lo: int, hi: int) -> jnp.ndarray:
        """Fused scale/shift + activation, in decoded instruction order."""
        for kind, act_id in tp.epilogue:
            if kind == "affine":
                sc = jnp.asarray(np.asarray(
                    weights[meta["fused_scale"]], np.float32))
                sh = jnp.asarray(np.asarray(
                    weights[meta["fused_shift"]], np.float32))
                sc = jnp.pad(sc, (0, max(0, hi - sc.shape[0])))[lo:hi]
                sh = jnp.pad(sh, (0, max(0, hi - sh.shape[0])))[lo:hi]
                tile = self.ack.affine(tile, sc, sh)
            else:
                tile = self.ack.act(tile, Activation(act_id))
        return tile

    def _assemble(self, tiles: Dict[Tuple[int, int], jnp.ndarray], nb: int,
                  nf: int) -> jnp.ndarray:
        rows = []
        for j in range(nb):
            rows.append(jnp.concatenate([tiles[(i, j)] for i in range(nf)],
                                        axis=1))
        return jnp.concatenate(rows, axis=0)

    def _block_order(self, lp: LayerPlan) -> List[TilePlan]:
        """PE-interleaved issue order (round-robin across PE streams)."""
        streams: Dict[int, List[TilePlan]] = {}
        for tp in lp.tiles:
            streams.setdefault(tp.pe, []).append(tp)
        order: List[TilePlan] = []
        idx = 0
        keys = sorted(streams)
        while any(streams[k] for k in keys):
            k = keys[idx % len(keys)]
            if streams[k]:
                order.append(streams[k].pop(0))
            idx += 1
        return order

    # ------------------------------------------------------------------ #
    def _run_aggregate(self, lp, meta, pg, h_in, edge_vals, inv_deg,
                       weights, gtiles=None) -> jnp.ndarray:
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        nf = ((max(lp.f_in, 1) + n2 - 1) // n2)
        op = {AggOp.SUM: "sum", AggOp.MEAN: "mean",
              AggOp.MAX: "max", AggOp.MIN: "min"}[AggOp(lp.mode)]
        ewl = meta.get("edge_weight_layer")
        ew = edge_vals[ewl] if ewl is not None else None
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        init = (jnp.full((n1, n2), -3.4e38, jnp.float32) if op == "max" else
                jnp.full((n1, n2), 3.4e38, jnp.float32) if op == "min" else
                jnp.zeros((n1, n2), jnp.float32))
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            acc = init
            flag = jnp.zeros((n1,), bool)
            for ins in tp.compute:           # SPDMM steps, stream order
                jj, k, ii = ins.args[0], ins.args[1], ins.args[2]
                s, dyn = ins.args[3] >> 1, ins.args[3] & 1
                h_tile = jax.lax.dynamic_slice(
                    h_in, (k * n1, ii * n2), (n1, n2))
                cols, v, mask, epos = _tile_arrays(pg, gtiles, jj, k, s)
                if dyn:
                    v = jnp.where(mask, ew[jnp.maximum(epos, 0)], 0.0)
                acc, flag = self.ack.spdmm(h_tile, cols, v, mask, acc,
                                           flag, op)
                self.stats.tile_ops += 1
            if op in ("max", "min"):
                acc = jnp.where(flag[:, None], acc, 0.0)
            elif op == "mean":
                scale = jax.lax.dynamic_slice(inv_deg, (j * n1,), (n1,))
                acc = acc * scale[:, None]
            acc = self._epilogue(tp, meta, acc, weights,
                                 i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = acc
            if not self.overlap:
                jax.block_until_ready(acc)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_linear(self, lp, meta, pg, h_in, weights):
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        fi_pad = ((max(lp.f_in, 1) + n2 - 1) // n2) * n2
        fo_pad = ((max(lp.f_out, 1) + n2 - 1) // n2) * n2
        W = np.zeros((fi_pad, fo_pad), np.float32)
        W0 = np.asarray(weights[meta["W"]], np.float32)
        W[: W0.shape[0], : W0.shape[1]] = W0
        Wj = jnp.asarray(W)
        b = None
        if "b" in meta:
            b0 = np.asarray(weights[meta["b"]], np.float32)
            b = jnp.asarray(np.pad(b0, (0, fo_pad - b0.shape[0])))
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            acc = jnp.zeros((n1, n2), jnp.float32)
            for ins in tp.compute:           # GEMM steps: args=(j, k, i)
                k = ins.args[1]
                h_tile = jax.lax.dynamic_slice(
                    h_in, (j * n1, k * n2), (n1, n2))
                w_tile = jax.lax.dynamic_slice(
                    Wj, (k * n2, i * n2), (n2, n2))
                acc = self.ack.gemm(h_tile, w_tile, acc)
                self.stats.tile_ops += 1
            if b is not None:
                acc = acc + jax.lax.dynamic_slice(b, (i * n2,), (n2,))
            acc = self._epilogue(tp, meta, acc, weights,
                                 i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = acc
            if not self.overlap:
                jax.block_until_ready(acc)
        return self._assemble(out_tiles, nb, fo_pad // n2)

    # ------------------------------------------------------------------ #
    def _run_vector_inner(self, lp, meta, pg, h_in, weights, gtiles=None):
        n1, n2 = pg.config.n1, pg.config.n2
        pair = lp.mode == 1          # CSI mode bit — the binary decides
        ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
        for tp in self._block_order(lp):
            j, k, s = tp.out_j, tp.tile_k, tp.slice_id
            cols, _, mask, epos = _tile_arrays(pg, gtiles, j, k, s)
            acc = jnp.zeros(cols.shape, jnp.float32)
            for ins in tp.compute:           # SDDMM steps: args=(j,k,i,s)
                i = ins.args[2]
                h_dst = jax.lax.dynamic_slice(h_in, (j * n1, i * n2),
                                              (n1, n2))
                h_src = jax.lax.dynamic_slice(h_in, (k * n1, i * n2),
                                              (n1, n2))
                acc = self.ack.sddmm(h_dst, h_src, cols, mask, acc,
                                     pair_sum=pair)
                self.stats.tile_ops += 1
            acc = self._epilogue(tp, meta, acc, weights, 0, n2)
            idx = jnp.where(mask, epos, pg.n_edges)
            ew = ew.at[idx.ravel()].set(acc.ravel())
            if not self.overlap:
                jax.block_until_ready(ew)
        return ew[: pg.n_edges]

    # ------------------------------------------------------------------ #
    def _run_vadd(self, lp, meta, pg, xa, xb, weights):
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        alpha, beta = meta["alpha"], meta["beta"]
        fi_pad = max(xa.shape[1], xb.shape[1])
        nf = fi_pad // n2
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            ta = jax.lax.dynamic_slice(xa, (j * n1, i * n2), (n1, n2))
            tc = jax.lax.dynamic_slice(xb, (j * n1, i * n2), (n1, n2))
            t = self.ack.vadd(ta, tc, alpha, beta)
            self.stats.tile_ops += 1
            t = self._epilogue(tp, meta, t, weights, i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = t
            if not self.overlap:
                jax.block_until_ready(t)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_vertex_act(self, lp, meta, pg, h_in, weights):
        n1, n2, nb = pg.config.n1, pg.config.n2, pg.n_blocks
        fi_pad = ((max(lp.f_in, 1) + n2 - 1) // n2) * n2
        nf = fi_pad // n2
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        for tp in self._block_order(lp):
            i, j = tp.out_i, tp.out_j
            t = jax.lax.dynamic_slice(h_in, (j * n1, i * n2), (n1, n2))
            op = tp.compute[0]               # the ACT / AFFINE instr
            if lp.layer_type == LayerType.BATCHNORM:
                mu, sig, gam, bet = (
                    np.asarray(weights[meta[k]], np.float32)
                    for k in ("mu", "sigma", "gamma", "beta"))
                eps = float(meta.get("eps", 1e-5))
                sc = gam / np.sqrt(sig ** 2 + eps)
                sh = bet - mu * sc
                sc = np.pad(sc, (0, fi_pad - sc.shape[0]))
                sh = np.pad(sh, (0, fi_pad - sh.shape[0]))
                t = self.ack.affine(t, jnp.asarray(sc[i * n2:(i + 1) * n2]),
                                    jnp.asarray(sh[i * n2:(i + 1) * n2]))
            else:
                t = self.ack.act(t, Activation(op.act))
            self.stats.tile_ops += 1
            out_tiles[(i, j)] = t
            if not self.overlap:
                jax.block_until_ready(t)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_edge_act(self, lp, pg, ew_in, gtiles=None):
        """Edge activations; EDGE_SOFTMAX uses the two-pass tile scheme
        (max/sum accumulated per destination row across a shard's tiles,
        the Activation Unit's exp/divide applied per tile)."""
        act = Activation(lp.mode)
        if act != Activation.EDGE_SOFTMAX:
            out = apply_activation(ew_in, act)
            self.stats.tile_ops += len(lp.tiles)
            return out
        n1 = pg.config.n1
        nb = pg.n_blocks
        ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
        for j in range(nb):
            row_tiles = [(k, s) for (jj, k), ts in sorted(pg.tiles.items())
                         if jj == j for s in range(len(ts))]
            if not row_tiles:
                continue
            mx = jnp.full((n1,), -3.4e38, jnp.float32)
            for k, s in row_tiles:
                _, _, mask, epos = _tile_arrays(pg, gtiles, j, k, s)
                sc = jnp.where(mask, ew_in[jnp.maximum(epos, 0)], -3.4e38)
                mx = jnp.maximum(mx, jnp.max(sc, axis=1))
            mx = jnp.where(mx <= -3.4e38, 0.0, mx)
            den = jnp.zeros((n1,), jnp.float32)
            exps = []
            for k, s in row_tiles:
                _, _, mask, epos = _tile_arrays(pg, gtiles, j, k, s)
                e = jnp.where(mask, jnp.exp(ew_in[jnp.maximum(epos, 0)]
                                            - mx[:, None]), 0.0)
                den = den + jnp.sum(e, axis=1)
                exps.append((mask, epos, e))
                self.stats.tile_ops += 1
            den = jnp.maximum(den, 1e-12)
            for mask, epos, e in exps:
                out_t = e / den[:, None]
                idx = jnp.where(mask, epos, pg.n_edges)
                ew = ew.at[idx.ravel()].set(
                    jnp.where(mask, out_t, 0.0).ravel())
        return ew[: pg.n_edges]
