"""Order optimization (Alg. 5 / Theorems 1-2) and layer fusion (§6.4):
semantics preservation + complexity monotonicity, incl. property tests."""
import jax.numpy as jnp
import numpy as np
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import gnn_builders as B
from repro.core import graph as G
from repro.core import reference as R
from repro.core.ir import AggOp, LayerType
from repro.core.passes import fusion, order_opt


def _g(nv=80, ne=240, f=12, c=4, seed=0, degree="uniform"):
    g = G.random_graph(nv, ne, seed=seed, degree=degree).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


@pytest.mark.parametrize("name", list(B.BENCHMARKS))
def test_order_opt_preserves_semantics(name):
    g = _g()
    x = jnp.asarray(G.random_features(g, seed=1))
    m = B.build(name, g)
    y0 = R.run_reference(m, g, x)
    m2 = m.copy()
    rep = order_opt.run(m2)
    m2.validate()
    y1 = R.run_reference(m2, g, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-5)
    assert rep.complexity_after <= rep.complexity_before


@pytest.mark.parametrize("name", list(B.BENCHMARKS))
def test_fusion_preserves_semantics(name):
    g = _g(seed=3)
    x = jnp.asarray(G.random_features(g, seed=2))
    m = B.build(name, g)
    y0 = R.run_reference(m, g, x)
    m2 = m.copy()
    rep = fusion.run(m2)
    m2.validate()
    y1 = R.run_reference(m2, g, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-5)
    assert rep.layers_after <= rep.layers_before
    # No standalone activations next to fusable producers should remain.
    for l in m2.layers.values():
        if l.layer_type == LayerType.ACTIVATION and len(l.parent_ids) == 1:
            p = m2.layers[l.parent_ids[0]]
            if (p.layer_type in (LayerType.LINEAR, LayerType.AGGREGATE)
                    and len(p.child_ids) == 1):
                assert "fused_act" in p.attrs or p.attrs.get("fused_scale")


def test_order_opt_direction_theorem2():
    """f1 > f2 => Linear moves before Aggregate (b1: 1433->16 analogue)."""
    g = _g(f=64, c=4)
    m = B.build("b1", g)  # hidden 16 < f_in 64
    m2 = m.copy()
    order_opt.run(m2)
    first = m2.layers[m2.topo_order()[0]]
    assert first.layer_type == LayerType.LINEAR


def test_order_opt_skips_nonlinear_agg():
    g = _g()
    m = B.build("b1", g)
    for l in m.layers.values():
        if l.layer_type == LayerType.AGGREGATE:
            l.agg_op = AggOp.MAX
    m2 = m.copy()
    rep = order_opt.run(m2)
    assert rep.exchanges == []


def test_sgc_pushes_linear_through_all_aggregates():
    g = _g(f=64, c=4)
    m = B.build("b7", g)
    m2 = m.copy()
    rep = order_opt.run(m2)
    assert len(rep.exchanges) == 2  # through both aggregates
    assert m2.layers[m2.topo_order()[0]].layer_type == LayerType.LINEAR


def test_graphgym_has_no_exchange():
    """Paper: b8's pre-MLP equalizes dims -> 0% effect of order-opt."""
    g = _g()
    m = B.build("b8", g)
    rep = order_opt.run(m.copy() if False else m)
    assert rep.exchanges == []


@settings(max_examples=20, deadline=None)
@given(
    nv=st.integers(20, 100),
    ne=st.integers(20, 400),
    f=st.sampled_from([4, 8, 24]),
    hidden=st.sampled_from([4, 16, 48]),
    seed=st.integers(0, 5),
)
def test_property_passes_preserve_gcn(nv, ne, f, hidden, seed):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, 3
    x = jnp.asarray(G.random_features(g, seed=seed + 1))
    m = B.build_gcn(g, hidden, 2, seed=seed)
    y0 = R.run_reference(m, g, x)
    m2 = m.copy()
    order_opt.run(m2)
    fusion.run(m2)
    m2.validate()
    y1 = R.run_reference(m2, g, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=3e-4, atol=3e-5)
