"""Quickstart: compile and run a GCN with the GraphAGILE Engine.

  PYTHONPATH=src python examples/quickstart.py
  (or `pip install -e .` once and drop the PYTHONPATH)

Builds a Cora-like synthetic graph, compiles a 2-layer GCN through the
full pipeline (order optimization -> fusion -> fiber-shard partitioning
-> kernel mapping/scheduling -> 128-bit binary), executes it **by
decoding that binary** on the Adaptive Computation Kernel, verifies
against the pure-jnp reference, then demonstrates the overlay contract:
the ``.gagi`` bundle saved here can be loaded by a *fresh* engine in a
later session and served with zero recompilation.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core import gnn_builders as B  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.core import reference as R  # noqa: E402
from repro.core.perfmodel import predict_loh  # noqa: E402
from repro.engine import Engine  # noqa: E402
from repro.obs import build_report  # noqa: E402


def main() -> None:
    # Cora statistics, synthesized (offline container).
    g = G.synthesize("CO").gcn_normalized()
    x = jnp.asarray(G.random_features(g, seed=1))
    print(f"graph: |V|={g.n_vertices} |E|={g.n_edges} f={g.feat_dim}")

    model = B.build_gcn(g, hidden=16, n_layers=2)   # the paper's b1
    print("IR:", model.dump())

    engine = Engine()                               # the overlay
    prog = engine.compile(model, g)
    cr = prog.source                                # pass reports
    print(f"\ncompiled in {prog.t_loc * 1e3:.1f} ms "
          f"(the paper's T_LoC; hours for regenerate-the-bitstream flows)")
    print(f"order opt: {len(cr.order_report.exchanges)} exchanges, "
          f"complexity -{cr.order_report.reduction:.1%}")
    print(f"fusion: {cr.fusion_report.layers_before} -> "
          f"{cr.fusion_report.layers_after} layers")
    print(f"binary: {len(prog.binary)} bytes "
          f"({prog.instruction_count()} instructions x 128 bit)")
    print(f"predicted T_LoH on TPU v5e: {predict_loh(cr.program)*1e3:.3f} ms")

    y = engine.run(prog, x)                         # decodes the binary
    y_ref = R.run_reference(model, g, x)
    err = float(jnp.max(jnp.abs(y - y_ref)))
    print(f"\noverlay output {y.shape}, max |err| vs reference: {err:.2e}")
    assert err < 1e-4

    # Cost-model conformance: join the analytic per-layer predictions
    # with the wall time the executor just measured for this run.
    rep = build_report(prog, engine.exec_stats, residency="device")
    print(f"T_LoH predicted {rep.predicted_s * 1e3:.3f} ms vs measured "
          f"{rep.measured_s * 1e3:.3f} ms "
          f"(model error {rep.model_error_overall:.2f} -> "
          f"{rep.model_error_overall_calibrated:.2f} after calibrating "
          f"effective machine constants)")

    # The overlay contract on disk: binary + weights/graph manifest.
    path = os.path.join(os.path.dirname(__file__), "gcn_cora.gagi")
    prog.save(path)
    fresh = Engine()                                # a later session
    y2 = fresh.run(fresh.load(path), x)
    assert bool(jnp.array_equal(y, y2))
    print(f"saved {os.path.getsize(path)} B to {os.path.basename(path)}; "
          f"a fresh engine replayed it bit-identically (T_LoC = 0)")
    os.remove(path)
    print("OK")


if __name__ == "__main__":
    main()
