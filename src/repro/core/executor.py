"""Overlay executor — runs a compiled Program on the ACK (paper Alg. 9).

Execution is layer by layer.  Within a layer, tiling blocks run in the
PE-interleaved order the scheduler produced; with ``overlap=True`` all tile
ops are dispatched asynchronously and synchronized once per layer (the
double/triple-buffering analogue — XLA overlaps transfers and compute);
with ``overlap=False`` every tiling block is forced to completion before
the next starts (Fig. 16 ablation baseline).

All vertex-valued intermediates live padded to (n_blocks*N1, ceil(f/N2)*N2)
— the fiber-shard layout — so layer outputs feed the next layer with no
repartitioning (paper §6.5).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .ack import ACK
from .ir import Activation, AggOp, LayerIR, LayerType, ModelIR
from .passes.kernel_map import Program, TilingBlock
from .reference import apply_activation


@dataclasses.dataclass
class ExecStats:
    tile_ops: int = 0
    layers: int = 0


class OverlayExecutor:
    def __init__(self, backend: str = "xla", overlap: bool = True,
                 interpret: bool = True) -> None:
        self.ack = ACK(backend=backend, interpret=interpret)
        self.overlap = overlap
        self.stats = ExecStats()

    # ------------------------------------------------------------------ #
    def run(self, prog: Program, x: jnp.ndarray,
            weights: Optional[Dict[str, np.ndarray]] = None) -> jnp.ndarray:
        m, pg = prog.model, prog.pgraph
        weights = weights if weights is not None else m.weights
        cfg = pg.config
        n1, n2, nb = cfg.n1, cfg.n2, pg.n_blocks
        vp = nb * n1
        ne = pg.n_edges

        def pad_vertex(a: jnp.ndarray, f_pad: int) -> jnp.ndarray:
            a = jnp.asarray(a, jnp.float32)
            return jnp.pad(a, ((0, vp - a.shape[0]),
                               (0, f_pad - a.shape[1])))

        fin_pad0 = prog.f_pad[m.topo_order()[0]][0]
        x_pad = pad_vertex(x, max(fin_pad0,
                                  ((x.shape[1] + n2 - 1) // n2) * n2))
        vals: Dict[int, jnp.ndarray] = {}      # layer -> padded output
        edge_vals: Dict[int, jnp.ndarray] = {}  # layer -> (E,) edge scores

        inv_deg = jnp.asarray(pg.inv_in_degree)

        for lb in prog.layer_blocks:
            l = lb.layer
            self.stats.layers += 1
            fi_pad, fo_pad = prog.f_pad[l.layer_id]
            feat_parents = [p for p in l.parent_ids
                            if p != l.attrs.get("edge_weight_layer")]
            # Vertex-valued input (edge-valued parents resolve per-branch).
            h_in = (vals.get(feat_parents[0], x_pad) if feat_parents
                    else x_pad)

            if l.layer_type == LayerType.AGGREGATE:
                out = self._run_aggregate(lb, pg, h_in, edge_vals, inv_deg,
                                          weights, fi_pad)
                vals[l.layer_id] = out
            elif l.layer_type == LayerType.LINEAR:
                out = self._run_linear(lb, pg, h_in, weights, fi_pad, fo_pad)
                vals[l.layer_id] = out
            elif l.layer_type == LayerType.VECTOR_INNER:
                edge_vals[l.layer_id] = self._run_vector_inner(
                    lb, pg, h_in, weights, fi_pad)
            elif l.layer_type == LayerType.VECTOR_ADD:
                a_id, b_id = l.attrs["operands"]
                xa = x_pad if a_id == -1 else vals[a_id]
                xb = x_pad if b_id == -1 else vals[b_id]
                vals[l.layer_id] = self._run_vadd(lb, pg, xa, xb, weights)
            elif l.layer_type in (LayerType.ACTIVATION, LayerType.BATCHNORM):
                if l.attrs.get("on_edges"):
                    src = edge_vals[feat_parents[0]]
                    edge_vals[l.layer_id] = self._run_edge_act(lb, pg, src)
                else:
                    vals[l.layer_id] = self._run_vertex_act(
                        lb, pg, h_in, weights, fi_pad)
            else:
                raise ValueError(l.layer_type)
            if not self.overlap:
                tree = vals.get(l.layer_id, edge_vals.get(l.layer_id))
                jax.block_until_ready(tree)

        sinks = [i for i, l in m.layers.items() if not l.child_ids]
        out_l = m.layers[sinks[-1]]
        y = vals[out_l.layer_id]
        nv = pg.n_vertices
        return y[:nv, :out_l.f_out]

    # ------------------------------------------------------------------ #
    def _epilogue(self, l: LayerIR, tile: jnp.ndarray, weights, lo: int,
                  hi: int) -> jnp.ndarray:
        """Fused scale/shift + activation on a feature tile (cols lo:hi)."""
        if "fused_scale" in l.attrs:
            sc = jnp.asarray(np.asarray(
                weights[l.attrs["fused_scale"]], np.float32))
            sh = jnp.asarray(np.asarray(
                weights[l.attrs["fused_shift"]], np.float32))
            sc = jnp.pad(sc, (0, max(0, hi - sc.shape[0])))[lo:hi]
            sh = jnp.pad(sh, (0, max(0, hi - sh.shape[0])))[lo:hi]
            tile = self.ack.affine(tile, sc, sh)
        if "fused_act" in l.attrs:
            tile = self.ack.act(tile, Activation(l.attrs["fused_act"]))
        return tile

    def _assemble(self, tiles: Dict[Tuple[int, int], jnp.ndarray], nb: int,
                  nf: int) -> jnp.ndarray:
        rows = []
        for j in range(nb):
            rows.append(jnp.concatenate([tiles[(i, j)] for i in range(nf)],
                                        axis=1))
        return jnp.concatenate(rows, axis=0)

    def _block_order(self, lb) -> List[TilingBlock]:
        """PE-interleaved issue order (round-robin across PE streams)."""
        streams: Dict[int, List[TilingBlock]] = {}
        for tb in lb.tiling_blocks:
            streams.setdefault(tb.pe, []).append(tb)
        order: List[TilingBlock] = []
        idx = 0
        keys = sorted(streams)
        while any(streams[k] for k in keys):
            k = keys[idx % len(keys)]
            if streams[k]:
                order.append(streams[k].pop(0))
            idx += 1
        return order

    # ------------------------------------------------------------------ #
    def _run_aggregate(self, lb, pg, h_in, edge_vals, inv_deg, weights,
                       fi_pad) -> jnp.ndarray:
        l = lb.layer
        cfg = pg.config
        n1, n2, nb = cfg.n1, cfg.n2, pg.n_blocks
        nf = fi_pad // n2
        op = {AggOp.SUM: "sum", AggOp.MEAN: "mean",
              AggOp.MAX: "max", AggOp.MIN: "min"}[l.agg_op]
        ewl = l.attrs.get("edge_weight_layer")
        ew = edge_vals[ewl] if ewl is not None else None
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        init = (jnp.full((n1, n2), -3.4e38, jnp.float32) if op == "max" else
                jnp.full((n1, n2), 3.4e38, jnp.float32) if op == "min" else
                jnp.zeros((n1, n2), jnp.float32))
        for tb in self._block_order(lb):
            i, j = tb.out_i, tb.out_j
            acc = init
            flag = jnp.zeros((n1,), bool)
            for (k, s) in tb.k_list:
                t = pg.tiles[(j, k)][s]
                h_tile = jax.lax.dynamic_slice(
                    h_in, (k * n1, i * n2), (n1, n2))
                cols = jnp.asarray(t.cols)
                mask = jnp.asarray(t.edge_pos >= 0)
                if ew is None:
                    v = jnp.asarray(t.vals)
                else:
                    epos = jnp.asarray(np.maximum(t.edge_pos, 0))
                    v = jnp.where(mask, ew[epos], 0.0)
                acc, flag = self.ack.spdmm(h_tile, cols, v, mask, acc,
                                           flag, op)
                self.stats.tile_ops += 1
            if op in ("max", "min"):
                acc = jnp.where(flag[:, None], acc, 0.0)
            elif op == "mean":
                scale = jax.lax.dynamic_slice(inv_deg, (j * n1,), (n1,))
                acc = acc * scale[:, None]
            acc = self._epilogue(l, acc, weights, i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = acc
            if not self.overlap:
                jax.block_until_ready(acc)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_linear(self, lb, pg, h_in, weights, fi_pad, fo_pad):
        l = lb.layer
        cfg = pg.config
        n1, n2, nb = cfg.n1, cfg.n2, pg.n_blocks
        W = np.zeros((fi_pad, fo_pad), np.float32)
        W0 = np.asarray(weights[l.attrs["W"]], np.float32)
        W[: W0.shape[0], : W0.shape[1]] = W0
        Wj = jnp.asarray(W)
        b = None
        if "b" in l.attrs:
            b0 = np.asarray(weights[l.attrs["b"]], np.float32)
            b = jnp.asarray(np.pad(b0, (0, fo_pad - b0.shape[0])))
        out_tiles: Dict[Tuple[int, int], jnp.ndarray] = {}
        for tb in self._block_order(lb):
            i, j = tb.out_i, tb.out_j
            acc = jnp.zeros((n1, n2), jnp.float32)
            for (k, _) in tb.k_list:
                h_tile = jax.lax.dynamic_slice(
                    h_in, (j * n1, k * n2), (n1, n2))
                w_tile = jax.lax.dynamic_slice(
                    Wj, (k * n2, i * n2), (n2, n2))
                acc = self.ack.gemm(h_tile, w_tile, acc)
                self.stats.tile_ops += 1
            if b is not None:
                acc = acc + jax.lax.dynamic_slice(b, (i * n2,), (n2,))
            acc = self._epilogue(l, acc, weights, i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = acc
            if not self.overlap:
                jax.block_until_ready(acc)
        return self._assemble(out_tiles, nb, fo_pad // n2)

    # ------------------------------------------------------------------ #
    def _run_vector_inner(self, lb, pg, h_in, weights, fi_pad):
        l = lb.layer
        cfg = pg.config
        n1, n2 = cfg.n1, cfg.n2
        nf = fi_pad // n2
        pair = l.attrs.get("mode") == "pair_sum"
        ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
        for tb in self._block_order(lb):
            j, k, s = tb.out_j, tb.tile_k, tb.slice_id
            t = pg.tiles[(j, k)][s]
            cols = jnp.asarray(t.cols)
            mask = jnp.asarray(t.edge_pos >= 0)
            acc = jnp.zeros(cols.shape, jnp.float32)
            n_fib = 1 if pair else nf
            for i in range(n_fib):
                h_dst = jax.lax.dynamic_slice(h_in, (j * n1, i * n2),
                                              (n1, n2))
                h_src = jax.lax.dynamic_slice(h_in, (k * n1, i * n2),
                                              (n1, n2))
                acc = self.ack.sddmm(h_dst, h_src, cols, mask, acc,
                                     pair_sum=pair)
                self.stats.tile_ops += 1
            acc = self._epilogue(l, acc, weights, 0, n2)
            epos = jnp.asarray(
                np.where(t.edge_pos >= 0, t.edge_pos, pg.n_edges))
            ew = ew.at[epos.ravel()].set(acc.ravel())
            if not self.overlap:
                jax.block_until_ready(ew)
        return ew[: pg.n_edges]

    # ------------------------------------------------------------------ #
    def _run_vadd(self, lb, pg, xa, xb, weights):
        l = lb.layer
        cfg = pg.config
        n1, n2, nb = cfg.n1, cfg.n2, pg.n_blocks
        alpha, beta = l.attrs["alpha"], l.attrs["beta"]
        fi_pad = max(xa.shape[1], xb.shape[1])
        nf = fi_pad // n2
        out_tiles = {}
        for tb in self._block_order(lb):
            i, j = tb.out_i, tb.out_j
            ta = jax.lax.dynamic_slice(xa, (j * n1, i * n2), (n1, n2))
            tc = jax.lax.dynamic_slice(xb, (j * n1, i * n2), (n1, n2))
            t = self.ack.vadd(ta, tc, alpha, beta)
            self.stats.tile_ops += 1
            t = self._epilogue(l, t, weights, i * n2, (i + 1) * n2)
            out_tiles[(i, j)] = t
            if not self.overlap:
                jax.block_until_ready(t)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_vertex_act(self, lb, pg, h_in, weights, fi_pad):
        l = lb.layer
        cfg = pg.config
        n1, n2, nb = cfg.n1, cfg.n2, pg.n_blocks
        nf = fi_pad // n2
        out_tiles = {}
        for tb in self._block_order(lb):
            i, j = tb.out_i, tb.out_j
            t = jax.lax.dynamic_slice(h_in, (j * n1, i * n2), (n1, n2))
            if l.layer_type == LayerType.BATCHNORM:
                mu, sig, gam, bet = (
                    np.asarray(weights[l.attrs[k]], np.float32)
                    for k in ("mu", "sigma", "gamma", "beta"))
                eps = float(l.attrs.get("eps", 1e-5))
                sc = gam / np.sqrt(sig ** 2 + eps)
                sh = bet - mu * sc
                sc = np.pad(sc, (0, fi_pad - sc.shape[0]))
                sh = np.pad(sh, (0, fi_pad - sh.shape[0]))
                t = self.ack.affine(t, jnp.asarray(sc[i * n2:(i + 1) * n2]),
                                    jnp.asarray(sh[i * n2:(i + 1) * n2]))
            else:
                t = self.ack.act(t, l.act)
            self.stats.tile_ops += 1
            out_tiles[(i, j)] = t
            if not self.overlap:
                jax.block_until_ready(t)
        return self._assemble(out_tiles, nb, nf)

    # ------------------------------------------------------------------ #
    def _run_edge_act(self, lb, pg, ew_in):
        """Edge activations; EDGE_SOFTMAX uses the two-pass tile scheme
        (max/sum accumulated per destination row across a shard's tiles,
        the Activation Unit's exp/divide applied per tile)."""
        l = lb.layer
        if l.act != Activation.EDGE_SOFTMAX:
            out = apply_activation(ew_in, l.act)
            self.stats.tile_ops += len(lb.tiling_blocks)
            return out
        n1 = pg.config.n1
        nb = pg.n_blocks
        ew = jnp.zeros((pg.n_edges + 1,), jnp.float32)
        for j in range(nb):
            row_tiles = [(k, s, t) for (jj, k), ts in sorted(pg.tiles.items())
                         if jj == j for s, t in enumerate(ts)]
            if not row_tiles:
                continue
            mx = jnp.full((n1,), -3.4e38, jnp.float32)
            for _, _, t in row_tiles:
                mask = jnp.asarray(t.edge_pos >= 0)
                epos = jnp.asarray(np.maximum(t.edge_pos, 0))
                sc = jnp.where(mask, ew_in[epos], -3.4e38)
                mx = jnp.maximum(mx, jnp.max(sc, axis=1))
            mx = jnp.where(mx <= -3.4e38, 0.0, mx)
            den = jnp.zeros((n1,), jnp.float32)
            exps = []
            for _, _, t in row_tiles:
                mask = jnp.asarray(t.edge_pos >= 0)
                epos = jnp.asarray(np.maximum(t.edge_pos, 0))
                e = jnp.where(mask, jnp.exp(ew_in[epos] - mx[:, None]), 0.0)
                den = den + jnp.sum(e, axis=1)
                exps.append((t, mask, e))
                self.stats.tile_ops += 1
            den = jnp.maximum(den, 1e-12)
            for t, mask, e in exps:
                out_t = e / den[:, None]
                idx = jnp.asarray(
                    np.where(t.edge_pos >= 0, t.edge_pos, pg.n_edges))
                ew = ew.at[idx.ravel()].set(
                    jnp.where(mask, out_t, 0.0).ravel())
        return ew[: pg.n_edges]
