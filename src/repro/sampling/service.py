"""SamplingService — per-user mini-batch inference over an OverlayPool.

Request lifecycle (the dominant real-world serving scenario)::

    TargetRequest(vertex_ids, model, fanouts)
        │ sample   k-hop ego network (seeded, fanout-capped) ── sampler.py
        │ norm     gcn / mean / none edge normalization on the subgraph
        │ bucket   pad to the power-of-two geometry bucket ──── buckets.py
        │          (template graph shared per bucket => one cache key)
        ▼
    InferenceRequest(model, template, gathered features, graph_data)
        │ batch    runtime Batcher coalesces same-bucket users
        │ overlay  cache-affinity routing; ONE binary pass per batch
        ▼
    InferenceResponse ── un-pad ──> TargetResponse(logits[T, C])

Steady-state traffic touches a handful of buckets, so the engines'
program caches converge to hit rate ~1 and every request is pure T_LoH.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.graph import Graph
from repro.engine import InferenceRequest, InferenceResponse
from repro.obs.tracer import get_tracer
from repro.runtime import Batch, OverlayPool, ServeLoop, request_cost

from .buckets import Bucket, bucket_for, layout_graph, template_graph
from .sampler import EgoNet, Fanout, sample_ego

_NORMS = ("gcn", "mean", "none")


@dataclasses.dataclass
class TargetRequest:
    """One user's question: label these vertices with this model."""

    targets: Sequence[int]                  # global vertex ids (unique)
    model: Any = "b1"                       # benchmark name or ModelIR
    fanouts: Sequence[Fanout] = (10, 5)     # per-hop caps; "full" = no cap
    request_id: Optional[str] = None
    seed: int = 0                           # sampling seed (deterministic)
    model_seed: int = 0                     # builder seed for named models


@dataclasses.dataclass
class TargetResponse:
    """Un-padded answer: one logit row per requested target."""

    request_id: str
    logits: np.ndarray                      # [T, n_classes]
    targets: np.ndarray                     # the global ids, request order
    bucket: str                             # geometry bucket key
    n_vertices: int                         # sampled ego-network size
    n_edges: int
    cache_hit: bool
    t_loc: float
    t_loh: float
    batch_size: int = 1
    overlay: Optional[int] = None


class SamplingService:
    """Wrap an :class:`~repro.runtime.OverlayPool` for per-user traffic.

    Holds the deployed graph (raw COO) + its feature matrix; turns every
    :class:`TargetRequest` into a bucketed graph-as-data
    :class:`~repro.engine.InferenceRequest` and routes it through the
    pool's batching serve loop.
    """

    def __init__(self, graph: Graph, features: np.ndarray,
                 pool: Optional[OverlayPool] = None, *, norm: str = "gcn",
                 n_overlays: int = 2, geometry=None,
                 max_batch: int = 8, max_wait_us: float = 2000.0,
                 max_queue: int = 256, **engine_kw) -> None:
        if norm not in _NORMS:
            raise ValueError(f"norm must be one of {_NORMS}, got {norm!r}")
        self.graph = graph
        self.features = np.asarray(features, np.float32)
        if self.features.shape[0] != graph.n_vertices:
            raise ValueError(
                f"features rows ({self.features.shape[0]}) != |V| "
                f"({graph.n_vertices})")
        self.norm = norm
        self.pool = pool if pool is not None else OverlayPool(
            n_overlays=n_overlays, geometry=geometry, **engine_kw)
        self.geometry = self.pool.engines[0].geometry
        if self.geometry is None:
            raise ValueError(
                "SamplingService needs a pool with an explicit tile "
                "geometry: the canonical bucket layout is defined by "
                "(n1, n2), so auto-chosen per-graph geometry would break "
                "the one-layout-per-bucket contract")
        self.loop = ServeLoop(self.pool, max_batch=max_batch,
                              max_wait_us=max_wait_us, max_queue=max_queue,
                              metrics=self.pool.metrics)
        self._templates: Dict[Bucket, Graph] = {}
        self.bucket_counts: Dict[str, int] = {}

    # ------------------------------------------------------------------ #
    def _normalize(self, sub: Graph) -> Graph:
        if self.norm == "gcn":
            return sub.gcn_normalized()
        if self.norm == "mean":
            return sub.mean_normalized()
        return sub

    def template_for(self, bucket: Bucket) -> Graph:
        """One shared template Graph object per bucket — its identity is
        what makes every user's cache key collide."""
        tpl = self._templates.get(bucket)
        if tpl is None:
            tpl = template_graph(bucket, self.geometry)
            self._templates[bucket] = tpl
        return tpl

    def prepare(self, req: TargetRequest, count: bool = True
                ) -> Tuple[InferenceRequest, EgoNet, Bucket]:
        """sample -> normalize -> bucket -> lay out; no execution.
        ``count=False`` keeps warmup traffic out of the bucket census."""
        tracer = get_tracer()
        with tracer.span("sample", cat="sample", track="sampling",
                         args={"targets": len(req.targets)}) as sp:
            ego = sample_ego(self.graph, req.targets, req.fanouts,
                             seed=req.seed)
            sub = self._normalize(ego.graph)
            sp.add(n_vertices=sub.n_vertices, n_edges=sub.n_edges)
        with tracer.span("layout", cat="sample", track="sampling") as sp:
            bucket = bucket_for(sub, self.geometry)
            gd = layout_graph(sub, bucket, self.geometry)
            sp.add(bucket=bucket.key)
        feats = np.zeros((bucket.n_vertices, self.graph.feat_dim),
                         np.float32)
        feats[: ego.vertices.shape[0]] = self.features[ego.vertices]
        if count:
            self.bucket_counts[bucket.key] = \
                self.bucket_counts.get(bucket.key, 0) + 1
        inf = InferenceRequest(
            model=req.model, graph=self.template_for(bucket),
            features=feats, request_id=req.request_id,
            seed=req.model_seed, graph_data=gd)
        return inf, ego, bucket

    def _unpad(self, resp: InferenceResponse, req: TargetRequest,
               ego: EgoNet, bucket: Bucket) -> TargetResponse:
        out = np.asarray(resp.output)
        return TargetResponse(
            request_id=resp.request_id,
            logits=out[ego.targets],        # targets are locals 0..T-1
            targets=ego.vertices[ego.targets],
            bucket=bucket.key,
            n_vertices=ego.graph.n_vertices,
            n_edges=ego.graph.n_edges,
            cache_hit=resp.cache_hit,
            t_loc=resp.t_loc, t_loh=resp.t_loh,
            batch_size=resp.batch_size, overlay=resp.overlay)

    def warm(self, requests: Sequence[TargetRequest]) -> int:
        """Pre-compile and pre-trace for the buckets ``requests`` touch.

        One representative request per bucket is executed at every
        power-of-two batch size up to ``max_batch``, so the program is
        compiled AND each batch-shaped jitted executable is traced —
        steady-state traffic then replays compiled code only, whatever
        ragged batch sizes deadline flushes produce.  Returns the number
        of buckets warmed.
        """
        reps: Dict[str, InferenceRequest] = {}
        for r in requests:
            inf, _, _ = self.prepare(r, count=False)
            # one representative per PROGRAM (model x bucket x seed),
            # not per bucket: two models sharing a bucket both warm
            reps.setdefault(self.pool.cache_key(inf), inf)
        sizes = []
        s = 1
        while s < self.loop.max_batch:
            sizes.append(s)
            s <<= 1
        sizes.append(self.loop.max_batch)
        for key, inf in reps.items():
            for n in sorted(set(sizes)):
                self.pool.submit_batch(Batch(
                    key=key, requests=[inf] * n, indices=list(range(n)),
                    created_at=0.0, cost=n * request_cost(inf)))
        return len(reps)

    # ------------------------------------------------------------------ #
    def serve(self, requests: Sequence[TargetRequest]
              ) -> List[TargetResponse]:
        """Batched drain of a per-user request stream (request order)."""
        prepared = [self.prepare(r) for r in requests]
        for i, (inf, _, _) in enumerate(prepared):
            if inf.request_id is None:
                inf.request_id = f"target{i}"
        # ServeLoop.serve returns responses in request (admission) order,
        # so the join is positional — duplicate request_ids stay safe.
        resps = self.loop.serve([p[0] for p in prepared])
        return [self._unpad(resp, req, ego, bucket)
                for resp, req, (inf, ego, bucket)
                in zip(resps, requests, prepared)]

    def submit(self, req: TargetRequest) -> TargetResponse:
        """Serve one request synchronously (no batching delay)."""
        return self.serve([req])[0]

    def shutdown(self) -> None:
        self.loop.shutdown()

    # ------------------------------------------------------------------ #
    @property
    def cache_hit_rate(self) -> float:
        return self.pool.cache_hit_rate

    def stats_snapshot(self) -> dict:
        snap = self.pool.stats_snapshot()
        snap["buckets"] = dict(self.bucket_counts)
        snap["distinct_buckets"] = len(self.bucket_counts)
        return snap
