"""Per-architecture smoke tests (deliverable f) + decode/forward
consistency + block-level equivalences (scan vs step forms)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.data import synthetic_batches
from repro.models import ssm as SSM
from repro.models import xlstm_blocks as XL
from repro.models.steps import (build_model, init_train_state, make_serve_step,
    make_train_step)
from repro.models.transformer import build_segments


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward/train step on CPU; shapes + no NaNs."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    b, t = 2, 16
    batch = {k: jnp.asarray(v) for k, v in
             next(synthetic_batches(cfg, b, t, seed=1)).items()}
    ts = jax.jit(make_train_step(model, cfg))
    p2, o2, m = ts(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    for leaf in jax.tree.leaves(p2):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b = 2
    cache = model.init_cache(b, 32)
    ss = jax.jit(make_serve_step(model, cfg))
    tok = jnp.zeros((b, 1), jnp.int32)
    nxt, cache2 = ss(params, cache, tok, jnp.int32(0))
    assert nxt.shape == (b, 1)
    assert int(nxt.min()) >= 0 and int(nxt.max()) < cfg.vocab


def _decode_matches_forward(arch, b=2, t=12, tol=2e-4):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, t)).astype(np.int32))
    if cfg.encoder_decoder:
        frames = jnp.asarray(
            rng.normal(0, 0.1, (b, 16, cfg.d_model)).astype(np.float32))
        logits_fwd, _ = model.forward(params, frames, toks)
        enc = model.encode(params, frames)
        src = enc
    elif cfg.cross_attn_every:
        src = jnp.asarray(rng.normal(
            0, 0.1, (b, cfg.n_vision_tokens, cfg.d_model)
        ).astype(np.float32))
        logits_fwd, _ = model.forward(params, toks, cross_kv_x=src)
    else:
        src = None
        logits_fwd, _ = model.forward(params, toks)
    cache = model.init_cache(b, t)
    if src is not None:
        dec = model.decoder if cfg.encoder_decoder else model
        dparams = params["decoder"] if cfg.encoder_decoder else params
        new_cache = []
        for (sb, rep), seg_p, seg_c in zip(dec.segments,
                                           dparams["segments"], cache):
            blocks = []
            for spec, bp, c in zip(sb, seg_p, seg_c):
                if spec.cross_attn:
                    def proj(pp):
                        k = jnp.einsum("bsd,dke->bske", src,
                                       pp["xattn"]["wk"])
                        v = jnp.einsum("bsd,dke->bske", src,
                                       pp["xattn"]["wv"])
                        return k, v
                    ks, vs = jax.vmap(proj)(bp)
                    c = dict(c, xk=ks.astype(c["xk"].dtype),
                             xv=vs.astype(c["xv"].dtype))
                blocks.append(c)
            new_cache.append(tuple(blocks))
        cache = new_cache
    outs = []
    for i in range(t):
        lg, cache = model.decode_step(params, cache, toks[:, i:i + 1],
                                      jnp.int32(i))
        outs.append(lg[:, 0])
    logits_dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(logits_dec - logits_fwd)))
    ref = float(jnp.max(jnp.abs(logits_fwd))) + 1e-9
    assert err / ref < tol, (arch, err, ref)


@pytest.mark.slow          # 10 archs x 12 positionwise decode steps
@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """KV caches / ring buffers / MLA absorption / SSM steps == the
    teacher-forced full forward, position by position."""
    _decode_matches_forward(arch)


def test_sliding_window_ring_buffer():
    """gemma3 local layers: decoding past the window with a ring cache
    must equal the windowed forward."""
    cfg = dataclasses.replace(get_smoke_config("gemma3-12b"),
                              dtype="float32", local_window=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    b, t = 1, 14
    toks = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab, (b, t))
        .astype(np.int32))
    logits_fwd, _ = model.forward(params, toks)
    cache = model.init_cache(b, t)  # local layers get window-size caches
    outs = []
    for i in range(t):
        lg, cache = model.decode_step(params, cache, toks[:, i:i + 1],
                                      jnp.int32(i))
        outs.append(lg[:, 0])
    err = float(jnp.max(jnp.abs(jnp.stack(outs, 1) - logits_fwd)))
    assert err / (float(jnp.max(jnp.abs(logits_fwd))) + 1e-9) < 2e-4


def test_ssm_chunked_equals_whole_and_step():
    rng = np.random.default_rng(0)
    B, T, D, H, P, N = 2, 12, 40, 5, 8, 4
    x = jnp.asarray(rng.normal(0, 0.5, (B, T, D)).astype(np.float32))
    p = SSM.ssm_init(jax.random.PRNGKey(1), D, H, P, N, jnp.float32)
    y1 = SSM.ssm_scan(p, x, N, chunk=4)
    y2 = SSM.ssm_scan(p, x, N, chunk=T)
    st = SSM.ssm_decode_init(B, H, P, N)
    outs = []
    for t in range(T):
        y, st = SSM.ssm_decode_step(p, x[:, t:t + 1], st, N)
        outs.append(y[:, 0])
    y3 = jnp.stack(outs, 1)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(y1, y3, atol=1e-5)


def test_mlstm_quadratic_equals_step():
    rng = np.random.default_rng(0)
    B, T, D, H = 2, 12, 40, 4
    x = jnp.asarray(rng.normal(0, 0.5, (B, T, D)).astype(np.float32))
    p = XL.mlstm_init(jax.random.PRNGKey(2), D, H, jnp.float32)
    ya = XL.mlstm_scan(p, x, chunk=4)
    st = XL.mlstm_decode_init(B, H, int(D * 2.0) // H)
    outs = []
    for t in range(T):
        y, st = XL.mlstm_decode_step(p, x[:, t:t + 1], st)
        outs.append(y[:, 0])
    np.testing.assert_allclose(ya, jnp.stack(outs, 1), atol=1e-5)


def test_segment_patterns():
    """Full configs produce the architecture-correct layer patterns."""
    segs = build_segments(get_config("gemma3-27b"))
    assert sum(len(sb) * rep for sb, rep in segs) == 62
    assert len(segs[1][0]) == 6            # 5 local + 1 global superblock
    assert segs[1][0][-1].window == 0      # global layer
    assert all(b.window > 0 for b in segs[1][0][:-1])

    segs = build_segments(get_config("deepseek-v3-671b"))
    assert segs[0][1] == 3 and segs[0][0][0].ffn == "dense"
    assert segs[1][0][0].ffn == "moe" and segs[1][0][0].attn == "mla"

    segs = build_segments(get_config("llama-3.2-vision-11b"))
    assert sum(len(sb) * rep for sb, rep in segs) == 40
    assert segs[0][0][-1].cross_attn and not segs[0][0][0].cross_attn

    segs = build_segments(get_config("xlstm-125m"))
    assert segs[0][0][0].attn == "mlstm" and segs[0][0][1].attn == "slstm"


def test_chunked_attention_exactness():
    """Query-chunked online softmax == dense attention."""
    from repro.models import attention as A
    rng = np.random.default_rng(3)
    d, h, kv, hd = 48, 4, 2, 12
    p = A.attn_init(jax.random.PRNGKey(5), d, h, kv, hd, jnp.float32)
    x = jnp.asarray(rng.normal(0, 0.5, (2, 16, d)).astype(np.float32))
    pos = jnp.arange(16, dtype=jnp.int32)
    y0 = A.attention(p, x, pos, chunk=0)
    y1 = A.attention(p, x, pos, chunk=4)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=2e-5)


def test_param_count_sanity():
    """n_params() tracks actual init sizes within 12% (report metric)."""
    for arch in ["granite-8b", "qwen3-0.6b", "xlstm-125m"]:
        cfg = get_config(arch)
        model = build_model(cfg)
        specs = model.param_specs()
        actual = sum(np.prod(l.shape) for l in jax.tree.leaves(specs))
        est = cfg.n_params()
        assert abs(actual - est) / actual < 0.12, (arch, actual, est)
