"""gemma3-12b [dense] 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global, 128k ctx [hf:google/gemma-3; unverified]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
        n_heads=16, n_kv_heads=8, d_ff=15360, vocab=262144,
        attn_pattern="local_global", local_window=1024,
        local_global_ratio=6, qk_norm=True, rope_theta=1000000.0,
        tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, local_window=8, attn_chunk=0, remat="none")
