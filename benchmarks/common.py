"""Shared benchmark plumbing: dataset roster, timing helpers, CSV.

All benchmarks drive the unified ``repro.engine.Engine`` API: programs
are compiled through ``engine.compile`` and executed by decoding their
128-bit ISA binaries (``engine.run``).  ``prog.source`` keeps the
in-process pass reports + object-graph Program for the analytic perf
model and the report columns.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time
from datetime import datetime, timezone
from typing import Dict, List, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import graph as G  # noqa: E402
from repro.core.perfmodel import predict_loh  # noqa: E402
from repro.engine import CompiledProgram, Engine  # noqa: E402

# dataset -> synthesis scale (big graphs scaled for CPU wall-time; always
# labeled in output).  PCIe model matches the paper's 31.5 GB/s.
DATASETS: List[Tuple[str, float]] = [
    ("CI", 1.0), ("CO", 1.0), ("PU", 1.0), ("FL", 0.125),
    ("RE", 1 / 256), ("YE", 1 / 64), ("AP", 1 / 512),
]
# the big four are costly on one CPU core; table7 runs them for this
# representative model subset only (all 8 models run on CI/CO/PU)
BIG_MODELS = ["b1", "b2", "b5"]
PCIE_BW = 31.5e9
MODELS = ["b1", "b2", "b3", "b4", "b5", "b6", "b7", "b8"]

_graph_cache: Dict[str, "G.Graph"] = {}


def dataset(name: str, scale: float) -> "G.Graph":
    key = f"{name}@{scale:g}"
    if key not in _graph_cache:
        g = G.synthesize(name, scale=scale, seed=0)
        _graph_cache[key] = g.gcn_normalized()
    return _graph_cache[key]


def features(g: "G.Graph") -> jnp.ndarray:
    return jnp.asarray(G.random_features(g, seed=1))


def run_model(bname: str, g: "G.Graph", x, engine: Engine,
              warm: int = 1, reps: int = 1, *, order_opt: bool = True,
              fusion: bool = True):
    """Returns (t_loc, t_loh, t_comm, prog, t_pred)."""
    prog: CompiledProgram = engine.compile(
        bname, g, order_opt=order_opt, fusion=fusion)
    if prog.source is None:
        # program-cache hit returned a slim copy; the benchmarks need the
        # pass reports + object-graph Program for the analytic perf model
        prog = engine.compile(bname, g, order_opt=order_opt,
                              fusion=fusion, use_cache=False)
    for _ in range(warm):
        jax.block_until_ready(engine.run(prog, x))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(engine.run(prog, x))
    t_loh = (time.perf_counter() - t0) / reps
    data_bytes = (g.n_edges * 12 + g.n_vertices * g.feat_dim * 4
                  + len(prog.binary)
                  + sum(np.asarray(w).nbytes for w in prog.weights.values()))
    t_comm = data_bytes / PCIE_BW
    t_pred = predict_loh(prog.source.program)
    return prog.t_loc, t_loh, t_comm, prog, t_pred


def verify_section(engine: Engine,
                   pairs: List[Tuple[str, "G.Graph"]]) -> Dict[str, object]:
    """Statically verify compiled programs and return the report's
    ``verify`` block.

    ``checks_passed``/``checks_failed`` are summed over programs and
    gated by the trajectory specs at zero width: the passed count may
    only grow (new checks, new programs) and the failed count must stay
    at zero — a verifier regression is a semantic break, not noise.
    """
    from repro.verify import verify_program
    programs: List[Dict[str, object]] = []
    passed = failed = 0
    for m, g in pairs:
        prog = engine.compile(m, g, verify=False)
        rep = verify_program(prog)
        programs.append({
            "program": rep.program,
            "ok": rep.ok,
            "checks_passed": len(rep.checks_passed),
            "checks_failed": rep.checks_failed,
        })
        passed += len(rep.checks_passed)
        failed += len(rep.checks_failed)
    return {
        "programs": programs,
        "checks_passed": passed,
        "checks_failed": failed,
        "ok": failed == 0,
    }


def emit(rows: List[str]) -> None:
    for r in rows:
        print(r, flush=True)


def _git(*args: str) -> str:
    try:
        return subprocess.run(
            ["git", *args], capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        return ""


def provenance(seed: int) -> Dict[str, object]:
    """Run context embedded in every BENCH_*.json so run-to-run variance
    (noisy CI hosts, backend differences) — and the trajectory gate's
    comparisons — are attributable to a specific commit and time."""
    sha = _git("rev-parse", "HEAD")
    return {
        "seed": seed,
        "jax_backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "cpu_count": os.cpu_count(),
        "platform": sys.platform,
        "git_sha": sha or "unknown",
        "git_dirty": bool(_git("status", "--porcelain")) if sha else False,
        "timestamp": datetime.now(timezone.utc).isoformat(
            timespec="seconds"),
    }
