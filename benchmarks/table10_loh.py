"""Paper Table 10: hardware-execution latency on the large graphs (b2).
We cannot run competitor accelerators; ``derived`` reports our overlay
vs the whole-graph reference executor (the PyG-style baseline the paper's
CPU columns embody) plus the predicted TPU-v5e latency."""
from __future__ import annotations

import time

import jax

from repro.core import gnn_builders as B
from repro.core import reference as R
from repro.obs import build_report

from .common import Engine, dataset, emit, features, run_model

GRAPHS = [("FL", 0.125), ("RE", 1 / 256), ("YE", 1 / 64), ("AP", 1 / 512)]

# Paper Table 10, GraphAGILE T_LoH on b2 (ms), for the scale-adjusted
# sanity check of our analytic TPU model (different hardware: U250 614
# GFLOPS vs v5e; the comparison is order-of-magnitude).
PAPER_LOH_MS = {"FL": 11.5, "RE": 97.2, "YE": 104.3, "AP": 315.9}


def run(quick: bool = False) -> None:
    graphs = GRAPHS[:1] if quick else GRAPHS
    engine = Engine()
    for dname, scale in graphs:
        g = dataset(dname, scale)
        x = features(g)
        _, t_loh, _, prog, t_pred = run_model("b2", g, x, engine)
        # Measured-vs-predicted conformance of the timed run above:
        # the analytic model's error before/after least-squares
        # calibration of the effective machine constants.
        rep = build_report(prog, engine.exec_stats, residency="device")
        model = B.build("b2", g)
        ref = jax.jit(lambda xx: R.run_reference(model, g, xx))
        jax.block_until_ready(ref(x))
        t0 = time.perf_counter()
        jax.block_until_ready(ref(x))
        t_ref = time.perf_counter() - t0
        label = dname if scale == 1.0 else f"{dname}@{scale:g}"
        pred_full = t_pred * 1e3 / scale      # linear-in-|E| extrapolation
        emit([f"table10,b2/{label},{t_loh * 1e6:.0f},"
              f"cpu_ref_ms={t_ref * 1e3:.0f};"
              f"pred_tpu_fullscale_ms={pred_full:.1f};"
              f"pred_tpu_ms={t_pred * 1e3:.3f};"
              f"measured_ms={rep.measured_s * 1e3:.1f};"
              f"conf_err={rep.model_error_overall:.2f};"
              f"conf_err_cal={rep.model_error_overall_calibrated:.2f};"
              f"paper_u250_ms={PAPER_LOH_MS[dname]}"])
