"""GraphAGILE instruction set (paper §5.3, Fig. 3).

Every high-level instruction is 128 bits, packed as ``uint32[4]``:

  word0: opcode(8) | pe_id(8) | act(6) | act_en(1) | on_edges(1) | flags(8)
  word1: arg0(16) | arg1(16)
  word2: arg2(16) | arg3(16)
  word3: arg4(32)          (sizes that may exceed 16 bits: nnz, counts)

The flags byte carries the double-buffer mutex annotations the compiler
emits (paper §6.6): LOCK marks a memory-read that acquires a buffer,
UNLOCK marks the compute instruction that releases it.
"""
from __future__ import annotations

import dataclasses
import enum
import struct
from typing import List, Tuple

import numpy as np

MAGIC = 0x47414749  # "GAGI"
VERSION = 2


class Opcode(enum.IntEnum):
    NOP = 0
    CSI = 1        # control & scheduling: heads a Layer Block
    MEM_RD = 2
    MEM_WR = 3
    GEMM = 4
    SPDMM = 5
    SDDMM = 6
    VADD = 7
    ACT = 8
    AFFINE = 9     # standalone batchnorm (only when fusion disabled)
    HALT = 10


class Buf(enum.IntEnum):
    EDGE = 0
    FEATURE = 1
    WEIGHT = 2
    RESULT = 3


class Region(enum.IntEnum):
    SUBSHARD = 0       # A(j, k)
    SUBFIBER = 1       # H(i, j)   (fiber i, row-block j)
    WEIGHT_BLOCK = 2   # W(k, i)
    EDGE_WEIGHTS = 3   # per-edge scalar array segment
    OUT_SUBFIBER = 4
    OUT_EDGE = 5


FLAG_LOCK = 1
FLAG_UNLOCK = 2
FLAG_ACC = 4        # accumulate into result buffer
FLAG_LAST = 8       # last instruction of a tiling block


@dataclasses.dataclass
class Instr:
    op: Opcode
    pe: int = 0
    act: int = 0
    act_en: bool = False
    on_edges: bool = False
    flags: int = 0
    args: Tuple[int, int, int, int] = (0, 0, 0, 0)
    arg4: int = 0

    # ------------------------------------------------------------------ #
    def encode(self) -> np.ndarray:
        w0 = ((int(self.op) & 0xFF)
              | (self.pe & 0xFF) << 8
              | (self.act & 0x3F) << 16
              | (1 << 22 if self.act_en else 0)
              | (1 << 23 if self.on_edges else 0)
              | (self.flags & 0xFF) << 24)
        a = [int(x) & 0xFFFF for x in self.args]
        w1 = a[0] | a[1] << 16
        w2 = a[2] | a[3] << 16
        w3 = int(self.arg4) & 0xFFFFFFFF
        return np.array([w0, w1, w2, w3], dtype=np.uint32)

    @staticmethod
    def decode(words: np.ndarray) -> "Instr":
        w0, w1, w2, w3 = (int(w) for w in words)
        return Instr(
            op=Opcode(w0 & 0xFF),
            pe=(w0 >> 8) & 0xFF,
            act=(w0 >> 16) & 0x3F,
            act_en=bool(w0 >> 22 & 1),
            on_edges=bool(w0 >> 23 & 1),
            flags=(w0 >> 24) & 0xFF,
            args=(w1 & 0xFFFF, w1 >> 16, w2 & 0xFFFF, w2 >> 16),
            arg4=w3,
        )

    def __repr__(self) -> str:  # compact trace form
        f = "".join(c for c, m in zip("LUAZ", (1, 2, 4, 8)) if self.flags & m)
        return (f"{self.op.name}(pe{self.pe} args={list(self.args)} "
                f"a4={self.arg4}{' ' + f if f else ''})")


# --------------------------------------------------------------------------- #
def assemble(instrs: List[Instr]) -> bytes:
    """Binary file: 16-byte header + 16 bytes per instruction (Table 8)."""
    header = struct.pack("<IIII", MAGIC, VERSION, len(instrs), 0)
    if not instrs:
        return header
    body = np.stack([i.encode() for i in instrs]).astype("<u4").tobytes()
    return header + body


def disassemble(blob: bytes) -> List[Instr]:
    magic, version, n, _ = struct.unpack_from("<IIII", blob, 0)
    assert magic == MAGIC and version == VERSION, "bad binary"
    words = np.frombuffer(blob, dtype="<u4", offset=16).reshape(n, 4)
    return [Instr.decode(w) for w in words]
