"""A tour of the GraphAGILE compiler (paper §6), pass by pass.

  PYTHONPATH=src python examples/compiler_tour.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import gnn_builders as B  # noqa: E402
from repro.core import graph as G  # noqa: E402
from repro.engine import Engine  # noqa: E402
from repro.core.isa import Opcode, disassemble  # noqa: E402
from repro.core.passes import fusion, order_opt  # noqa: E402
from repro.core.passes.partition import (PartitionConfig,  # noqa: E402
                                         partition_graph)


def main() -> None:
    g = G.synthesize("CO").gcn_normalized()
    model = B.build("b7", g)   # SGC: the order optimizer's best case

    print("== IR (PyG-style decomposition, paper Table 2) ==")
    print(model.dump(), "\n")

    m1 = model.copy()
    rep = order_opt.run(m1)
    print("== Step 1: computation order optimization (Alg. 5) ==")
    print(f"exchanges: {rep.exchanges}")
    print(f"complexity: {rep.complexity_before:.3g} -> "
          f"{rep.complexity_after:.3g}  (-{rep.reduction:.1%})")
    print(m1.dump(), "\n")

    frep = fusion.run(m1)
    print("== Step 2: layer fusion ==")
    print(f"fused activations {frep.fused_activations}, "
          f"batchnorms {frep.fused_batchnorms}")
    print(m1.dump(), "\n")

    print("== Step 3: fiber-shard partitioning (Fig. 8) ==")
    cfg = PartitionConfig(n1=512, n2=32)
    pg = partition_graph(g, cfg)
    widths = [t.width for ts in pg.tiles.values() for t in ts]
    print(f"N1={cfg.n1} N2={cfg.n2}: {pg.n_blocks}x{pg.n_blocks} grid, "
          f"{sum(len(ts) for ts in pg.tiles.values())} non-empty ELL "
          f"tiles, widths {min(widths)}..{max(widths)}, "
          f"{pg.tile_bytes() / 1e6:.2f} MB of tiles\n")

    print("== Step 4 + codegen: 128-bit instruction stream ==")
    engine = Engine(geometry=cfg)
    prog = engine.compile(model, g)
    instrs = disassemble(prog.binary)
    print(f"{len(instrs)} instructions, {len(prog.binary)} bytes; "
          f"first Layer Block:")
    shown = 0
    for ins in instrs:
        print("  ", ins)
        shown += 1
        if shown > 1 and ins.op == Opcode.CSI or shown > 14:
            break
    print(f"\nworst per-layer PE load imbalance: "
          f"{prog.source.schedule_report.worst_imbalance:.2f}x "
          f"(LPT over edge-count costs)")


if __name__ == "__main__":
    main()
