"""Pipeline parallelism (GPipe schedule) via shard_map + ppermute.

Stage weights live sharded over a ``stage`` mesh axis; microbatches flow
through stages with collective_permute between neighbours.  The classic
SPMD formulation: every device runs the same program; at tick t, stage s
holds microbatch (t - s) — a rotating buffer of live activations.  Total
ticks = n_micro + n_stages - 1 (the pipeline bubble).

This is the manual-collective counterpart of the GSPMD paths used by the
main models: available for hillclimbing the pod axis (DESIGN.md §5) and
exercised by tests/test_distributed.py for exact equivalence with the
sequential execution.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(
    stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
    stage_params: Any,          # leaves [n_stages, ...] sharded over axis
    x: jnp.ndarray,             # [n_micro, micro_batch, ...]
    mesh: Mesh,
    axis: str = "stage",
) -> jnp.ndarray:
    """Run x through n_stages of stage_fn in a GPipe schedule."""
    n_stages = mesh.shape[axis]
    n_micro = x.shape[0]
    assert n_micro % 1 == 0

    def body(params_local, x_local):
        # params_local: stage-local params (leading dim 1); x_local: this
        # stage's slice of the microbatch queue [n_micro/n_stages, ...].
        # We all-gather the queue so stage 0 can feed any microbatch
        # (queue is small relative to activations in real use).
        p_loc = jax.tree.map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        xq = jax.lax.all_gather(x_local, axis, axis=0, tiled=True)
        n_ticks = n_micro + n_stages - 1
        buf = jnp.zeros_like(xq[0])
        out = jnp.zeros_like(xq[: n_micro])

        def tick(t, carry):
            buf, out = carry
            # stage 0 ingests microbatch t (if any); others use the
            # activation permuted from the previous stage.
            feed = jnp.where(
                t < n_micro,
                jax.lax.dynamic_index_in_dim(xq, jnp.minimum(t, n_micro - 1),
                                             axis=0, keepdims=False),
                jnp.zeros_like(buf))
            cur = jnp.where(stage_id == 0, feed, buf)
            y = stage_fn(p_loc, cur)
            # pass to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            # last stage emits microbatch (t - n_stages + 1)
            mb = t - (n_stages - 1)
            emit = jnp.logical_and(stage_id == n_stages - 1, mb >= 0)
            out = jax.lax.cond(
                emit,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.maximum(mb, 0), axis=0),
                lambda o: o, out)
            return buf, out

        _, out = jax.lax.fori_loop(0, n_ticks, tick, (buf, out))
        # result lives on the last stage; psum broadcasts it (all other
        # stages contribute zeros), so out_specs can be replicated.
        return jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, out, jnp.zeros_like(out)),
            axis)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)
