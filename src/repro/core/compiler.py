"""GraphAGILE software compiler (paper §6, Fig. 1).

  inputs : GNN ModelIR (from the PyG-like builders) + input graph
  output : CompileResult — the Program, the serialized 128-bit binary,
           per-pass reports, and T_LoC (compilation latency).

Pipeline: Input parsing/IR -> Step 1 order optimization -> Step 2 layer
fusion -> Step 3 fiber-shard partitioning -> Step 4 kernel mapping + task
scheduling -> code generation.

The public entry point is :class:`repro.engine.Engine` (``engine.compile``
wraps :func:`run_pipeline`); the module-level :func:`compile_model` /
:func:`compile_benchmark` remain as deprecated shims.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional

from repro.obs.tracer import get_tracer

from .gnn_builders import BENCHMARKS
from .graph import Graph
from .ir import ModelIR
from .isa import assemble
from .passes import fusion, kernel_map, order_opt, schedule
from .passes.kernel_map import Program
from .passes.partition import (PartitionConfig, choose_partition,
                               partition_graph)


@dataclasses.dataclass
class CompileOptions:
    order_opt: bool = True
    fusion: bool = True
    n_pes: int = 8                      # paper: 8 PEs on Alveo U250
    partition: Optional[PartitionConfig] = None
    vmem_budget_bytes: int = 3 << 20    # paper: 3MB feature buffer / PE


@dataclasses.dataclass
class CompileResult:
    program: Program
    binary: bytes
    t_loc: float                        # seconds — the paper's T_LoC
    order_report: order_opt.OrderOptReport
    fusion_report: fusion.FusionReport
    schedule_report: schedule.ScheduleReport

    @property
    def binary_bytes(self) -> int:
        return len(self.binary)


def run_pipeline(
    model: ModelIR, g: Graph, opts: Optional[CompileOptions] = None
) -> CompileResult:
    """The §6 software-compilation pipeline (internal entry point)."""
    opts = opts or CompileOptions()
    tracer = get_tracer()
    t0 = time.perf_counter()

    m = model.copy()
    # Step 1: computation order optimization.
    with tracer.span("order_opt", cat="compile", track="compile"):
        orep = order_opt.run(m, enabled=opts.order_opt)
    # Step 2: layer fusion.
    with tracer.span("fusion", cat="compile", track="compile") as sp:
        frep = fusion.run(m, enabled=opts.fusion)
        sp.add(layers_before=frep.layers_before,
               layers_after=frep.layers_after)
    # Step 3: data partitioning (O(|V| + |E|)).
    with tracer.span("partition", cat="compile", track="compile") as sp:
        f_max = max(max(l.f_in, l.f_out) for l in m.layers.values())
        cfg = opts.partition or choose_partition(
            g.n_vertices, f_max, opts.vmem_budget_bytes)
        pg = partition_graph(g, cfg)
        sp.add(n1=cfg.n1, n2=cfg.n2, blocks=pg.n_blocks)
    # Step 4: kernel mapping + task scheduling.
    with tracer.span("kernel_map", cat="compile", track="compile"):
        prog = kernel_map.run(m, pg, n_pes=opts.n_pes)
    with tracer.span("schedule", cat="compile", track="compile"):
        srep = schedule.run(prog, n_pes=opts.n_pes)
    # Code generation.
    with tracer.span("codegen", cat="compile", track="compile") as sp:
        binary = assemble(prog.all_instrs())
        sp.add(binary_bytes=len(binary))

    t_loc = time.perf_counter() - t0
    return CompileResult(program=prog, binary=binary, t_loc=t_loc,
                         order_report=orep, fusion_report=frep,
                         schedule_report=srep)


def compile_model(
    model: ModelIR, g: Graph, opts: Optional[CompileOptions] = None
) -> CompileResult:
    """Deprecated shim — use ``repro.engine.Engine.compile`` instead."""
    warnings.warn(
        "compile_model is deprecated; use repro.engine.Engine.compile "
        "(binary-driven execution, program cache, save/load)",
        DeprecationWarning, stacklevel=2)
    return run_pipeline(model, g, opts)


def compile_benchmark(name: str, g: Graph, seed: int = 0,
                      opts: Optional[CompileOptions] = None) -> CompileResult:
    """Deprecated shim — use ``engine.compile("b1", g)`` instead."""
    warnings.warn(
        "compile_benchmark is deprecated; use repro.engine.Engine.compile "
        "with a benchmark name", DeprecationWarning, stacklevel=2)
    model = BENCHMARKS[name](g, seed)
    return run_pipeline(model, g, opts)
