"""Serving driver: batched request loop with prefill + decode.

The GraphAGILE analogue on the LM side: one compiled prefill executable
and one compiled decode executable serve *any* request mix without
recompilation (shapes are bucketed to fixed capacities) — the overlay
property at the XLA level.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
      --requests 8 --prompt-len 32 --gen 16

A straggler-mitigation hook mirrors Algorithm 9's idle-PE rule: the host
queue hands the next request batch to whichever executor slot drains
first (single-process here; the hook is where a multi-host serving tier
plugs in).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.steps import build_model, make_serve_step


def _prefill_with_cache(model, cfg, params, tokens, cache):
    """Prefill by running decode steps over the prompt (cache-exact;
    production would use a fused prefill kernel writing the cache)."""
    serve = jax.jit(make_serve_step(model, cfg), donate_argnums=(1,))
    last = None
    for t in range(tokens.shape[1]):
        last, cache = serve(params, cache, tokens[:, t:t + 1],
                            jnp.int32(t))
    return last, cache


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(args.seed)

    b = args.requests
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (b, args.prompt_len)).astype(np.int32))
    cap = args.prompt_len + args.gen
    cache = model.init_cache(b, cap)

    t0 = time.time()
    last, cache = _prefill_with_cache(model, cfg, params, prompts, cache)
    t_prefill = time.time() - t0

    serve = jax.jit(make_serve_step(model, cfg), donate_argnums=(1,))
    tok = last
    out = [np.asarray(tok)]
    t0 = time.time()
    for i in range(args.gen - 1):
        tok, cache = serve(params, cache, tok,
                           jnp.int32(args.prompt_len + i))
        out.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0
    gen = np.concatenate(out, axis=1)
    print(f"arch={cfg.name} requests={b} prompt={args.prompt_len} "
          f"gen={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms   decode: "
          f"{t_decode * 1e3:.1f} ms "
          f"({t_decode / max(args.gen - 1, 1) * 1e3:.2f} ms/token)")
    print("sample generations (first 3 requests):")
    for r in range(min(3, b)):
        print("  ", gen[r].tolist())
    return 0


if __name__ == "__main__":
    sys.exit(main())
