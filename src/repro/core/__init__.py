# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.
#
# Public entry point: `repro.engine.Engine` — compile a (model, graph)
# pair to a 128-bit instruction binary, execute by decoding it, save /
# load `.gagi` bundles, and serve request streams with a program cache.
# `core.compiler.compile_model` / `core.executor.OverlayExecutor` are
# deprecated shims over that API.
