"""ZeRO-1: shard optimizer state over the data axis.

In GSPMD land, ZeRO-1 is purely a *sharding spec* decision: the AdamW
moments and the f32 master copy get an extra partitioning over ``data``
along the first dimension that (a) divides evenly and (b) is not already
sharded by the tensor-parallel rule.  XLA then emits reduce-scattered
gradient + all-gathered updated params — the ZeRO-1 communication
schedule — without any change to the update rule.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .sharding import param_specs


def zero_param_spec(spec: P, shape, mesh: Mesh, axis: str = "data") -> P:
    if axis not in mesh.axis_names:
        return spec
    n = mesh.shape[axis]
    parts = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, parts)):
        if cur is None and dim % n == 0 and dim >= n:
            parts[i] = axis
            return P(*parts)
        if cur is not None and not isinstance(cur, tuple) and cur != axis:
            # combine with existing tensor-parallel axis when divisible
            ax_total = n * mesh.shape[cur]
            if dim % ax_total == 0:
                parts[i] = (cur, axis)
                return P(*parts)
    return spec


def opt_state_specs(params_tree: Any, mesh: Mesh, enable: bool = True):
    """Specs for AdamWState(step, mu, nu, master) given a params pytree."""
    base = param_specs(params_tree, mesh)

    def z(spec, leaf):
        if not enable:
            return spec
        return zero_param_spec(spec, leaf.shape, mesh)

    zspec = jax.tree_util.tree_map(z, base, params_tree)
    from repro.optim import AdamWState
    return AdamWState(step=P(), mu=zspec, nu=zspec, master=zspec)


def opt_state_shardings(params_tree: Any, mesh: Mesh, enable: bool = True):
    specs = opt_state_specs(params_tree, mesh, enable)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
