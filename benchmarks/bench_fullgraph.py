"""Full-graph out-of-core benchmark: device-resident vs partition-centric
vs placement-scheduled multi-device.

  PYTHONPATH=src python benchmarks/bench_fullgraph.py [--smoke] [--full]
                                                      [--devices N]

The workload is full-graph inference (GCN b1 / SAGE b3 / GAT b6) on a
power-law graph with community locality: vertex ids are assumed
renumbered so that most edges land within a few neighbouring N1-blocks
of the tile grid (the standard vertex-reordering/community structure of
deployed graphs — and the property the paper's fiber-shard partitioning
exploits: a destination shard's working set is its (j, k) sub-shard
tiles plus the FEW source sub-fibers they reference).

Execution modes over the SAME compiled binary:

  * ``device`` — every padded layer output device-resident.  The
    executor prices the run with its liveness-aware peak estimate and
    REFUSES when it exceeds ``resident_budget_bytes`` (recorded as the
    refusal, naming the first layer that busts the budget).
  * ``host``   — the partition-centric scheme (§6.5, Algorithms 6-8):
    features host-resident, one destination shard's working set staged
    at a time with double-buffered transfers.  Completes within budget
    and is bit-identical (asserted here at smoke size, tested at unit
    size in tests/test_fullgraph.py).
  * ``mesh``   — with ``--devices N``: destination shards LPT-placed on
    N (virtual host) devices, per-device shard schedules with halo
    sub-fiber exchange; records the compile-time placement loads,
    per-device load imbalance, and halo exchange volume.

The budget is placed between the streaming window and the device peak,
so the artifact shows a (graph size, budget) point where ONLY the
partitioned path completes.  Results land in ``BENCH_fullgraph.json``:
per-model device estimates (with and without interval liveness),
streaming latency, peak staged bytes, H2D traffic, shard counts, the
placement/mesh figures, plus seed/backend/CPU/device provenance.

Sizes: --smoke ~33k vertices (CI); default ~262k; --full ~1M vertices.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MODELS = ["b1", "b3", "b6"]     # GCN, GraphSAGE-mean, GAT


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI size (~33k vertices)")
    ap.add_argument("--full", action="store_true",
                    help="~1M-vertex point (minutes on CPU)")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_fullgraph.json"))
    ap.add_argument("--conformance-out",
                    default=os.path.join(ROOT, "CONFORMANCE.md"),
                    help="markdown ConformanceReport destination")
    ap.add_argument("--seed", type=int, default=0,
                    help="graph seed; recorded in provenance")
    ap.add_argument("--devices", type=int, default=1,
                    help="run the placement-scheduled multi-device path "
                         "on N devices (forces virtual host devices "
                         "when fewer are physically present)")
    ap.add_argument("--remap", dest="remap", action="store_true",
                    default=True,
                    help="race the sparsity-adaptive remapped binary "
                         "against the canonical one (default on)")
    ap.add_argument("--no-remap", dest="remap", action="store_false")
    return ap.parse_args(argv)


def force_device_count(n: int) -> None:
    """Must run BEFORE jax is imported: virtual host devices are an XLA
    boot flag, not a runtime knob."""
    if n > 1 and "jax" not in sys.modules:
        flags = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = \
                f"{flags} --xla_force_host_platform_device_count={n}".strip()


def make_local_powerlaw(nv: int, ne: int, n1: int, seed: int):
    """Power-law degree profile + community locality: destination drawn
    with a heavy-tailed rank bias (hubs), source placed a geometric
    block-offset away — the post-reordering shape of real graphs.
    Duplicate draws are folded into one weighted edge (multi-edges are
    measurement artifacts; folding also keeps ELL tile widths honest)."""
    from repro.core import graph as G
    rng = np.random.default_rng(seed)
    dst = (nv * rng.random(ne) ** 1.4).astype(np.int64)   # hub bias
    delta = rng.geometric(4.0 / n1, ne) * rng.choice((-1, 1), ne)
    src = np.clip(dst + delta, 0, nv - 1)
    key = src * np.int64(nv) + dst
    uniq, counts = np.unique(key, return_counts=True)
    g = G.Graph(n_vertices=nv, src=(uniq // nv).astype(np.int32),
                dst=(uniq % nv).astype(np.int32),
                weight=counts.astype(np.float32),
                name=f"localpl:{nv}")
    return g.gcn_normalized()


def bench_remap(eng, prog, x, rep, reps: int, devices: int,
                check_bits: bool) -> dict:
    """Sparsity-adaptive remap pass (Dynasparse-style): re-encode the
    binary's aggregate kernels from the probe oracle + the calibrated
    conformance constants, then race the remapped program against the
    canonical one on the streaming path (min-of-reps both sides, same
    warm kernels).  Bit-identity of the remapped run is checked ACROSS
    residency paths — densified GEMM reassociates the per-edge sums, so
    vs the canonical baseline only the max-abs delta is recorded."""
    reps = max(reps, 3)
    y_base = np.asarray(eng.run(prog, x, residency="host"))
    base = []
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.run(prog, x, residency="host")
        base.append(time.perf_counter() - t0)

    rprog = eng.remap(prog, report=rep, probe=True)
    record = rprog.manifest["remap"]
    y_re = np.asarray(eng.run(rprog, x, residency="host"))   # warm
    rlats = []
    for _ in range(reps):
        t0 = time.perf_counter()
        eng.run(rprog, x, residency="host")
        rlats.append(time.perf_counter() - t0)
    st = eng.exec_stats

    identical = []
    if check_bits:
        identical.append(bool(np.array_equal(
            np.asarray(eng.run(rprog, x)), y_re)))
    if devices > 1:
        identical.append(bool(np.array_equal(
            np.asarray(eng.run(rprog, x, mesh=devices)), y_re)))

    buckets: dict = {}
    for t in record["tiles"].values():
        b = min(int(t["density"] * 10), 9)
        key = f"{b / 10:.1f}-{(b + 1) / 10:.1f}"
        buckets.setdefault(key, {"spdmm": 0, "gemm": 0, "skip": 0})
        buckets[key][t["mode"]] += 1

    out = {
        "source": record["source"],
        "probe": record["probe"],
        "calibrated": record["calibrated"],
        "remap_ms": record["remap_ms"],
        "counts": record["counts"],
        "remapped_ops": record["remapped_ops"],
        "skipped_tile_ops": record["skipped_tile_ops"],
        "predicted_gain_s": round(record["predicted_gain_s"], 6),
        "baseline_host_s": round(min(base), 4),
        "remapped_host_s": round(min(rlats), 4),
        "remap_speedup": round(min(base) / min(rlats), 4),
        "max_abs_delta_vs_baseline": float(np.max(np.abs(y_re - y_base))),
        "remap_bit_identical": float(all(identical)) if identical else 1.0,
        "tiles_remapped_per_run": st.tiles_remapped,
        "tile_ops_by_mode": st.tile_ops_by_mode,
        "mode_share_by_density": buckets,
    }
    print(f"    remap: {record['counts']} -> "
          f"{out['remap_speedup']}x host speedup "
          f"(base {out['baseline_host_s']}s, remapped "
          f"{out['remapped_host_s']}s)", flush=True)
    return out


def run_model(name: str, eng, g, x, reps: int, check_bits: bool,
              devices: int, remap: bool = True) -> dict:
    from repro.engine import ResidentBudgetError
    from repro.obs import build_report, tracing
    ex = eng._executor
    eng.resident_budget_bytes = None
    prog = eng.compile(name, g, mesh=devices if devices > 1 else None)
    if prog.source is None:
        # program-cache hit returned a slim copy; conformance needs the
        # object-graph Program behind the analytic cost model
        prog = eng.compile(name, g,
                           mesh=devices if devices > 1 else None,
                           use_cache=False)
    dev_peak = ex.estimate_device_peak_bytes(prog, x.shape[1])
    rec: dict = {
        "model": name,
        "binary_bytes": prog.binary_bytes,
        "n_instructions": prog.instruction_count(),
        "device_peak_bytes_liveness": dev_peak,
        "device_peak_bytes_naive": ex.estimate_device_peak_bytes(
            prog, x.shape[1], assume_liveness=False),
    }
    if devices > 1:
        # Compile-time placement figures: LPT loads over the mesh and
        # the halo volume a targeted exchange would move per pass.
        pl = prog.manifest["placement"]
        mean = sum(pl["loads"]) / devices
        rec["placement"] = {
            "n_devices": devices,
            "loads": pl["loads"],
            "load_imbalance": (max(pl["loads"]) / mean) if mean else 1.0,
            "halo_bytes_total": pl["halo_bytes_total"],
        }

    # Warm-up streaming pass (jits the tile kernels) doubles as the
    # working-set probe: the measured double-buffered window + resident
    # weights is what the streaming path actually needs on device.
    y = np.asarray(eng.run(prog, x, residency="host"))
    window = ex.stats.peak_stage_bytes
    need = window + ex._static_bytes
    rec["host_window_bytes"] = window

    # Traced conformance pass (kernels now warm): per-layer measured
    # wall time joined against the analytic cost model, staging
    # bandwidth fitted from the stage spans, critical path from the
    # span DAG.  This is the run the `model_error` gate prices.
    with tracing() as tr:
        y_conf = np.asarray(eng.run(prog, x, residency="host"))
    assert np.array_equal(y, y_conf)
    rep = build_report(prog, eng.exec_stats, residency="host",
                       events=tr.events())

    if devices > 1:
        t0 = time.perf_counter()
        y_mesh = np.asarray(eng.run(prog, x, mesh=devices))
        mesh_s = time.perf_counter() - t0
        st = eng.exec_stats
        rec["mesh"] = {
            "latency_s": round(mesh_s, 4),
            "bit_identical_to_host": bool(np.array_equal(y, y_mesh)),
            "halo_bytes": st.halo_bytes,
            "halo_gather_bytes": st.halo_gather_bytes,
            "halo_gap_bytes": max(0, st.halo_gather_bytes
                                  - st.halo_bytes),
            "peak_device_bytes": st.peak_device_bytes,
            "per_device_tile_ops": [d["tile_ops"]
                                    for d in st.per_device],
            "per_device_blocks": [d["blocks"] for d in st.per_device],
            "tile_op_imbalance": round(st.device_imbalance, 4),
        }
        # Fold the measured-vs-estimated halo gap of the mesh run into
        # the conformance report (the host pass has no exchange).
        # Signed: positive = the planner under-estimated the exchange,
        # negative = the all_gather moved less than the estimate.
        gap = int(st.halo_gather_bytes) - int(st.halo_bytes)
        rep.halo = {
            "estimated_bytes": int(st.halo_bytes),
            "gathered_bytes": int(st.halo_gather_bytes),
            "gap_bytes": gap,
            "gap_fraction": gap / st.halo_bytes if st.halo_bytes else 0.0,
        }

    overall = rep.model_error_overall
    overall_cal = rep.model_error_overall_calibrated
    rec["conformance"] = {
        "residency": rep.residency,
        "predicted_s": round(rep.predicted_s, 6),
        "measured_s": round(rep.measured_s, 6),
        "model_error": {k: round(v, 4)
                        for k, v in rep.model_error.items()},
        "model_error_calibrated": {
            k: round(v, 4)
            for k, v in rep.model_error_calibrated.items()},
        "model_error_overall": round(overall, 4),
        "model_error_overall_calibrated": round(overall_cal, 4),
        "calibration_gain": round(overall - overall_cal, 4),
        "scales": {k: round(v, 4) for k, v in rep.scales.items()},
        "calibrated_constants": {k: round(v, 1) for k, v
                                 in rep.calibrated_constants.items()},
        "halo": rep.halo,
        "makespan_us": rep.critical_path["makespan_us"],
        "critical_path_us": rep.critical_path["critical_path_us"],
    }
    rec["conformance_markdown"] = rep.to_markdown()

    if remap:
        rec["remap"] = bench_remap(eng, prog, x, rep, reps, devices,
                                   check_bits)

    if need >= dev_peak:
        # No gap (tiny graph / degenerate tiling): record and move on.
        rec["budget_bytes"] = None
        rec["no_gap"] = True
        return rec
    # The demonstration point: a budget the streaming path fits with
    # 2x headroom (capped below the device peak) and the resident path
    # cannot meet.
    budget = min(2 * need, (need + dev_peak) // 2)
    rec["budget_bytes"] = budget
    eng.resident_budget_bytes = budget
    try:
        eng.run(prog, x)
        rec["device_under_budget"] = {"completed": True}
    except ResidentBudgetError as e:
        rec["device_under_budget"] = {"completed": False,
                                      "refusal": str(e)}

    lats = []
    for _ in range(reps):                # under the budget: must fit
        t0 = time.perf_counter()
        y = np.asarray(eng.run(prog, x, residency="host"))
        lats.append(time.perf_counter() - t0)
    st = eng.exec_stats
    rec["host_under_budget"] = {
        "completed": True,
        "latency_s": round(min(lats), 4),
        "peak_stage_bytes": st.peak_stage_bytes,
        "h2d_bytes": st.h2d_bytes,
        "shards_streamed": st.shards_streamed,
        "peak_live_outputs": st.peak_live_outputs,
        "tile_ops": st.tile_ops,
    }
    if check_bits:                       # unbudgeted resident reference
        eng.resident_budget_bytes = None
        t0 = time.perf_counter()
        y_ref = np.asarray(eng.run(prog, x))
        rec["device_latency_s"] = round(time.perf_counter() - t0, 4)
        rec["bit_identical"] = bool(np.array_equal(y_ref, y))
    eng.resident_budget_bytes = None
    print(f"  {name}: device peak {dev_peak:,}B (naive "
          f"{rec['device_peak_bytes_naive']:,}B) vs streamed window "
          f"{window:,}B -> budget {budget:,}B — host "
          f"{rec['host_under_budget']['latency_s']}s, "
          f"{st.shards_streamed} shards", flush=True)
    return rec


def main(mode: str, out_path: str, seed: int, devices: int,
         conformance_out: str = None, remap: bool = True) -> None:
    import jax
    import jax.numpy as jnp

    try:                        # script: python benchmarks/bench_fullgraph.py
        from common import provenance, verify_section
    except ImportError:         # module: python -m benchmarks.bench_fullgraph
        from benchmarks.common import provenance, verify_section

    from repro.core import graph as G
    from repro.core.passes.partition import PartitionConfig
    from repro.engine import Engine

    nv, avg_deg, f, c, n1, reps = {
        "smoke": (1 << 15, 8, 32, 8, 2048, 2),
        "default": (1 << 18, 8, 64, 16, 8192, 1),
        "full": (1 << 20, 8, 64, 16, 8192, 1),
    }[mode]
    devices = min(devices, jax.local_device_count())
    ne = nv * avg_deg
    t0 = time.perf_counter()
    g = make_local_powerlaw(nv, ne, n1, seed)
    g.feat_dim, g.n_classes = f, c
    x = jnp.asarray(G.random_features(g, seed=seed + 1))
    build_s = time.perf_counter() - t0
    print(f"graph: |V|={g.n_vertices:,} |E|={g.n_edges:,} f={f} "
          f"({build_s:.1f}s to build), devices={devices}", flush=True)

    eng = Engine(geometry=PartitionConfig(n1=n1, n2=min(f, 128)))
    results = [run_model(m, eng, g, x, reps,
                         check_bits=(mode == "smoke"), devices=devices,
                         remap=remap)
               for m in MODELS]
    report = {
        "benchmark": "fullgraph_out_of_core",
        "mode": mode,
        "graph": {"n_vertices": g.n_vertices, "n_edges": g.n_edges,
                  "feat_dim": f, "n_classes": c,
                  "generator": "localized_powerlaw"},
        "geometry": {"n1": n1, "n2": eng.geometry.n2,
                     "n_blocks": eng.geometry.n_blocks(g.n_vertices)},
        "devices": {"requested": devices,
                    "available": jax.local_device_count(),
                    "mesh_axes": ["dev"] if devices > 1 else None},
        "models": results,
        "provenance": provenance(seed),
    }
    only_streaming = all(
        not r.get("device_under_budget", {}).get("completed", True)
        and r.get("host_under_budget", {}).get("completed", False)
        for r in results)
    report["only_partitioned_path_completes"] = only_streaming
    # Static verification of every benched program (cache hits off the
    # warm engine — no recompiles) — semantic trajectory metrics.
    report["verify"] = verify_section(eng, [(m, g) for m in MODELS])
    # The per-model ConformanceReports ship as one markdown artifact
    # (CONFORMANCE.md); the JSON keeps only the gated summary numbers.
    sections = [f"# Cost-model conformance — fullgraph {mode}", ""]
    for r in results:
        md = r.pop("conformance_markdown", None)
        if md:
            sections += [f"# model {r['model']}", "", md, ""]
    if conformance_out and len(sections) > 2:
        with open(conformance_out, "w") as fp:
            fp.write("\n".join(sections))
        print(f"wrote {conformance_out}", flush=True)
    with open(out_path, "w") as fp:
        json.dump(report, fp, indent=1)
    print(f"wrote {out_path} (only_partitioned_path_completes="
          f"{only_streaming})", flush=True)


if __name__ == "__main__":
    args = parse_args()
    force_device_count(args.devices)     # before any jax import
    mode = "smoke" if args.smoke else ("full" if args.full else "default")
    main(mode, args.out, args.seed, args.devices,
         conformance_out=args.conformance_out, remap=args.remap)
