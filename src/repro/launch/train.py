"""Training driver: data pipeline -> sharded train loop -> checkpoints.

Runs real steps at smoke scale on CPU and is the template for pod scale:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt --resume auto

Fault tolerance: checkpoints are atomic (checkpoint/ckpt.py); ``--resume
auto`` restarts from the last complete step; ``--crash-at N`` simulates a
node failure mid-run (used by tests/test_train_loop.py to verify
loss-curve continuity across a crash/restart).  On a real cluster this
process runs once per host with jax.distributed.initialize(); elastic
re-mesh = restore onto whatever mesh the relaunch got (checkpoints are
host-gathered and mesh-free).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.configs import get_config, get_smoke_config
from repro.data import synthetic_batches
from repro.distributed.compression import ef_transform, init_error_feedback
from repro.models.steps import (build_model, init_train_state,
                                make_train_step)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", default="no", choices=["no", "auto"])
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="simulate a failure after this step (testing)")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--data-seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, dtype="float32") if args.smoke else cfg
    model = build_model(cfg)
    train_step = make_train_step(model, cfg, base_lr=args.lr)

    def train_step_compressed(params, opt_state, ef, batch):
        # error-feedback int8 gradient path (see compression.py)
        from repro.models.layers import softmax_xent
        from repro.optim import adamw_update, cosine_schedule

        def loss_fn(p):
            logits, aux = model.forward(p, batch["tokens"])
            return softmax_xent(logits, batch["labels"]) \
                + cfg.router_aux_coef * aux
        loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, ef = ef_transform(grads, ef)
        lr = cosine_schedule(opt_state.step, args.lr)
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, ef, {"loss": loss, "lr": lr,
                                       "aux": jnp.zeros(())}

    params, opt_state = init_train_state(model, jax.random.PRNGKey(0))
    start = 0
    if args.resume == "auto" and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, opt_state), start, meta = restore(
                args.ckpt_dir, (params, opt_state))
            print(f"[resume] restored step {start} "
                  f"(loss was {meta.get('loss')})", flush=True)

    if args.compress_grads:
        jstep = jax.jit(train_step_compressed, donate_argnums=(0, 1, 2))
    else:
        jstep = jax.jit(train_step, donate_argnums=(0, 1))
    it = synthetic_batches(cfg, args.batch, args.seq, seed=args.data_seed)
    ef = init_error_feedback(params) if args.compress_grads else None

    # fast-forward the data stream for determinism across restarts
    for _ in range(start):
        next(it)

    t0 = time.time()
    loss_val = float("nan")
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        if args.compress_grads:
            params, opt_state, ef, metrics = jstep(params, opt_state, ef,
                                                   batch)
        else:
            params, opt_state, metrics = jstep(params, opt_state, batch)
        if (step + 1) % args.log_every == 0 or step == start:
            loss_val = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {step + 1:5d} loss {loss_val:8.4f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save(args.ckpt_dir, step + 1, (params, opt_state),
                 meta={"loss": float(metrics["loss"]),
                       "arch": args.arch})
        if args.crash_at == step + 1:
            print(f"[crash] simulated failure at step {step + 1}",
                  flush=True)
            os._exit(42)
    print(f"done: {args.steps} steps, final loss {loss_val:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
