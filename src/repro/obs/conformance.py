"""Cost-model conformance: measured-vs-predicted accounting.

The compiler's decisions (kernel mapping, Algorithm 9 scheduling, LPT
placement) all price work with the analytic roofline in
:mod:`repro.core.perfmodel`; this module closes the loop by joining
those *predictions* with what the executor *measured*:

* per-layer join — ``perfmodel.layer_costs`` against
  ``ExecStats.per_layer`` (populated on every residency path), grouped
  by kernel mode;
* per-mode **model error** — normalized RMSE of predicted vs measured
  layer times, the drift metric the CI trajectory gate holds;
* **least-squares calibration** — a per-mode scale fitted through the
  origin (``a = Σ p·m / Σ p²``, the exact minimizer of the squared
  error, so calibrated error ≤ uncalibrated by construction), folded
  back into *effective* machine constants (``ModelConstants`` with
  fitted FLOPS/BW) plus a staging-bandwidth fit from traced ``stage``
  spans;
* **density join** — predicted vs measured cost share per tile-density
  bucket, reusing the ``exec_profile`` histogram (the Dynasparse
  remapper's decision input);
* **halo gap** — measured all_gather volume vs the compile-time
  targeted-halo estimate on mesh runs (what a ppermute-style exchange
  would save);
* optional **critical path** — :mod:`repro.obs.attrib` summary of the
  traced run folded into the report.

Reports serialize as JSON (``to_dict``) and markdown (``to_markdown``)
and feed both ``BENCH_fullgraph.json`` (the gated ``model_error``
metric) and the ``CONFORMANCE.md`` CI artifact.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.perfmodel import (DEFAULT_CONSTANTS, ModelConstants,
                                  layer_costs)

from .attrib import build_dag

__all__ = ["ConformanceReport", "build_report", "ls_scale", "nrmse",
           "fit_stage_bw"]

# which machine constant each kernel mode's roofline leans on
_CONSTANT_OF_MODE = {
    "gemm": "peak_flops",
    "spdmm": "vpu_flops",
    "sddmm": "vpu_flops",
    "vadd": "hbm_bw",
    "act": "hbm_bw",
}


def ls_scale(pairs: Sequence[Tuple[float, float]]) -> float:
    """Least-squares scale ``a`` minimizing ``Σ (m - a·p)²`` over
    (predicted, measured) pairs — fit through the origin, so the
    calibrated error can never exceed the uncalibrated one."""
    num = sum(p * m for p, m in pairs)
    den = sum(p * p for p, _ in pairs)
    return (num / den) if den > 0 else 1.0


def nrmse(pairs: Sequence[Tuple[float, float]], scale: float = 1.0
          ) -> float:
    """RMSE of ``scale·predicted`` vs measured, normalized by the mean
    measured value (dimensionless; comparable across modes)."""
    if not pairs:
        return 0.0
    mse = sum((m - scale * p) ** 2 for p, m in pairs) / len(pairs)
    mean = sum(m for _, m in pairs) / len(pairs)
    return math.sqrt(mse) / mean if mean > 0 else 0.0


def fit_stage_bw(events: Sequence[dict]) -> Optional[float]:
    """Effective h2d staging bandwidth (bytes/s) least-squares fitted
    from traced ``stage`` spans (``t ≈ bytes / B``)."""
    num = den = 0.0
    for e in events:
        if e.get("ph") == "X" and e.get("name") == "stage":
            b = float(e.get("args", {}).get("bytes", 0))
            t = float(e.get("dur", 0.0)) / 1e6       # µs -> s
            if b > 0 and t > 0:
                num += b * b
                den += b * t
    return (num / den) if den > 0 else None


@dataclasses.dataclass
class ConformanceReport:
    """Joined measured-vs-predicted accounting for one traced run."""

    residency: str
    predicted_s: float
    measured_s: float
    model_error: Dict[str, float]              # per kernel mode, a=1
    model_error_calibrated: Dict[str, float]   # per mode, fitted a
    scales: Dict[str, float]                   # fitted per-mode scale
    constants: Dict[str, float]                # defaults the model used
    calibrated_constants: Dict[str, float]     # effective constants
    per_layer: List[dict]                      # join rows
    density: List[dict]                        # per-bucket join rows
    halo: Optional[dict] = None                # mesh halo gap
    critical_path: Optional[dict] = None       # attrib summary

    @property
    def model_error_overall(self) -> float:
        return nrmse([(r["predicted_s"], r["measured_s"])
                      for r in self.per_layer])

    @property
    def model_error_overall_calibrated(self) -> float:
        return nrmse([(r["predicted_s"] * self.scales.get(r["kernel"], 1.0),
                       r["measured_s"]) for r in self.per_layer])

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["model_error_overall"] = self.model_error_overall
        d["model_error_overall_calibrated"] = \
            self.model_error_overall_calibrated
        return d

    def to_markdown(self) -> str:
        out = ["## Cost-model conformance", "",
               f"Residency: `{self.residency}` — predicted "
               f"{self.predicted_s:.4g}s vs measured "
               f"{self.measured_s:.4g}s "
               f"(overall error {self.model_error_overall:.3f} -> "
               f"{self.model_error_overall_calibrated:.3f} calibrated)",
               "", "| mode | layers | predicted s | measured s | "
               "scale | error | error (cal) |",
               "|---|---|---|---|---|---|---|"]
        modes = sorted(self.model_error)
        for m in modes:
            rows = [r for r in self.per_layer if r["kernel"] == m]
            out.append(
                f"| {m} | {len(rows)} "
                f"| {sum(r['predicted_s'] for r in rows):.4g} "
                f"| {sum(r['measured_s'] for r in rows):.4g} "
                f"| {self.scales[m]:.3g} | {self.model_error[m]:.3f} "
                f"| {self.model_error_calibrated[m]:.3f} |")
        out += ["", "### Calibrated machine constants", "",
                "| constant | default | effective |", "|---|---|---|"]
        for k, v in self.constants.items():
            eff = self.calibrated_constants.get(k)
            out.append(f"| {k} | {v:.4g} | "
                       + (f"{eff:.4g} |" if eff is not None else "- |"))
        if self.density:
            out += ["", "### Density buckets (sparse tiles)", "",
                    "| bucket | tiles | ops | predicted share | "
                    "measured share |", "|---|---|---|---|---|"]
            for r in self.density:
                out.append(
                    f"| {r['bucket']} | {r['tiles']} | {r['ops']} "
                    f"| {r['predicted_share']:.3f} "
                    f"| {r['measured_share']:.3f} |")
        if self.halo:
            h = self.halo
            out += ["", "### Halo exchange (mesh)", "",
                    "- gathered (measured all_gather): "
                    f"{h['gathered_bytes']} bytes",
                    "- targeted estimate (placement): "
                    f"{h['estimated_bytes']} bytes",
                    "- gap (gathered - estimated, positive = planner "
                    f"under-estimate): {h['gap_bytes']} bytes "
                    f"({100 * h['gap_fraction']:.1f}% of estimate)"]
        if self.critical_path:
            cp = self.critical_path
            out += ["", "### Critical path", "",
                    f"- makespan: {cp['makespan_us']:.0f} µs over "
                    f"{cp['n_spans']} spans; critical path "
                    f"{cp['critical_path_us']:.0f} µs "
                    f"({len(cp['critical_path'])} spans)"]
            stalls = cp.get("stall_us_by_name") or {}
            for name, us in sorted(stalls.items(),
                                   key=lambda kv: -kv[1])[:5]:
                out.append(f"- stall[{name}]: {us:.0f} µs")
        return "\n".join(out)


def _density_join(prog, per_mode_measured: Dict[str, float],
                  constants: ModelConstants) -> List[dict]:
    """Predicted vs measured cost share per tile-density bucket of the
    sparse kernel modes, reusing the ``exec_profile`` per-tile records.
    Measured share attributes each mode's measured seconds over its
    tiles proportionally to dispatched ops (the per-tile resolution the
    executor has); predicted share prices each tile with the roofline."""
    prof = (prog.manifest or {}).get("exec_profile")
    if not prof or not prof.get("tiles"):
        return []
    pg = prog.pgraph
    n1, n2 = pg.config.n1, pg.config.n2
    buckets: Dict[int, dict] = {}
    total_ops: Dict[str, int] = {}
    for rec in prof["tiles"].values():
        total_ops[rec["kernel"]] = (total_ops.get(rec["kernel"], 0)
                                    + int(rec["ops"]))
    tot_pred = 0.0
    for rec in prof["tiles"].values():
        nnz, slots = int(rec["nnz"]), int(rec["slots"])
        density = float(rec["density"])
        mode = rec["kernel"]
        flops = 2.0 * nnz * n2
        bytes_ = slots * 4 * 2 + n1 * n2 * 4
        t_pred = max(flops / constants.vpu_flops,
                     bytes_ / constants.hbm_bw) * int(rec["ops"])
        m_tot = per_mode_measured.get(mode, 0.0)
        t_meas = (m_tot * rec["ops"] / total_ops[mode]
                  if total_ops.get(mode) else 0.0)
        b = buckets.setdefault(min(int(density * 10), 9), {
            "tiles": 0, "ops": 0, "predicted_s": 0.0, "measured_s": 0.0})
        b["tiles"] += 1
        b["ops"] += int(rec["ops"])
        b["predicted_s"] += t_pred
        b["measured_s"] += t_meas
        tot_pred += t_pred
    tot_meas = sum(b["measured_s"] for b in buckets.values())
    out = []
    for k in sorted(buckets):
        b = buckets[k]
        out.append({
            "bucket": k, "tiles": b["tiles"], "ops": b["ops"],
            "predicted_share": (b["predicted_s"] / tot_pred
                                if tot_pred > 0 else 0.0),
            "measured_share": (b["measured_s"] / tot_meas
                               if tot_meas > 0 else 0.0)})
    return out


def build_report(prog, stats, residency: str = "device",
                 events: Optional[Sequence[dict]] = None,
                 overlap: bool = True,
                 constants: Optional[ModelConstants] = None
                 ) -> ConformanceReport:
    """Join one run's measurements against the cost model.

    ``prog`` is the :class:`CompiledProgram` (must carry ``source`` —
    recompile with ``use_cache=False`` after a cache hit), ``stats`` the
    run's :class:`ExecStats` (``per_layer`` populated), ``events`` an
    optional traced event list for the staging-bandwidth fit and the
    critical-path summary.
    """
    if getattr(prog, "source", None) is None:
        raise ValueError(
            "conformance needs prog.source (the object-graph Program); "
            "recompile with use_cache=False after a program-cache hit")
    if not getattr(stats, "per_layer", None):
        raise ValueError(
            "stats.per_layer is empty — run the program first (every "
            "residency path populates per-layer attribution)")
    c = constants or DEFAULT_CONSTANTS
    model_res = "host" if residency == "host" else "device"
    pred = {lc.layer_id: lc for lc in layer_costs(
        prog.source.program, overlap=overlap, residency=model_res,
        constants=c)}

    rows: List[dict] = []
    for r in stats.per_layer:
        lc = pred.get(r["layer"])
        if lc is None:
            continue
        rows.append({
            "layer": r["layer"], "kernel": r["kernel"],
            "step": r.get("step"),
            "instr_lo": r.get("instr_lo", -1),
            "instr_hi": r.get("instr_hi", -1),
            "tile_ops": r.get("tile_ops", 0),
            "predicted_s": lc.t, "measured_s": r["wall_s"],
            "h2d_bytes": r.get("h2d_bytes", 0)})

    by_mode: Dict[str, List[Tuple[float, float]]] = {}
    meas_by_mode: Dict[str, float] = {}
    for r in rows:
        by_mode.setdefault(r["kernel"], []).append(
            (r["predicted_s"], r["measured_s"]))
        meas_by_mode[r["kernel"]] = (meas_by_mode.get(r["kernel"], 0.0)
                                     + r["measured_s"])
    scales = {m: ls_scale(p) for m, p in by_mode.items()}
    err = {m: nrmse(p) for m, p in by_mode.items()}
    err_cal = {m: nrmse(p, scales[m]) for m, p in by_mode.items()}

    # Effective machine constants: measured ≈ scale · predicted and the
    # roofline divides by the constant, so the fitted constant is
    # default / scale (measured-time-weighted across modes sharing it).
    eff: Dict[str, float] = {}
    weight: Dict[str, float] = {}
    for m, a in scales.items():
        key = _CONSTANT_OF_MODE.get(m)
        if key is None or a <= 0:
            continue
        w = meas_by_mode.get(m, 0.0) or 1e-12
        eff[key] = eff.get(key, 0.0) + w * a
        weight[key] = weight.get(key, 0.0) + w
    calibrated = {}
    for k, v in c.to_dict().items():
        if k in eff and weight[k] > 0:
            calibrated[k] = v / (eff[k] / weight[k])
    if events is not None:
        bw = fit_stage_bw(events)
        if bw is not None:
            calibrated["stage_bw"] = bw

    halo = None
    est = int(getattr(stats, "halo_bytes", 0) or 0)
    gath = int(getattr(stats, "halo_gather_bytes", 0) or 0)
    if gath > 0 or est > 0:
        # Signed: positive = the all_gather moved more than the
        # placement estimate (planner under-estimate), negative = less.
        gap = gath - est
        halo = {"estimated_bytes": est, "gathered_bytes": gath,
                "gap_bytes": gap,
                "gap_fraction": (gap / est) if est > 0 else 0.0}

    cp = None
    if events is not None:
        cp = build_dag(list(events)).summary()

    return ConformanceReport(
        residency=residency,
        predicted_s=sum(r["predicted_s"] for r in rows),
        measured_s=sum(r["measured_s"] for r in rows),
        model_error=err, model_error_calibrated=err_cal, scales=scales,
        constants=c.to_dict(), calibrated_constants=calibrated,
        per_layer=rows,
        density=_density_join(prog, meas_by_mode, c),
        halo=halo, critical_path=cp)
