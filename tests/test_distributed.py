"""Distributed substrate tests.  Multi-device cases run in a subprocess
with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the main test
process stays single-device per the dry-run contract)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (compress, decompress,
                                           ef_transform,
                                           init_error_feedback)

REPO = os.path.join(os.path.dirname(__file__), "..")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_compression_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 1, (64, 64)).astype(np.float32))
    q, s = compress(g)
    assert q.dtype == jnp.int8
    err = float(jnp.max(jnp.abs(decompress(q, s) - g)))
    assert err <= float(s) * 0.5 + 1e-6
    # error feedback: residual carries the rounding error exactly
    ef = init_error_feedback({"w": g})
    (deq, ), _ = (None,), None
    newg, newef = ef_transform({"w": g}, ef)
    np.testing.assert_allclose(
        np.asarray(newg["w"] + newef["w"]), np.asarray(g), atol=1e-5)


@pytest.mark.slow          # 80 jitted train steps in a subprocess
def test_compressed_training_converges():
    """int8+EF training tracks uncompressed loss on a tiny model."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke_config
    from repro.models.steps import build_model, init_train_state
    from repro.models.layers import softmax_xent
    from repro.optim import adamw_update
    from repro.distributed.compression import ef_transform, init_error_feedback
    import dataclasses
    cfg = dataclasses.replace(get_smoke_config("qwen3-0.6b"), dtype="float32")
    model = build_model(cfg)
    def losses(compressed):
        params, opt = init_train_state(model, jax.random.PRNGKey(0))
        ef = init_error_feedback(params)
        rng = np.random.default_rng(0)
        # memorize one fixed batch: loss must drop
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32))
        labs = jnp.asarray(rng.integers(0, cfg.vocab, (4, 32)).astype(np.int32))
        ls = []
        @jax.jit
        def step(params, opt, ef):
            def lf(p):
                lg, _ = model.forward(p, toks)
                return softmax_xent(lg, labs)
            l, g = jax.value_and_grad(lf)(params)
            if compressed:
                g, ef = ef_transform(g, ef)
            params, opt = adamw_update(params, g, opt, 3e-3)
            return params, opt, ef, l
        for i in range(40):
            params, opt, ef, l = step(params, opt, ef)
            ls.append(float(l))
        return ls
    base = losses(False); comp = losses(True)
    print("BASE", base[0], base[-1], "COMP", comp[-1])
    assert comp[-1] < 0.7 * base[0], (comp[-1], base[0])    # it learns
    assert abs(comp[-1] - base[-1]) < 0.35 * abs(base[0])   # and tracks
    """
    out = _run_subprocess(code)
    assert "BASE" in out


def test_moe_a2a_matches_dense():
    """Expert-parallel all-to-all MoE == dense oracle on an 8-device mesh."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import moe as MOE
    from repro.compat import make_mesh, set_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    d, f, e, topk = 16, 32, 8, 2
    p = MOE.moe_init(jax.random.PRNGKey(0), d, f, e, jnp.float32, n_shared=1)
    x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (4, 8, d)).astype(np.float32))
    y_dense, aux_d = MOE.moe_dense(p, x, topk)
    with set_mesh(mesh):
        y_a2a, aux_a = MOE.moe_a2a(p, x, topk, cap_factor=4.0, mesh=mesh)
    err = float(jnp.max(jnp.abs(y_dense - y_a2a)))
    print("ERR", err, float(aux_d), float(aux_a))
    assert err < 2e-4, err
    assert abs(float(aux_d) - float(aux_a)) < 1e-4
    """
    out = _run_subprocess(code)
    assert "ERR" in out


def test_zero_sharding_specs():
    """ZeRO-1 adds a data-axis partition to optimizer state leaves."""
    code = """
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.distributed.zero import opt_state_specs, zero_param_spec
    from repro.compat import make_mesh, set_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    # plain leaf: first divisible dim gets 'data'
    s = zero_param_spec(P(None, "model"), (8, 16), mesh)
    assert s == P("data", "model"), s
    # already-sharded dim combines axes when divisible
    s2 = zero_param_spec(P("model", None), (8, 3), mesh)
    assert s2 == P(("model", "data"), None), s2
    print("OK")
    """
    out = _run_subprocess(code)
    assert "OK" in out


@pytest.mark.slow          # granite-8b pjit on an 8-device mesh
def test_sharded_train_step_matches_single_device():
    """pjit on a 4x2 mesh == single-device math (same loss/params)."""
    code = """
    import dataclasses, jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models.steps import build_model, init_train_state, make_train_step
    from repro.distributed import sharding as SH
    cfg = dataclasses.replace(get_smoke_config("granite-8b"), dtype="float32")
    model = build_model(cfg)
    params, opt = init_train_state(model, jax.random.PRNGKey(0))
    ts = make_train_step(model, cfg)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)).astype(np.int32)),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)).astype(np.int32))}
    p1, o1, m1 = jax.jit(ts)(params, opt, batch)
    from repro.compat import make_mesh, set_mesh
    mesh = make_mesh((4, 2), ("data", "model"))
    with set_mesh(mesh):
        psh = SH.param_shardings(mesh, params)
        bsh = {k: NamedSharding(mesh, P(("data",), None)) for k in batch}
        f = jax.jit(ts, in_shardings=(psh, None, bsh))
        p2, o2, m2 = f(params, opt, batch)
    d = abs(float(m1["loss"]) - float(m2["loss"]))
    print("LOSSDIFF", d)
    assert d < 1e-4, d
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    print("PDIFF", err)
    assert err < 1e-4, err
    """
    out = _run_subprocess(code)
    assert "LOSSDIFF" in out


def test_pipeline_parallel_equivalence():
    """GPipe shard_map schedule == sequential stage application."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_apply
    from repro.compat import make_mesh, set_mesh
    mesh = make_mesh((4,), ("stage",))
    rng = np.random.default_rng(0)
    n_stages, n_micro, mb, d = 4, 8, 2, 16
    Ws = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (n_micro, mb, d)).astype(np.float32))
    def stage_fn(w, h):
        return jnp.tanh(h @ w)
    with set_mesh(mesh):
        y_pipe = pipeline_apply(stage_fn, Ws, x, mesh, axis="stage")
    y_seq = x
    for s in range(n_stages):
        y_seq = jax.vmap(lambda h: stage_fn(Ws[s], h))(y_seq)
    err = float(jnp.max(jnp.abs(y_pipe - y_seq)))
    print("ERR", err)
    assert err < 1e-5, err
    """
    out = _run_subprocess(code)
    assert "ERR" in out


def test_moe_local_matches_dense_decode():
    """a2a-free local-experts decode path == dense oracle (kimi decode
    hillclimb, EXPERIMENTS.md §Perf C1)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.models import moe as MOE
    from repro.compat import make_mesh, set_mesh
    mesh = make_mesh((2, 4), ("data", "model"))
    d, f, e, topk = 16, 32, 8, 2
    p = MOE.moe_init(jax.random.PRNGKey(0), d, f, e, jnp.float32, n_shared=1)
    for b, t in [(4, 1), (8, 2)]:
        x = jnp.asarray(np.random.default_rng(b).normal(0, 1, (b, t, d))
                        .astype(np.float32))
        y_dense, _ = MOE.moe_dense(p, x, topk)
        with set_mesh(mesh):
            y_loc, _ = MOE.moe_local(p, x, topk, cap_factor=4.0, mesh=mesh)
        err = float(jnp.max(jnp.abs(y_dense - y_loc)))
        assert err < 2e-4, (b, t, err)
    print("OK")
    """
    out = _run_subprocess(code)
    assert "OK" in out
