"""Partition-centric out-of-core execution (paper §6.5, Algorithms 6-8).

Covers the tentpole acceptance criteria:
  * ``residency="host"`` (features host-resident, one destination
    shard's working set staged on device at a time, double-buffered) is
    BIT-identical to the device-resident path for GCN/SAGE/GAT on the
    b1/b3/b6 fixtures;
  * interval liveness actually frees: the peak number of concurrently
    live padded outputs (counted through the executor's liveness hook)
    is bounded by the residency table's live-set, strictly below "every
    layer alive" on a deep model;
  * the shard schedule round-trips through ``.gagi`` (and executing the
    loaded bundle host-resident matches in-process device execution);
  * ``resident_budget_bytes`` gates: the device path refuses a run whose
    liveness-aware peak exceeds the budget, the streaming path completes
    under the same budget with the same bits.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.passes.partition import PartitionConfig
from repro.engine import Engine, ResidentBudgetError
from repro.engine.executor import derive_residency

GEOM = PartitionConfig(n1=32, n2=8)


def _g(nv=90, ne=400, f=12, c=4, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _engine(**kw) -> Engine:
    return Engine(geometry=GEOM, n_pes=4, **kw)


# --------------------------------------------------------------------------- #
# Bit-exactness: streaming == resident, for GCN (b1), SAGE (b3), GAT (b6).
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["b1", "b3", "b6"])
@pytest.mark.parametrize("gseed", [3, 21])
def test_host_resident_is_bit_identical(name, gseed):
    g = _g(seed=gseed)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile(name, g)
    y_dev = np.asarray(eng.run(prog, x))
    y_host = np.asarray(eng.run(prog, x, residency="host"))
    assert np.array_equal(y_dev, y_host)
    # the streaming pass actually streamed (several shards staged)
    assert eng.exec_stats.shards_streamed > 1
    assert eng.exec_stats.h2d_bytes > 0


def test_run_batch_host_matches_device():
    g = _g(seed=7)
    x = jnp.asarray(G.random_features(g, seed=4))
    xs = jnp.stack([x, x * 0.5, x * -1.0])
    eng = _engine()
    prog = eng.compile("b1", g)
    yd = np.asarray(eng.run_batch(prog, xs))
    yh = np.asarray(eng.run_batch(prog, xs, residency="host"))
    assert np.array_equal(yd, yh)


@pytest.mark.parametrize("name", ["b1", "b6"])
def test_host_batch_interleaves_lanes_and_amortizes_h2d(name):
    """Host-path batching: lanes stream TOGETHER, interleaved per staged
    shard, so each shard's tile working set is shipped once per batch
    instead of once per lane — strictly less H2D traffic than looping
    the lanes, with the same bits and one double-buffered window."""
    g = _g(seed=7)
    x = jnp.asarray(G.random_features(g, seed=4))
    lanes = [x, x * 0.5, x * -1.0, x + 2.0]
    eng = _engine()
    prog = eng.compile(name, g)
    h2d_seq = 0
    shards_seq = 0
    for xl in lanes:
        eng.run(prog, xl, residency="host")
        h2d_seq += eng.exec_stats.h2d_bytes
        shards_seq += eng.exec_stats.shards_streamed
    xs = jnp.stack(lanes)
    yh = np.asarray(eng.run_batch(prog, xs, residency="host"))
    st = eng.exec_stats
    assert st.runs == 1                      # one logical batched pass
    assert st.h2d_bytes < h2d_seq            # tile transfers amortized
    assert st.shards_streamed == shards_seq // len(lanes)
    yd = np.asarray(eng.run_batch(prog, xs))
    assert np.array_equal(yd, yh)


def test_compile_residency_default_is_carried_not_cached():
    g = _g(seed=9)
    x = jnp.asarray(G.random_features(g, seed=1))
    eng = _engine()
    ph = eng.compile("b1", g, residency="host")
    assert ph.default_residency == "host"
    y_host = np.asarray(eng.run(ph, x))          # uses the host default
    assert eng.exec_stats.shards_streamed > 0
    # the shared cache entry keeps serving device-resident by default
    pd = eng.compile("b1", g)
    assert pd.default_residency is None
    assert np.array_equal(np.asarray(eng.run(pd, x)), y_host)


# --------------------------------------------------------------------------- #
# Liveness: the manifest table is respected and outputs really free.
# --------------------------------------------------------------------------- #
def _expected_peak_live(prog) -> int:
    res = prog.manifest["residency"]
    last_use = {int(k): v for k, v in res["last_use"].items()}
    plan = prog.plan()
    n = len(plan.layers)
    births = {lp.layer_id: t for t, lp in enumerate(plan.layers)}
    return max(
        sum(1 for lid, bt in births.items()
            if bt <= t <= max(last_use.get(lid, n), bt))
        for t in range(n))


@pytest.mark.parametrize("residency", ["device", "host"])
def test_liveness_frees_outputs(residency):
    g = _g(seed=5)
    x = jnp.asarray(G.random_features(g, seed=3))
    eng = _engine()
    prog = eng.compile("b8", g)        # deepest benchmark stack
    events = []
    eng._executor.liveness_hook = \
        lambda ev, lid, live: events.append((ev, lid, live))
    eng.run(prog, x, residency=residency)
    expected = _expected_peak_live(prog)
    n_layers = len(prog.plan().layers)
    assert expected < n_layers         # the bound itself is non-trivial
    assert eng.exec_stats.peak_live_outputs <= expected
    frees = [e for e in events if e[0] == "free"]
    allocs = [e for e in events if e[0] == "alloc"]
    assert len(allocs) == n_layers
    assert frees                       # something was actually released
    # everything except the sink (and values still live at the end) is
    # freed exactly once
    freed = {lid for _, lid, _ in frees}
    assert prog.manifest["sink"] not in freed


# --------------------------------------------------------------------------- #
# Manifest + .gagi round-trip of the shard schedule.
# --------------------------------------------------------------------------- #
def test_manifest_residency_shape():
    g = _g(seed=11)
    prog = _engine().compile("b6", g)
    res = prog.manifest["residency"]
    assert set(res) == {"last_use", "layers"}
    plan = prog.plan()
    for lp in plan.layers:
        rl = res["layers"][str(lp.layer_id)]
        # shard_order is a permutation of the shards that have sources
        assert sorted(rl["shard_order"]) == sorted(
            int(j) for j in rl["sources"])
        for js, ks in rl["sources"].items():
            assert all(0 <= k < prog.pgraph.n_blocks for k in ks)
    # every consumed value appears in the liveness table, incl. input -1
    assert "-1" in res["last_use"]


def test_gagi_roundtrips_shard_schedule(tmp_path):
    g = _g(seed=13)
    x = jnp.asarray(G.random_features(g, seed=6))
    eng = _engine()
    prog = eng.compile("b6", g)
    y_dev = np.asarray(eng.run(prog, x))
    path = os.path.join(str(tmp_path), "gat.gagi")
    prog.save(path)
    loaded = _engine().load(path)
    assert loaded.manifest["residency"] == prog.manifest["residency"]
    y_host = np.asarray(_engine().run(loaded, x, residency="host"))
    assert np.array_equal(y_dev, y_host)


def test_pre_residency_bundle_falls_back_to_derivation(tmp_path):
    """A .gagi written before manifests carried a residency section
    still streams: the executor derives the schedule from the binary."""
    g = _g(seed=17)
    x = jnp.asarray(G.random_features(g, seed=8))
    eng = _engine()
    prog = eng.compile("b1", g)
    y_dev = np.asarray(eng.run(prog, x))
    path = os.path.join(str(tmp_path), "old.gagi")
    prog.save(path)
    loaded = _engine().load(path)
    emitted = loaded.manifest.pop("residency")   # simulate an old bundle
    fresh = _engine()
    y_host = np.asarray(fresh.run(loaded, x, residency="host"))
    assert np.array_equal(y_dev, y_host)
    # and the derived schedule agrees with what the compiler emitted
    derived = derive_residency(loaded.plan(), loaded.manifest["layers"])
    assert derived == emitted


# --------------------------------------------------------------------------- #
# Budget: a point where only the partitioned path completes.
# --------------------------------------------------------------------------- #
def test_budget_gates_device_but_not_streaming():
    g = _g(nv=400, ne=2400, seed=19)
    x = jnp.asarray(G.random_features(g, seed=5))
    eng = _engine()
    prog = eng.compile("b1", g)
    y_ref = np.asarray(eng.run(prog, x))
    np.asarray(eng.run(prog, x, residency="host"))
    host_peak = eng.exec_stats.peak_stage_bytes
    est = eng._executor.estimate_device_peak_bytes(prog, x.shape[1])
    assert host_peak < est             # streaming working set is smaller
    eng.resident_budget_bytes = (host_peak + est) // 2
    with pytest.raises(ResidentBudgetError):
        eng.run(prog, x)
    y_host = np.asarray(eng.run(prog, x, residency="host"))
    assert np.array_equal(y_ref, y_host)


def test_budget_gates_batched_device_runs_at_batch_scale():
    """A budget that fits ONE lane must still refuse a vmapped batch
    (and keep refusing on the memoized-executable replay path)."""
    g = _g(seed=31)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile("b1", g)
    est1 = eng._executor.estimate_device_peak_bytes(prog, x.shape[1])
    eng.resident_budget_bytes = est1 + 1
    eng.run(prog, x)                             # one lane fits
    xs = jnp.stack([x] * 8)
    with pytest.raises(ResidentBudgetError):
        eng.run_batch(prog, xs)
    eng.resident_budget_bytes = None
    eng.run_batch(prog, xs)                      # memoize the executable
    eng.resident_budget_bytes = est1 + 1
    with pytest.raises(ResidentBudgetError):     # replay is gated too
        eng.run_batch(prog, xs)
    eng.resident_budget_bytes = None


def test_budget_refusal_reports_peak_budget_and_first_layer():
    """A device-path refusal must be actionable: the message carries
    the liveness-aware peak estimate, the budget (both in bytes), the
    overshoot, and names the FIRST layer step that exceeds it."""
    g = _g(nv=400, ne=2400, seed=19)
    x = jnp.asarray(G.random_features(g, seed=5))
    eng = _engine()
    prog = eng.compile("b1", g)
    est = eng._executor.estimate_device_peak_bytes(prog, x.shape[1])
    budget = est // 2
    eng.resident_budget_bytes = budget
    with pytest.raises(ResidentBudgetError) as ei:
        eng.run(prog, x)
    msg = str(ei.value)
    assert str(est) in msg and str(budget) in msg
    assert str(est - budget) in msg          # the overshoot
    assert "first exceeded at layer" in msg
    # the named layer is the first step whose live set busts the budget
    static, x_bytes, live = eng._executor._live_profile(prog, x.shape[1])
    first = next(t for t, lv in enumerate(live)
                 if static + x_bytes + lv > budget)
    lp = prog.plan().layers[first]
    assert f"layer {lp.layer_id}" in msg
    eng.resident_budget_bytes = None


def test_budget_rejects_oversized_shard_in_host_mode():
    g = _g(seed=23)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine(resident_budget_bytes=1024)    # absurdly small
    prog = eng.compile("b1", g)
    with pytest.raises(ResidentBudgetError):
        eng.run(prog, x, residency="host")


def test_graph_data_is_device_resident_only():
    g = _g(seed=29)
    x = jnp.asarray(G.random_features(g, seed=2))
    eng = _engine()
    prog = eng.compile("b1", g)
    with pytest.raises(ValueError, match="device-resident"):
        eng.run(prog, x, graph_data={"tiles": {}}, residency="host")
