"""LRU program cache for the streaming request interface.

Keyed by (model schema hash, graph partition signature, geometry) — see
``repro.engine.engine`` for key construction.  Repeated (model, graph)
shapes skip software compilation entirely (T_LoC == 0 on a hit), which is
what lets one overlay serve heavy repeated traffic.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Generic, Optional, TypeVar

V = TypeVar("V")


class LRUCache(Generic[V]):
    def __init__(self, capacity: int = 32) -> None:
        if capacity < 1:
            raise ValueError(f"cache capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._d: "OrderedDict[str, V]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._d)

    def __contains__(self, key: str) -> bool:
        return key in self._d

    def get(self, key: str) -> Optional[V]:
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key: str, value: V) -> None:
        if key in self._d:
            self._d.move_to_end(key)
        self._d[key] = value
        while len(self._d) > self.capacity:
            self._d.popitem(last=False)
            self.evictions += 1

    def values(self):
        return list(self._d.values())

    def clear(self) -> None:
        self._d.clear()
