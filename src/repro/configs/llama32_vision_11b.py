"""llama-3.2-vision-11b [vlm] 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attn image layers every 5th layer; vision frontend
STUB (input_specs provides projected patch embeddings)
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-11b", family="vlm", n_layers=40,
        d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
        cross_attn_every=5, n_vision_tokens=1601, vision_dim=1280,
        rope_theta=500000.0)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, cross_attn_every=2, n_vision_tokens=16,
        attn_chunk=0, remat="none")
