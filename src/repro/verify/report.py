"""VerifyReport — the machine-readable result of a verification pass.

Every checker emits :class:`Violation` records naming the check that
fired, the offending instruction index range (``instr_lo``/``instr_hi``
— the same coordinates ``ExecStats.per_layer`` and the obs layer spans
carry, so a violation is joinable against traces and profiles), and a
human sentence.  A :class:`VerifyReport` rolls the full run up: which
checks ran, which were skipped (and why — a bytes-only verification
cannot re-derive the residency schedule, for instance), and renders as
JSON (CI artifact) or markdown (human artifact).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List

# Canonical checker roster, in run order.  ``checks_run`` is always a
# subset of this; anything absent lands in ``checks_skipped`` with a
# reason.
ALL_CHECKS = (
    "structure",
    "def_before_use",
    "use_after_free",
    "partition_coverage",
    "kernel_legality",
    "halo_completeness",
    "resident_budget",
    "liveness_schedule",
)


@dataclasses.dataclass
class Violation:
    """One checker finding, anchored to an instruction index range."""

    check: str
    message: str
    layer_id: int = -1
    instr_lo: int = -1
    instr_hi: int = -1

    def to_dict(self) -> Dict[str, Any]:
        return {
            "check": self.check,
            "message": self.message,
            "layer_id": int(self.layer_id),
            "instr_lo": int(self.instr_lo),
            "instr_hi": int(self.instr_hi),
        }

    def __str__(self) -> str:
        where = ""
        if self.instr_lo >= 0:
            where = f" [instr {self.instr_lo}..{self.instr_hi}]"
        layer = f" layer {self.layer_id}" if self.layer_id >= 0 else ""
        return f"{self.check}:{layer}{where} {self.message}"


@dataclasses.dataclass
class VerifyReport:
    """Outcome of one program verification."""

    program: str = ""
    checks_run: List[str] = dataclasses.field(default_factory=list)
    checks_skipped: Dict[str, str] = dataclasses.field(default_factory=dict)
    violations: List[Violation] = dataclasses.field(default_factory=list)
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not self.violations

    @property
    def checks_failed(self) -> List[str]:
        seen: List[str] = []
        for v in self.violations:
            if v.check not in seen:
                seen.append(v.check)
        return seen

    @property
    def checks_passed(self) -> List[str]:
        bad = set(self.checks_failed)
        return [c for c in self.checks_run if c not in bad]

    # ------------------------------------------------------------------ #
    def add(self, check: str, message: str, layer_id: int = -1,
            instr_lo: int = -1, instr_hi: int = -1) -> None:
        self.violations.append(Violation(
            check=check, message=message, layer_id=layer_id,
            instr_lo=instr_lo, instr_hi=instr_hi))

    def ran(self, check: str) -> None:
        if check not in self.checks_run:
            self.checks_run.append(check)

    def skip(self, check: str, reason: str) -> None:
        if check not in self.checks_run:
            self.checks_skipped[check] = reason

    # ------------------------------------------------------------------ #
    def to_dict(self) -> Dict[str, Any]:
        return {
            "program": self.program,
            "ok": self.ok,
            "checks_run": list(self.checks_run),
            "checks_passed": list(self.checks_passed),
            "checks_failed": list(self.checks_failed),
            "checks_skipped": dict(self.checks_skipped),
            "violations": [v.to_dict() for v in self.violations],
            "stats": dict(self.stats),
        }

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def to_markdown(self) -> str:
        lines = [f"## `{self.program or 'program'}` — "
                 f"{'PASS' if self.ok else 'FAIL'}", ""]
        lines.append(f"checks passed: {len(self.checks_passed)}/"
                     f"{len(self.checks_run)}"
                     + (" (skipped: "
                        f"{', '.join(sorted(self.checks_skipped))})"
                        if self.checks_skipped else ""))
        if self.stats:
            stat = ", ".join(f"{k}={v}" for k, v in sorted(
                self.stats.items()) if not isinstance(v, dict))
            lines += ["", f"_{stat}_"]
        if self.violations:
            lines += ["", "| check | layer | instrs | message |",
                      "|---|---|---|---|"]
            for v in self.violations:
                span = (f"{v.instr_lo}..{v.instr_hi}"
                        if v.instr_lo >= 0 else "")
                lid = str(v.layer_id) if v.layer_id >= 0 else ""
                lines.append(f"| `{v.check}` | {lid} | {span} | "
                             f"{v.message} |")
        lines.append("")
        return "\n".join(lines)


class VerifyError(RuntimeError):
    """Raised by ``Engine.compile(verify=True)`` on a failing report."""

    def __init__(self, report: VerifyReport) -> None:
        self.report = report
        head = "; ".join(str(v) for v in report.violations[:3])
        more = (f" (+{len(report.violations) - 3} more)"
                if len(report.violations) > 3 else "")
        super().__init__(
            f"program verification failed for {report.program or '?'}: "
            f"{head}{more}")
