"""CI gate: compare fresh BENCH_*.json against committed baselines.

Usage::

    python benchmarks/check_trajectory.py \
        --baseline-dir /tmp/bench_baselines --fresh-dir . \
        --out TRAJECTORY.md

Exits 1 when any gated metric leaves its tolerance band (see
``repro.obs.trajectory.DEFAULT_SPECS``); prints the markdown report
either way.  Files whose ``mode`` differs between baseline and fresh
run (e.g. a committed full-scale run vs a CI ``--smoke`` run) are
skipped, not failed.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.obs.trajectory import DEFAULT_SPECS, compare_dirs  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline-dir", required=True,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("--fresh-dir", required=True,
                    help="directory holding the freshly produced files")
    ap.add_argument("--out", default=None,
                    help="also write the markdown report here")
    ap.add_argument("--files", nargs="*", default=None,
                    help="subset of registered files to compare")
    args = ap.parse_args(argv)

    report = compare_dirs(args.baseline_dir, args.fresh_dir,
                          DEFAULT_SPECS, files=args.files)
    md = report.to_markdown()
    print(md)
    if args.out:
        with open(args.out, "w") as f:
            f.write(md + "\n")
    if not report.ok:
        print(f"trajectory gate FAILED: {len(report.regressions)} "
              "metric(s) regressed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
