"""OverlayPool — K virtual overlays with cache-affinity routing.

One :class:`~repro.engine.Engine` is one overlay: a fixed tile-geometry
contract, its own ACK kernel cache and its own LRU *program* cache.  A
pool is the host-scale analogue of the paper's PE array, and routing is
Algorithm 9's dynamic load balance lifted to request granularity:

  * **cache affinity** — a cache key (deployed (model, graph) pair) is
    routed to the overlay that already holds its compiled program, so
    repeated traffic never pays T_LoC twice and never duplicates the
    program across overlays;
  * **least-loaded fallback** — a new key goes to the overlay with the
    least assigned work, via the very same :func:`lpt_assign` greedy
    the compiler uses to pack tiling blocks onto PEs
    (``repro.core.passes.schedule``): the idle PE pulls the next block.

Load is tracked as cumulative assigned cost (graph work x batch size),
updated at placement time — deterministic whatever the thread timing of
the serving loop above.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.core.passes.partition import PartitionConfig
from repro.core.passes.schedule import lpt_assign
from repro.engine import Engine, InferenceRequest, InferenceResponse

from .batcher import Batch, request_cost
from .metrics import Metrics


class OverlayPool:
    """K engines + cache-affinity routing; see module docstring."""

    def __init__(self, n_overlays: int = 2,
                 geometry: Optional[PartitionConfig] = None, *,
                 engines: Optional[Sequence[Engine]] = None,
                 metrics: Optional[Metrics] = None,
                 **engine_kw) -> None:
        if engines is not None:
            self.engines: List[Engine] = list(engines)
        else:
            self.engines = [Engine(geometry=geometry, **engine_kw)
                            for _ in range(n_overlays)]
        if not self.engines:
            raise ValueError("OverlayPool needs at least one overlay")
        tags = {e._geometry_tag() for e in self.engines}
        if len(tags) != 1:
            # Affinity routing compares cache keys across overlays, so
            # every overlay must produce the same key for a request.
            raise ValueError(
                f"all overlays must share one tile geometry, got {tags}")
        self.metrics = metrics if metrics is not None else Metrics()
        self._affinity: Dict[str, int] = {}
        self._load: List[float] = [0.0] * len(self.engines)

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self.engines)

    @property
    def loads(self) -> List[float]:
        return list(self._load)

    def cache_key(self, req: InferenceRequest) -> str:
        """Pool-wide batching/routing key (identical on every overlay).

        Live-versioned graphs (``repro.livegraph``) get a ``@v<N>``
        suffix: versions deliberately SHARE the engine's structural
        cache key (that is the no-recompile guarantee), but a batch is
        one binary pass over one tile set, so the batcher must never
        coalesce requests admitted against different versions.
        :func:`engine_key` strips the suffix wherever the program cache
        is consulted, so affinity still routes every version of a graph
        to the overlay that compiled it."""
        key = self.engines[0].cache_key(req.model, req.graph,
                                        seed=req.seed)
        lv = getattr(req.graph, "_live_version", None)
        return key if lv is None else f"{key}@v{lv.vid}"

    @staticmethod
    def engine_key(key: str) -> str:
        """Batch key -> program-cache key (drop the live-version tag)."""
        return key.split("@v", 1)[0]

    def overlay_for(self, key: str) -> Optional[int]:
        """Which overlay already holds this key's compiled program?

        Checks the live program caches first (covers engines warmed
        out-of-band and keys re-compiled after eviction), then the
        sticky affinity map (keeps a key's home overlay even while its
        program is momentarily evicted, preserving kernel locality)."""
        ekey = self.engine_key(key)
        for i, e in enumerate(self.engines):
            if ekey in e.cache:
                return i
        return self._affinity.get(key, self._affinity.get(ekey))

    def place(self, batches: Sequence[Batch]) -> List[int]:
        """Assign each batch to an overlay; deterministic.

        Affinity-bound keys go home; the rest are LPT-packed onto the
        least-loaded overlays (``lpt_assign`` seeded with current
        loads).  Loads are charged at placement time.
        """
        idxs: List[Optional[int]] = [None] * len(batches)
        new: List[int] = []
        for i, b in enumerate(batches):
            home = self.overlay_for(b.key)
            if home is not None:
                idxs[i] = home
                self._affinity[b.key] = home
                self._affinity[self.engine_key(b.key)] = home
                self._load[home] += b.cost
            else:
                new.append(i)
        if new:
            assignment, self._load = lpt_assign(
                [batches[i].cost for i in new], len(self.engines),
                initial_loads=self._load)
            for i, home in zip(new, assignment):
                idxs[i] = home
                self._affinity[batches[i].key] = home
                self._affinity[self.engine_key(batches[i].key)] = home
        return [int(i) for i in idxs]  # every slot is assigned above

    def route(self, key: str, cost: float = 1.0) -> int:
        """Route a single key (thin wrapper over :meth:`place`)."""
        return self.place([Batch(key=key, requests=[], indices=[],
                                 created_at=0.0, cost=cost)])[0]

    # ------------------------------------------------------------------ #
    def submit_batch(self, batch: Batch) -> List[InferenceResponse]:
        """Route one batch and execute it as a single binary pass."""
        idx = self.place([batch])[0]
        return self.execute_on(idx, batch)

    def execute_on(self, idx: int, batch: Batch
                   ) -> List[InferenceResponse]:
        """Execute an already-placed batch on overlay ``idx``."""
        resps = self.engines[idx].submit_batch(batch.requests)
        for r in resps:
            r.overlay = idx
        return resps

    def serve(self, requests: Sequence[InferenceRequest], **loop_kw
              ) -> List[InferenceResponse]:
        """Batched, multi-overlay drain of a request stream.

        Convenience wrapper: builds a :class:`~repro.runtime.ServeLoop`
        over this pool (sharing its metrics) and serves the stream.
        Keyword arguments are forwarded to the loop (``max_batch``,
        ``max_wait_us``, ``max_queue``, ``overlap_overlays``, ...).
        """
        from .serve_loop import ServeLoop
        loop = ServeLoop(self, **loop_kw)
        try:
            return loop.serve(requests)
        finally:
            loop.shutdown()     # don't leak per-overlay worker threads

    # ------------------------------------------------------------------ #
    @property
    def cache_hit_rate(self) -> float:
        """Program-cache hit rate aggregated across overlays."""
        hits = sum(e.stats.cache_hits for e in self.engines)
        total = sum(e.stats.requests for e in self.engines)
        return hits / total if total else 0.0

    def stats_snapshot(self) -> dict:
        """JSON-serializable per-overlay + aggregate engine stats."""
        per = [{
            "requests": e.stats.requests,
            "cache_hits": e.stats.cache_hits,
            "cache_misses": e.stats.cache_misses,
            "compiles": e.stats.compiles,
            "programs_cached": len(e.cache),
            "total_t_loc_s": round(e.stats.total_t_loc, 6),
            "total_t_loh_s": round(e.stats.total_t_loh, 6),
            "assigned_load": round(load, 3),
        } for e, load in zip(self.engines, self._load)]
        return {
            "n_overlays": len(self.engines),
            "cache_hit_rate": round(self.cache_hit_rate, 6),
            "overlays": per,
        }


def warm_pool(pool: OverlayPool,
              requests: Sequence[InferenceRequest],
              clock=time.monotonic) -> None:
    """Pre-compile one program per distinct cache key (batch size 1),
    so steady-state traffic measures pure T_LoH.  Routing happens
    through the pool, so affinity is established exactly as live
    traffic would."""
    seen = set()
    for req in requests:
        key = pool.cache_key(req)
        if key in seen:
            continue
        seen.add(key)
        pool.submit_batch(Batch(key=key, requests=[req], indices=[0],
                                created_at=clock(),
                                cost=request_cost(req)))
