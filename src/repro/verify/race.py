"""Dynamic race detector — recorded trace order vs static hazard edges.

The static analyzer (:mod:`repro.verify.checks`) proves what *must*
happen-before what; the tracer (:mod:`repro.obs.tracer`) records what
*did*.  This module cross-checks the two: every RAW layer edge in the
``dep_graph`` manifest section must appear in the trace as
producer-span-ends-before-consumer-span-starts, every streamed shard's
compute window must be preceded by its own h2d stage span, and no
layer's execution may overlap that layer's halo exchange (the gather is
a barrier — compute reading half-exchanged sub-fibers is the mesh
path's one true race).

Order violations are reported through the same :class:`VerifyReport`
machinery as the static checks, under check names:

  race_layer_order          RAW layer edge inverted/overlapped
  race_stage_before_compute compute window opened before its working
                            set finished staging
  race_halo_barrier         layer execution overlaps its halo exchange

``stats["overlap_pairs"]`` counts stage(j')-inside-compute(j) windows
(j' != j) — the double-buffer overlap the streaming path exists for, so
a healthy host-streaming trace shows a positive count here with zero
violations.
"""
from __future__ import annotations

import json
import re
from typing import Any, Dict, List, Optional, Tuple

from .report import VerifyReport

# Two spans touching end-to-start is legal ordering; only a genuine
# inversion/overlap beyond float-roundoff fires.
_EPS_US = 1e-6

_LAYER_RE = re.compile(r"^layer(\d+)$")


class _Span:
    __slots__ = ("name", "cat", "t0", "t1", "track", "args")

    def __init__(self, ev: dict, track: str) -> None:
        self.name = ev.get("name", "")
        self.cat = ev.get("cat", "")
        self.t0 = float(ev.get("ts", 0.0))
        self.t1 = self.t0 + float(ev.get("dur", 0.0))
        self.track = track
        self.args = ev.get("args") or {}


def _load_events(trace: Any) -> List[dict]:
    """Accept a Tracer, a trace dict, a raw event list, or a
    ``trace.json`` path."""
    if hasattr(trace, "to_dict"):
        trace = trace.to_dict()
    if isinstance(trace, str):
        with open(trace) as f:
            trace = json.load(f)
    if isinstance(trace, dict):
        return list(trace.get("traceEvents", []))
    return list(trace)


def _spans(events: List[dict]) -> List[_Span]:
    tracks: Dict[int, str] = {}
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            tracks[ev.get("tid", 0)] = ev.get("args", {}).get("name", "")
    out = [_Span(ev, tracks.get(ev.get("tid", 0), ""))
           for ev in events if ev.get("ph") == "X"]
    out.sort(key=lambda s: s.t0)
    return out


def _layer_edges_of(manifest: Optional[dict]) -> List[Tuple[int, int]]:
    dg = (manifest or {}).get("dep_graph") or {}
    return [(int(a), int(b)) for a, b, kind in dg.get("layer_edges", [])
            if kind == "RAW" and a >= 0]


def check_trace(trace: Any, prog_or_manifest: Any = None
                ) -> VerifyReport:
    """Cross-check a recorded trace against static hazard edges.

    ``prog_or_manifest``: a :class:`CompiledProgram`, a manifest dict
    (with a ``dep_graph`` section), or ``None`` — without it the
    layer-order check is skipped and only the self-contained stage /
    halo orderings run."""
    manifest = prog_or_manifest
    if manifest is not None and hasattr(manifest, "manifest"):
        manifest = manifest.manifest
    report = VerifyReport(program=(manifest or {}).get(
        "model_name", "<trace>"))
    spans = _spans(_load_events(trace))
    report.stats["n_spans"] = len(spans)

    # Index the span families the executor emits.
    layer_spans: Dict[int, List[_Span]] = {}
    stage_spans: List[_Span] = []
    compute_spans: List[_Span] = []
    halo_spans: List[_Span] = []
    for s in spans:
        m = _LAYER_RE.match(s.name)
        if m and s.cat == "exec":
            layer_spans.setdefault(int(m.group(1)), []).append(s)
        elif s.name == "stage" and s.cat == "h2d":
            stage_spans.append(s)
        elif s.name == "compute" and s.cat == "exec":
            compute_spans.append(s)
        elif s.name == "halo_exchange" and s.cat == "comm":
            halo_spans.append(s)

    # -- race_layer_order -------------------------------------------------- #
    edges = _layer_edges_of(manifest)
    if manifest is None or not edges:
        report.skip("race_layer_order",
                    "no dep_graph layer edges supplied")
    else:
        report.ran("race_layer_order")
        for prod, cons in edges:
            ps, cs = layer_spans.get(prod, []), layer_spans.get(cons, [])
            if not ps or not cs:
                continue
            # Pair per track (mesh runs emit one span per device) and
            # per round (a trace may hold many runs of the program).
            by_track: Dict[str, Tuple[List[_Span], List[_Span]]] = {}
            for s in ps:
                by_track.setdefault(s.track, ([], []))[0].append(s)
            for s in cs:
                by_track.setdefault(s.track, ([], []))[1].append(s)
            for track, (pp, cc) in sorted(by_track.items()):
                for r in range(min(len(pp), len(cc))):
                    if pp[r].t1 > cc[r].t0 + _EPS_US:
                        report.add(
                            "race_layer_order",
                            f"layer {cons} (RAW-dependent on layer "
                            f"{prod}) started at {cc[r].t0:.1f}us on "
                            f"track {track or '?'} before its producer "
                            f"finished at {pp[r].t1:.1f}us",
                            layer_id=cons,
                            instr_lo=int(cc[r].args.get("instr_lo", -1)),
                            instr_hi=int(cc[r].args.get("instr_hi", -1)))

    # -- race_stage_before_compute ----------------------------------------- #
    if not compute_spans:
        report.skip("race_stage_before_compute",
                    "trace has no streaming compute spans")
    else:
        report.ran("race_stage_before_compute")
        stages_by_key: Dict[Tuple[int, int], List[_Span]] = {}
        for s in stage_spans:
            key = (int(s.args.get("layer", -1)),
                   int(s.args.get("shard", -1)))
            stages_by_key.setdefault(key, []).append(s)
        seen_rounds: Dict[Tuple[int, int], int] = {}
        for c in compute_spans:
            key = (int(c.args.get("layer", -1)),
                   int(c.args.get("shard", -1)))
            r = seen_rounds.get(key, 0)
            seen_rounds[key] = r + 1
            stages = stages_by_key.get(key, [])
            if r >= len(stages):
                report.add(
                    "race_stage_before_compute",
                    f"compute window for layer {key[0]} shard {key[1]} "
                    "has no matching h2d stage span",
                    layer_id=key[0])
            elif stages[r].t1 > c.t0 + _EPS_US:
                report.add(
                    "race_stage_before_compute",
                    f"compute window for layer {key[0]} shard {key[1]} "
                    f"opened at {c.t0:.1f}us while its working set was "
                    f"still staging (h2d ended {stages[r].t1:.1f}us)",
                    layer_id=key[0])
        # The healthy-overlap evidence: the NEXT shard staging inside
        # the current compute window.
        overlap = 0
        for c in compute_spans:
            cl = int(c.args.get("layer", -1))
            cj = int(c.args.get("shard", -1))
            for s in stage_spans:
                if int(s.args.get("layer", -1)) != cl or \
                        int(s.args.get("shard", -1)) == cj:
                    continue
                if s.t0 < c.t1 - _EPS_US and s.t1 > c.t0 + _EPS_US:
                    overlap += 1
        report.stats["overlap_pairs"] = overlap

    # -- race_halo_barrier ------------------------------------------------- #
    if not halo_spans:
        report.skip("race_halo_barrier",
                    "trace has no halo exchange spans")
    else:
        report.ran("race_halo_barrier")
        for h in halo_spans:
            lid = int(h.args.get("layer", -1))
            for s in layer_spans.get(lid, []):
                if s.t0 < h.t1 - _EPS_US and s.t1 > h.t0 + _EPS_US:
                    report.add(
                        "race_halo_barrier",
                        f"layer {lid} executed on track "
                        f"{s.track or '?'} during its own halo "
                        f"exchange ({h.t0:.1f}..{h.t1:.1f}us) — "
                        "gather is a barrier",
                        layer_id=lid,
                        instr_lo=int(s.args.get("instr_lo", -1)),
                        instr_hi=int(s.args.get("instr_hi", -1)))
    return report
