"""Serving telemetry for the multi-overlay runtime.

One :class:`Metrics` instance aggregates everything the serving loop
observes — per-request latency, batch occupancy, queue depth, admission
rejections, program-cache behaviour — both globally and per cache key
(i.e. per deployed (model, graph) pair).  ``snapshot()`` exports a plain
JSON-serializable dict so dashboards / benchmark files can consume it
without importing anything from this package.

Latency percentiles use the nearest-rank method over the recorded
samples; sample lists are capped (oldest dropped) so a long-lived
serving process cannot grow without bound.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Deque, Dict, List, Optional


def percentile(samples: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 100]) of unsorted samples."""
    if not samples:
        return 0.0
    s = sorted(samples)
    rank = max(1, math.ceil(q / 100.0 * len(s)))
    return s[min(rank, len(s)) - 1]


class _Series:
    """Latency/occupancy accumulators shared by global and per-key views."""

    def __init__(self, max_samples: int) -> None:
        self.requests = 0
        self.cache_hits = 0
        self.batches = 0
        self.batched_requests = 0       # sum of batch sizes
        self.total_t_loc = 0.0
        self.total_t_loh = 0.0
        self.latencies: Deque[float] = deque(maxlen=max_samples)
        # Phase split (populated when the loop reports it): where a
        # request's experienced latency went — queued vs executing.
        self.waits: Deque[float] = deque(maxlen=max_samples)
        self.executes: Deque[float] = deque(maxlen=max_samples)

    def record(self, resp, latency_s: float,
               queue_wait_s: Optional[float] = None,
               execute_s: Optional[float] = None) -> None:
        self.requests += 1
        self.cache_hits += int(resp.cache_hit)
        self.total_t_loc += resp.t_loc
        self.total_t_loh += resp.t_loh
        self.latencies.append(latency_s)
        if queue_wait_s is not None:
            self.waits.append(queue_wait_s)
        if execute_s is not None:
            self.executes.append(execute_s)

    def record_batch(self, size: int) -> None:
        self.batches += 1
        self.batched_requests += size

    def snapshot(self, max_batch: Optional[int] = None) -> dict:
        lat = list(self.latencies)
        hit_rate = (self.cache_hits / self.requests) if self.requests else 0.0
        mean_batch = (self.batched_requests / self.batches) \
            if self.batches else 0.0
        out = {
            "requests": self.requests,
            "cache_hit_rate": round(hit_rate, 6),
            "p50_latency_ms": round(percentile(lat, 50) * 1e3, 6),
            "p90_latency_ms": round(percentile(lat, 90) * 1e3, 6),
            "p99_latency_ms": round(percentile(lat, 99) * 1e3, 6),
            "max_latency_ms": round(max(lat) * 1e3, 6) if lat else 0.0,
            "batches": self.batches,
            "mean_batch_size": round(mean_batch, 6),
        }
        if self.waits or self.executes:
            w, e = list(self.waits), list(self.executes)
            mean = lambda xs: (sum(xs) / len(xs)) if xs else 0.0  # noqa: E731
            out["queue_wait_ms"] = {
                "mean": round(mean(w) * 1e3, 6),
                "p99": round(percentile(w, 99) * 1e3, 6)}
            out["execute_ms"] = {
                "mean": round(mean(e) * 1e3, 6),
                "p99": round(percentile(e, 99) * 1e3, 6)}
        if max_batch:
            out["batch_occupancy"] = round(mean_batch / max_batch, 6)
        return out


class Metrics:
    """Aggregates serving telemetry; see module docstring."""

    def __init__(self, max_samples: int = 4096) -> None:
        self.max_samples = max_samples
        self._global = _Series(max_samples)
        self._per_key: Dict[str, _Series] = {}
        self._key_names: Dict[str, str] = {}    # key -> "model@graph" label
        self.rejected = 0
        self.max_queue_depth = 0
        self._depth_sum = 0
        self._depth_obs = 0
        self._served = 0
        self._serve_wall = 0.0
        # Live-graph (repro.livegraph) observability: which graph
        # version is active, how often it changed, and how much traffic
        # each version served — version skew made visible.
        self.active_graph_version: Optional[int] = None
        self.cutovers = 0
        self.versions_reclaimed = 0
        self._version_requests: Dict[int, int] = {}
        # Per-cutover version-skew log: requests still pinned to the
        # outgoing version at swap time (bounded; oldest dropped).
        self._cutover_log: Deque[dict] = deque(maxlen=256)
        # Per-request phase samples (latency joined to its breakdown),
        # so a p99 number can be traced to where the time went.
        self._phase_samples: Deque[dict] = deque(maxlen=max_samples)

    # ------------------------------------------------------------------ #
    def _series(self, key: str) -> _Series:
        if key not in self._per_key:
            self._per_key[key] = _Series(self.max_samples)
        return self._per_key[key]

    def record_response(self, resp, latency_s: float,
                        queue_wait_s: Optional[float] = None,
                        execute_s: Optional[float] = None,
                        compile_s: Optional[float] = None) -> None:
        """One completed request.  ``latency_s`` is the full experienced
        latency (queue wait + compile + execute), measured by the loop;
        the optional phase terms feed the wait-vs-execute split and the
        per-request breakdown behind :meth:`slowest`."""
        self._global.record(resp, latency_s, queue_wait_s, execute_s)
        self._series(resp.cache_key).record(resp, latency_s,
                                            queue_wait_s, execute_s)
        self._key_names.setdefault(
            resp.cache_key, f"{resp.model_name}@{resp.graph_name}")
        if queue_wait_s is not None or execute_s is not None:
            self._phase_samples.append({
                "request_id": getattr(resp, "request_id", None),
                "latency_ms": round(latency_s * 1e3, 6),
                "queue_wait_ms": round((queue_wait_s or 0.0) * 1e3, 6),
                "execute_ms": round((execute_s or 0.0) * 1e3, 6),
                "compile_ms": round((compile_s or 0.0) * 1e3, 6),
            })

    def slowest(self, n: int = 5) -> List[dict]:
        """The ``n`` worst recorded requests WITH their phase breakdown
        — how a p99 latency sample is traced to queue wait vs compile
        vs execute (requires the loop to report phase terms)."""
        return sorted(self._phase_samples,
                      key=lambda s: s["latency_ms"],
                      reverse=True)[:n]

    def record_batch(self, key: str, size: int) -> None:
        self._global.record_batch(size)
        self._series(key).record_batch(size)

    def record_queue_depth(self, depth: int) -> None:
        self.max_queue_depth = max(self.max_queue_depth, depth)
        self._depth_sum += depth
        self._depth_obs += 1

    def record_rejection(self) -> None:
        self.rejected += 1

    def record_serve_wall(self, n_requests: int, wall_s: float) -> None:
        """Credit a completed serve() drain toward throughput."""
        self._served += n_requests
        self._serve_wall += wall_s

    # ------------------------------------------------------------------ #
    # Live-graph versioning (called by repro.livegraph.LiveGraphServer
    # and the serving loop's admission/release path).
    # ------------------------------------------------------------------ #
    def set_active_version(self, vid: int) -> None:
        self.active_graph_version = vid

    def record_cutover(self, from_vid: int, to_vid: int,
                       pinned_old: int = 0) -> None:
        """One zero-downtime version swap completed.  ``pinned_old`` is
        the number of requests still pinned to ``from_vid`` at swap
        time — the per-cutover version skew."""
        self.cutovers += 1
        self.active_graph_version = to_vid
        self._cutover_log.append({"from": from_vid, "to": to_vid,
                                  "pinned_old": int(pinned_old)})

    def record_version_request(self, vid: int) -> None:
        """One request served on graph version ``vid``."""
        self._version_requests[vid] = \
            self._version_requests.get(vid, 0) + 1

    def record_version_reclaimed(self, vid: int) -> None:
        self.versions_reclaimed += 1

    # ------------------------------------------------------------------ #
    @property
    def throughput_rps(self) -> float:
        return self._served / self._serve_wall if self._serve_wall else 0.0

    def snapshot(self, max_batch: Optional[int] = None) -> dict:
        """JSON-serializable view of everything recorded so far."""
        g = self._global.snapshot(max_batch)
        g.update({
            "throughput_rps": round(self.throughput_rps, 6),
            "rejected": self.rejected,
            "max_queue_depth": self.max_queue_depth,
            "mean_queue_depth": round(
                self._depth_sum / self._depth_obs, 6)
            if self._depth_obs else 0.0,
        })
        per_key = {}
        for key, series in self._per_key.items():
            s = series.snapshot(max_batch)
            s["name"] = self._key_names.get(key, key[:12])
            per_key[key] = s
        out = {"global": g, "per_key": per_key}
        if (self.active_graph_version is not None or self.cutovers
                or self._version_requests):
            # Only present when live graphs are in play: snapshots of
            # static-graph deployments are unchanged.
            out["livegraph"] = {
                "active_version": self.active_graph_version,
                "cutovers": self.cutovers,
                "versions_reclaimed": self.versions_reclaimed,
                "requests_per_version": {
                    f"v{k}": v for k, v in
                    sorted(self._version_requests.items())},
                "cutover_log": list(self._cutover_log),
                "max_version_skew": max(
                    (c["pinned_old"] for c in self._cutover_log),
                    default=0),
            }
        return out
