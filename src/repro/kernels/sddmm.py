"""SDDMM-mode Pallas kernel (ACK SDDMM mode, paper Alg. 3).

Blocked-ELL sampled dense-dense:
  score[r, k] = < h_dst[r, :], h_src[cols[r, k], :] >

Grid: (row blocks, feature fibers); partial inner products accumulate over
the fiber axis in a VMEM f32 scratch of shape (bm, width) and flush on the
last fiber.  Same dynamic-gather pattern as the SpDMM kernel; the
multiply-adder-tree of the paper's UR pipeline becomes a lane-wise
multiply + feature-axis reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _sddmm_kernel(cols_ref, hd_ref, hs_ref, o_ref, acc_ref,
                  *, width: int, f_steps: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    hd = hd_ref[...].astype(jnp.float32)
    hs = hs_ref[...].astype(jnp.float32)

    def body(k, acc):
        c = cols_ref[:, k]                       # [bm]
        hv = jnp.take(hs, c, axis=0)             # [bm, bf]
        part = jnp.sum(hd * hv, axis=1)          # [bm]
        return acc.at[:, k].add(part)

    acc_ref[...] = jax.lax.fori_loop(0, width, body, acc_ref[...])

    @pl.when(pl.program_id(1) == f_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bf", "interpret", "out_dtype"))
def sddmm(
    h_dst: jnp.ndarray,      # [n1, f] destination feature tile
    h_src: jnp.ndarray,      # [n_src, f] source feature tile
    cols: jnp.ndarray,       # [n1, w] int32 local src indices
    *,
    bm: int = 128,
    bf: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    n1, f = h_dst.shape
    n_src, f2 = h_src.shape
    assert f == f2 and cols.shape[0] == n1
    assert n1 % bm == 0 and f % bf == 0
    w = cols.shape[1]
    grid = (n1 // bm, f // bf)
    return pl.pallas_call(
        functools.partial(_sddmm_kernel, width=w, f_steps=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, bf), lambda i, j: (i, j)),
            pl.BlockSpec((n_src, bf), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n1, w), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, w), jnp.float32)],
        interpret=interpret,
    )(cols, h_dst, h_src)
