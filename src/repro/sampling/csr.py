"""Cached CSR in-adjacency view on :class:`repro.core.graph.Graph`.

The serving graph lives in COO (the compiler's input format); per-user
sampling instead needs "who sends messages to vertex v" in O(degree).
Message passing flows src -> dst, so the view is indexed by destination:
``in_neighbors(v)`` returns the sources (and weights / original edge
ids) of every edge targeting ``v``.

The O(|V| + |E|) build is memoized on the graph object via
``Graph.in_csr()`` (the hook in ``core/graph.py``), with the same
identity-keyed invalidation rule as the engine's signature memo:
rebinding the edge arrays (what every ``Graph`` method does) invalidates
the cache.  In-place content mutation is invisible to identity checks,
so the memo additionally records ``Graph.mutation_token`` — a dirty
counter bumped by ``Graph.invalidate_views()`` (which ``repro.livegraph``
calls per applied delta) — and rebuilds when the token moved.  A mutated
graph can therefore never silently serve stale adjacency, provided the
mutator invalidates.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.graph import Graph


@dataclasses.dataclass
class CSR:
    """In-adjacency CSR: edges grouped by destination, src-sorted."""

    n_vertices: int
    indptr: np.ndarray    # int64 [V+1]: dst v's edges at indptr[v]:indptr[v+1]
    src: np.ndarray       # int32 [E]  source endpoint per slot
    weight: np.ndarray    # float32 [E]
    edge_id: np.ndarray   # int32 [E]  index into the original COO arrays

    @property
    def n_edges(self) -> int:
        return int(self.src.shape[0])

    def in_degree(self, v: int) -> int:
        return int(self.indptr[v + 1] - self.indptr[v])

    def in_neighbors(self, v: int) -> Tuple[np.ndarray, np.ndarray,
                                            np.ndarray]:
        """(sources, weights, original edge ids) of edges into ``v``."""
        lo, hi = int(self.indptr[v]), int(self.indptr[v + 1])
        return self.src[lo:hi], self.weight[lo:hi], self.edge_id[lo:hi]

    def max_in_degree(self) -> int:
        return int(np.max(np.diff(self.indptr))) if self.n_vertices else 0


def build_csr(g: Graph) -> CSR:
    """COO -> in-adjacency CSR, dst-grouped with src-sorted runs (the
    same (dst, src) order the partitioner uses)."""
    order = np.lexsort((g.src, g.dst)).astype(np.int64)
    dst = g.dst[order]
    counts = np.bincount(dst, minlength=g.n_vertices)
    indptr = np.zeros(g.n_vertices + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(
        n_vertices=g.n_vertices,
        indptr=indptr,
        src=g.src[order].astype(np.int32),
        weight=g.weight[order].astype(np.float32),
        edge_id=order.astype(np.int32),
    )


def in_csr(g: Graph) -> CSR:
    """Memoized :func:`build_csr`; backs ``Graph.in_csr()``.

    Invalidation is two-tier: array identity (rebinding arrays, what
    every ``Graph`` method does) AND the graph's ``mutation_token``
    dirty counter (bumped by ``Graph.invalidate_views()`` whenever
    contents are mutated in place — e.g. per applied ``livegraph``
    delta)."""
    token = g.mutation_token
    cached = g.__dict__.get("_in_csr")
    if (cached is None or cached[0] is not g.src or cached[1] is not g.dst
            or cached[2] is not g.weight or cached[3] != token):
        cached = (g.src, g.dst, g.weight, token, build_csr(g))
        g.__dict__["_in_csr"] = cached
    return cached[4]
