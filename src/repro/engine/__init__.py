"""repro.engine — the unified GraphAGILE engine API.

The paper's contract is: one fixed overlay (bitstream) + per-(model,
graph) instruction binaries.  This package is that contract in software:

  * :class:`Engine` — one overlay instance (tile geometry + kernel cache);
    ``compile`` / ``run`` / ``load`` / ``submit`` / ``serve``.
  * :class:`CompiledProgram` — the serialized unit: 128-bit ISA binary +
    weights/graph manifest; ``save``/``load`` round-trips ``.gagi`` files.
  * :class:`BinaryExecutor` — executes by decoding the binary; no IR
    objects on the hot path.

Quickstart::

    from repro.engine import Engine

    engine = Engine()                       # the overlay
    prog = engine.compile("b1", graph)      # GCN -> 128-bit binary
    y = engine.run(prog, x)                 # decode + execute
    prog.save("gcn.gagi")                   # serve it in a later session

The legacy ``repro.core.compiler.compile_model`` /
``repro.core.executor.OverlayExecutor`` entry points remain as thin
deprecated shims over this package.
"""
from .cache import LRUCache
from .decoder import ExecutionPlan, LayerPlan, TilePlan, decode_binary
from .engine import (Engine, EngineStats, InferenceRequest,
                     InferenceResponse, graph_signature, model_signature,
                     stack_features, stack_graph_data)
from .executor import (BinaryExecutor, ExecStats, ResidentBudgetError,
                       derive_placement, derive_residency,
                       ensure_placement)
from .program import CompiledProgram, build_manifest, from_program

__all__ = [
    "Engine", "EngineStats", "InferenceRequest", "InferenceResponse",
    "CompiledProgram", "BinaryExecutor", "ExecStats",
    "ResidentBudgetError", "LRUCache",
    "derive_placement", "derive_residency", "ensure_placement",
    "ExecutionPlan", "LayerPlan", "TilePlan", "decode_binary",
    "build_manifest", "from_program", "graph_signature", "model_signature",
    "stack_features", "stack_graph_data",
]
