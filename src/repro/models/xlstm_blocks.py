"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, fully
parallelizable via associative scan) and sLSTM (scalar memory with a true
recurrence, executed with lax.scan).

mLSTM per head (d_h = head dim):
  C_t = f_t C_{t-1} + i_t v_t k_t^T          (C in R^{d_h x d_h})
  n_t = f_t n_{t-1} + i_t k_t
  y_t = (C_t q_t) / max(|n_t . q_t|, 1)
with exp input gate / sigmoid forget gate in log space for stability
(we use the stabilized formulation with a running max m_t folded into the
associative scan elements).

sLSTM per head: scalar cell c_t, normalizer n_t, recurrent connection on
the hidden state (block-diagonal per head).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .layers import Params, dense_init


# --------------------------------------------------------------------------- #
# mLSTM
# --------------------------------------------------------------------------- #
def mlstm_init(key, d: int, n_heads: int, dtype, pf: float = 2.0) -> Params:
    ku, kq, kk, kv, ki, kf, ko, kd = jax.random.split(key, 8)
    dh = int(d * pf) // n_heads
    du = dh * n_heads
    return {
        "w_up": dense_init(ku, d, du, dtype),
        "w_q": dense_init(kq, du, (n_heads, dh), dtype),
        "w_k": dense_init(kk, du, (n_heads, dh), dtype),
        "w_v": dense_init(kv, du, (n_heads, dh), dtype),
        "w_i": dense_init(ki, du, n_heads, jnp.float32, std=0.02),
        "w_f": dense_init(kf, du, n_heads, jnp.float32, std=0.02),
        "f_bias": jnp.ones((n_heads,), jnp.float32) * 3.0,
        "w_down": dense_init(kd, du, d, dtype),
    }


def _mlstm_gates(p, x):
    u = jnp.einsum("btd,df->btf", x, p["w_up"])
    q = jnp.einsum("btf,fhe->bthe", u, p["w_q"])
    k = jnp.einsum("btf,fhe->bthe", u, p["w_k"])
    v = jnp.einsum("btf,fhe->bthe", u, p["w_v"])
    logi = jnp.einsum("btf,fh->bth", u, p["w_i"]).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        jnp.einsum("btf,fh->bth", u, p["w_f"]).astype(jnp.float32)
        + p["f_bias"])
    return u, q, k, v, logi, logf


def mlstm_scan(p: Params, x: jnp.ndarray, chunk: int = 256) -> jnp.ndarray:
    """Full-sequence mLSTM via the stabilized *quadratic* parallel form.

    Materializing C_t (matrix memory) per step costs O(T * dh^2) memory;
    the quadratic form computes y_t = sum_j D[t,j] (q_t.k_j) v_j with
    D[t,j] = exp(logi_j + F_t - F_j - m_t), F = cumsum(logf) — identical
    math (contribution of step j decayed through t), attention-like
    memory, chunked over queries.  x [B,T,D] -> y [B,T,D]."""
    u, q, k, v, logi, logf = _mlstm_gates(p, x)
    b, t, h, dh = q.shape
    F = jnp.cumsum(logf, axis=1)                           # [B,T,H]

    # stabilizer m_t = max_j (logi_j + F_t - F_j), via associative scan
    def mcomb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, jnp.maximum(b1 + a2, b2)
    _, m = jax.lax.associative_scan(mcomb, (logf, logi), axis=1)

    a = (logi - F).astype(jnp.float32)                     # [B,T,H] (keys)
    kf = k.astype(jnp.float32) * (dh ** -0.5)
    vf = v.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    jpos = jnp.arange(t, dtype=jnp.int32)

    def one_chunk(args):
        qc, Fc, mc, pc = args   # [B,c,H,dh], [B,c,H], [B,c,H], [c]
        logD = (a[:, None] + Fc[:, :, None] - mc[:, :, None])  # [B,c,T,H]
        mask = pc[:, None] >= jpos[None, :]
        D = jnp.where(mask[None, :, :, None], jnp.exp(logD), 0.0)
        s = jnp.einsum("bqhe,bkhe->bqkh", qc, kf) * D
        num = jnp.einsum("bqkh,bkhe->bqhe", s, vf)
        den = jnp.maximum(jnp.abs(jnp.sum(s, axis=2)), 1.0)  # [B,c,H]
        return num / den[..., None]

    if chunk and t > chunk and t % chunk == 0:
        nc = t // chunk
        args = (qf.reshape(b, nc, chunk, h, dh).swapaxes(0, 1),
                F.reshape(b, nc, chunk, h).swapaxes(0, 1),
                m.reshape(b, nc, chunk, h).swapaxes(0, 1),
                jpos.reshape(nc, chunk))
        y = jax.lax.map(one_chunk, args)
        y = y.swapaxes(0, 1).reshape(b, t, h, dh)
    else:
        y = one_chunk((qf, F, m, jpos))
    y = y.astype(x.dtype).reshape(b, t, h * dh)
    y = y * jax.nn.silu(u.astype(jnp.float32)).astype(y.dtype)
    return jnp.einsum("btf,fd->btd", y, p["w_down"])


def mlstm_decode_init(batch: int, n_heads: int, dh: int) -> Dict:
    return {
        "C": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def mlstm_decode_step(p: Params, x: jnp.ndarray, st: Dict
                      ) -> Tuple[jnp.ndarray, Dict]:
    u, q, k, v, logi, logf = _mlstm_gates(p, x)
    dh = q.shape[-1]
    logi, logf = logi[:, 0], logf[:, 0]
    m_new = jnp.maximum(logf + st["m"], logi)
    f_ = jnp.exp(logf + st["m"] - m_new)
    i_ = jnp.exp(logi - m_new)
    kf = k[:, 0].astype(jnp.float32) * (dh ** -0.5)
    vf = v[:, 0].astype(jnp.float32)
    C = st["C"] * f_[..., None, None] \
        + i_[..., None, None] * jnp.einsum("bhe,bhf->bhef", vf, kf)
    n = st["n"] * f_[..., None] + i_[..., None] * kf
    qf = q[:, 0].astype(jnp.float32)
    num = jnp.einsum("bhef,bhf->bhe", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", n, qf)), 1.0)
    y = (num / den[..., None]).astype(x.dtype)
    b, h, _ = y.shape
    y = y.reshape(b, 1, h * dh)
    y = y * jax.nn.silu(u.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("btf,fd->btd", y, p["w_down"])
    return out, {"C": C, "n": n, "m": m_new}


# --------------------------------------------------------------------------- #
# sLSTM
# --------------------------------------------------------------------------- #
def slstm_init(key, d: int, n_heads: int, dtype, pf: float = 4 / 3) -> Params:
    kz, ki, kf, ko, kr, ku, kd = jax.random.split(key, 7)
    dh = d // n_heads
    return {
        "w_z": dense_init(kz, d, (n_heads, dh), dtype),
        "w_i": dense_init(ki, d, n_heads, jnp.float32, std=0.02),
        "w_f": dense_init(kf, d, n_heads, jnp.float32, std=0.02),
        "w_o": dense_init(ko, d, (n_heads, dh), dtype),
        "r_z": dense_init(kr, dh, (n_heads, dh), jnp.float32, std=0.02),
        "f_bias": jnp.ones((n_heads,), jnp.float32) * 3.0,
        "w_up": dense_init(ku, d, int(d * pf), dtype),
        "w_down": dense_init(kd, int(d * pf), d, dtype),
    }


def slstm_scan(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    """Sequential sLSTM (true recurrence on h).  x [B,T,D]."""
    b, t, d = x.shape
    h_heads = p["w_i"].shape[-1]
    dh = d // h_heads

    z_in = jnp.einsum("btd,dhe->bthe", x, p["w_z"]).astype(jnp.float32)
    i_in = jnp.einsum("btd,dh->bth", x, p["w_i"]).astype(jnp.float32)
    f_in = jnp.einsum("btd,dh->bth", x, p["w_f"]).astype(jnp.float32)
    o_in = jnp.einsum("btd,dhe->bthe", x, p["w_o"]).astype(jnp.float32)

    def step(carry, inp):
        c, n, hprev, m = carry
        z_t, i_t, f_t, o_t = inp
        z_t = z_t + jnp.einsum("bhe,ehf->bhf", hprev, p["r_z"])
        logf = jax.nn.log_sigmoid(f_t + p["f_bias"])
        m_new = jnp.maximum(logf + m, i_t)
        fs = jnp.exp(logf + m - m_new)
        is_ = jnp.exp(i_t - m_new)
        c = fs[..., None] * c + is_[..., None] * jnp.tanh(z_t)
        n = fs[..., None] * n + is_[..., None]
        hcur = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
        return (c, n, hcur, m_new), hcur

    init = (jnp.zeros((b, h_heads, dh), jnp.float32),
            jnp.zeros((b, h_heads, dh), jnp.float32),
            jnp.zeros((b, h_heads, dh), jnp.float32),
            jnp.full((b, h_heads), -1e30, jnp.float32))
    xs = (z_in.swapaxes(0, 1), i_in.swapaxes(0, 1), f_in.swapaxes(0, 1),
          o_in.swapaxes(0, 1))
    _, hs = jax.lax.scan(step, init, xs)
    y = hs.swapaxes(0, 1).reshape(b, t, d).astype(x.dtype)
    u = jnp.einsum("btd,df->btf", y, p["w_up"])
    u = jax.nn.gelu(u.astype(jnp.float32)).astype(u.dtype)
    return jnp.einsum("btf,fd->btd", u, p["w_down"])


def slstm_decode_init(batch: int, n_heads: int, dh: int) -> Dict:
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, n_heads), -1e30, jnp.float32)}


def slstm_decode_step(p: Params, x: jnp.ndarray, st: Dict
                      ) -> Tuple[jnp.ndarray, Dict]:
    b, _, d = x.shape
    h_heads = p["w_i"].shape[-1]
    dh = d // h_heads
    z_t = jnp.einsum("btd,dhe->bhe", x, p["w_z"]).astype(jnp.float32)
    i_t = jnp.einsum("btd,dh->bh", x, p["w_i"]).astype(jnp.float32)
    f_t = jnp.einsum("btd,dh->bh", x, p["w_f"]).astype(jnp.float32)
    o_t = jnp.einsum("btd,dhe->bhe", x, p["w_o"]).astype(jnp.float32)
    z_t = z_t + jnp.einsum("bhe,ehf->bhf", st["h"], p["r_z"])
    logf = jax.nn.log_sigmoid(f_t + p["f_bias"])
    m_new = jnp.maximum(logf + st["m"], i_t)
    fs = jnp.exp(logf + st["m"] - m_new)
    is_ = jnp.exp(i_t - m_new)
    c = fs[..., None] * st["c"] + is_[..., None] * jnp.tanh(z_t)
    n = fs[..., None] * st["n"] + is_[..., None]
    hcur = jax.nn.sigmoid(o_t) * c / jnp.maximum(n, 1.0)
    y = hcur.reshape(b, 1, d).astype(x.dtype)
    u = jnp.einsum("btd,df->btf", y, p["w_up"])
    u = jax.nn.gelu(u.astype(jnp.float32)).astype(u.dtype)
    out = jnp.einsum("btf,fd->btd", u, p["w_down"])
    return out, {"c": c, "n": n, "h": hcur, "m": m_new}
