"""Dynamic request batching — coalesce same-key traffic into one pass.

Production GNN traffic is dominated by *repeats*: the same deployed
(model, graph) pair queried with fresh features (Zhang et al.'s
CPU-FPGA mini-batch system, arXiv 2206.08536, batches exactly this way
to keep the accelerator saturated).  The :class:`Batcher` groups
concurrent :class:`~repro.engine.InferenceRequest`s by their program
cache key and flushes a group as ONE batch when either

  * it reaches ``max_batch`` requests (size flush), or
  * its oldest request has waited ``max_wait_us`` (deadline flush),

whichever comes first.  A flushed batch executes a single binary pass
(``Engine.submit_batch``: features padded/stacked to ``[N, V, F]``,
instruction stream traversed once).

The batcher is a passive, clock-injected data structure — callers feed
it requests and poll it for due batches — so tests can drive it with a
fake clock and the serving loop stays deterministic: groups flush in
the order their first request arrived, and requests keep arrival order
within a group.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Callable, List, Optional

from repro.engine import InferenceRequest


@dataclasses.dataclass
class Batch:
    """A flushed group: same cache key, arrival-ordered requests."""

    key: str
    requests: List[InferenceRequest]
    indices: List[int]            # admission sequence numbers
    created_at: float             # clock time of the first request
    cost: float = 0.0             # routing cost estimate (graph work x N)

    def __len__(self) -> int:
        return len(self.requests)


def request_cost(req: InferenceRequest) -> float:
    """Deterministic per-request work estimate for load balancing:
    proportional to the graph traffic a pass touches (edges dominate
    aggregation, vertices dominate the dense layers)."""
    g = req.graph
    return float(g.n_edges + g.n_vertices)


class Batcher:
    """Groups requests by cache key; flush on size or deadline."""

    def __init__(self, max_batch: int = 8, max_wait_us: float = 2000.0,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.max_wait_us = max_wait_us
        self.clock = clock
        self._groups: "OrderedDict[str, Batch]" = OrderedDict()

    # ------------------------------------------------------------------ #
    @property
    def depth(self) -> int:
        """Requests currently queued (admitted, not yet flushed)."""
        return sum(len(b) for b in self._groups.values())

    def add(self, key: str, req: InferenceRequest, index: int,
            now: Optional[float] = None) -> Optional[Batch]:
        """Queue one request; returns the batch if this fills a group."""
        now = self.clock() if now is None else now
        group = self._groups.get(key)
        if group is None:
            group = Batch(key=key, requests=[], indices=[], created_at=now)
            self._groups[key] = group
        group.requests.append(req)
        group.indices.append(index)
        group.cost += request_cost(req)
        if len(group) >= self.max_batch:
            return self._groups.pop(key)
        return None

    def due(self, now: Optional[float] = None) -> List[Batch]:
        """Flush every group whose oldest request hit the deadline."""
        now = self.clock() if now is None else now
        deadline_s = self.max_wait_us * 1e-6
        out = []
        for key in [k for k, b in self._groups.items()
                    if now - b.created_at >= deadline_s]:
            out.append(self._groups.pop(key))
        return out

    def flush_all(self) -> List[Batch]:
        """Drain everything, in first-arrival order of each group."""
        out = list(self._groups.values())
        self._groups.clear()
        return out
