"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips (data, model).
Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the pod axis carries
data parallelism across pods (DCN-ish), model stays within a pod (ICI).
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for tests (host devices)."""
    return make_mesh((n_data, n_model), ("data", "model"))


def make_device_mesh(n_devices=None):
    """1-D ``dev`` mesh for the placement-scheduled multi-device
    executor (``Engine.run(..., mesh=...)``): destination shards are
    LPT-assigned to these devices and halo sub-fibers move over the
    mesh axis.  ``n_devices=None`` takes every local device; an int
    takes the first N (``XLA_FLAGS=--xla_force_host_platform_device_count=4``
    forces virtual host devices for tests/CI)."""
    import jax
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n < 1 or n > len(devs):
        raise ValueError(
            f"make_device_mesh: asked for {n} devices but "
            f"{len(devs)} are available")
    return make_mesh((n,), ("dev",), devices=devs[:n])
