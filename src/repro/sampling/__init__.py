"""repro.sampling — per-user mini-batch ego-network inference.

The layer below :mod:`repro.runtime`: realistic heavy traffic asks
"infer labels for *these* target vertices", not "run the whole graph"
(Zhang et al., arXiv 2206.08536).  Sampled ego networks have wildly
varying geometry, which would thrash the engine's program cache; this
package normalizes them at runtime instead of recompiling (the
Dynasparse move, arXiv 2303.12901):

  * :mod:`~repro.sampling.csr` — cached CSR in-adjacency view on
    :class:`~repro.core.graph.Graph` (O(degree) host-side lookup);
  * :mod:`~repro.sampling.sampler` — seeded, deterministic k-hop fanout
    sampling (GraphSAGE-style caps, ``"full"`` fallback), targets-first
    relabeling, per-hop frontiers recorded;
  * :mod:`~repro.sampling.buckets` — power-of-two geometry buckets with
    canonical ELL layouts; one compiled program per bucket, per-request
    topology as runtime ``graph_data`` (inert zero padding);
  * :mod:`~repro.sampling.service` — :class:`SamplingService`: wraps an
    :class:`~repro.runtime.OverlayPool`; sample -> bucket -> batch ->
    overlay -> un-pad, returning per-target logits.

Quickstart::

    from repro.sampling import SamplingService, TargetRequest

    svc = SamplingService(graph, features, n_overlays=2, geometry=geom)
    resp = svc.submit(TargetRequest(targets=[7, 42], model="b1",
                                    fanouts=(10, 5)))
    resp.logits                                # [2, n_classes]
"""
from .buckets import Bucket, bucket_for, layout_graph, template_graph
from .csr import CSR, build_csr, in_csr
from .sampler import EgoNet, sample_ego
from .service import SamplingService, TargetRequest, TargetResponse

__all__ = [
    "Bucket", "CSR", "EgoNet", "SamplingService", "TargetRequest",
    "TargetResponse", "bucket_for", "build_csr", "in_csr", "layout_graph",
    "sample_ego", "template_graph",
]
