"""repro.livegraph — incremental graph mutation + versioned serving.

The compiler stack below this package treats a graph as a snapshot:
change an edge, recompile.  This package makes the deployed graph a
*living* object without giving up the compiled-program economics:

  * :class:`GraphDelta`       — validated, coalescible mutation log
    (add/remove edges, add vertices with features);
  * :class:`TileStore`        — incremental fiber-shard tile patching:
    a delta rebuilds only the (j, k) tiles it touches, with per-tile
    content hashes folded into a Merkle-style graph signature
    (``livegraph.tiles``);
  * :class:`GraphVersionStore` / :class:`GraphVersion` — copy-on-write
    immutable versions sharing untouched tiles, each binding compiled
    programs to its tiles without recompilation
    (``livegraph.versioning``);
  * :class:`LiveGraphServer`  — zero-downtime cutover: in-flight
    requests finish on version N while new admissions route to N+1;
    drained versions are reclaimed (``livegraph.swap``).

Quickstart::

    from repro.livegraph import (GraphDelta, GraphVersionStore,
                                 LiveGraphServer)

    store = GraphVersionStore(graph, geometry=engine.geometry)
    live = LiveGraphServer(store)
    resp = engine.submit(InferenceRequest("b1", live, x))   # version 0

    delta = GraphDelta(live.n_vertices).add_edge(3, 7, 0.5)
    live.apply(delta)                                       # cut over
    resp = engine.submit(InferenceRequest("b1", live, x))   # version 1,
    # same compiled program, patched tiles — no recompile, bit-identical
    # to a cold compile of the mutated graph.
"""
from .delta import CoalescedDelta, GraphDelta
from .swap import LiveGraphServer, admit, resolve_version
from .tiles import (PatchStats, TileStore, as_graph_data,
                    tile_density_stats)
from .versioning import GraphVersion, GraphVersionStore

__all__ = [
    "CoalescedDelta", "GraphDelta", "GraphVersion", "GraphVersionStore",
    "LiveGraphServer", "PatchStats", "TileStore", "admit",
    "as_graph_data", "resolve_version", "tile_density_stats",
]
