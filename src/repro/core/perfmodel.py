"""Analytic latency model for the overlay on TPU v5e.

The paper evaluates T_LoH with a cycle-accurate simulator of the Alveo
U250 design; our hardware-adapted equivalent is a roofline model over the
compiled Program: each tiling block costs
    max(flops / peak_flops, hbm_bytes / hbm_bw)
(double-buffering overlaps the loads of block t+1 with the compute of
block t — the paper's Fig. 16 optimization — so the max, not the sum),
blocks execute on their assigned PE, and a layer ends when its slowest PE
drains (Algorithm 9 barrier).  ``overlap=False`` models the ablation
(sum instead of max).

``residency="host"`` adds the out-of-core streaming term: every block's
input operands cross the host→device staging link (PCIe-class bandwidth,
``ModelConstants.stage_bw``), double-buffered per shard window so the
layer costs max(exec, stage) under overlap and their sum without.

The model's machine constants live in :class:`ModelConstants` so
``repro.obs.conformance`` can fit *effective* constants from measured
runs and re-predict with them; per-block and per-layer breakdowns
(:func:`block_costs`, :func:`layer_costs`) expose what ``predict_loh``
previously reduced to a scalar.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .ir import LayerType
from .passes.kernel_map import Program

PEAK_FLOPS = 197e12        # bf16 MXU, per chip
VPU_FLOPS = 8e12           # vector unit (sparse modes run on gathers/VPU)
HBM_BW = 819e9
STAGE_BW = 31.5e9          # host->device staging link (paper's PCIe 31.5GB/s)

# layer-level kernel dispatch, mirroring the executor's _KERNEL_MODES
KERNEL_OF_LAYER = {
    LayerType.AGGREGATE: "spdmm",
    LayerType.LINEAR: "gemm",
    LayerType.VECTOR_INNER: "sddmm",
    LayerType.VECTOR_ADD: "vadd",
    LayerType.ACTIVATION: "act",
    LayerType.BATCHNORM: "act",
}
# tiling-block kinds fold the same way (affine epilogues run on the VPU
# activation path)
KERNEL_OF_KIND = {"affine": "act"}


@dataclasses.dataclass(frozen=True)
class ModelConstants:
    """Machine constants the roofline is evaluated against.

    The defaults are datasheet numbers; conformance calibration
    (``repro.obs.conformance.calibrate``) produces a fitted instance.
    """

    peak_flops: float = PEAK_FLOPS
    vpu_flops: float = VPU_FLOPS
    hbm_bw: float = HBM_BW
    stage_bw: float = STAGE_BW

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


DEFAULT_CONSTANTS = ModelConstants()


@dataclasses.dataclass
class BlockCost:
    """Predicted cost of one tiling block (one PE work item)."""

    layer_id: int
    kind: str            # tiling-block kind: gemm/spdmm/sddmm/vadd/act/affine
    kernel: str          # executor kernel mode (affine -> act)
    pe: int
    flops: float
    hbm_bytes: float     # total HBM traffic (inputs + output)
    stage_bytes: float   # input operand bytes crossing the h2d link
    t_compute: float
    t_memory: float
    t: float             # effective block time: max(c, m) or sum


@dataclasses.dataclass
class LayerCost:
    """Predicted cost of one layer (Algorithm 9 barrier to barrier)."""

    layer_id: int
    kernel: str
    n_blocks: int
    flops: float
    hbm_bytes: float
    stage_bytes: float
    t_exec: float        # slowest-PE drain time
    t_stage: float       # staging time under host residency (0 on device)
    t: float             # layer wall: max(exec, stage) or sum


def _block_terms(kind: str, tb, pg, f_in: int, c: ModelConstants):
    """Returns (flops, hbm_bytes, stage_in_bytes, t_compute, t_memory)."""
    n1, n2 = pg.config.n1, pg.config.n2
    if kind == "gemm":
        flops = 2.0 * n1 * n2 * n2 * max(len(tb.k_list), 1)
        in_bytes = (n1 * n2 * 4 * len(tb.k_list)
                    + n2 * n2 * 4 * len(tb.k_list))
        bytes_ = in_bytes + n1 * n2 * 4
        t_c, t_m = flops / c.peak_flops, bytes_ / c.hbm_bw
    elif kind == "spdmm":
        nnz = sum(pg.tiles[(tb.out_j, k)][s].nnz for k, s in tb.k_list) \
            if tb.k_list else 0
        flops = 2.0 * nnz * n2
        in_bytes = sum(
            pg.tiles[(tb.out_j, k)][s].cols.nbytes * 2 + n1 * n2 * 4
            for k, s in tb.k_list)
        bytes_ = in_bytes + n1 * n2 * 4
        t_c, t_m = flops / c.vpu_flops, bytes_ / c.hbm_bw
    elif kind == "sddmm":
        t = pg.tiles[(tb.out_j, tb.tile_k)][tb.slice_id]
        flops = 2.0 * t.nnz * f_in
        in_bytes = t.cols.nbytes * 2 + 2 * n1 * f_in * 4
        bytes_ = in_bytes + t.nnz * 4
        t_c, t_m = flops / c.vpu_flops, bytes_ / c.hbm_bw
    else:  # vadd / act / affine: bandwidth bound
        bytes_ = 3.0 * n1 * n2 * 4
        in_bytes = 2.0 * n1 * n2 * 4
        flops = 0.0
        t_c, t_m = bytes_ / c.hbm_bw / 8, bytes_ / c.hbm_bw
    return flops, bytes_, in_bytes, t_c, t_m


def _block_cost(kind: str, tb, pg, f_in: int, overlap: bool,
                constants: Optional[ModelConstants] = None) -> float:
    """Scalar effective time of one tiling block (kept for callers of the
    pre-refactor API)."""
    c = constants or DEFAULT_CONSTANTS
    _, _, _, t_c, t_m = _block_terms(kind, tb, pg, f_in, c)
    return max(t_c, t_m) if overlap else (t_c + t_m)


def block_costs(prog: Program, overlap: bool = True,
                constants: Optional[ModelConstants] = None
                ) -> List[BlockCost]:
    """Per-tiling-block predicted costs for every layer of ``prog``."""
    c = constants or DEFAULT_CONSTANTS
    out: List[BlockCost] = []
    for lb in prog.layer_blocks:
        for tb in lb.tiling_blocks:
            fl, by, sb, t_c, t_m = _block_terms(
                tb.kind, tb, prog.pgraph, lb.layer.f_in, c)
            out.append(BlockCost(
                layer_id=lb.layer_id, kind=tb.kind,
                kernel=KERNEL_OF_KIND.get(tb.kind, tb.kind), pe=tb.pe,
                flops=fl, hbm_bytes=by, stage_bytes=sb,
                t_compute=t_c, t_memory=t_m,
                t=max(t_c, t_m) if overlap else (t_c + t_m)))
    return out


def layer_costs(prog: Program, overlap: bool = True,
                residency: str = "device",
                constants: Optional[ModelConstants] = None
                ) -> List[LayerCost]:
    """Per-layer predicted costs.

    ``residency="host"`` charges each layer's input operand bytes to the
    staging link; double-buffering hides the smaller of (exec, stage)
    under the larger when ``overlap``.
    """
    if residency not in ("device", "host"):
        raise ValueError(f"unknown residency {residency!r}")
    c = constants or DEFAULT_CONSTANTS
    blocks = block_costs(prog, overlap=overlap, constants=c)
    by_layer: Dict[int, List[BlockCost]] = {}
    for b in blocks:
        by_layer.setdefault(b.layer_id, []).append(b)
    out: List[LayerCost] = []
    for lb in prog.layer_blocks:
        bs = by_layer.get(lb.layer_id, [])
        pe_time: Dict[int, float] = {}
        for b in bs:
            pe_time[b.pe] = pe_time.get(b.pe, 0.0) + b.t
        t_exec = max(pe_time.values(), default=0.0)
        stage_bytes = sum(b.stage_bytes for b in bs)
        t_stage = (stage_bytes / c.stage_bw
                   if residency == "host" else 0.0)
        t = max(t_exec, t_stage) if overlap else (t_exec + t_stage)
        out.append(LayerCost(
            layer_id=lb.layer_id,
            kernel=KERNEL_OF_LAYER.get(lb.layer.layer_type, "act"),
            n_blocks=len(bs),
            flops=sum(b.flops for b in bs),
            hbm_bytes=sum(b.hbm_bytes for b in bs),
            stage_bytes=stage_bytes,
            t_exec=t_exec, t_stage=t_stage, t=t))
    return out


def predict_loh(prog: Program, overlap: bool = True,
                residency: str = "device",
                constants: Optional[ModelConstants] = None) -> float:
    """Predicted hardware-execution latency (seconds) on TPU v5e."""
    return sum(lc.t for lc in layer_costs(
        prog, overlap=overlap, residency=residency, constants=constants))
