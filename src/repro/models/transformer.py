"""Config-driven decoder LM covering every assigned architecture family.

A model is a list of *segments*; each segment is a superblock of one or
more BlockSpecs scanned ``repeat`` times (scan-over-layers keeps the HLO
small and compile times flat in depth).  Heterogeneous layer patterns
(gemma3's 5 local : 1 global, deepseek's first-k-dense, llama-vision's
cross-attention every 5th layer, xLSTM's mLSTM/sLSTM alternation) become
superblock structure.

Public surface:
  init_params / param_specs          — real weights or ShapeDtypeStructs
  forward(params, tokens, ...)       — train/prefill logits
  init_cache_specs / init_cache      — decode caches per shape cell
  decode_step(params, cache, ...)    — one token with KV/state caches
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import mla as MLA
from . import moe as MOE
from . import ssm as SSM
from . import xlstm_blocks as XL
from .config import ModelConfig
from .layers import Params, dense_init, rms_norm, swiglu, swiglu_init


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    attn: str = "gqa"        # gqa | mla | hymba | mlstm | slstm
    ffn: str = "dense"       # dense | moe | none
    window: int = 0          # sliding-window size (0 = full attention)
    cross_attn: bool = False


def build_segments(cfg: ModelConfig) -> List[Tuple[Tuple[BlockSpec, ...],
                                                   int]]:
    """Architecture pattern -> [(superblock, repeat)]."""
    if cfg.xlstm:
        pair = (BlockSpec(attn="mlstm", ffn="none"),
                BlockSpec(attn="slstm", ffn="none"))
        assert cfg.n_layers % 2 == 0
        return [(pair, cfg.n_layers // 2)]
    if cfg.ssm_heads:  # hymba: parallel attn+ssm heads every layer
        return [((BlockSpec(attn="hymba", window=cfg.local_window),),
                 cfg.n_layers)]
    attn = "mla" if cfg.mla else "gqa"
    ffn_main = "moe" if cfg.is_moe else "dense"
    segs: List[Tuple[Tuple[BlockSpec, ...], int]] = []
    if cfg.attn_pattern == "local_global":
        r = cfg.local_global_ratio
        sb = tuple([BlockSpec(attn=attn, ffn=ffn_main,
                              window=cfg.local_window)] * (r - 1)
                   + [BlockSpec(attn=attn, ffn=ffn_main)])
        rem = cfg.n_layers % r
        if rem:
            segs.append(((BlockSpec(attn=attn, ffn=ffn_main,
                                    window=cfg.local_window),), rem))
        segs.append((sb, cfg.n_layers // r))
        return segs
    if cfg.is_moe and cfg.first_k_dense:
        segs.append(((BlockSpec(attn=attn, ffn="dense"),),
                     cfg.first_k_dense))
        segs.append(((BlockSpec(attn=attn, ffn="moe"),),
                     cfg.n_layers - cfg.first_k_dense))
        return segs
    if cfg.cross_attn_every:
        k = cfg.cross_attn_every
        assert cfg.n_layers % k == 0
        sb = tuple([BlockSpec(attn=attn)] * (k - 1)
                   + [BlockSpec(attn=attn, cross_attn=True)])
        return [(sb, cfg.n_layers // k)]
    return [((BlockSpec(attn=attn, ffn=ffn_main),), cfg.n_layers)]


# --------------------------------------------------------------------------- #
# Block init / apply
# --------------------------------------------------------------------------- #
def block_init(key, cfg: ModelConfig, spec: BlockSpec) -> Params:
    dt = cfg.jdtype
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    p: Params = {}
    if spec.attn in ("gqa", "hymba"):
        p["ln1"] = jnp.zeros((d,), dt)
        p["attn"] = A.attn_init(keys[0], d, cfg.n_heads, cfg.n_kv_heads,
                                cfg.hd, dt, qk_norm=cfg.qk_norm)
        if spec.attn == "hymba":
            p["ssm"] = SSM.ssm_init(keys[1], d, cfg.ssm_heads,
                                    d // cfg.ssm_heads, cfg.ssm_state, dt)
    elif spec.attn == "mla":
        p["ln1"] = jnp.zeros((d,), dt)
        p["attn"] = MLA.mla_init(keys[0], cfg, dt)
    elif spec.attn == "mlstm":
        p["ln1"] = jnp.zeros((d,), dt)
        p["core"] = XL.mlstm_init(keys[0], d, cfg.n_heads, dt)
    elif spec.attn == "slstm":
        p["ln1"] = jnp.zeros((d,), dt)
        p["core"] = XL.slstm_init(keys[0], d, cfg.n_heads, dt)
    if spec.cross_attn:
        p["ln_x"] = jnp.zeros((d,), dt)
        p["xattn"] = A.attn_init(keys[2], d, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.hd, dt, kv_input_dim=d)
    if spec.ffn == "dense":
        p["ln2"] = jnp.zeros((d,), dt)
        p["mlp"] = swiglu_init(keys[3], d, cfg.d_ff, dt)
    elif spec.ffn == "moe":
        p["ln2"] = jnp.zeros((d,), dt)
        p["moe"] = MOE.moe_init(keys[3], d, cfg.d_ff_moe, cfg.n_experts,
                                dt, n_shared=cfg.n_shared_experts)
    return p


def block_apply(cfg: ModelConfig, spec: BlockSpec, bp: Params,
                x: jnp.ndarray, positions: jnp.ndarray,
                ctx: Dict[str, Any]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence (train/prefill) application.  Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    eps = cfg.norm_eps
    if spec.attn in ("gqa", "hymba"):
        h = rms_norm(x, bp["ln1"], eps)
        a = A.attention(bp["attn"], h, positions, window=spec.window,
                        rope_theta=cfg.rope_theta, eps=eps,
                        chunk=cfg.attn_chunk)
        if spec.attn == "hymba":
            scan_fn = (SSM.ssm_scan_ssd if cfg.ssm_impl == "ssd"
                       else SSM.ssm_scan)
            s = scan_fn(bp["ssm"], h, cfg.ssm_state)
            a = 0.5 * (a + s)
        x = x + a
    elif spec.attn == "mla":
        h = rms_norm(x, bp["ln1"], eps)
        x = x + MLA.mla_attention(bp["attn"], cfg, h, positions,
                                  chunk=cfg.attn_chunk)
    elif spec.attn == "mlstm":
        x = x + XL.mlstm_scan(bp["core"], rms_norm(x, bp["ln1"], eps))
    elif spec.attn == "slstm":
        x = x + XL.slstm_scan(bp["core"], rms_norm(x, bp["ln1"], eps))
    if spec.cross_attn:
        h = rms_norm(x, bp["ln_x"], eps)
        x = x + A.attention(bp["xattn"], h, positions,
                            kv_x=ctx["cross_kv_x"], causal=False,
                            use_rope=False, eps=eps)
    if spec.ffn == "dense":
        x = x + swiglu(bp["mlp"], rms_norm(x, bp["ln2"], eps))
    elif spec.ffn == "moe":
        h = rms_norm(x, bp["ln2"], eps)
        if ctx.get("moe_impl", "dense") == "a2a":
            y, aux = MOE.moe_a2a(bp["moe"], h, cfg.top_k,
                                 cfg.capacity_factor, ctx["mesh"])
        else:
            y, aux = MOE.moe_dense(bp["moe"], h, cfg.top_k)
        x = x + y
    return x, aux


# --------------------------------------------------------------------------- #
def block_cache_init(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     seq_len: int, zeros: bool = True,
                     cross_len: Optional[int] = None):
    """Decode cache for one block (ShapeDtypeStructs when zeros=False)."""
    dt = cfg.jdtype
    cross_len = cross_len or cfg.n_vision_tokens
    mk = (jnp.zeros if zeros
          else (lambda s, d: jax.ShapeDtypeStruct(s, d)))
    c: Dict[str, Any] = {}
    if spec.attn in ("gqa", "hymba"):
        s = min(spec.window, seq_len) if spec.window else seq_len
        c["k"] = mk((batch, s, cfg.n_kv_heads, cfg.hd), dt)
        c["v"] = mk((batch, s, cfg.n_kv_heads, cfg.hd), dt)
        if spec.attn == "hymba":
            c["ssm"] = mk((batch, cfg.ssm_heads,
                           cfg.d_model // cfg.ssm_heads, cfg.ssm_state),
                          jnp.float32)
    elif spec.attn == "mla":
        c["c"] = mk((batch, seq_len, cfg.kv_lora), dt)
        c["k_rope"] = mk((batch, seq_len, cfg.qk_rope), dt)
    # xLSTM stabilizer state 'm' must start at -inf (log-space max).
    mk_m = ((lambda s, d: jnp.full(s, -1e30, d)) if zeros
            else (lambda s, d: jax.ShapeDtypeStruct(s, d)))
    if spec.attn == "mlstm":
        dh = int(cfg.d_model * 2.0) // cfg.n_heads
        c["C"] = mk((batch, cfg.n_heads, dh, dh), jnp.float32)
        c["n"] = mk((batch, cfg.n_heads, dh), jnp.float32)
        c["m"] = mk_m((batch, cfg.n_heads), jnp.float32)
    elif spec.attn == "slstm":
        dh = cfg.d_model // cfg.n_heads
        for k in ("c", "n", "h"):
            c[k] = mk((batch, cfg.n_heads, dh), jnp.float32)
        c["m"] = mk_m((batch, cfg.n_heads), jnp.float32)
    if spec.cross_attn:
        c["xk"] = mk((batch, cross_len, cfg.n_kv_heads, cfg.hd), dt)
        c["xv"] = mk((batch, cross_len, cfg.n_kv_heads, cfg.hd), dt)
    return c


def block_decode(cfg: ModelConfig, spec: BlockSpec, bp: Params,
                 x: jnp.ndarray, cache, pos,
                 ctx: Optional[Dict[str, Any]] = None
                 ) -> Tuple[jnp.ndarray, Any]:
    ctx = ctx or {}
    eps = cfg.norm_eps
    if spec.attn in ("gqa", "hymba"):
        h = rms_norm(x, bp["ln1"], eps)
        kv = {"k": cache["k"], "v": cache["v"]}
        a, kv = A.decode_attention(bp["attn"], h, kv, pos,
                                   window=spec.window,
                                   rope_theta=cfg.rope_theta, eps=eps)
        cache = dict(cache, **kv)
        if spec.attn == "hymba":
            s, st = SSM.ssm_decode_step(bp["ssm"], h, cache["ssm"],
                                        cfg.ssm_state)
            cache = dict(cache, ssm=st)
            a = 0.5 * (a + s)
        x = x + a
    elif spec.attn == "mla":
        h = rms_norm(x, bp["ln1"], eps)
        a, mc = MLA.mla_decode_step(
            bp["attn"], cfg, h, {"c": cache["c"],
                                 "k_rope": cache["k_rope"]}, pos)
        cache = dict(cache, **mc)
        x = x + a
    elif spec.attn == "mlstm":
        a, st = XL.mlstm_decode_step(bp["core"],
                                     rms_norm(x, bp["ln1"], eps),
                                     {k: cache[k] for k in ("C", "n", "m")})
        cache = dict(cache, **st)
        x = x + a
    elif spec.attn == "slstm":
        a, st = XL.slstm_decode_step(
            bp["core"], rms_norm(x, bp["ln1"], eps),
            {k: cache[k] for k in ("c", "n", "h", "m")})
        cache = dict(cache, **st)
        x = x + a
    if spec.cross_attn:
        h = rms_norm(x, bp["ln_x"], eps)
        a, _ = A.decode_attention(bp["xattn"], h,
                                  {"k": cache["xk"], "v": cache["xv"]},
                                  pos, cross=True, eps=eps)
        x = x + a
    if spec.ffn == "dense":
        x = x + swiglu(bp["mlp"], rms_norm(x, bp["ln2"], eps))
    elif spec.ffn == "moe":
        h = rms_norm(x, bp["ln2"], eps)
        if ctx.get("moe_impl", "dense") == "a2a":
            # decode (t==1): tokens are replicated over the expert axis —
            # use the a2a-free local-experts path (see moe.moe_local)
            y, _ = MOE.moe_local(bp["moe"], h, cfg.top_k,
                                 cfg.capacity_factor, ctx["mesh"])
        else:
            y, _ = MOE.moe_dense(bp["moe"], h, cfg.top_k)
        x = x + y
    return x, cache


# --------------------------------------------------------------------------- #
class DecoderLM:
    def __init__(self, cfg: ModelConfig, moe_impl: str = "dense",
                 mesh=None) -> None:
        self.cfg = cfg
        self.segments = build_segments(cfg)
        self.moe_impl = moe_impl
        self.mesh = mesh

    # -- params -------------------------------------------------------- #
    def init_params(self, key) -> Params:
        cfg = self.cfg
        dt = cfg.jdtype
        k_embed, k_head, *seg_keys = jax.random.split(
            key, 2 + len(self.segments))
        params: Params = {
            "embed": dense_init(k_embed, cfg.vocab, cfg.d_model, dt,
                                std=0.02),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(k_head, cfg.d_model, cfg.vocab, dt)
        segs = []
        for (sb, rep), sk in zip(self.segments, seg_keys):
            keys = jax.random.split(sk, rep)
            blocks = []
            for pos_i, spec in enumerate(sb):
                init_one = lambda kk, s=spec: block_init(
                    jax.random.fold_in(kk, pos_i), self.cfg, s)
                blocks.append(jax.vmap(init_one)(keys))
            segs.append(tuple(blocks))
        params["segments"] = segs
        return params

    def param_specs(self) -> Any:
        """ShapeDtypeStruct pytree (no allocation) for dry-run lowering."""
        return jax.eval_shape(
            lambda: self.init_params(jax.random.PRNGKey(0)))

    # -- forward (train / prefill) -------------------------------------- #
    def forward(self, params: Params, tokens: jnp.ndarray,
                cross_kv_x: Optional[jnp.ndarray] = None,
                positions: Optional[jnp.ndarray] = None) -> Tuple[
                    jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        b, t = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0)
        positions = (jnp.arange(t, dtype=jnp.int32)
                     if positions is None else positions)
        ctx = {"moe_impl": self.moe_impl, "mesh": self.mesh,
               "cross_kv_x": cross_kv_x}
        aux_total = jnp.zeros((), jnp.float32)

        for (sb, rep), seg_params in zip(self.segments, params["segments"]):
            def body(carry, layer_params):
                xx, aux = carry
                for spec, bp in zip(sb, layer_params):
                    xx, a = block_apply(cfg, spec, bp, xx, positions, ctx)
                    aux = aux + a
                return (xx, aux), None

            body = _remat(body, cfg.remat)
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), tuple(seg_params))
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x)
        return logits, aux_total

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            return jnp.einsum("btd,vd->btv", x, params["embed"])
        return jnp.einsum("btd,dv->btv", x, params["head"])

    # -- decode --------------------------------------------------------- #
    def init_cache(self, batch: int, seq_len: int, zeros: bool = True,
                   cross_len: Optional[int] = None):
        caches = []
        for (sb, rep) in self.segments:
            blocks = []
            for spec in sb:
                one = block_cache_init(self.cfg, spec, batch, seq_len,
                                       zeros=zeros, cross_len=cross_len)
                if zeros:
                    stacked = jax.tree.map(
                        lambda a: jnp.broadcast_to(a, (rep,) + a.shape),
                        one)
                else:
                    stacked = jax.tree.map(
                        lambda s: jax.ShapeDtypeStruct(
                            (rep,) + s.shape, s.dtype), one)
                blocks.append(stacked)
            caches.append(tuple(blocks))
        return caches

    def decode_step(self, params: Params, cache, token: jnp.ndarray,
                    pos: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
        """token [B,1] int32; pos scalar int32.  Returns (logits, cache)."""
        cfg = self.cfg
        ctx = {"moe_impl": self.moe_impl, "mesh": self.mesh}
        x = jnp.take(params["embed"], token, axis=0)
        new_caches = []
        for (sb, rep), seg_params, seg_cache in zip(
                self.segments, params["segments"], cache):
            def body(xx, scanned):
                layer_params, layer_cache = scanned
                new_lc = []
                for spec, bp, lc in zip(sb, layer_params, layer_cache):
                    xx, lc2 = block_decode(cfg, spec, bp, xx, lc, pos, ctx)
                    new_lc.append(lc2)
                return xx, tuple(new_lc)

            x, new_c = jax.lax.scan(body, x, (tuple(seg_params),
                                              tuple(seg_cache)))
            new_caches.append(new_c)
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x), new_caches


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "full": save nothing
