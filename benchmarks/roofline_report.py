"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts
(§Roofline deliverable; also emitted as CSV here for the harness)."""
from __future__ import annotations

import glob
import json
import os

from .common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(quick: bool = False) -> None:
    files = sorted(glob.glob(os.path.join(ART, "*.json")))
    if quick:
        files = files[:6]
    for f in files:
        if "__naive" in f:
            continue
        r = json.load(open(f))
        name = f"{r['arch']}/{r['shape']}/{r['mesh']}"
        if r.get("skipped"):
            emit([f"roofline,{name},0,skipped"])
            continue
        if r.get("status") != "ok":
            emit([f"roofline,{name},0,error"])
            continue
        rf = r["roofline"]
        dom_us = rf[rf["dominant"]] * 1e6
        emit([f"roofline,{name},{dom_us:.0f},"
              f"dominant={rf['dominant']};compute_s={rf['compute_s']:.4f};"
              f"memory_s={rf['memory_s']:.4f};"
              f"collective_s={rf['collective_s']:.4f};"
              "mem_per_dev_GiB="
              f"{r['memory']['per_device_total'] / 2 ** 30:.1f}"])
