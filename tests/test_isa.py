"""128-bit ISA encode/decode roundtrip + binary format (paper §5.3)."""
import pytest  # noqa: F401

from _hypothesis_compat import given, settings, st  # noqa: E402

from repro.core import gnn_builders as B
from repro.core import graph as G
from repro.core.compiler import CompileOptions, run_pipeline
from repro.core.isa import Instr, Opcode, assemble, disassemble
from repro.core.passes.partition import PartitionConfig


@settings(max_examples=60, deadline=None)
@given(
    op=st.sampled_from(list(Opcode)),
    pe=st.integers(0, 255),
    act=st.integers(0, 63),
    act_en=st.booleans(),
    on_edges=st.booleans(),
    flags=st.integers(0, 255),
    args=st.tuples(*[st.integers(0, 0xFFFF)] * 4),
    arg4=st.integers(0, 0xFFFFFFFF),
)
def test_instr_roundtrip(op, pe, act, act_en, on_edges, flags, args, arg4):
    i = Instr(op=op, pe=pe, act=act, act_en=act_en, on_edges=on_edges,
              flags=flags, args=args, arg4=arg4)
    j = Instr.decode(i.encode())
    assert j == i


def test_instruction_is_128_bits():
    assert Instr(Opcode.GEMM).encode().nbytes == 16


def test_assemble_roundtrip_and_size():
    instrs = [Instr(Opcode.CSI, args=(1, 0, 8, 8), arg4=4),
              Instr(Opcode.GEMM, pe=3, args=(64, 16, 16, 0)),
              Instr(Opcode.HALT)]
    blob = assemble(instrs)
    assert len(blob) == 16 + 16 * len(instrs)
    back = disassemble(blob)
    assert back == instrs


def test_compiled_binary_is_wellformed():
    g = G.random_graph(1000, 5000, seed=0).gcn_normalized()
    g.feat_dim, g.n_classes = 64, 3
    m = B.build("b2", g)
    cr = run_pipeline(m, g, CompileOptions(
        partition=PartitionConfig(n1=256, n2=32)))
    instrs = disassemble(cr.binary)
    assert instrs[0].op == Opcode.CSI
    assert instrs[-1].op == Opcode.HALT
    # every layer contributes exactly one CSI
    csis = [i for i in instrs if i.op == Opcode.CSI]
    assert len(csis) == cr.program.model.num_layers
    # binary size is tiny relative to the graph (paper Table 8 point)
    graph_bytes = g.n_edges * 12 + g.n_vertices * g.feat_dim * 4
    assert len(cr.binary) < graph_bytes
