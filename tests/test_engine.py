"""Unified Engine API: binary round-trip, .gagi save/load, program cache.

Covers the tentpole acceptance criteria:
  * `engine.run` executes from the DECODED binary — a program saved to
    disk and loaded into a fresh engine (no in-memory Program anywhere)
    matches `reference.run_reference` to <= 1e-4 on b1 (GCN) and b6 (GAT);
  * compile -> assemble -> disassemble -> execute equals the in-process
    path bit for bit;
  * `engine.serve` hits the LRU program cache for repeated (model, graph)
    pairs, returns bit-identical results to cold compiles, and pays
    strictly less total compile time than a no-cache baseline.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gnn_builders as B
from repro.core import graph as G
from repro.core import reference as R
from repro.core.ir import LayerType
from repro.core.isa import (MAGIC, VERSION, Instr, Opcode, assemble,
                            disassemble)
from repro.core.passes.partition import PartitionConfig
from repro.engine import (CompiledProgram, Engine, InferenceRequest,
                          LRUCache, decode_binary)

GEOM = PartitionConfig(n1=32, n2=8)


def _g(nv=90, ne=400, f=12, c=4, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _engine(**kw) -> Engine:
    return Engine(geometry=GEOM, n_pes=4, **kw)


# --------------------------------------------------------------------------- #
# Binary round-trip at program level (tentpole acceptance).
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["b1", "b6"])
def test_saved_binary_executes_without_program_objects(name, tmp_path):
    """save -> load in a fresh engine -> run matches the reference.

    The loaded CompiledProgram carries no ModelIR/Program at all
    (`source is None`): execution is driven purely by the decoded
    128-bit stream + the weights/graph manifest.
    """
    g = _g(seed=3)
    x = jnp.asarray(G.random_features(g, seed=2))
    m = B.build(name, g)
    y_ref = R.run_reference(m, g, x)

    eng = _engine()
    prog = eng.compile(m, g)
    y_mem = eng.run(prog, x)

    path = str(tmp_path / f"{name}.gagi")
    prog.save(path)
    del prog, m                                   # drop all IR objects

    fresh = _engine()
    loaded = fresh.load(path)
    assert loaded.source is None                  # no Program round-trips
    y_disk = fresh.run(loaded, x)

    assert float(jnp.max(jnp.abs(y_disk - y_ref))) <= 1e-4
    # in-process and from-disk execution are the SAME binary-driven path:
    assert bool(jnp.array_equal(np.asarray(y_mem), np.asarray(y_disk)))


@pytest.mark.parametrize("name", ["b1", "b6"])
def test_binary_reassembly_is_identity(name):
    """compile -> assemble -> disassemble -> reassemble is lossless."""
    g = _g(seed=5)
    eng = _engine()
    prog = eng.compile(name, g)
    instrs = disassemble(prog.binary)
    assert assemble(instrs) == prog.binary
    plan = decode_binary(prog.binary)
    src = prog.source.program
    assert plan.n_layers == src.model.num_layers
    for lp, lb in zip(plan.layers, src.layer_blocks):
        assert lp.layer_id == lb.layer_id
        assert lp.layer_type == lb.layer.layer_type
        assert len(lp.tiles) == len(lb.tiling_blocks)


def test_decoded_plan_carries_dispatch_facts():
    g = _g(seed=1)
    eng = _engine()
    prog = eng.compile("b1", g)
    plan = prog.plan()
    agg = [lp for lp in plan.layers if lp.layer_type == LayerType.AGGREGATE]
    lin = [lp for lp in plan.layers if lp.layer_type == LayerType.LINEAR]
    assert agg and lin
    # every tiling block knows its PE and its output tile coordinates
    for lp in agg + lin:
        for tp in lp.tiles:
            assert tp.out_i >= 0 and tp.out_j >= 0
    # SPDMM steps address real ELL tiles: (j, k) grid + slice in range
    for tp in agg[0].tiles:
        for ins in tp.compute:
            j, k, s = ins.args[0], ins.args[1], ins.args[3] >> 1
            assert s < len(prog.pgraph.tiles[(j, k)])


# --------------------------------------------------------------------------- #
# Streaming interface + LRU program cache.
# --------------------------------------------------------------------------- #
def _request_mix():
    """The serve_gnn example's 8-request shape, shrunk for test speed:
    4 distinct (model, graph) pairs, each appearing twice."""
    pairs = [("b1", 0), ("b7", 0), ("b1", 1), ("b7", 1)] * 2
    graphs = {0: _g(seed=21, nv=70, ne=260, f=8, c=3),
              1: _g(seed=22, nv=80, ne=300, f=8, c=3)}
    reqs = []
    for i, (mname, gid) in enumerate(pairs):
        g = graphs[gid]
        x = jnp.asarray(G.random_features(g, seed=i))
        reqs.append(InferenceRequest(model=mname, graph=g, features=x,
                                     request_id=f"req{i}", seed=0))
    return reqs


def test_serve_reports_cache_hits_and_saves_compile_time():
    reqs = _request_mix()
    eng = _engine()
    responses = eng.serve(reqs)

    # first occurrence of each pair misses, the repeat hits
    assert [r.cache_hit for r in responses] == [False] * 4 + [True] * 4
    assert all(r.t_loc == 0.0 for r in responses[4:])
    assert eng.stats.cache_hits == 4 and eng.stats.cache_misses == 4
    assert eng.stats.compiles == 4

    # Total compile time strictly below the no-cache baseline.  The
    # baseline is derived from the SAME measured compiles (each pair's
    # cold T_LoC counted once per occurrence) rather than a second
    # wall-clock run, so the comparison is deterministic: with every
    # pair repeated, the cache pays exactly half.
    miss_t_loc = {r.cache_key: r.t_loc for r in responses if not r.cache_hit}
    cached_total = sum(r.t_loc for r in responses)
    baseline_total = sum(miss_t_loc[r.cache_key] for r in responses)
    assert 0 < cached_total < baseline_total


def test_cache_hits_are_bit_identical_to_cold_compiles():
    reqs = _request_mix()
    warm = _engine().serve(reqs)
    # a cold engine compiles every request from scratch
    cold = _engine(cache_capacity=1).serve(reqs)
    for w, c in zip(warm, cold):
        assert bool(jnp.array_equal(np.asarray(w.output),
                                    np.asarray(c.output))), w.request_id


def test_lru_cache_eviction():
    cache = LRUCache(capacity=2)
    cache.put("a", 1)
    cache.put("b", 2)
    assert cache.get("a") == 1        # refresh a; b is now LRU
    cache.put("c", 3)                 # evicts b
    assert "b" not in cache and "a" in cache and "c" in cache
    assert cache.evictions == 1
    with pytest.raises(ValueError):
        LRUCache(capacity=0)


def test_same_topology_different_feat_dims_miss_cache():
    """Two graphs with identical topology but different feat_dim /
    n_classes build differently-sized models — they must not collide."""
    g1 = G.random_graph(60, 200, seed=4).gcn_normalized()
    g1.feat_dim, g1.n_classes = 12, 4
    g2 = G.random_graph(60, 200, seed=4).gcn_normalized()
    g2.feat_dim, g2.n_classes = 16, 7
    eng = _engine()
    p1 = eng.compile("b1", g1)
    p2 = eng.compile("b1", g2)
    assert p1.cache_key != p2.cache_key
    y2 = eng.run(p2, jnp.asarray(G.random_features(g2, seed=0)))
    assert y2.shape == (60, 7)


def test_weight_change_misses_cache():
    """The schema hash covers weight contents: a retrained model must
    not be served from a stale cached program."""
    g = _g(seed=7)
    eng = _engine()
    m1 = B.build("b1", g, seed=0)
    m2 = B.build("b1", g, seed=1)     # same schema, different weights
    k1 = eng.cache_key(m1, g)
    k2 = eng.cache_key(m2, g)
    assert k1 != k2
    assert eng.cache_key(B.build("b1", g, seed=0), g) == k1


# --------------------------------------------------------------------------- #
# Satellite: disassemble raises ValueError instead of asserting/crashing.
# --------------------------------------------------------------------------- #
def test_disassemble_rejects_bad_magic():
    blob = assemble([Instr(Opcode.HALT)])
    bad = b"\x00\x00\x00\x00" + blob[4:]
    with pytest.raises(ValueError, match="magic"):
        disassemble(bad)


def test_disassemble_rejects_wrong_version():
    import struct
    blob = assemble([Instr(Opcode.HALT)])
    bad = blob[:4] + struct.pack("<I", VERSION + 7) + blob[8:]
    with pytest.raises(ValueError, match="version"):
        disassemble(bad)


def test_disassemble_rejects_truncated_body():
    blob = assemble([Instr(Opcode.CSI), Instr(Opcode.HALT)])
    with pytest.raises(ValueError, match="truncated"):
        disassemble(blob[:-8])
    with pytest.raises(ValueError, match="too short"):
        disassemble(blob[:10])
    assert disassemble(blob)[0].op == Opcode.CSI  # intact blob still fine
    assert MAGIC == 0x47414749
