"""Paper Fig. 15: impact of layer fusion on T_LoH (paper: 4.7-8.2%)."""
from __future__ import annotations

from .common import (Engine, MODELS, dataset, emit, features, run_model)

GRAPHS = [("PU", 1.0)]


def run(quick: bool = False) -> None:
    graphs = GRAPHS[:1] if quick else GRAPHS
    models = ["b1", "b5", "b8"] if quick else MODELS
    engine = Engine()
    for bname in models:
        for dname, scale in graphs:
            g = dataset(dname, scale)
            x = features(g)
            _, t_on, _, prog_on, p_on = run_model(
                bname, g, x, engine, fusion=True)
            _, t_off, _, prog_off, p_off = run_model(
                bname, g, x, engine, fusion=False)
            label = dname if scale == 1.0 else f"{dname}@{scale:g}"
            layers = (f"{prog_off.source.program.model.num_layers}->"
                      f"{prog_on.source.program.model.num_layers}")
            emit([f"fig15,{bname}/{label},{t_on * 1e6:.0f},"
                  f"speedup={(t_off / t_on - 1) * 100:.1f}%;"
                  f"pred_speedup={(p_off / p_on - 1) * 100:.1f}%;"
                  f"layers={layers}"])
