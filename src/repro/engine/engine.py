"""The unified GraphAGILE engine — the repo's single public entry point.

    from repro.engine import Engine

    engine = Engine(geometry=PartitionConfig(n1=256, n2=32))
    prog = engine.compile("b1", graph)          # -> CompiledProgram
    y = engine.run(prog, x)                     # executes the 128-bit binary
    prog.save("gcn_cora.gagi")                  # binary + manifest bundle
    y2 = engine.run(engine.load("gcn_cora.gagi"), x)   # later session

One ``Engine`` is one overlay instance: a fixed tile-geometry contract plus
the ACK kernel cache, exactly like one FPGA bitstream.  Compiling a new
model or a new graph changes the instruction binary only — never the
kernels (the paper's "no reconfiguration" property).

For serving traffic, ``engine.submit(request)`` / ``engine.serve(requests)``
run a streaming loop with an LRU program cache keyed by (model schema
hash, graph partition signature, geometry): repeated (model, graph)
shapes skip software compilation and report ``T_LoC == 0``.
``engine.submit_batch(requests)`` executes ONE binary pass for N
requests that share a cache key (features stacked on a batch axis).

One Engine is one overlay.  The traffic layer above it — dynamic
batching, a pool of K overlays with cache-affinity routing, bounded
work queues with backpressure, and serving telemetry — lives in
:mod:`repro.runtime` (``OverlayPool`` / ``ServeLoop``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import re
import time
import warnings
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import CompileOptions, run_pipeline
from repro.core.gnn_builders import build
from repro.core.graph import Graph
from repro.core.ir import ModelIR
from repro.core.passes.partition import PartitionConfig
from repro.obs.tracer import get_tracer

from .cache import LRUCache
from .executor import BinaryExecutor, ExecStats, ensure_placement
from .program import CompiledProgram, from_program

ModelSpec = Union[str, ModelIR]


def _env_verify_default() -> bool:
    """Process default for ``Engine(verify=...)``: the ``REPRO_VERIFY``
    env var (tests/CI export 1; hot serving paths leave it unset)."""
    return os.environ.get("REPRO_VERIFY", "0").lower() in (
        "1", "true", "yes", "on")


def _export_gagi(prog: CompiledProgram) -> None:
    """``GAGI_EXPORT_DIR``: drop every freshly compiled program as a
    ``.gagi`` bundle there (how CI collects the verify-gate corpus)."""
    out = os.environ.get("GAGI_EXPORT_DIR")
    if not out:
        return
    os.makedirs(out, exist_ok=True)
    stem = re.sub(r"[^A-Za-z0-9_.-]+", "_",
                  f"{prog.model_name}-{prog.graph_name}")
    prog.save(os.path.join(
        out, f"{stem}-{prog.cache_key[:8] or 'nokey'}.gagi"))


def _mesh_count(mesh) -> Optional[int]:
    """Device count of the ``mesh`` knob (int, Mesh, or None) — what
    ``compile`` needs to emit a placement schedule; no devices touched."""
    if mesh is None:
        return None
    return int(mesh) if isinstance(mesh, int) else int(mesh.size)


def _resolve_mesh(mesh):
    """``mesh`` knob -> jax Mesh for execution.  Accepts ``None``, a
    device count (int, builds the 1-D ``dev`` mesh over local devices),
    or a prebuilt mesh from :mod:`repro.launch.mesh`."""
    if mesh is None:
        return None
    if isinstance(mesh, int):
        from repro.launch.mesh import make_device_mesh
        mesh = make_device_mesh(mesh)
    return mesh


# --------------------------------------------------------------------------- #
# Cache-key signatures.
# --------------------------------------------------------------------------- #
def _live_version_of(graph):
    """The :class:`repro.livegraph.GraphVersion` a graph-ish object
    denotes, or ``None``.  Duck-typed (no livegraph import): a
    ``LiveGraphServer`` handle carries ``_live_server`` and resolves to
    its *active* version; a version's materialized graph carries
    ``_live_version``."""
    server = getattr(graph, "_live_server", None)
    if server is not None:
        return server.active
    return getattr(graph, "_live_version", None)


def graph_signature(g: Graph) -> str:
    """Partition signature of a graph: everything the compiled program
    depends on — topology (Step 3) plus feat_dim/n_classes, which size
    the layers of builder-constructed models.

    Live-versioned graphs (``repro.livegraph``) return their
    *structural* signature instead: tile-grid geometry + the
    (j, k, n_slices) tile structure, which is everything the
    instruction binary depends on.  Content-only deltas keep the
    signature — and therefore the program-cache key — so a mutated
    live graph reuses its compiled program with rebound tiles.

    The O(|E|) hash over the edge arrays is memoized on the graph object,
    keyed by the array objects themselves (strong references, compared
    with ``is``, so a freed array's id can never be mistaken for a new
    one) plus the graph's ``mutation_token`` dirty counter.  Deployed
    graphs are treated as immutable: rebinding arrays (what
    ``dataclasses.replace`` and every Graph method do) invalidates the
    memo; mutating array *contents* in place requires a
    ``Graph.invalidate_views()`` call (which bumps the token).
    Repeated ``submit`` calls on the same deployed graph cost O(1); the
    cheap scalars are folded in fresh every call.
    """
    lv = _live_version_of(g)
    if lv is not None:
        return lv.structural_signature
    token = getattr(g, "mutation_token", 0)
    cached = g.__dict__.get("_edge_digest")
    if (cached is None or cached[0] is not g.src
            or cached[1] is not g.dst or cached[2] is not g.weight
            or cached[3] != token):
        h = hashlib.sha1()
        h.update(np.ascontiguousarray(g.src).tobytes())
        h.update(np.ascontiguousarray(g.dst).tobytes())
        h.update(np.ascontiguousarray(g.weight).tobytes())
        cached = (g.src, g.dst, g.weight, token, h.hexdigest())
        g.__dict__["_edge_digest"] = cached
    scalars = f"{g.n_vertices}:{g.n_edges}:{g.feat_dim}:{g.n_classes}"
    return hashlib.sha1(f"{scalars}|{cached[4]}".encode()).hexdigest()


def _weight_digest(model: ModelIR) -> str:
    """SHA-1 over weight contents, memoized on the model keyed by the
    array objects themselves (identity compared with ``is``, strong refs
    held) — rebinding an entry invalidates the memo, so repeat submits of
    the same ModelIR cost O(1); in-place array mutation is unsupported,
    as for graphs."""
    names = tuple(sorted(model.weights))
    cached = model.__dict__.get("_weight_digest")
    if (cached is None or cached[0] != names
            or any(a is not model.weights[n]
                   for n, a in zip(names, cached[1]))):
        h = hashlib.sha1()
        for name in names:
            w = np.asarray(model.weights[name])
            h.update(name.encode())
            h.update(repr((w.shape, str(w.dtype))).encode())
            h.update(w.tobytes())
        cached = (names, tuple(model.weights[n] for n in names),
                  h.hexdigest())
        model.__dict__["_weight_digest"] = cached
    return cached[2]


def model_signature(model: ModelSpec, seed: int = 0) -> str:
    """Schema hash of a model: layer DAG + weight contents.  The layer
    structure (cheap, and mutable pre-compile) is hashed fresh every
    call; the weight bytes (the O(MB) part) are memoized."""
    if isinstance(model, str):
        return f"bench:{model}:seed{seed}"
    h = hashlib.sha1()
    h.update(model.name.encode())
    for lid in sorted(model.layers):
        l = model.layers[lid]
        h.update(repr((
            lid, int(l.layer_type), l.f_in, l.f_out,
            int(l.agg_op) if l.agg_op is not None else -1,
            int(l.act), l.act_enabled, tuple(l.parent_ids),
            tuple(sorted((k, repr(v)) for k, v in l.attrs.items())),
        )).encode())
    h.update(_weight_digest(model).encode())
    return h.hexdigest()


# --------------------------------------------------------------------------- #
# Streaming request interface.
# --------------------------------------------------------------------------- #
def stack_features(features: Sequence[Any]) -> "jax.Array":
    """Pad N ``[V, F]`` feature arrays to a common shape and stack them
    into the ``[N, V, F]`` tensor ``run_batch`` consumes.

    Requests that share a cache key come from the same deployed graph,
    so shapes normally already agree; zero-padding is safe regardless
    because the executor zero-pads features *and* weight rows to the
    tile grid — extra zero columns contribute nothing.
    """
    arrs = [np.asarray(f, np.float32) for f in features]
    v = max(a.shape[0] for a in arrs)
    f = max(a.shape[1] for a in arrs)
    arrs = [np.pad(a, ((0, v - a.shape[0]), (0, f - a.shape[1])))
            for a in arrs]
    return jnp.asarray(np.stack(arrs))


def stack_graph_data(gds: Sequence[dict], pad_to: int) -> dict:
    """Stack N per-request ``graph_data`` pytrees (identical structure —
    one geometry bucket) into a leading batch axis, zero-filling up to
    ``pad_to`` lanes.  Zero lanes are inert: mask False everywhere, so
    padded lanes compute on empty graphs and their outputs are sliced
    off with the feature padding."""
    stacked = jax.tree_util.tree_map(
        lambda *a: jnp.stack([jnp.asarray(x) for x in a]), *gds)
    extra = pad_to - len(gds)
    if extra > 0:
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.pad(a, ((0, extra),) + ((0, 0),) * (a.ndim - 1)),
            stacked)
    return stacked


@dataclasses.dataclass
class InferenceRequest:
    """One unit of serving traffic: (model, graph, features).

    ``graph_data`` switches the request to graph-as-data execution (the
    mini-batch sampling layer): ``graph`` is then a geometry-bucket
    *template* shared by every request in the bucket — making the
    program-cache key collide across users — and the request's actual
    topology travels in ``graph_data`` (canonical ELL layout, see
    ``repro.sampling.buckets.layout_graph``)."""

    model: ModelSpec              # benchmark name ("b1".."b8") or a ModelIR
    graph: Graph
    features: Any                 # [V, F] array
    request_id: Optional[str] = None
    seed: int = 0                 # builder seed when model is a name
    graph_data: Optional[dict] = None


@dataclasses.dataclass
class InferenceResponse:
    request_id: str
    output: Any                   # [V, n_classes] jnp array
    t_loc: float                  # compile latency paid by THIS request (s)
    t_loh: float                  # execution latency (s)
    cache_hit: bool
    cache_key: str
    model_name: str
    graph_name: str
    batch_size: int = 1           # requests coalesced into this binary pass
    overlay: Optional[int] = None  # pool overlay index (set by repro.runtime)


@dataclasses.dataclass
class EngineStats:
    requests: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    compiles: int = 0
    total_t_loc: float = 0.0
    total_t_loh: float = 0.0


# --------------------------------------------------------------------------- #
class Engine:
    """One overlay instance: fixed tile contract + ACK kernel cache."""

    def __init__(self, geometry: Optional[PartitionConfig] = None,
                 n_pes: int = 8, backend: str = "xla", *,
                 overlap: bool = True, interpret: bool = True,
                 vmem_budget_bytes: int = 3 << 20,
                 cache_capacity: int = 32,
                 resident_budget_bytes: Optional[int] = None,
                 verify: Optional[bool] = None) -> None:
        self.geometry = geometry
        self.n_pes = n_pes
        self.backend = backend
        # Static verification of every fresh compile / livegraph rebind
        # (repro.verify).  None -> the REPRO_VERIFY env var; tests/CI
        # set it, hot serving paths keep it off.
        self.verify = _env_verify_default() if verify is None else verify
        self.vmem_budget_bytes = vmem_budget_bytes
        self._executor = BinaryExecutor(
            backend=backend, overlap=overlap, interpret=interpret,
            resident_budget_bytes=resident_budget_bytes)
        self.cache: LRUCache[CompiledProgram] = LRUCache(cache_capacity)
        self.stats = EngineStats()

    @property
    def resident_budget_bytes(self) -> Optional[int]:
        """Device-residency budget enforced by the executor: the
        device-resident path refuses runs whose liveness-aware peak
        exceeds it, the ``residency="host"`` path streams within it."""
        return self._executor.resident_budget_bytes

    @resident_budget_bytes.setter
    def resident_budget_bytes(self, v: Optional[int]) -> None:
        self._executor.resident_budget_bytes = v

    # ------------------------------------------------------------------ #
    @property
    def exec_stats(self) -> ExecStats:
        """Counters of the most recent ``run``/``run_batch`` only."""
        return self._executor.stats

    @property
    def exec_stats_total(self) -> ExecStats:
        """Lifetime counters accumulated across all runs."""
        return self._executor.total

    def _geometry_tag(self) -> str:
        if self.geometry is None:
            return f"auto:{self.vmem_budget_bytes}"
        return (f"n1={self.geometry.n1},n2={self.geometry.n2},"
                f"cap={self.geometry.width_cap}")

    def cache_key(self, model: ModelSpec, graph: Graph, *, seed: int = 0,
                  order_opt: bool = True, fusion: bool = True) -> str:
        parts = "|".join([
            model_signature(model, seed), graph_signature(graph),
            self._geometry_tag(), f"pes={self.n_pes}",
            f"oo={int(order_opt)}", f"fu={int(fusion)}",
        ])
        return hashlib.sha1(parts.encode()).hexdigest()

    # ------------------------------------------------------------------ #
    def compile(self, model: ModelSpec, graph: Graph, *, seed: int = 0,
                order_opt: bool = True, fusion: bool = True,
                use_cache: bool = True, residency: Optional[str] = None,
                mesh=None, verify: Optional[bool] = None,
                _key: Optional[str] = None) -> CompiledProgram:
        """Model + graph -> CompiledProgram (through the §6 pipeline).

        ``model`` is a benchmark name ("b1".."b8", built with ``seed``) or
        a :class:`ModelIR`.  Hits in the program cache skip compilation.
        ``_key`` lets callers that already computed the cache key (submit)
        skip rehashing the graph/weights.

        ``residency`` ("device" | "host") sets the program's default
        execution mode: "host" keeps features host-resident and streams
        one destination shard's working set to the device at a time
        (bit-identical results, bounded device footprint).  The returned
        handle carries the default; the shared cache entry is unchanged.

        ``mesh`` (a device count or a mesh from
        ``repro.launch.mesh.make_device_mesh``) records the placement
        schedule — per-device shard orders + halo sets for that many
        devices — in the program manifest, so it round-trips ``.gagi``.
        Programs compiled without it still run on a mesh: the executor
        derives an identical schedule from the binary.

        Live-versioned graphs (a ``repro.livegraph`` handle or a
        version's materialized graph): the cache key is the version's
        *structural* signature, so a content-only delta hits the cache;
        the returned program is then *rebound* to the version's patched
        tiles (``GraphVersion.bind``) — fresh tiles, zero recompiles.

        ``verify`` statically verifies the program (``repro.verify``:
        hazard/coverage/legality/budget checks, no execution) on every
        fresh compile and every livegraph rebind, raising
        :class:`repro.verify.VerifyError` on a failing report.  None
        defers to ``Engine(verify=...)`` / the ``REPRO_VERIFY`` env var;
        plain cache hits are never re-verified.
        """
        if residency not in (None, "device", "host"):
            raise ValueError("residency must be 'device' or 'host', "
                             f"got {residency!r}")
        do_verify = self.verify if verify is None else verify
        n_devices = _mesh_count(mesh)
        lv = _live_version_of(graph)
        if lv is not None:
            graph = lv.as_graph()
        key = _key or self.cache_key(model, graph, seed=seed,
                                     order_opt=order_opt, fusion=fusion)
        tracer = get_tracer()
        if use_cache:
            cached = self.cache.get(key)
            if cached is not None:
                tracer.instant("cache_hit", cat="compile",
                               track="compile", args={"key": key[:12]})
                if n_devices is not None:
                    ensure_placement(cached, n_devices)
                if lv is not None:
                    cached = lv.bind(cached)
                    if do_verify:
                        self._verify_program(cached)
                if residency is not None:
                    return dataclasses.replace(
                        cached, default_residency=residency)
                return cached
        with tracer.span("compile", cat="compile", track="compile",
                         args={"key": key[:12],
                               "graph": graph.name}) as sp:
            model_ir = build(model, graph, seed) \
                if isinstance(model, str) else model
            opts = CompileOptions(order_opt=order_opt, fusion=fusion,
                                  n_pes=self.n_pes,
                                  partition=self.geometry,
                                  vmem_budget_bytes=self.vmem_budget_bytes)
            cr = run_pipeline(model_ir, graph, opts)
            sp.add(t_loc_s=round(cr.t_loc, 6),
                   binary_bytes=len(cr.binary))
        prog = from_program(cr.program, binary=cr.binary, t_loc=cr.t_loc,
                            cache_key=key, graph_name=graph.name,
                            source=cr, n_devices=n_devices)
        if residency is not None:
            prog = dataclasses.replace(prog, default_residency=residency)
        self.stats.compiles += 1
        self.stats.total_t_loc += cr.t_loc
        if use_cache:
            # The cached copy drops `source` (the full IR/Program/report
            # graph): execution needs only binary+manifest+weights+tiles,
            # so a long-lived serving cache stays slim.  The caller that
            # paid for this compile still gets the reports.  It also
            # drops the residency default: serving traffic runs
            # device-resident unless a caller asks otherwise.
            self.cache.put(key, dataclasses.replace(
                prog, source=None, default_residency=None))
        if lv is not None:
            # Rebind to the version's tile store (labels the manifest
            # with version + tile stats); keep this caller's reports.
            prog = dataclasses.replace(lv.bind(prog), source=prog.source,
                                       default_residency=residency)
        if do_verify:
            self._verify_program(prog)
        _export_gagi(prog)
        return prog

    def remap(self, prog: CompiledProgram, report: Any = None, *,
              source: str = "auto", force: Any = None, margin: float = 0.1,
              probe: bool = False,
              modes: Optional[Sequence[str]] = None) -> CompiledProgram:
        """Sparsity-adaptive kernel remapping of a compiled program
        (``repro.core.passes.remap``): re-encode each AGGREGATE tile's
        kernel fields — SpDMM as-is, densified GEMM, or skip-empty —
        from the tile's measured/derived density and a cost oracle.  No
        recompile, no new partition; the cache key is preserved.

        ``report`` supplies the oracle's machine constants: a
        ``repro.obs.conformance.ConformanceReport`` (its LS-fitted
        ``calibrated_constants``), a plain constants dict, or ``None``
        for the paper-default roofline.  ``probe=True`` instead
        microbenchmarks the two ACK kernels at the program's tile
        geometry on this engine's backend.  ``force``/``modes`` pin or
        restrict decisions (oracle tests / ablations).

        If ``prog`` is the cached entry for its key, the cache is
        updated in place (slim copy, same key), so subsequent cache
        hits — and livegraph rebinds on top of them — stay remapped.
        """
        from repro.core.passes.remap import remap_program
        new = remap_program(prog, source=source, constants=report,
                            margin=margin, force=force, modes=modes,
                            probe=probe, ack=self._executor.ack)
        if prog.cache_key and self.cache.get(prog.cache_key) is not None:
            self.cache.put(prog.cache_key, dataclasses.replace(
                new, source=None, default_residency=None))
        if self.verify:
            self._verify_program(new)
        return new

    def _verify_program(self, prog: CompiledProgram) -> None:
        from repro.verify import VerifyError, verify_program
        tracer = get_tracer()
        with tracer.span("verify", cat="compile", track="compile",
                         args={"key": prog.cache_key[:12]}) as sp:
            report = verify_program(prog)
            sp.add(ok=report.ok, violations=len(report.violations))
        if not report.ok:
            raise VerifyError(report)

    def run(self, prog: CompiledProgram, x,
            weights: Optional[Dict[str, np.ndarray]] = None,
            graph_data: Optional[dict] = None,
            residency: Optional[str] = None, mesh=None, graph=None):
        """Execute a compiled program by decoding its ISA binary.

        ``residency="host"`` streams the partition-centric out-of-core
        path (features host-resident, one shard's working set on device
        at a time); ``"device"`` keeps every padded layer output on
        device.  ``mesh`` (a device count or a prebuilt mesh) runs the
        placement-scheduled multi-device path: each device executes its
        assigned destination shards under ``shard_map``, exchanging halo
        sub-fibers with collectives.  Results are bit-identical across
        all three; ``None`` uses the program's compile-time default.

        ``graph`` (a live-versioned graph or ``repro.livegraph``
        handle) rebinds the program to that version's patched tiles
        before executing — every residency stages the patched tiles
        transparently, since staging reads ``prog.pgraph``."""
        prog = self._rebind_live(prog, graph)
        residency = residency or prog.default_residency or "device"
        mesh = _resolve_mesh(mesh)
        return self._executor.run(prog, x, weights=weights,
                                  graph_data=graph_data,
                                  residency=residency, mesh=mesh)

    @staticmethod
    def _rebind_live(prog: CompiledProgram, graph) -> CompiledProgram:
        if graph is None:
            return prog
        lv = _live_version_of(graph)
        return lv.bind(prog) if lv is not None else prog

    def run_batch(self, prog: CompiledProgram, xs,
                  weights: Optional[Dict[str, np.ndarray]] = None,
                  graph_data: Optional[dict] = None,
                  residency: Optional[str] = None, mesh=None,
                  graph=None):
        """One binary pass for stacked ``[N, V, F]`` features -> [N, V, C].
        ``graph_data`` (stacked, leading batch axis) lets each lane carry
        its own topology over the same compiled program.  ``residency``
        as in :meth:`run` ("host" interleaves the lanes per staged
        shard, so each shard's tile working set ships once per batch —
        note the staged window's sub-fiber half then scales with the
        batch).  ``mesh`` as in :meth:`run`: lanes run as sequential
        eager multi-device passes (tile kernels are cached, but there
        is no whole-pass executable to replay — device-resident
        batching is the throughput path).  ``graph`` rebinds to a live
        version's tiles, as in :meth:`run`."""
        prog = self._rebind_live(prog, graph)
        residency = residency or prog.default_residency or "device"
        mesh = _resolve_mesh(mesh)
        return self._executor.run_batch(prog, xs, weights=weights,
                                        graph_data=graph_data,
                                        residency=residency, mesh=mesh)

    def load(self, path: str) -> CompiledProgram:
        """Load a ``.gagi`` bundle saved by ``CompiledProgram.save``."""
        prog = CompiledProgram.load(path)
        if self.geometry is not None:
            geo = prog.manifest["geometry"]
            mine = (self.geometry.n1, self.geometry.n2,
                    self.geometry.width_cap)
            theirs = (geo["n1"], geo["n2"], geo["width_cap"])
            if theirs != mine:
                warnings.warn(
                    f"{path} was compiled for tile geometry "
                    f"(n1, n2, width_cap)={theirs} but this engine is "
                    f"fixed at {mine}; new tile kernels will be "
                    "compiled", stacklevel=2)
        return prog

    # ------------------------------------------------------------------ #
    @staticmethod
    def _admit_live(req: InferenceRequest):
        """Resolve a live-graph handle at admission: pin the active
        version (inflight refcount) and swap the request's graph for
        that version's materialized snapshot.  Returns ``(req, pin)``;
        callers release the pin when the request completes."""
        server = getattr(req.graph, "_live_server", None)
        if server is None:
            return req, None
        version = server.admit()
        return (dataclasses.replace(req, graph=version.as_graph()),
                (server, version.vid))

    def submit(self, req: InferenceRequest) -> InferenceResponse:
        """Serve one request: cached compile -> binary-driven execution.

        ``req.graph`` may be a ``repro.livegraph.LiveGraphServer``
        handle: the request is then pinned to the version active at
        admission and served on exactly that version's tiles, whatever
        cutovers happen meanwhile."""
        req, pin = self._admit_live(req)
        try:
            key = self.cache_key(req.model, req.graph, seed=req.seed)
            hit = key in self.cache
            prog = self.compile(req.model, req.graph, seed=req.seed,
                                _key=key)
            t0 = time.perf_counter()
            y = self.run(prog, req.features, graph_data=req.graph_data)
            jax.block_until_ready(y)
            t_loh = time.perf_counter() - t0
            t_loc = 0.0 if hit else prog.t_loc

            self.stats.requests += 1
            self.stats.cache_hits += int(hit)
            self.stats.cache_misses += int(not hit)
            self.stats.total_t_loh += t_loh
            rid = req.request_id or f"req{self.stats.requests - 1}"
            return InferenceResponse(
                request_id=rid, output=y, t_loc=t_loc, t_loh=t_loh,
                cache_hit=hit, cache_key=key, model_name=prog.model_name,
                graph_name=req.graph.name)
        finally:
            if pin is not None:
                pin[0].release(pin[1])

    def submit_batch(self, reqs: Sequence[InferenceRequest]
                     ) -> List[InferenceResponse]:
        """Serve N coalesced requests with ONE binary pass.

        All requests must share this engine's cache key — same model
        schema + weights, same deployed graph, same compile options —
        which is exactly the grouping ``repro.runtime.Batcher`` produces.
        Features are padded/stacked to ``[N, V, F]`` and executed by a
        single traversal of the instruction stream (``run_batch``).

        Latency accounting reflects what each request *experienced*:
        every response reports the batch's compile latency (they all
        waited for the one compile on a miss) and the batch's execution
        wall time.
        """
        if not reqs:
            return []
        admitted = [self._admit_live(r) for r in reqs]
        reqs = [r for r, _ in admitted]
        pins = [p for _, p in admitted if p is not None]
        try:
            return self._submit_batch_resolved(reqs)
        finally:
            for server, vid in pins:
                server.release(vid)

    def _submit_batch_resolved(self, reqs: Sequence[InferenceRequest]
                               ) -> List[InferenceResponse]:
        key = self.cache_key(reqs[0].model, reqs[0].graph,
                             seed=reqs[0].seed)
        for r in reqs[1:]:
            k = self.cache_key(r.model, r.graph, seed=r.seed)
            if k != key:
                raise ValueError(
                    "submit_batch requires one cache key per batch: "
                    f"request {r.request_id!r} has key {k[:12]}… but the "
                    f"batch was opened with {key[:12]}…")
        # Live versions share the structural cache key by design, but a
        # batch is ONE binary pass over ONE tile set: mixing versions
        # would silently serve some requests the wrong graph.
        lv = _live_version_of(reqs[0].graph)
        for r in reqs[1:]:
            if _live_version_of(r.graph) is not lv:
                raise ValueError(
                    "submit_batch cannot mix graph versions in one "
                    "batch: all requests must be admitted against the "
                    "same live version (the runtime batches per "
                    "version for exactly this reason)")
        with_gd = sum(r.graph_data is not None for r in reqs)
        if 0 < with_gd < len(reqs):
            raise ValueError(
                "submit_batch cannot mix graph-as-data requests with "
                "baked-topology requests in one batch")
        hit = key in self.cache
        prog = self.compile(reqs[0].model, reqs[0].graph,
                            seed=reqs[0].seed, _key=key)
        if not hit:
            # Execute the long-lived cached copy: the jitted batched
            # executable is memoized on the program object, so it must
            # attach to the instance repeat batches will see.  (On a
            # hit, compile() already returned that instance.)
            prog = self.cache.get(key) or prog
            if lv is not None:
                prog = lv.bind(prog)
        xs = stack_features([r.features for r in reqs])
        # Bucket the batch axis to the next power of two (zero-filled
        # lanes, outputs sliced off): deadline flushes produce ragged
        # sizes 1..max_batch, and each DISTINCT shape would pay a fresh
        # whole-program trace+jit — buckets cap that at log2(max_batch)
        # executables per program for at most 2x lane waste.
        n = len(reqs)
        bucket = 1 << (n - 1).bit_length()
        if bucket != n:
            xs = jnp.pad(xs, ((0, bucket - n), (0, 0), (0, 0)))
        gd = stack_graph_data([r.graph_data for r in reqs], bucket) \
            if with_gd else None
        t0 = time.perf_counter()
        ys = self.run_batch(prog, xs, graph_data=gd)[:n]
        jax.block_until_ready(ys)
        t_loh = time.perf_counter() - t0
        t_loc = 0.0 if hit else prog.t_loc

        base = self.stats.requests
        self.stats.requests += n
        self.stats.cache_hits += n * int(hit)
        self.stats.cache_misses += n * int(not hit)
        self.stats.total_t_loh += t_loh
        return [InferenceResponse(
            request_id=r.request_id or f"req{base + i}", output=ys[i],
            t_loc=t_loc, t_loh=t_loh, cache_hit=hit, cache_key=key,
            model_name=prog.model_name, graph_name=r.graph.name,
            batch_size=n) for i, r in enumerate(reqs)]

    def serve(self, requests: Iterable[InferenceRequest]
              ) -> List[InferenceResponse]:
        """Drain a request stream through :meth:`submit` (Alg. 9's
        idle-PE rule at request granularity: the queue feeds the overlay
        whenever it drains).  For batched, multi-overlay serving use
        :class:`repro.runtime.OverlayPool` / ``ServeLoop`` instead."""
        return [self.submit(r) for r in requests]
