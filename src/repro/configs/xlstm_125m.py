"""xlstm-125m [ssm] 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
sLSTM + mLSTM blocks (block-internal up/down projections)
[arXiv:2405.04517; unverified]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm", n_layers=12, d_model=768,
        n_heads=4, n_kv_heads=4, d_ff=0, vocab=50304, xlstm=True,
        tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        vocab=256, remat="none")
