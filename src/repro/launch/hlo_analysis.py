"""Static analysis of post-optimization (SPMD-partitioned) HLO text.

XLA's ``compiled.cost_analysis()`` visits every instruction ONCE — a
scan-over-layers while loop is counted as a single iteration, which makes
it useless for roofline work on scanned models (verified in this repo's
dry-run bring-up: 8.4 MFLOP reported vs 67.1 MFLOP actual for an 8-layer
scan).  This module re-derives, with while-loop trip counts:

  * flops            — dot general (2*M*N*K) + elementwise
  * hbm_bytes        — operand + result bytes of every top-level op
                       (fusion callsites count their boundary, not their
                       internals — that is what fusion means)
  * collective wire bytes per device, split by collective kind, with
    ring-algorithm scaling  (all-reduce = 2*S*(n-1)/n, gather/scatter =
    S*(n-1)/n, all-to-all = S*(n-1)/n, permute = S)

All byte/flop numbers are PER DEVICE (the module is the per-device SPMD
program).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DT_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "token": 0, "opaque": 0,
    "u4": 1, "s4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "power", "negate",
    "select", "compare", "and", "or", "xor", "abs", "sign", "floor",
    "ceil", "cosine", "sine", "logistic", "remainder", "atan2",
    "exponential-minus-one", "log-plus-one", "cbrt",
}
_FREE = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "copy-start",
    "copy-done",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(shape_str: str) -> int:
    """Sum of bytes over every dtype[dims] group in a result type."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DT_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class Instr:
    name: str
    result: str          # raw result-type string
    op: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]   # instr name -> result type string


# op = first "word(" token after a space; everything before it = type.
_OP_RE = re.compile(r" ([\w\-]+)\(")


def _parse_instr(stripped: str) -> Optional[Tuple[str, str, str, str]]:
    if " = " not in stripped:
        return None
    lhs, rhs = stripped.split(" = ", 1)
    name = lhs.replace("ROOT ", "").strip().lstrip("%")
    m = _OP_RE.search(" " + rhs)
    if not m:
        return None
    op = m.group(1)
    result = rhs[: m.start()].strip()
    # balanced-paren operand extraction
    start = m.end()  # index into " " + rhs just past "("
    depth = 1
    i = start
    s = " " + rhs
    while i < len(s) and depth:
        depth += s[i] in "([{"
        depth -= s[i] in ")]}"
        i += 1
    args = s[start: i - 1]
    return name, result, op, args


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped:
            head = stripped.split()
            name_tok = head[1] if head[0] == "ENTRY" else head[0]
            cur = Computation(name_tok.lstrip("%"), [], {})
            comps[cur.name] = cur
            if head[0] == "ENTRY":
                entry = cur.name
            continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        parsed = _parse_instr(stripped)
        if parsed:
            name, result, op, args = parsed
            operands = [a.strip().lstrip("%") for a in _split_args(args)]
            cur.instrs.append(Instr(name, result, op, operands, stripped))
            cur.shapes[name] = result
    return comps, entry


def _split_args(args: str) -> List[str]:
    out, depth, curp = [], 0, []
    for ch in args:
        if ch == "," and depth == 0:
            out.append("".join(curp))
            curp = []
        else:
            depth += ch in "({["
            depth -= ch in ")}]"
            curp.append(ch)
    if curp:
        out.append("".join(curp))
    return [a for a in (s.strip() for s in out) if a]


def _attr(line: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w.\-]+)", line)
    return m.group(1) if m else None


def _trip_count(cond: Computation) -> int:
    """Trip limit of a while condition: the integer constant feeding the
    ROOT comparison (directly or through one wrapped-compare fusion);
    falls back to the max integer constant in the computation."""
    consts: Dict[str, int] = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                consts[ins.name] = int(m.group(1))
    root = None
    for ins in cond.instrs:
        if ins.line.startswith("ROOT") or "ROOT %" + ins.name in ins.line:
            root = ins
    if root is None and cond.instrs:
        root = cond.instrs[-1]
    if root is not None:
        fed = [consts[o.split(" ")[-1].lstrip("%")]
               for o in root.operands
               if o.split(" ")[-1].lstrip("%") in consts]
        if fed:
            return max(max(fed), 1)
    return max(list(consts.values()) + [1])


def _fusion_traffic(ins: "Instr", comps, operand_bytes: List[int],
                    result_bytes: int) -> int:
    """Exact-ish HBM traffic of a fusion callsite, derived from the fused
    computation body:

      * a parameter consumed ONLY by dynamic-slice ops contributes the
        slice bytes (the loop reads one step of a stacked buffer, not the
        buffer);
      * the output contributes 2x the update size when the root is a
        dynamic-update-slice of a pass-through buffer (in-place
        accumulate), else the full result bytes.
    """
    fc_name = _attr(ins.line, "calls")
    fc = comps.get(fc_name) if fc_name else None
    if fc is None:
        return result_bytes + sum(operand_bytes)
    # parameter index -> instr name
    params: Dict[int, str] = {}
    for i in fc.instrs:
        if i.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", i.line)
            if m:
                params[int(m.group(1))] = i.name
    # consumers of each instr name
    consumers: Dict[str, List[str]] = {}
    for i in fc.instrs:
        for o in i.operands:
            nm = o.split(" ")[-1].lstrip("%")
            consumers.setdefault(nm, []).append(i.op)
    slice_out: Dict[str, int] = {}
    for i in fc.instrs:
        if i.op == "dynamic-slice":
            for o in i.operands:
                nm = o.split(" ")[-1].lstrip("%")
                slice_out[nm] = slice_out.get(nm, 0) \
                    + _shape_bytes(i.result)
    total = 0
    for idx, ob in enumerate(operand_bytes):
        pname = params.get(idx)
        uses = consumers.get(pname, []) if pname else []
        if (pname and uses and ob > (1 << 20)
                and all(u == "dynamic-slice" for u in uses)):
            total += slice_out.get(pname, ob)
        else:
            total += ob
    # output side
    dus_update = 0
    for i in fc.instrs:
        if (i.op == "dynamic-update-slice"
                and _shape_bytes(i.result) == result_bytes
                and len(i.operands) > 1):
            nm = i.operands[1].split(" ")[-1].lstrip("%")
            dus_update = _shape_bytes(fc.shapes.get(nm, ""))
            break
    if dus_update and result_bytes > (1 << 20):
        # in-place slice write; the pass-through operand (same bytes as
        # the result) was charged above — remove it, charge 2x the slice.
        if result_bytes in operand_bytes:
            total -= result_bytes
        total += 2 * dus_update
    else:
        total += result_bytes
    return total


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return max(n_devices, 1)


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    unknown_trip_whiles: int = 0

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str, n_devices: int = 1) -> HloCosts:
    comps, entry = parse_hlo(text)
    costs = HloCosts()
    if entry is None:
        return costs

    # multiplicity per computation
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    # BFS through call graph accumulating multiplicity
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult.get(cname, 0.0)
        for ins in comp.instrs:
            callee_mults: List[Tuple[str, float]] = []
            if ins.op == "while":
                body = _attr(ins.line, "body")
                cond = _attr(ins.line, "condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if trips <= 1:
                    costs.unknown_trip_whiles += 1
                if body:
                    callee_mults.append((body, m * max(trips, 1)))
                if cond:
                    callee_mults.append((cond, m * max(trips, 1)))
            elif ins.op == "fusion":
                fc = _attr(ins.line, "calls")
                if fc:
                    callee_mults.append((fc, m))
            elif ins.op in ("call", "async-start"):
                fc = _attr(ins.line, "to_apply") or _attr(ins.line, "calls")
                if fc:
                    callee_mults.append((fc, m))
            elif ins.op == "conditional":
                for key in ("true_computation", "false_computation"):
                    fc = _attr(ins.line, key)
                    if fc:
                        callee_mults.append((fc, m))
                for mm in re.finditer(r"branch_computations=\{([^}]*)\}",
                                      ins.line):
                    for b in mm.group(1).split(","):
                        callee_mults.append((b.strip().lstrip("%"), m))
            for callee, cm in callee_mults:
                mult[callee] = mult.get(callee, 0.0) + cm
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    # "executed" computations for byte accounting: entry + while bodies
    # + called (non-fusion) computations.
    fusion_comps = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                fc = _attr(ins.line, "calls")
                if fc:
                    fusion_comps.add(fc)

    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None or m == 0.0:
            continue
        in_fusion = cname in fusion_comps
        for ins in comp.instrs:
            # ---- flops (counted everywhere, incl. fusion internals)
            if ins.op == "dot":
                res_elems = 1
                for d in _shape_dims(ins.result):
                    res_elems *= d
                # Operands appear as "%name" or "f32[...] %name"; resolve
                # the NAME against the computation's result shapes, and
                # fall back to the inline type when the operand is
                # written with one (cross-computation references).
                lhs_shape = ""
                if ins.operands:
                    lhs_name = ins.operands[0].split(" ")[-1].lstrip("%")
                    lhs_shape = comp.shapes.get(lhs_name, "")
                    if not lhs_shape and "[" in ins.operands[0]:
                        lhs_shape = ins.operands[0]
                mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}",
                               ins.line)
                k = 1
                if mm and lhs_shape:
                    dims = _shape_dims(lhs_shape)
                    for ci in mm.group(1).split(","):
                        if ci and int(ci) < len(dims):
                            k *= dims[int(ci)]
                costs.flops += m * 2.0 * res_elems * k
            elif ins.op == "convolution":
                # rare here; approximate with result*2 (frontend stubs)
                res_elems = 1
                for d in _shape_dims(ins.result):
                    res_elems *= d
                costs.flops += m * 2.0 * res_elems
            elif ins.op in _ELEMWISE:
                res_elems = 1
                for d in _shape_dims(ins.result):
                    res_elems *= d
                costs.flops += m * res_elems
            # ---- bytes (top-level ops only; fusion boundary = traffic)
            if not in_fusion and ins.op not in _FREE and ins.op != "while":
                ob = []
                for opnd in ins.operands:
                    nm = opnd.split(" ")[-1].lstrip("%")
                    if nm in comp.shapes:
                        ob.append(_shape_bytes(comp.shapes[nm]))
                    else:
                        # operand written as "f32[...] %name"
                        ob.append(_shape_bytes(opnd))
                rb = _shape_bytes(ins.result)
                # In-place / slicing ops move only the slice, not the
                # buffer (scan carries would otherwise count the full
                # stacked array once per iteration):
                if ins.op == "dynamic-slice" or ins.op == "gather":
                    b = 2 * rb
                elif ins.op in ("dynamic-update-slice", "scatter"):
                    upd = ob[1] if len(ob) > 1 else 0
                    b = 2 * upd + (sum(ob) - max(ob, default=0) - upd
                                   if len(ob) > 2 else 0)
                elif ins.op == "fusion":
                    b = _fusion_traffic(ins, comps, ob, rb)
                else:
                    b = rb + sum(ob)
                costs.hbm_bytes += m * max(b, 0)
            # ---- collectives
            base_op = ins.op.replace("-start", "")
            if base_op in _COLLECTIVES:
                size = _shape_bytes(ins.result)
                n = _group_size(ins.line, n_devices)
                if base_op == "all-reduce":
                    wire = 2.0 * size * (n - 1) / max(n, 1)
                elif base_op in ("all-gather", "reduce-scatter",
                                 "all-to-all"):
                    wire = size * (n - 1) / max(n, 1)
                else:  # collective-permute
                    wire = float(size)
                costs.collective_bytes[base_op] = (
                    costs.collective_bytes.get(base_op, 0.0) + m * wire)
    return costs
