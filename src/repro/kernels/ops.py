"""Jit'd public wrappers around the Pallas kernels.

These pad arbitrary shapes up to block multiples, invoke the kernel, and
slice back — so the ACK can call them with the compiler's native tile
shapes.  ``interpret=True`` executes the kernel body in Python on CPU
(correctness path in this container); on a real TPU, interpret=False
lowers through Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import gemm as _gemm
from . import sddmm as _sddmm
from . import spdmm as _spdmm

_LANE = 128


def _pad_to(x: jnp.ndarray, mults) -> jnp.ndarray:
    pads = []
    for dim, mult in zip(x.shape, mults):
        target = (dim + mult - 1) // mult * mult
        pads.append((0, target - dim))
    if all(p == (0, 0) for p in pads):
        return x
    return jnp.pad(x, pads)


@functools.partial(jax.jit, static_argnames=("interpret", "bm", "bk", "bn"))
def gemm(x, w, *, interpret: bool = True, bm: int = 128, bk: int = 128,
         bn: int = 128):
    m, n = x.shape[0], w.shape[1]
    bm_, bk_, bn_ = (min(bm, _ceil(x.shape[0])), min(bk, _ceil(x.shape[1])),
                     min(bn, _ceil(w.shape[1])))
    xp = _pad_to(x, (bm_, bk_))
    wp = _pad_to(w, (bk_, bn_))
    out = _gemm.gemm(xp, wp, bm=bm_, bk=bk_, bn=bn_, interpret=interpret)
    return out[:m, :n]


def _ceil(d: int, base: int = 8) -> int:
    """Smallest multiple of ``base`` >= d, capped to 128 for block picks."""
    t = (d + base - 1) // base * base
    return min(t, 128)


@functools.partial(jax.jit, static_argnames=("interpret", "bm", "bf"))
def spdmm(cols, vals, h, *, interpret: bool = True, bm: int = 128,
          bf: int = 128):
    n1, f = cols.shape[0], h.shape[1]
    bm_, bf_ = min(bm, _ceil(n1)), min(bf, _ceil(f))
    colsp = _pad_to(cols, (bm_, 1))
    valsp = _pad_to(vals, (bm_, 1))
    hp = _pad_to(h, (1, bf_))
    out = _spdmm.spdmm(colsp, valsp, hp, bm=bm_, bf=bf_, interpret=interpret)
    return out[:n1, :f]


@functools.partial(jax.jit, static_argnames=("interpret", "bm", "bf"))
def sddmm(h_dst, h_src, cols, *, interpret: bool = True, bm: int = 128,
          bf: int = 128):
    n1, w = cols.shape
    f = h_dst.shape[1]
    bm_, bf_ = min(bm, _ceil(n1)), min(bf, _ceil(f))
    hd = _pad_to(h_dst, (bm_, bf_))
    hs = _pad_to(h_src, (1, bf_))
    colsp = _pad_to(cols, (bm_, 1))
    out = _sddmm.sddmm(hd, hs, colsp, bm=bm_, bf=bf_, interpret=interpret)
    return out[:n1, :w]
