"""repro.obs — tracing, profiling, trajectory-gate, telemetry tests.

PR 7 acceptance criteria:
  * a traced host-streaming run (b3, 1 graph) produces Perfetto-valid
    trace JSON in which stage spans and compute spans demonstrably
    overlap (span timestamp intersection);
  * ``check_trajectory`` passes on the committed BENCH_*.json and fails
    on a synthetically degraded copy;
  * (satellites) ``percentile`` edge cases, tracer thread-interleaving
    round-trips as valid JSON, ``ExecStats.add`` merges ``per_device``,
    ``Metrics`` p90/max + wait-vs-execute split + cutover skew.
"""
import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import graph as G
from repro.core.passes.partition import PartitionConfig
from repro.engine import Engine, InferenceRequest
from repro.engine.executor import ExecStats
from repro.engine.program import CompiledProgram
from repro.obs import (DEFAULT_SPECS, MetricSpec, NullTracer, Tracer,
                       compare_docs, compare_metrics, lookup, tracing)
from repro.obs.tracer import get_tracer
from repro.runtime import Metrics, OverlayPool, ServeLoop
from repro.runtime.metrics import percentile

GEOM = PartitionConfig(n1=32, n2=8)


def _g(nv=70, ne=260, f=8, c=3, seed=0):
    g = G.random_graph(nv, ne, seed=seed).gcn_normalized()
    g.feat_dim, g.n_classes = f, c
    return g


def _overlaps(a, b):
    return max(a["ts"], b["ts"]) < min(a["ts"] + a["dur"],
                                       b["ts"] + b["dur"])


# --------------------------------------------------------------------------- #
# percentile() edge cases (satellite).
# --------------------------------------------------------------------------- #
def test_percentile_empty_is_zero():
    assert percentile([], 50) == 0.0
    assert percentile([], 0) == 0.0
    assert percentile([], 100) == 0.0


def test_percentile_single_sample_every_q():
    for q in (0, 1, 50, 99, 100):
        assert percentile([7.5], q) == 7.5


def test_percentile_q0_and_q100_are_min_and_max():
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 0) == 1.0       # nearest-rank: rank >= 1
    assert percentile(xs, 100) == 5.0
    assert percentile(xs, 50) == 3.0


def test_percentile_deque_cap_evicts_oldest():
    m = Metrics(max_samples=4)
    class R:  # minimal response stub
        cache_hit = True
        cache_key = "k"
        t_loc = 0.0
        t_loh = 0.0
        model_name = "m"
        graph_name = "g"
        request_id = "r"
    for v in (100.0, 1.0, 2.0, 3.0, 4.0):   # 100.0 evicted by cap
        m.record_response(R(), v)
    snap = m.snapshot()
    assert snap["global"]["max_latency_ms"] == 4000.0
    assert snap["global"]["p99_latency_ms"] == 4000.0


# --------------------------------------------------------------------------- #
# Tracer: spans, nesting, threads, Perfetto JSON round-trip.
# --------------------------------------------------------------------------- #
def test_null_tracer_is_default_and_noop():
    t = get_tracer()
    assert isinstance(t, NullTracer) and not t.enabled
    s = t.span("x")
    assert s.add(a=1) is s          # chainable no-op
    s.done()
    t.instant("i")
    t.counter("c", 1.0)
    assert t.to_dict() == {"traceEvents": [], "displayTimeUnit": "ms"}
    with pytest.raises(RuntimeError):
        t.save("/tmp/never.json")


def test_tracing_scope_restores_previous_tracer():
    before = get_tracer()
    with tracing() as t:
        assert get_tracer() is t and t.enabled
    assert get_tracer() is before


def test_span_nesting_and_json_round_trip(tmp_path):
    t = Tracer()
    with t.span("outer", cat="a", track="tk"):
        with t.span("inner", cat="a", track="tk", args={"k": 1}):
            pass
    t.instant("mark", track="tk")
    t.counter("depth", 3, track="tk")
    path = tmp_path / "trace.json"
    t.save(str(path))
    doc = json.loads(path.read_text())      # schema round-trip
    evs = doc["traceEvents"]
    X = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(X) == {"outer", "inner"}
    # Perfetto infers nesting from containment: inner ⊆ outer.
    assert X["outer"]["ts"] <= X["inner"]["ts"]
    assert (X["inner"]["ts"] + X["inner"]["dur"]
            <= X["outer"]["ts"] + X["outer"]["dur"] + 1e-6)
    assert X["inner"]["args"] == {"k": 1}
    # One named track -> one tid, announced by thread_name metadata.
    assert X["outer"]["tid"] == X["inner"]["tid"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(m["args"]["name"] == "tk" for m in meta)
    for e in evs:                           # minimal Chrome-format keys
        assert {"ph", "name", "pid", "tid"} <= set(e)


def test_tracer_thread_interleaving_valid_json():
    t = Tracer()
    gate = threading.Barrier(4)             # keep all 4 alive at once
                                            # (thread idents get reused)

    def work(n):
        gate.wait()
        for i in range(20):
            with t.span(f"w{n}", cat="t"):
                t.counter(f"c{n}", i)

    threads = [threading.Thread(target=work, args=(n,)) for n in range(4)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    doc = json.loads(json.dumps(t.to_dict()))
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == 80
    # Each recording thread claimed its own tid (separate tracks).
    assert len({e["tid"] for e in spans}) == 4
    summ = t.summary()
    assert sum(s["count"] for s in summ["spans"].values()) == 80


# --------------------------------------------------------------------------- #
# ACCEPTANCE: traced host-streaming run -> stage/compute spans overlap.
# --------------------------------------------------------------------------- #
def test_traced_host_streaming_stage_compute_overlap(tmp_path):
    g = _g(nv=90, ne=340)
    x = jnp.asarray(G.random_features(g, seed=1))
    eng = Engine(geometry=GEOM, n_pes=4)
    with tracing() as t:
        prog = eng.compile("b3", g)
        y = eng.run(prog, x, residency="host")
    assert y.shape == (g.n_vertices, g.n_classes)
    path = tmp_path / "trace.json"
    t.save(str(path))
    doc = json.loads(path.read_text())      # Perfetto-valid JSON
    evs = doc["traceEvents"]
    stages = [e for e in evs if e["ph"] == "X" and e["name"] == "stage"]
    computes = [e for e in evs
                if e["ph"] == "X" and e["name"] == "compute"]
    assert stages and computes
    # The double buffer stages shard j+1 INSIDE shard j's compute span:
    # timestamp intersection is the structural overlap, asserted.
    pairs = sum(1 for s in stages for c in computes if _overlaps(s, c))
    assert pairs > 0
    # Compile passes were traced too (b3 paid one compile).
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert {"order_opt", "fusion", "partition", "kernel_map",
            "schedule", "codegen", "compile", "decode"} <= names
    # stage spans carry the staged byte counts the stats aggregate.
    assert sum(e["args"]["bytes"] for e in stages) \
        == eng.exec_stats.h2d_bytes


def test_tracing_disabled_emits_nothing_and_same_results():
    g = _g()
    x = jnp.asarray(G.random_features(g, seed=1))
    eng = Engine(geometry=GEOM, n_pes=4)
    prog = eng.compile("b3", g)
    y0 = eng.run(prog, x, residency="host")
    with tracing() as t:
        y1 = eng.run(prog, x, residency="host")
    assert np.allclose(np.asarray(y0), np.asarray(y1))
    assert get_tracer().to_dict()["traceEvents"] == []
    assert len(t.events()) > 0


# --------------------------------------------------------------------------- #
# Per-tile execution profile -> manifest -> .gagi round-trip.
# --------------------------------------------------------------------------- #
def test_exec_profile_recorded_and_roundtrips_gagi(tmp_path):
    g = _g()
    x = jnp.asarray(G.random_features(g, seed=1))
    eng = Engine(geometry=GEOM, n_pes=4)
    prog = eng.compile("b1", g)
    assert "exec_profile" not in prog.manifest
    eng._executor.profile_tiles = True      # no tracer needed
    eng.run(prog, x)
    prof = prog.manifest["exec_profile"]
    assert prof["runs"] == 1
    assert prof["kernel_modes"].get("spdmm", 0) > 0
    assert prof["kernel_modes"].get("gemm", 0) > 0
    assert len(prof["tiles"]) > 0
    for key, rec in prof["tiles"].items():
        j, k, s = map(int, key.split(":"))
        assert rec["kernel"] == "spdmm"
        assert 0.0 <= rec["density"] <= 1.0
        assert rec["nnz"] <= rec["slots"]
    assert sum(prof["density_histogram"]) == len(prof["tiles"])
    # Second run accumulates.
    eng.run(prog, x)
    assert prog.manifest["exec_profile"]["runs"] == 2
    # Round-trips the .gagi bundle (manifest is serialized verbatim).
    p = tmp_path / "b1.gagi"
    prog.save(str(p))
    loaded = CompiledProgram.load(str(p))
    assert loaded.manifest["exec_profile"]["kernel_modes"] \
        == prof["kernel_modes"]


def test_profile_off_by_default():
    g = _g()
    x = jnp.asarray(G.random_features(g, seed=1))
    eng = Engine(geometry=GEOM, n_pes=4)
    prog = eng.compile("b1", g)
    eng.run(prog, x)
    assert "exec_profile" not in prog.manifest


# --------------------------------------------------------------------------- #
# ExecStats.add merges per_device instead of clobbering (satellite).
# --------------------------------------------------------------------------- #
def test_exec_stats_add_merges_per_device():
    total = ExecStats()
    run1 = ExecStats(per_device=[
        {"device": 0, "tile_ops": 10, "shards": 2, "halo_bytes": 100,
         "blocks": 3},
        {"device": 1, "tile_ops": 20, "shards": 3, "halo_bytes": 200,
         "blocks": 2}])
    run2 = ExecStats(per_device=[
        {"device": 0, "tile_ops": 5, "shards": 1, "halo_bytes": 50,
         "blocks": 3},
        {"device": 2, "tile_ops": 7, "shards": 1, "halo_bytes": 0,
         "blocks": 1}])
    total.add(run1)
    total.add(run2)
    by = {d["device"]: d for d in total.per_device}
    assert by[0]["tile_ops"] == 15 and by[0]["shards"] == 3
    assert by[0]["halo_bytes"] == 150
    assert by[1]["tile_ops"] == 20          # untouched by run2
    assert by[2]["tile_ops"] == 7           # new device appended
    assert by[0]["blocks"] == 3             # geometry kept, not summed
    assert [d["device"] for d in total.per_device] == [0, 1, 2]
    # run1/run2 are themselves untouched (add deep-copies).
    assert run1.per_device[0]["tile_ops"] == 10


# --------------------------------------------------------------------------- #
# Metrics: p90/max, wait-vs-execute split, slowest(), cutover skew.
# --------------------------------------------------------------------------- #
class _Resp:
    def __init__(self, rid="r", hit=True):
        self.request_id = rid
        self.cache_hit = hit
        self.cache_key = "key"
        self.t_loc = 0.0
        self.t_loh = 0.0
        self.model_name = "b1"
        self.graph_name = "g"


def test_metrics_p90_max_and_phase_split():
    m = Metrics()
    for i in range(10):
        lat = (i + 1) / 1000.0              # 1..10 ms
        m.record_response(_Resp(rid=f"r{i}"), lat,
                          queue_wait_s=lat * 0.25,
                          execute_s=lat * 0.75)
    g = m.snapshot()["global"]
    assert g["p90_latency_ms"] == 9.0
    assert g["max_latency_ms"] == 10.0
    assert g["p50_latency_ms"] == 5.0
    assert g["queue_wait_ms"]["mean"] == pytest.approx(1.375)
    assert g["execute_ms"]["mean"] == pytest.approx(4.125)
    # slowest() joins the tail sample to its phase breakdown.
    worst = m.slowest(2)
    assert [w["request_id"] for w in worst] == ["r9", "r8"]
    assert worst[0]["queue_wait_ms"] == pytest.approx(2.5)
    assert worst[0]["execute_ms"] == pytest.approx(7.5)
    json.dumps(m.snapshot())                # stays serializable


def test_metrics_without_phase_terms_keeps_old_shape():
    m = Metrics()
    m.record_response(_Resp(), 0.005)
    g = m.snapshot()["global"]
    assert "queue_wait_ms" not in g and "execute_ms" not in g
    assert m.slowest() == []


def test_record_cutover_version_skew():
    m = Metrics()
    m.set_active_version(1)
    m.record_cutover(1, 2, pinned_old=3)
    m.record_cutover(2, 3)                  # default: no skew
    snap = m.snapshot()["livegraph"]
    assert snap["cutovers"] == 2
    assert snap["active_version"] == 3
    assert snap["cutover_log"] == [
        {"from": 1, "to": 2, "pinned_old": 3},
        {"from": 2, "to": 3, "pinned_old": 0}]
    assert snap["max_version_skew"] == 3
    json.dumps(snap)


# --------------------------------------------------------------------------- #
# ServeLoop lifecycle spans + phase split wiring.
# --------------------------------------------------------------------------- #
def test_serve_loop_emits_lifecycle_spans_and_phase_split():
    g = _g()
    pool = OverlayPool(n_overlays=1, geometry=GEOM, n_pes=4)
    loop = ServeLoop(pool, max_batch=4)
    x = jnp.asarray(G.random_features(g, seed=1))
    reqs = [InferenceRequest(model="b1", graph=g, features=x,
                             request_id=f"q{i}") for i in range(4)]
    with tracing() as t:
        resps = loop.serve(reqs)
    assert len(resps) == 4
    evs = t.events()
    admits = [e for e in evs if e["name"] == "admit" and e["ph"] == "i"]
    waits = [e for e in evs
             if e["name"] == "queue_wait" and e["ph"] == "X"]
    batches = [e for e in evs if e["name"] == "batch" and e["ph"] == "X"]
    assert len(admits) == 4 and len(waits) == 4 and batches
    assert {w["args"]["request"] for w in waits} \
        == {"q0", "q1", "q2", "q3"}
    # Metrics got the wait-vs-execute split from the same code path.
    snap = pool.metrics.snapshot()["global"]
    assert "queue_wait_ms" in snap and "execute_ms" in snap
    assert len(pool.metrics.slowest(10)) == 4
    loop.shutdown()


# --------------------------------------------------------------------------- #
# Trajectory: tolerance bands, mode guard, markdown, degraded copies.
# --------------------------------------------------------------------------- #
def test_lookup_dotted_paths_and_list_indices():
    doc = {"a": {"b": [10, {"c": 5}]}}
    assert lookup(doc, "a.b.0") == 10
    assert lookup(doc, "a.b.1.c") == 5
    with pytest.raises(KeyError):
        lookup(doc, "a.z")
    with pytest.raises(KeyError):
        lookup(doc, "a.b.9")


def test_compare_metrics_bands_and_directions():
    specs = [MetricSpec("thr", "higher", 0.2),
             MetricSpec("p99", "lower", 0.5),
             MetricSpec("flag", "higher", 0.0, 0.0)]
    base = {"thr": 100.0, "p99": 10.0, "flag": True}
    # Inside the bands: ok / improved, never regressed.
    rs = compare_metrics(base, {"thr": 90.0, "p99": 12.0, "flag": True},
                         specs)
    assert [r.status for r in rs] == ["ok", "ok", "ok"]
    rs = compare_metrics(base, {"thr": 150.0, "p99": 5.0, "flag": True},
                         specs)
    assert [r.status for r in rs] == ["improved", "improved", "ok"]
    # Outside: regressed (and .failed); a flipped flag regresses at 0-tol.
    rs = compare_metrics(base, {"thr": 70.0, "p99": 16.0, "flag": False},
                         specs)
    assert all(r.status == "regressed" and r.failed for r in rs)
    # Missing fresh metric fails; missing baseline metric is "new".
    rs = compare_metrics(base, {"p99": 10.0, "flag": True}, specs)
    assert rs[0].status == "missing" and rs[0].failed
    rs = compare_metrics({"p99": 10.0, "flag": True},
                         {"thr": 1.0, "p99": 10.0, "flag": True}, specs)
    assert rs[0].status == "new" and not rs[0].failed


def test_compare_docs_mode_guard_skips():
    specs = [MetricSpec("x", "higher")]
    rep = compare_docs("f.json", {"mode": "full", "x": 1},
                       {"mode": "smoke", "x": 0}, specs)
    assert rep.skipped is not None and rep.ok


def test_trajectory_on_committed_bench_files(tmp_path):
    """The real gate: committed BENCH_*.json pass against themselves;
    a synthetically degraded copy fails."""
    import os
    import shutil
    repo = os.path.join(os.path.dirname(__file__), "..")
    from repro.obs import compare_dirs
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    names = [n for n in DEFAULT_SPECS
             if os.path.exists(os.path.join(repo, n))]
    assert names, "no committed BENCH_*.json found"
    for n in names:
        shutil.copy(os.path.join(repo, n), base / n)
        shutil.copy(os.path.join(repo, n), fresh / n)
    rep = compare_dirs(str(base), str(fresh))
    assert rep.ok                           # identical -> PASS
    compared = [f for f in rep.files if f.skipped is None]
    assert compared and all(f.results for f in compared)
    md = rep.to_markdown()
    assert "**PASS**" in md and "| metric |" in md

    # Degrade one semantic metric in one comparable file.
    victim = compared[0].name
    doc = json.loads((fresh / victim).read_text())
    spec = next(s for s in DEFAULT_SPECS[victim]
                if s.rel_tol == 0.0)        # a zero-band metric
    # walk to the parent and flip/bump the leaf the wrong way
    *parents, leaf = spec.path.split(".")
    cur = doc
    for seg in parents:
        cur = cur[int(seg)] if isinstance(cur, list) else cur[seg]
    old = cur[leaf]
    cur[leaf] = (not old) if isinstance(old, bool) else \
        (old + 1 if spec.direction == "lower" else max(0, old - 1)
         if isinstance(old, int) else old * 0.5
         if spec.direction == "higher" else old * 2)
    (fresh / victim).write_text(json.dumps(doc))
    rep2 = compare_dirs(str(base), str(fresh))
    assert not rep2.ok
    assert any(r.path == spec.path for r in rep2.regressions)
    md2 = rep2.to_markdown()
    assert "**FAIL**" in md2 and "**REGRESSED**" in md2


def test_check_trajectory_cli_exit_codes(tmp_path):
    import os
    import shutil
    import subprocess
    import sys
    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    base = tmp_path / "base"
    fresh = tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    name = "BENCH_serve.json"
    src = os.path.join(repo, name)
    if not os.path.exists(src):
        pytest.skip("no committed BENCH_serve.json")
    shutil.copy(src, base / name)
    shutil.copy(src, fresh / name)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src")
    out_md = tmp_path / "TRAJECTORY.md"
    r = subprocess.run(
        [sys.executable, os.path.join(repo, "benchmarks",
                                      "check_trajectory.py"),
         "--baseline-dir", str(base), "--fresh-dir", str(fresh),
         "--files", name, "--out", str(out_md)],
        capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == 0, r.stderr
    assert "**PASS**" in out_md.read_text()
    # Degrade: zero-band binary_passes metric bumped the wrong way.
    doc = json.loads((fresh / name).read_text())
    doc["traffic"]["same_key"]["batched"]["binary_passes"] += 5
    (fresh / name).write_text(json.dumps(doc))
    r = subprocess.run(
        [sys.executable, os.path.join(repo, "benchmarks",
                                      "check_trajectory.py"),
         "--baseline-dir", str(base), "--fresh-dir", str(fresh),
         "--files", name],
        capture_output=True, text=True, env=env, timeout=120)
    assert r.returncode == 1
    assert "REGRESSED" in r.stdout
