"""Paper Table 7: end-to-end latency = T_LoC + T_comm + T_LoH for every
(model b1-b8 x dataset).  ``derived`` = T_E2E in ms and the predicted
TPU-v5e T_LoH from the analytic perf model."""
from __future__ import annotations

from .common import (BIG_MODELS, DATASETS, Engine, MODELS, dataset, emit,
                     features, run_model)


def run(quick: bool = False) -> None:
    ds = DATASETS[:3] if quick else DATASETS
    models = MODELS[:2] if quick else MODELS
    engine = Engine()
    for bname in models:
        for dname, scale in ds:
            if scale < 1.0 and bname not in BIG_MODELS:
                continue
            g = dataset(dname, scale)
            x = features(g)
            t_loc, t_loh, t_comm, prog, t_pred = run_model(
                bname, g, x, engine)
            e2e = t_loc + t_comm + t_loh
            label = dname if scale == 1.0 else f"{dname}@{scale:g}"
            emit([f"table7,{bname}/{label}/T_LoC,{t_loc * 1e6:.0f},"
                  f"E2E_ms={e2e * 1e3:.2f}",
                  f"table7,{bname}/{label}/T_LoH,{t_loh * 1e6:.0f},"
                  f"pred_tpu_ms={t_pred * 1e3:.3f}",
                  f"table7,{bname}/{label}/T_comm,{t_comm * 1e6:.0f},"
                  f"binary_B={len(prog.binary)}"])
