"""gemma3-27b [dense] 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global (62 = 2 local + 10 superblocks of 6)."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
        n_heads=32, n_kv_heads=16, d_ff=21504, vocab=262144,
        attn_pattern="local_global", local_window=1024,
        local_global_ratio=6, qk_norm=True, rope_theta=1000000.0,
        tie_embeddings=True)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=8, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, local_window=8, attn_chunk=0, remat="none")
