"""Seeded k-hop ego-network sampling (GraphSAGE-style fanout caps).

The realistic heavy-traffic GNN serving workload is "infer labels for
*these* target vertices" (Zhang et al., arXiv 2206.08536): each request
carries a handful of targets, and the host extracts the k-hop ego
network that a k-layer GNN actually reads — per hop, at most ``fanout``
in-neighbors per frontier vertex (``"full"`` keeps them all).

Determinism contract: given (graph, targets, fanouts, seed) the sampled
ego network is bit-reproducible — vertex order, edge order, everything —
so the bucketing layer downstream produces identical layouts and the
engine's exactness guarantees are testable.

Local vertex ids are assigned in discovery order with the targets first
(locals ``0..T-1``), and the per-hop frontiers are recorded, so the
service can slice exactly the final-hop targets' logit rows out of the
overlay's output.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Union

import numpy as np

from repro.core.graph import Graph

from .csr import in_csr

Fanout = Union[int, str, None]      # per-hop cap; "full"/None = no cap


@dataclasses.dataclass
class EgoNet:
    """A sampled, relabeled ego network."""

    graph: Graph              # relabeled COO subgraph (weights inherited)
    vertices: np.ndarray      # int32 [V_sub]: local id -> global id
    targets: np.ndarray       # int32 [T]: local ids of the targets (0..T-1)
    hops: List[np.ndarray]    # local-id frontier per hop; hops[0] == targets

    @property
    def n_targets(self) -> int:
        return int(self.targets.shape[0])


def _cap(fanout: Fanout) -> int:
    if fanout is None or fanout == "full":
        return -1
    f = int(fanout)
    if f < 1:
        raise ValueError(f"fanout must be >= 1 or 'full', got {fanout!r}")
    return f


def sample_ego(g: Graph, targets: Sequence[int],
               fanouts: Sequence[Fanout], seed: int = 0) -> EgoNet:
    """Sample the k-hop ego network of ``targets`` (k = len(fanouts)).

    Hop h draws up to ``fanouts[h]`` in-neighbors (message senders)
    without replacement for every vertex of the current frontier; the
    sampled edges — and only those — form the subgraph, so a k-layer
    GNN over it touches exactly the traffic the caps promise.
    """
    tgt = np.asarray(list(targets), np.int64)
    if tgt.ndim != 1 or tgt.shape[0] == 0:
        raise ValueError("targets must be a non-empty 1-D sequence")
    if np.unique(tgt).shape[0] != tgt.shape[0]:
        raise ValueError("targets must be unique")
    if tgt.min() < 0 or tgt.max() >= g.n_vertices:
        raise ValueError(
            f"targets out of range for |V|={g.n_vertices}")

    csr = in_csr(g)
    rng = np.random.default_rng(seed)
    # inverse map global -> local id; -1 = undiscovered (hot path is
    # array-relabeling, no per-edge Python loops)
    inv = np.full(g.n_vertices, -1, np.int64)
    inv[tgt] = np.arange(tgt.shape[0])
    n_local = tgt.shape[0]
    hops: List[np.ndarray] = [np.arange(tgt.shape[0], dtype=np.int32)]
    vert_chunks: List[np.ndarray] = [tgt]
    e_src: List[np.ndarray] = []
    e_dst: List[np.ndarray] = []
    e_w: List[np.ndarray] = []

    frontier = tgt
    for fanout in fanouts:
        cap = _cap(fanout)
        hop_src: List[np.ndarray] = []
        for v in frontier:
            srcs, ws, _ = csr.in_neighbors(int(v))
            deg = srcs.shape[0]
            if deg == 0:
                continue
            if 0 <= cap < deg:
                pick = rng.choice(deg, size=cap, replace=False)
                pick.sort()                   # deterministic edge order
                srcs, ws = srcs[pick], ws[pick]
            hop_src.append(srcs.astype(np.int64))
            e_dst.append(np.full(srcs.shape[0], v, np.int64))
            e_w.append(ws)
        if not hop_src:
            hops.append(np.zeros(0, np.int32))
            break
        hop_all = np.concatenate(hop_src)
        e_src.append(hop_all)
        # discover new vertices in first-occurrence (edge) order
        uniq, first = np.unique(hop_all, return_index=True)
        fresh = uniq[inv[uniq] < 0]
        fresh = fresh[np.argsort(first[inv[uniq] < 0], kind="stable")]
        inv[fresh] = n_local + np.arange(fresh.shape[0])
        n_local += fresh.shape[0]
        vert_chunks.append(fresh)
        hops.append(inv[fresh].astype(np.int32))
        frontier = fresh
        if frontier.shape[0] == 0:
            break

    vertices = np.concatenate(vert_chunks).astype(np.int32)
    if e_src:
        gsrc = np.concatenate(e_src)
        gdst = np.concatenate(e_dst)
        weight = np.concatenate(e_w).astype(np.float32)
    else:
        gsrc = np.zeros(0, np.int64)
        gdst = np.zeros(0, np.int64)
        weight = np.zeros(0, np.float32)
    sub = Graph(
        n_vertices=n_local,
        src=inv[gsrc].astype(np.int32),
        dst=inv[gdst].astype(np.int32),
        weight=weight,
        feat_dim=g.feat_dim,
        n_classes=g.n_classes,
        name=f"{g.name}:ego{tgt.shape[0]}",
    )
    return EgoNet(graph=sub, vertices=vertices, targets=hops[0],
                  hops=hops)
