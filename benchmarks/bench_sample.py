"""Mini-batch serving benchmark: per-subgraph compiles vs bucketed pool.

  PYTHONPATH=src python benchmarks/bench_sample.py [--smoke]

The workload is per-user ego-network inference on a power-law (RE-class)
graph: every request carries its own targets, target count, and fanouts,
so every sampled subgraph is topologically unique.  Two serving paths:

  * ``sequential_unbucketed`` — each subgraph compiled and executed
    exactly as sampled on one Engine.  Unique topology means a unique
    program-cache key per request: steady state still pays T_LoC every
    time (hit rate ~0).  This is what the pre-sampling repo would do.
  * ``bucketed_batched`` — the :class:`repro.sampling.SamplingService`
    path: subgraphs padded to power-of-two geometry buckets and shipped
    as runtime graph data, so the cache key collides per bucket, the
    Batcher coalesces users, and steady state replays compiled programs
    (hit rate ~1).

Both paths are warmed with a disjoint request stream (tile kernels +
batched executables jitted; for the sequential path programs can NOT
warm — that is the point).  Results land in ``BENCH_sample.json``:
p50/p99 latency, throughput, cache hit rate, bucket census, speedup,
plus seed/backend/CPU provenance (run-to-run variance attribution).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import List

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

try:                                   # script: python benchmarks/bench_sample.py
    from common import provenance, verify_section
except ImportError:                    # module: python -m benchmarks.bench_sample
    from benchmarks.common import provenance, verify_section

from repro.core import graph as G  # noqa: E402
from repro.core.passes.partition import PartitionConfig  # noqa: E402
from repro.engine import Engine, InferenceRequest  # noqa: E402
from repro.runtime.metrics import percentile  # noqa: E402
from repro.sampling import SamplingService, TargetRequest  # noqa: E402
from repro.sampling.sampler import sample_ego  # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FANOUTS = [(6, 4), (4, 2), (6, 2)]


def make_graph(smoke: bool, seed: int):
    """RE-class power-law parent, duplicate draws folded into weights."""
    nv, ne = (466, 24000) if smoke else (2330, 240000)
    g = G.random_graph(nv, ne, seed=seed, degree="powerlaw", alpha=1.1,
                       dedupe=True)
    g.feat_dim, g.n_classes = (16, 5) if smoke else (64, 41)
    g.name = f"RE-class@{nv}"
    return g


def make_traffic(g, n: int, seed: int, tag: str) -> List[TargetRequest]:
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        t = rng.choice(g.n_vertices, size=int(rng.integers(1, 4)),
                       replace=False)
        reqs.append(TargetRequest(
            targets=[int(v) for v in t], model="b1",
            fanouts=FANOUTS[i % len(FANOUTS)],
            request_id=f"{tag}{i}", seed=seed * 10007 + i))
    return reqs


def bench_sequential(g, X, geom, n_pes, warm, reqs) -> dict:
    eng = Engine(geometry=geom, n_pes=n_pes, cache_capacity=8)

    def submit(tr: TargetRequest):
        ego = sample_ego(g, tr.targets, tr.fanouts, seed=tr.seed)
        sub = ego.graph.gcn_normalized()
        x = jnp.asarray(X[ego.vertices])
        r = eng.submit(InferenceRequest(model=tr.model, graph=sub,
                                        features=x,
                                        request_id=tr.request_id))
        return r.t_loc + r.t_loh

    for tr in warm:                    # jit tile kernels; programs can't warm
        submit(tr)
    c0, n0 = eng.stats.cache_hits, eng.stats.requests
    lats = []
    t0 = time.perf_counter()
    for tr in reqs:
        lats.append(submit(tr))
    wall = time.perf_counter() - t0
    return {
        "wall_s": round(wall, 6),
        "throughput_rps": round(len(reqs) / wall, 3),
        "p50_ms": round(percentile(lats, 50) * 1e3, 3),
        "p99_ms": round(percentile(lats, 99) * 1e3, 3),
        "cache_hit_rate": round(
            (eng.stats.cache_hits - c0) / (eng.stats.requests - n0), 6),
        "compiles": eng.stats.compiles,
    }


def bench_bucketed(g, X, geom, n_pes, n_overlays, max_batch, warm,
                   reqs) -> dict:
    svc = SamplingService(g, X, n_overlays=n_overlays, geometry=geom,
                          n_pes=n_pes, max_batch=max_batch,
                          max_wait_us=1e6)
    try:
        # programs + every power-of-two batch-shape executable per bucket
        svc.warm(warm)
        h0 = sum(e.stats.cache_hits for e in svc.pool.engines)
        n0 = sum(e.stats.requests for e in svc.pool.engines)
        t0 = time.perf_counter()
        resps = svc.serve(reqs)
        wall = time.perf_counter() - t0
        h1 = sum(e.stats.cache_hits for e in svc.pool.engines)
        n1 = sum(e.stats.requests for e in svc.pool.engines)
        lats = [r.t_loc + r.t_loh for r in resps]
        return {
            "wall_s": round(wall, 6),
            "throughput_rps": round(len(reqs) / wall, 3),
            "p50_ms": round(percentile(lats, 50) * 1e3, 3),
            "p99_ms": round(percentile(lats, 99) * 1e3, 3),
            "cache_hit_rate": round((h1 - h0) / (n1 - n0), 6),
            "mean_batch_size": round(
                float(np.mean([r.batch_size for r in resps])), 3),
            "buckets": svc.stats_snapshot()["buckets"],
        }
    finally:
        svc.shutdown()


def run(smoke: bool, n_requests: int, n_overlays: int, max_batch: int,
        out_path: str, seed: int = 0) -> dict:
    geom = PartitionConfig(n1=32, n2=8) if smoke \
        else PartitionConfig(n1=256, n2=32)
    n_pes = 4 if smoke else 8
    g = make_graph(smoke, seed)
    X = G.random_features(g, seed=seed + 1)
    warm = make_traffic(g, max(8, n_requests // 4), seed + 1, "warm")
    reqs = make_traffic(g, n_requests, seed + 2, "u")

    seq = bench_sequential(g, X, geom, n_pes, warm, reqs)
    bkt = bench_bucketed(g, X, geom, n_pes, n_overlays, max_batch, warm,
                         reqs)
    speedup = bkt["throughput_rps"] / seq["throughput_rps"] \
        if seq["throughput_rps"] else 0.0
    report = {
        "benchmark": "bench_sample",
        "mode": "smoke" if smoke else "full",
        "requests": n_requests,
        "overlays": n_overlays,
        "max_batch": max_batch,
        "graph": {"n_vertices": g.n_vertices, "n_edges": g.n_edges,
                  "profile": "powerlaw", "alpha": 1.1},
        "fanouts": [list(f) for f in FANOUTS],
        "provenance": provenance(seed),
        "sequential_unbucketed": seq,
        "bucketed_batched": bkt,
        "bucketed_speedup": round(speedup, 3),
    }
    print("path,wall_s,throughput_rps,p50_ms,p99_ms,cache_hit_rate")
    for path, r in (("sequential_unbucketed", seq),
                    ("bucketed_batched", bkt)):
        print(f"{path},{r['wall_s']},{r['throughput_rps']},"
              f"{r['p50_ms']},{r['p99_ms']},{r['cache_hit_rate']}")
    print(f"speedup,{speedup:.3f}x,,,,")
    # Static verification of the served model against the parent graph.
    report["verify"] = verify_section(
        Engine(geometry=geom, n_pes=n_pes), [("b1", g.gcn_normalized())])
    with open(out_path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small graph + short stream (CI gate)")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--overlays", type=int, default=2)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0,
                    help="graph/traffic seed; recorded in provenance")
    ap.add_argument("--out", default=os.path.join(ROOT,
                                                  "BENCH_sample.json"))
    args = ap.parse_args()
    n = args.requests if args.requests is not None \
        else (24 if args.smoke else 96)
    run(args.smoke, n, args.overlays, args.max_batch, args.out,
        seed=args.seed)


if __name__ == "__main__":
    main()
