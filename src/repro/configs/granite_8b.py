"""granite-8b [dense] 36L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
import dataclasses
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-8b", family="dense", n_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=49152,
        rope_theta=10000.0)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        config(), n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=256, attn_chunk=0, remat="none")
