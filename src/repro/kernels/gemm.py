"""GEMM-mode Pallas kernel (ACK GEMM mode, paper Alg. 1).

Output-stationary blocked matmul targeting the TPU MXU:
  grid = (M/bm, N/bn, K/bk); x tile (bm, bk) and w tile (bk, bn) stream
  through VMEM; an f32 accumulator lives in VMEM scratch and is flushed to
  the output tile on the last K step.  Block shapes default to MXU-aligned
  multiples of 128 (the paper's p_sys x p_sys systolic tile, scaled to the
  TPU's native 128x128 systolic array).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(x_ref, w_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bk", "bn", "interpret", "out_dtype"))
def gemm(
    x: jnp.ndarray,
    w: jnp.ndarray,
    *,
    bm: int = 128,
    bk: int = 128,
    bn: int = 128,
    interpret: bool = False,
    out_dtype=jnp.float32,
) -> jnp.ndarray:
    """x: [M, K] @ w: [K, N] -> [M, N].  Shapes must divide block sizes
    (ops.gemm pads arbitrary shapes)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (x.shape, w.shape)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
