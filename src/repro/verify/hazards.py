"""Hazard graph derivation — RAW/WAR/WAW edges over Tiling Blocks.

The FPGA overlay resolves hazards in hardware (the paper's
lock/unlock-annotated double-buffer WAR protection and its
destination-sorting RAW reorder unit); the software overlay resolves
them by construction (layer-sequential dispatch).  Either way the
*true* dependence structure is a property of the binary, and this
module makes it explicit:

  * tile-level edges between Tiling Blocks (RAW: a block reads a value
    another block wrote; WAW/WAR only arise in malformed programs —
    duplicate defs — and are reported, not tolerated);
  * layer-level edges (the coarse DAG the streaming and mesh paths
    sequence by);
  * staging/halo dependencies: which producer layers each destination
    shard's h2d working set and each device's halo exchange read —
    the edges the dynamic race detector (:mod:`repro.verify.race`)
    checks recorded traces against.

``dep_graph_manifest`` folds the graph into ``.gagi`` manifests — the
input contract for the ROADMAP's scoreboard-issue executor.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.core.ir import LayerType

from .model import DefUseModel, TileOp, ValueKey, layer_consumes

# Tile-level node/edge lists beyond this many edges are summarized
# (layer-level edges are always emitted): million-vertex programs have
# millions of tile edges and the manifest is a JSON file.
DEP_GRAPH_TILE_EDGE_CAP = 20000


@dataclasses.dataclass
class HazardGraph:
    ops: List[TileOp]
    # (src node, dst node, kind) with kind in {"RAW", "WAR", "WAW"}
    edges: List[Tuple[int, int, str]]
    # (producer lid, consumer lid, "RAW") — layer-boundary dependencies
    layer_edges: List[Tuple[int, int, str]]
    # (lid, shard j) -> producer lids whose outputs the shard's staged
    # working set reads (h2d staging dependencies, -1 = input features)
    stage_deps: Dict[Tuple[int, int], Set[int]]

    @property
    def counts(self) -> Dict[str, int]:
        c = {"RAW": 0, "WAR": 0, "WAW": 0}
        for _, _, kind in self.edges:
            c[kind] += 1
        return c


def build_hazards(model: DefUseModel, lmeta: dict) -> HazardGraph:
    """Derive every hazard edge from the def/use model."""
    ops = model.ops
    def_nodes: Dict[ValueKey, List[int]] = {}
    use_nodes: Dict[ValueKey, List[int]] = {}
    for op in ops:
        for d in op.defs:
            def_nodes.setdefault(d, []).append(op.node_id)
        for u in op.uses:
            use_nodes.setdefault(u, []).append(op.node_id)

    edges: Set[Tuple[int, int, str]] = set()
    # RAW: use after def (and WAR's malformed cousin: def after use of a
    # value someone else owns).
    for u, readers in use_nodes.items():
        writers = def_nodes.get(u)
        if not writers:
            continue
        for r in readers:
            prior = [w for w in writers if w < r]
            if prior:
                edges.add((prior[-1], r, "RAW"))
            later = [w for w in writers if w > r]
            for w in later:
                edges.add((r, w, "WAR"))
    # WAW: duplicate defs of one value.
    for v, writers in def_nodes.items():
        for a, b in zip(writers, writers[1:]):
            edges.add((a, b, "WAW"))

    # Layer-boundary RAW edges from the manifest layer table.
    layer_edges: List[Tuple[int, int, str]] = []
    present = {lp.layer_id for lp in model.plan.layers}
    for lp in model.plan.layers:
        meta = lmeta.get(str(lp.layer_id), {})
        for c in layer_consumes(meta, lp.layer_type):
            if c >= 0 and c in present:
                layer_edges.append((int(c), lp.layer_id, "RAW"))

    # Staging dependencies: shard (lid, j)'s working set reads the
    # sub-fibers of every source block its tiles use — produced by the
    # layers those "v" uses name.
    stage_deps: Dict[Tuple[int, int], Set[int]] = {}
    for op in ops:
        j = _out_shard(op)
        if j < 0:
            continue
        dep = stage_deps.setdefault((op.layer_id, j), set())
        for u in op.uses:
            if u[0] in ("v", "e"):
                dep.add(int(u[1]))
    return HazardGraph(ops=ops, edges=sorted(edges),
                       layer_edges=layer_edges, stage_deps=stage_deps)


def _out_shard(op: TileOp) -> int:
    """Destination row block of a tile op (the streaming path's shard
    coordinate), from its defs."""
    for d in op.defs:
        if d[0] == "v":
            return int(d[3])
        if d[0] == "e":
            return int(d[2])
    return -1


def sources_by_shard(model: DefUseModel
                     ) -> Dict[int, Dict[int, Set[int]]]:
    """lid -> destination shard j -> source blocks its tiles gather
    from — the def/use re-derivation of the residency ``sources``
    tables (and the halo-set ingredient)."""
    out: Dict[int, Dict[int, Set[int]]] = {}
    for lp in model.plan.layers:
        lt = lp.layer_type
        shard_sources: Dict[int, Set[int]] = {}
        for tp in lp.tiles:
            j = tp.out_j
            if j < 0:
                continue
            e = shard_sources.setdefault(j, set())
            if lt == LayerType.AGGREGATE:
                e.update(int(ins.args[1]) for ins in tp.compute)
            elif lt == LayerType.VECTOR_INNER:
                e.add(int(j))
                e.add(int(tp.tile_k))
            elif not lp.on_edges:
                e.add(int(j))
        out[lp.layer_id] = shard_sources
    return out


# --------------------------------------------------------------------------- #
def dep_graph_manifest(model: DefUseModel, lmeta: dict,
                       hazards: Optional[HazardGraph] = None,
                       tile_edge_cap: int = DEP_GRAPH_TILE_EDGE_CAP
                       ) -> dict:
    """JSON-ready ``dep_graph`` manifest section.

    Layer-level structure is always complete; tile-level nodes/edges
    are included up to ``tile_edge_cap`` edges and marked ``truncated``
    beyond it (the counts stay exact either way)."""
    hz = hazards if hazards is not None else build_hazards(model, lmeta)
    counts = hz.counts
    layers = [{
        "id": int(lp.layer_id),
        "step": step,
        "type": int(lp.layer_type),
        "n_tiles": len(lp.tiles),
        "instr_lo": int(lp.instr_lo),
        "instr_hi": int(lp.instr_hi),
    } for step, lp in enumerate(model.plan.layers)]
    out = {
        "version": 1,
        "layers": layers,
        "layer_edges": [[int(a), int(b), kind]
                        for a, b, kind in hz.layer_edges],
        "n_tile_nodes": len(hz.ops),
        "n_tile_edges": len(hz.edges),
        "edge_counts": counts,
        "truncated": len(hz.edges) > tile_edge_cap,
    }
    if not out["truncated"]:
        out["tile_nodes"] = [[int(op.layer_id), int(op.tile_idx),
                              int(op.instr_lo), int(op.instr_hi),
                              int(op.pe)] for op in hz.ops]
        out["tile_edges"] = [[int(a), int(b), kind]
                             for a, b, kind in hz.edges]
    return out
